"""Collect the full CNN profiling dataset (paper §5.1/§6) into the on-disk
cache.  Long-running; intended to be launched once in the background:

    PYTHONPATH=src python -m benchmarks.collect_cnn_data

Every datapoint is cached in ``benchmarks/cache/cnn_profile.json`` so the
collection is resumable and all paper-table benchmarks afterwards run from
cache.  Grid layout (reduced CPU-host grid; ``--full`` restores the paper
grid — see DESIGN.md §5):

  fig3   : resnet18, mobilenetv2, squeezenet, mnasnet
           train  = random strategy, levels {0,30,50,70,90}%
           test   = random + L1 strategies, levels {10,40,60,80}%
  fig4   : + resnet50, googlenet test grids (basis generalisation)
  §6.1   : alexnet, all 19 levels (training-set-size sweep)
"""

from __future__ import annotations

import argparse
import time

from repro.core.dataset import (
    DEFAULT_BATCH_SIZES,
    DEFAULT_TEST_LEVELS,
    DEFAULT_TRAIN_LEVELS,
    PAPER_ALL_LEVELS,
    DatasetCache,
    GridSpec,
    collect_grid,
)

CACHE_PATH = "benchmarks/cache/cnn_profile.json"

FIG3_FAMILIES = ("resnet18", "mobilenetv2", "squeezenet", "mnasnet")
FIG4_EXTRA_FAMILIES = ("resnet50", "googlenet")


def all_grids(full: bool = False) -> list[GridSpec]:
    bss = DEFAULT_BATCH_SIZES
    train_l, test_l = DEFAULT_TRAIN_LEVELS, DEFAULT_TEST_LEVELS
    grids: list[GridSpec] = []
    for fam in FIG3_FAMILIES:
        grids.append(GridSpec(fam, train_l, "random", bss))
        grids.append(GridSpec(fam, test_l, "random", bss))
        grids.append(GridSpec(fam, test_l, "l1", bss))
    for fam in FIG4_EXTRA_FAMILIES:
        grids.append(GridSpec(fam, test_l, "random", bss))
        grids.append(GridSpec(fam, test_l, "l1", bss))
    # §6.2.1 DNNMem comparison trains a same-network Γ model on ResNet50.
    grids.append(GridSpec("resnet50", train_l, "random", bss))
    # §6.1 training-set-size sweep: AlexNet across all 19 paper levels.
    grids.append(GridSpec("alexnet", PAPER_ALL_LEVELS, "random", bss))
    return grids


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size grid")
    ap.add_argument("--cache", default=CACHE_PATH)
    args = ap.parse_args()

    cache = DatasetCache(args.cache)
    grids = all_grids(args.full)
    total_pts = sum(len(g.levels) * len(g.batch_sizes) for g in grids)
    print(f"collecting {total_pts} datapoints across {len(grids)} grids "
          f"({len(cache)} already cached)", flush=True)
    t0 = time.time()
    done = 0
    for g in grids:
        print(f"[{time.time() - t0:7.1f}s] grid {g.family}/{g.strategy}/"
              f"levels={[round(l, 2) for l in g.levels]}", flush=True)
        collect_grid(g, cache, verbose=True)
        done += len(g.levels) * len(g.batch_sizes)
        print(f"[{time.time() - t0:7.1f}s] {done}/{total_pts} points done", flush=True)
    print(f"ALL DONE in {time.time() - t0:.0f}s — cache has {len(cache)} points", flush=True)


if __name__ == "__main__":
    main()
