"""Kernel micro-benchmarks: static roofline stats per Pallas kernel config
(FLOPs, HBM bytes, arithmetic intensity, VMEM working set), CPU oracle
wall-time as a correctness-path sanity check, and — per kernel — the
autotuner's pick vs the hand-coded default under the same roofline model
(tuned modelled time must never be worse: the default is always in the
candidate set).

Wall-clock of interpret-mode Pallas is meaningless (Python interpreter), so
the perf numbers reported are the *structural* ones the TPU roofline uses.

    PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.devices import get_device
from repro.kernels.autotune import KernelTuner
from repro.kernels.conv_mm import tiling as conv_tiling
from repro.kernels.conv_mm.ref import conv_ref
from repro.kernels.flash_attention import tiling as flash_tiling
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_dispatch import tiling as moe_tiling
from repro.kernels.paged_decode import tiling as pd_tiling
from repro.kernels.serve_kv import tiling as kv_tiling
from repro.kernels.ssm_scan import tiling as ssm_tiling
from repro.kernels.ssm_scan.ref import ssd_ref
from repro.launch.mesh import TPU_V5E

from .common import csv_line

TUNING_CACHE = "/tmp/perf4sight_kernel_bench_tuning.json"


def _time(fn, *args, n=3):
    fn(*args)  # compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _fmt(config: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(config.items()))


def _tuned_rows(tuner: KernelTuner, kernel: str, shape: dict, print_fn) -> dict:
    """Emit model_default_us / model_tuned_us rows for one kernel shape."""
    entry = tuner.explain(kernel, shape)
    default_us = entry["default_model_us"]
    tuned_us = entry["model_us"]   # modelled time of the chosen config
    speedup = default_us / max(tuned_us, 1e-12)
    print_fn(csv_line(f"kernel/{kernel}/model_default_us", default_us,
                      _fmt(entry["default_config"])))
    print_fn(csv_line(f"kernel/{kernel}/model_tuned_us", tuned_us,
                      f"{_fmt(entry['config'])} speedup={speedup:.2f}x "
                      f"vmem_kb={entry['vmem_kb']:.0f} "
                      f"cands={entry['candidates']} "
                      f"rejected_vmem={entry['rejected_vmem']} "
                      f"source={entry['source']}"))
    return {"default_us": default_us, "tuned_us": tuned_us,
            "speedup": speedup, "config": entry["config"]}


def run(print_fn=print) -> dict:
    peak, bw = TPU_V5E["peak_flops_bf16"], TPU_V5E["hbm_bw"]
    if os.path.exists(TUNING_CACHE):
        os.unlink(TUNING_CACHE)
    tuner = KernelTuner(device=get_device("tpu_v5e"), cache=TUNING_CACHE,
                        measure=False)
    results: dict = {}
    rng = np.random.default_rng(0)

    # flash attention: (B,H,S,Dh) production-ish tile
    B, H, S, Dh, bq, bk = 1, 8, 2048, 128, 512, 512
    flops = 4.0 * B * H * S * S * Dh * 0.5  # causal
    bytes_ = 2.0 * (B * H * S * Dh * 3 + B * H * S * Dh)
    vmem = (bq * Dh + 2 * bk * Dh) * 2 + bq * Dh * 4
    q = jnp.asarray(rng.standard_normal((B, H, S, Dh)), jnp.bfloat16)
    us = _time(jax.jit(lambda q: attention_ref(q, q, q, causal=True)), q)
    print_fn(csv_line("kernel/flash_attn/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f} vmem_kb={vmem / 1024:.0f}"))
    results["flash_attention"] = _tuned_rows(
        tuner, "flash_attention",
        flash_tiling.shape_key((B, H, S, Dh), (B, H, S, Dh), causal=True,
                               dtype="bfloat16"),
        print_fn)

    # conv_mm: ResNet-ish layer
    N, HW, C, K, O = 8, 32, 128, 3, 128
    flops = 2.0 * N * HW * HW * O * K * K * C
    bytes_ = 2.0 * (N * HW * HW * C + K * K * C * O + N * HW * HW * O)
    x = jnp.asarray(rng.standard_normal((N, HW, HW, C)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((K, K, C, O)), jnp.bfloat16)
    us = _time(jax.jit(lambda x, w: conv_ref(x, w, stride=1, padding=1)), x, w)
    print_fn(csv_line("kernel/conv_mm/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f}"))
    results["conv_mm"] = _tuned_rows(
        tuner, "conv_mm",
        conv_tiling.shape_key((N, HW, HW, C), (K, K, C, O), stride=1,
                              padding=1, dtype="bfloat16"),
        print_fn)

    # ssd: mamba2-780m layer tile
    B2, S2, Hh, P, Nst, ch = 1, 2048, 24, 64, 128, 128
    flops = 2.0 * B2 * S2 * ch * Hh * (Nst + P) + 2.0 * B2 * S2 * Hh * P * Nst
    bytes_ = 2.0 * B2 * S2 * Hh * P * 2 + 4.0 * B2 * S2 * Hh
    xh = jnp.asarray(rng.standard_normal((B2, S2, Hh, P)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B2, S2, Hh)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.standard_normal((B2, S2, Nst)), jnp.float32)
    us = _time(jax.jit(lambda xh, a, Bm: ssd_ref(xh, a, Bm, Bm, chunk=ch)[0]),
               xh, a, Bm)
    print_fn(csv_line("kernel/ssd/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f}"))
    results["ssm_scan"] = _tuned_rows(
        tuner, "ssm_scan",
        ssm_tiling.shape_key((B2, S2, Hh, P), Nst, dtype="float32"),
        print_fn)

    # moe dispatch: qwen3-moe-30b-ish layer (groups × capacity factor knobs;
    # XLA-lowered, so only the model rows — there is no standalone oracle)
    Bm_, Sm_, Dm_, Em_, Km_, Fm_ = 8, 2048, 2048, 128, 8, 768
    moe_shape = moe_tiling.shape_key(Bm_, Sm_, Dm_, Em_, Km_, Fm_, 1.25,
                                     "bfloat16")
    results["moe_dispatch"] = _tuned_rows(tuner, "moe_dispatch", moe_shape,
                                          print_fn)

    # paged decode: serving hot path — 8 slots, long KV, GQA, paged pool.
    # The gather baseline is the XLA fallback at the same shape, priced by
    # the same roofline; tuned kernel must never be slower (it touches only
    # live blocks where the gather streams the whole logical view).  The
    # pool block size matches what serve_kv's joint model resolves for
    # this window (asserted below) — small pool blocks would drown the
    # win in per-block grid-step overhead, which is exactly why the two
    # are resolved jointly.
    Bp, Hp, Hkvp, Dhp, NBp, bsp = 8, 32, 8, 128, 16, 256
    pd_shape = pd_tiling.shape_key(Bp, Hp, Hkvp, Dhp, NBp, bsp, "bfloat16")
    results["paged_decode"] = _tuned_rows(tuner, "paged_decode", pd_shape,
                                          print_fn)
    from repro.kernels.autotune import roofline_seconds
    gather_us = roofline_seconds(pd_tiling.gather_cost(pd_shape),
                                 get_device("tpu_v5e")) * 1e6
    results["paged_decode"]["gather_us"] = gather_us
    results["paged_decode"]["vs_gather"] = (
        gather_us / max(results["paged_decode"]["tuned_us"], 1e-12))
    print_fn(csv_line("kernel/paged_decode/model_gather_us", gather_us,
                      f"vs_tuned={results['paged_decode']['vs_gather']:.2f}x "
                      f"(full {NBp * bsp}-token logical view, no early exit)"))

    # serve_kv ⇄ paged_decode joint resolution: the pool block size the
    # serve_kv model picks must admit the kernel's tuned block_kv as a
    # divisor (structural — candidates snap to the pool block).
    kv_shape = kv_tiling.shape_key(Bp, NBp * bsp, Hkvp, Dhp, "bfloat16",
                                   n_heads=Hp)
    kv_bs = int(tuner.tune("serve_kv", kv_shape)["block_size"])
    pd_joint_shape = pd_tiling.shape_key(
        Bp, Hp, Hkvp, Dhp, -(-NBp * bsp // kv_bs), kv_bs, "bfloat16")
    kv_bkv = int(tuner.tune("paged_decode", pd_joint_shape)["block_kv"])
    results["serve_kv_joint"] = {
        "block_size": kv_bs, "block_kv": kv_bkv,
        "aligned": kv_bs % kv_bkv == 0,
    }
    print_fn(csv_line("kernel/serve_kv/joint_block_size", kv_bs,
                      f"paged_decode_block_kv={kv_bkv} "
                      f"aligned={kv_bs % kv_bkv == 0}"))

    # second visit to the whole grid must be pure cache hits (no re-search)
    h0, m0 = tuner.hits, tuner.misses
    for kernel, shape in (
        ("flash_attention", flash_tiling.shape_key(
            (B, H, S, Dh), (B, H, S, Dh), causal=True, dtype="bfloat16")),
        ("conv_mm", conv_tiling.shape_key(
            (N, HW, HW, C), (K, K, C, O), stride=1, padding=1,
            dtype="bfloat16")),
        ("ssm_scan", ssm_tiling.shape_key(
            (B2, S2, Hh, P), Nst, dtype="float32")),
        ("moe_dispatch", moe_shape),
        ("paged_decode", pd_shape),
        ("serve_kv", kv_shape),
    ):
        tuner.tune(kernel, shape)
    results["second_call_hits"] = tuner.hits - h0
    results["second_call_misses"] = tuner.misses - m0
    print_fn(csv_line("kernel/autotune/second_call_hits",
                      results["second_call_hits"],
                      f"misses={results['second_call_misses']} expect=6/0"))
    return results


if __name__ == "__main__":
    run()
