"""Kernel micro-benchmarks: static roofline stats per Pallas kernel config
(FLOPs, HBM bytes, arithmetic intensity, VMEM working set) plus CPU oracle
wall-time as a correctness-path sanity check.

Wall-clock of interpret-mode Pallas is meaningless (Python interpreter), so
the perf numbers reported are the *structural* ones the TPU roofline uses."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ref import ssd_ref
from repro.kernels.conv_mm.ref import conv_ref
from repro.launch.mesh import TPU_V5E

from .common import csv_line


def _time(fn, *args, n=3):
    fn(*args)  # compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(print_fn=print) -> None:
    peak, bw = TPU_V5E["peak_flops_bf16"], TPU_V5E["hbm_bw"]

    # flash attention: (B,H,S,Dh) production-ish tile
    B, H, S, Dh, bq, bk = 1, 8, 2048, 128, 512, 512
    flops = 4.0 * B * H * S * S * Dh * 0.5  # causal
    bytes_ = 2.0 * (B * H * S * Dh * 3 + B * H * S * Dh)
    vmem = (bq * Dh + 2 * bk * Dh) * 2 + bq * Dh * 4
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, S, Dh)), jnp.bfloat16)
    us = _time(jax.jit(lambda q: attention_ref(q, q, q, causal=True)), q)
    print_fn(csv_line("kernel/flash_attn/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f} vmem_kb={vmem / 1024:.0f}"))

    # conv_mm: ResNet-ish layer
    N, HW, C, K, O = 8, 32, 128, 3, 128
    flops = 2.0 * N * HW * HW * O * K * K * C
    bytes_ = 2.0 * (N * HW * HW * C + K * K * C * O + N * HW * HW * O)
    x = jnp.asarray(rng.standard_normal((N, HW, HW, C)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((K, K, C, O)), jnp.bfloat16)
    us = _time(jax.jit(lambda x, w: conv_ref(x, w, stride=1, padding=1)), x, w)
    print_fn(csv_line("kernel/conv_mm/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f}"))

    # ssd: mamba2-780m layer tile
    B2, S2, Hh, P, Nst, ch = 1, 2048, 24, 64, 128, 128
    flops = 2.0 * B2 * S2 * ch * Hh * (Nst + P) + 2.0 * B2 * S2 * Hh * P * Nst
    bytes_ = 2.0 * B2 * S2 * Hh * P * 2 + 4.0 * B2 * S2 * Hh
    xh = jnp.asarray(rng.standard_normal((B2, S2, Hh, P)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B2, S2, Hh)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.standard_normal((B2, S2, Nst)), jnp.float32)
    us = _time(jax.jit(lambda xh, a, Bm: ssd_ref(xh, a, Bm, Bm, chunk=ch)[0]),
               xh, a, Bm)
    print_fn(csv_line("kernel/ssd/ref_us", us,
                      f"AI={flops / bytes_:.0f} tpu_roofline_us="
                      f"{max(flops / peak, bytes_ / bw) * 1e6:.1f}"))


if __name__ == "__main__":
    run()
