"""Nightly benchmark regression gate (ROADMAP: scheduled job running
engine_bench + kernel_bench with speedup/accuracy thresholds that fail
the job).

Runs both benchmarks in-process and enforces:

* engine batched-vs-scalar speedup ≥ ``ENGINE_SPEEDUP_MIN`` (acceptance
  target is 5×; the gate is laxer to absorb CI-runner noise),
* batched/scalar prediction parity is exact,
* calibrated accuracy on the golden fixture: phi MAPE ≤ 0.25, gamma
  MAPE ≤ 0.10 (the fitted targets are 0.15 / 0.04),
* cost-ledger parity (docs/engine.md "Cost ledger"): per-op class sums
  reproduce the HloCost scalars (relative 1e-9; exact at smoke scale), on
  a compiled golden program and on the campaign records' recorded
  breakdowns, and the APPLIED class-wise
  calibration (CNN and campaign HLO fits both) is never worse than the
  aggregate 3-term fallback,
* energy (docs/engine.md "Energy"): the priced ledger's per-class joule
  sums reproduce its aggregate (same relative 1e-9), and the applied
  energy fit (CNN calibration and campaign HLO both) is never worse than
  the tied-aggregate fallback,
* campaign LM-forest accuracy (docs/campaign.md): held-out-cell latency
  MAPE and combined latency+memory MAPE from the campaign-fitted forest
  beat the uncalibrated analytical path on the host-CPU smoke grid,
* serving (docs/serve.md): on the seeded mixed-length Poisson trace the
  continuous-batching engine sustains at least the lockstep engine's
  req/s at equal ``n_slots`` (speedup ≥ ``SERVE_SPEEDUP_MIN``), records
  finite p50/p99 TTFT and per-token latency, its goodput is never worse,
  and the paged KV pool is smaller than the dense cache it replaced,
* chunked prefill (docs/serve.md): greedy streams identical with and
  without chunking, decode never stalls, the running-slot stall bound
  drops from the whole prompt to one chunk, and deterministic step-count
  TTFT p99 degrades at most 10%,
* per kernel (incl. the moe_dispatch model), the autotuned config's
  modelled roofline time is never worse than the hand-coded default (the
  default is a candidate, so any violation means the cost model or
  search broke),
* paged_decode (docs/kernels.md): the tuned flash-decode kernel is never
  modelled slower than the XLA gather fallback at the serving shape, and
  the serve_kv pool block jointly admits the kernel's tuned block_kv,
* a second autotune pass over the bench grid is a pure cache hit.

Exit code 1 with a FAIL line per violated threshold.

    PYTHONPATH=src python -m benchmarks.check_thresholds
"""

from __future__ import annotations

import sys

ENGINE_SPEEDUP_MIN = 3.0
PHI_MAPE_MAX = 0.25
GAMMA_MAPE_MAX = 0.10
PARITY_TOL = 1e-9   # packed-forest float accumulation order (≈1e-14 observed)
# Class-grouped vs sequential ledger sums: relative, since addition
# reordering is only bit-exact below the 2^53 integer ceiling (0 observed
# at smoke scale).
LEDGER_PARITY_RTOL = 1e-9
CAMPAIGN_GAMMA_MAPE_MAX = 0.50  # sanity bound on the LM forest's memory error
PLANNER_WALL_S_MAX = 1.0        # price the whole layout space, zero compiles
COLLECTIVE_CELLS_MIN = 2        # >1-device smoke cells the NNLS must see
SERVE_SPEEDUP_MIN = 1.0         # continuous must never lose to lockstep
# Under the seeded chaos plan the engine must keep a usable fraction of
# its fault-free goodput (lax: CI wall-clock noise dominates the rest).
CHAOS_GOODPUT_RATIO_MIN = 0.25
# Chunked prefill (ISSUE 10): gated on the deterministic step-count
# metrics (wall-clock ratios are reported but too noisy to gate on a
# shared CI host).  TTFT in engine steps may degrade at most 10%.
CHUNKED_TTFT_STEPS_RATIO_MAX = 1.10
# The tuned paged_decode kernel must never be modelled slower than the
# gather fallback at the serving bench shape.
PAGED_DECODE_VS_GATHER_MIN = 1.0


def main() -> int:
    from . import engine_bench, kernel_bench

    failures: list[str] = []

    def check(ok: bool, msg: str) -> None:
        print(("PASS " if ok else "FAIL ") + msg)
        if not ok:
            failures.append(msg)

    eng = engine_bench.run()
    check(eng["speedup"] >= ENGINE_SPEEDUP_MIN,
          f"engine batched speedup {eng['speedup']:.1f}x >= {ENGINE_SPEEDUP_MIN}x")
    check(eng["max_dev"] <= PARITY_TOL,
          f"engine batched/scalar parity dev {eng['max_dev']:.3g} <= {PARITY_TOL}")
    # Cost-ledger contract: per-op class sums reproduce the HloCost scalars
    # on a compiled golden program.
    check(eng["ledger_parity_dev"] <= LEDGER_PARITY_RTOL,
          f"cost-ledger breakdown parity rel dev "
          f"{eng['ledger_parity_dev']:.3g} <= {LEDGER_PARITY_RTOL}")
    # Energy obeys the same contract: per-class joule sums reproduce the
    # priced ledger's aggregate (docs/engine.md "Energy").
    check(eng["ledger_energy_parity_dev"] <= LEDGER_PARITY_RTOL,
          f"cost-ledger energy parity rel dev "
          f"{eng['ledger_energy_parity_dev']:.3g} <= {LEDGER_PARITY_RTOL}")
    if "phi_mape_cal" in eng:  # golden fixture present
        check(eng["phi_mape_cal"] <= PHI_MAPE_MAX,
              f"calibrated phi MAPE {eng['phi_mape_cal']:.3f} <= {PHI_MAPE_MAX}")
        check(eng["gamma_mape_cal"] <= GAMMA_MAPE_MAX,
              f"calibrated gamma MAPE {eng['gamma_mape_cal']:.3f} <= {GAMMA_MAPE_MAX}")
        # Class-wise calibration must never be worse than the 3-term
        # aggregate fit (the aggregate is the tied-coefficient special
        # case, and calibrate() falls back when the split carries nothing).
        check(eng["phi_mape_cal"] <= eng["phi_mape_cal_aggregate"] * (1 + 1e-9),
              f"class-wise phi MAPE {eng['phi_mape_cal']:.3f} <= aggregate "
              f"{eng['phi_mape_cal_aggregate']:.3f}")
        if "energy_mape_cal" in eng:
            # Same never-worse contract for the energy fit: the applied
            # (lower-MAPE) fit can never lose to the tied aggregate.
            check(eng["energy_mape_cal"]
                  <= eng["energy_mape_cal_aggregate"] * (1 + 1e-9),
                  f"applied energy MAPE {eng['energy_mape_cal']:.3f} <= "
                  f"aggregate {eng['energy_mape_cal_aggregate']:.3f}")
    else:
        print("SKIP calibration accuracy (golden fixture absent)")

    # Campaign LM-forest accuracy (ISSUE 4 acceptance): the campaign-fitted
    # forest must beat the uncalibrated analytical path on held-out smoke
    # cells — individually on latency, and on the combined latency+memory
    # error (analytical memory is derived from a real AOT compile, so it is
    # near ground truth; the forest's win there is paying zero compiles).
    camp = engine_bench.campaign_accuracy()
    if camp:
        check(camp["forest_phi_mape"] < camp["analytical_phi_mape"],
              f"campaign forest phi MAPE {camp['forest_phi_mape']:.3f} < "
              f"analytical {camp['analytical_phi_mape']:.3f} "
              f"(heldout n={camp['n_heldout']})")
        forest_total = camp["forest_phi_mape"] + camp["forest_gamma_mape"]
        anal_total = camp["analytical_phi_mape"] + camp["analytical_gamma_mape"]
        check(forest_total < anal_total,
              f"campaign forest phi+gamma MAPE {forest_total:.3f} < "
              f"analytical {anal_total:.3f}")
        check(camp["forest_gamma_mape"] <= CAMPAIGN_GAMMA_MAPE_MAX,
              f"campaign forest gamma MAPE {camp['forest_gamma_mape']:.3f} "
              f"<= {CAMPAIGN_GAMMA_MAPE_MAX}")
        if "breakdown_parity_dev" in camp:
            check(camp["breakdown_parity_dev"] <= LEDGER_PARITY_RTOL,
                  f"campaign ledger breakdown parity rel dev "
                  f"{camp['breakdown_parity_dev']:.3g} <= {LEDGER_PARITY_RTOL}")
        if "hlo_phi_mape_applied" in camp:
            check(camp["hlo_phi_mape_applied"]
                  <= camp["hlo_phi_mape_aggregate"] * (1 + 1e-9),
                  f"campaign applied HLO phi MAPE "
                  f"{camp['hlo_phi_mape_applied']:.3f} <= aggregate "
                  f"{camp['hlo_phi_mape_aggregate']:.3f}")
        if "hlo_energy_mape_applied" in camp:
            check(camp["hlo_energy_mape_applied"]
                  <= camp["hlo_energy_mape_aggregate"] * (1 + 1e-9),
                  f"campaign applied HLO energy MAPE "
                  f"{camp['hlo_energy_mape_applied']:.3f} <= aggregate "
                  f"{camp['hlo_energy_mape_aggregate']:.3f}")
    else:
        print("SKIP campaign accuracy (smoke grid too sparse)")

    # Auto-sharding planner (docs/planner.md, ISSUE 9 acceptance): the
    # chosen layout's predicted step cost is never worse than the
    # hard-coded production mesh (1x16x16 — which is itself a candidate,
    # so any violation means the ranking broke), the FULL layout space is
    # priced well under a second, and the booby-trapped compiler counted
    # zero invocations while it happened.
    pl = engine_bench.planner_bench()
    check(pl["compiles"] == 0,
          f"planner priced {pl['layouts']} layouts with zero compiles "
          f"(counted {pl['compiles']})")
    check(pl["chosen_phi_ms"] <= pl["default_phi_ms"] * (1 + 1e-9),
          f"planner chosen {pl['chosen']} phi {pl['chosen_phi_ms']:.2f}ms <= "
          f"default 1x16x16 phi {pl['default_phi_ms']:.2f}ms "
          f"(speedup {pl['speedup']:.2f}x)")
    check(pl["wall_s"] < PLANNER_WALL_S_MAX,
          f"planner pricing wall {pl['wall_s'] * 1e3:.1f}ms < "
          f"{PLANNER_WALL_S_MAX * 1e3:.0f}ms")

    # Collective calibration (the >1-device smoke grid): after the fit,
    # the collective column must have entered the class-wise system on
    # real multi-device measurements — the coefficient the planner's
    # collective_seconds() prices layouts with.
    coll = engine_bench.collective_calibration()
    if coll:
        check(coll["collective_cells"] >= COLLECTIVE_CELLS_MIN,
              f"collective calibration saw {coll['collective_cells']} "
              f">1-device cells >= {COLLECTIVE_CELLS_MIN}")
        check(bool(coll["collective_column_fitted"]),
              f"collective coeffs present after smoke fit "
              f"(coeff={coll['collective_coeff']:.3g} s/B, "
              f"n={coll['n_records']} records)")
    else:
        print("SKIP collective calibration (subprocess or fit unavailable)")

    # Serving: continuous batching vs lockstep on the seeded open-loop
    # trace (ISSUE 6 acceptance) — never worse on sustained req/s or
    # goodput, latency percentiles recorded and finite, paged pool
    # strictly smaller than the dense cache it replaced.
    import math

    from . import serve_bench

    srv = serve_bench.run()
    check(srv["speedup"] >= SERVE_SPEEDUP_MIN,
          f"serve continuous {srv['continuous_rps']:.2f} req/s >= lockstep "
          f"{srv['lockstep_rps']:.2f} req/s "
          f"(speedup {srv['speedup']:.2f}x >= {SERVE_SPEEDUP_MIN}x)")
    check(all(math.isfinite(srv[k]) and srv[k] > 0 for k in
              ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "tpot_p99_ms")),
          f"serve latency percentiles recorded "
          f"(ttft p50/p99 {srv['ttft_p50_ms']:.2f}/{srv['ttft_p99_ms']:.2f}ms, "
          f"tpot p50/p99 {srv['tpot_p50_ms']:.2f}/{srv['tpot_p99_ms']:.2f}ms)")
    check(srv["goodput_continuous"] >= srv["goodput_lockstep"],
          f"serve goodput continuous {srv['goodput_continuous']:.2f} >= "
          f"lockstep {srv['goodput_lockstep']:.2f} req/s")
    check(srv["kv_bytes"] < srv["kv_dense_bytes"],
          f"paged KV pool {srv['kv_bytes'] / 1e6:.3g}MB < dense "
          f"{srv['kv_dense_bytes'] / 1e6:.3g}MB (block={srv['block_size']})")

    # Chunked prefill (ISSUE 10 acceptance): greedy streams identical to
    # the unchunked engine, decode never stalls, the running-slot stall
    # bound drops from the whole prompt to one chunk, and step-count TTFT
    # p99 degrades at most 10%.  All gated quantities are deterministic.
    chk = serve_bench.run_chunked()
    check(chk["streams_equal"],
          "serve chunked greedy streams identical to unchunked")
    check(chk["chunked"]["prefill_chunks"] > 0,
          f"serve chunked prefill actually chunked "
          f"({chk['chunked']['prefill_chunks']} chunks of "
          f"{serve_bench.PREFILL_CHUNK})")
    check(chk["chunked"]["max_decode_stall_steps"] == 0,
          f"serve chunked decode never stalls "
          f"(max stall {chk['chunked']['max_decode_stall_steps']} steps)")
    check(chk["chunked"]["lost"] == 0 and chk["unchunked"]["lost"] == 0,
          "serve chunked zero lost requests")
    check(chk["chunked"]["max_prefill_stall_tokens"]
          < chk["unchunked"]["max_prefill_stall_tokens"],
          f"serve chunked running-slot stall bound "
          f"{chk['chunked']['max_prefill_stall_tokens']} tokens < unchunked "
          f"{chk['unchunked']['max_prefill_stall_tokens']} (one chunk, "
          f"not the whole prompt)")
    check(chk["ttft_steps_ratio"] <= CHUNKED_TTFT_STEPS_RATIO_MAX,
          f"serve chunked step-TTFT p99 ratio "
          f"{chk['ttft_steps_ratio']:.3f} <= {CHUNKED_TTFT_STEPS_RATIO_MAX} "
          f"(chunked {chk['chunked']['ttft_steps_p99']:.1f} vs unchunked "
          f"{chk['unchunked']['ttft_steps_p99']:.1f} steps)")
    check(0 < chk["chunked"]["kv_touched_bytes"]
          < chk["chunked"]["kv_gathered_bytes"],
          f"serve chunked decode kernel touches "
          f"{chk['chunked']['kv_touched_bytes'] / 1e6:.1f}MB < gather's "
          f"{chk['chunked']['kv_gathered_bytes'] / 1e6:.1f}MB logical view")

    # Chaos (ISSUE 8 acceptance): under the seeded fault plan no request
    # is lost (all reach a typed terminal state), the planned faults
    # actually fired, the pool conserves, and goodput under faults holds
    # a floor fraction of the identical fault-free cell's.
    chaos = serve_bench.run_chaos()
    check(chaos["chaos_lost"] == 0 and chaos["baseline_lost"] == 0,
          f"serve chaos zero lost requests "
          f"(chaos={chaos['chaos_lost']}, baseline={chaos['baseline_lost']})")
    check(chaos["chaos_terminal"] == chaos["n_requests"],
          f"serve chaos all terminal "
          f"({chaos['chaos_terminal']}/{chaos['n_requests']}: "
          f"{chaos['chaos_finished']} finished, {chaos['chaos_refused']} "
          f"refused, {chaos['chaos_expired']} expired)")
    check(chaos["faults_alloc_fired"] > 0 and chaos["faults_backend_fired"] > 0,
          f"serve chaos faults actually fired "
          f"(alloc={chaos['faults_alloc_fired']}, "
          f"backend={chaos['faults_backend_fired']})")
    check(chaos["pool_conserved"],
          "serve chaos KV pool fully reclaimed after drain")
    check(chaos["goodput_ratio"] >= CHAOS_GOODPUT_RATIO_MIN,
          f"serve chaos goodput {chaos['goodput_chaos']:.2f} req/s >= "
          f"{CHAOS_GOODPUT_RATIO_MIN} x fault-free "
          f"{chaos['goodput_faultfree']:.2f} req/s "
          f"(ratio {chaos['goodput_ratio']:.2f})")

    kern = kernel_bench.run()
    for name in ("conv_mm", "flash_attention", "ssm_scan", "moe_dispatch",
                 "paged_decode"):
        r = kern[name]
        check(r["tuned_us"] <= r["default_us"] * (1 + 1e-9),
              f"{name} tuned model {r['tuned_us']:.2f}us <= "
              f"default {r['default_us']:.2f}us ({r['config']})")
    # Flash-decode fast path (ISSUE 10): the tuned paged_decode kernel is
    # never modelled slower than the XLA gather fallback at the serving
    # shape, and the serve_kv pool block jointly admits the kernel's
    # tuned block_kv (divisibility — no mid-block remainder handling).
    check(kern["paged_decode"]["vs_gather"] >= PAGED_DECODE_VS_GATHER_MIN,
          f"paged_decode tuned {kern['paged_decode']['tuned_us']:.2f}us "
          f"beats gather {kern['paged_decode']['gather_us']:.2f}us "
          f"({kern['paged_decode']['vs_gather']:.2f}x >= "
          f"{PAGED_DECODE_VS_GATHER_MIN}x)")
    check(kern["serve_kv_joint"]["aligned"],
          f"serve_kv block_size {kern['serve_kv_joint']['block_size']} "
          f"admits paged_decode block_kv "
          f"{kern['serve_kv_joint']['block_kv']} (joint resolution)")
    check(kern["second_call_hits"] == 6 and kern["second_call_misses"] == 0,
          f"autotune second pass pure cache hit "
          f"({kern['second_call_hits']} hits, {kern['second_call_misses']} misses)")

    if failures:
        print(f"\n{len(failures)} threshold(s) violated")
        return 1
    print("\nall benchmark thresholds met")
    return 0


if __name__ == "__main__":
    sys.exit(main())
