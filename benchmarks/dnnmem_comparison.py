"""§6.2.1 reproduction: memory-prediction protocol vs DNNMem.

The paper profiles ResNet50 (server GPU), trains the Γ forest on pruning
levels {0,30,50,70,90} and reports 2.45 % memory error across batch sizes and
topologies, vs DNNMem's 17.4 %.  Here: same protocol on this host's ResNet50
(Γ ground truth = XLA memory plan)."""

from __future__ import annotations

from repro.core.dataset import DEFAULT_TEST_LEVELS, DEFAULT_TRAIN_LEVELS

from .common import cache, csv_line, fit_predictor, grid_points


def run(print_fn=print) -> float:
    c = cache()
    train = grid_points(c, "resnet50", DEFAULT_TRAIN_LEVELS, "random")
    test = grid_points(c, "resnet50", DEFAULT_TEST_LEVELS, "random")
    model = fit_predictor(train)
    rep = model.evaluate(test)
    print_fn(csv_line("dnnmem/resnet50/gamma_err_pct", rep.gamma_mape * 100,
                      "paper=2.45 dnnmem=17.4"))
    return rep.gamma_mape * 100


if __name__ == "__main__":
    run()
