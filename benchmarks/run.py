"""Benchmark driver — one function per paper table/figure.

Prints ``name,value,derived`` CSV lines.  All CNN benchmarks read the
profiling cache (populated by ``benchmarks.collect_cnn_data``; missing points
are profiled lazily).  The roofline table reads the dry-run JSONL.

    PYTHONPATH=src python -m benchmarks.run [--skip-slow]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip benches that may profile new configs")
    args = ap.parse_args()

    from . import (dnnmem_comparison, engine_bench, fig3_same_network,
                   fig4_basis, kernel_bench, roofline_table,
                   strategy_variation, table2_case_study, trainset_sweep)

    benches = {
        "fig3": fig3_same_network.run,            # Fig. 3
        "fig4": fig4_basis.run,                   # Fig. 4
        "trainset": trainset_sweep.run,           # §6.1
        "dnnmem": dnnmem_comparison.run,          # §6.2.1
        "strategies": strategy_variation.run,     # §6.2 (100 strategies)
        "table2": table2_case_study.run,          # Table 2 / §6.4
        "roofline": roofline_table.run,           # §Roofline (beyond paper)
        "kernels": kernel_bench.run,              # kernel μ-bench
        "engine": engine_bench.run,               # batched CostBackend API
    }
    slow = {"strategies", "table2"}
    selected = (args.only.split(",") if args.only else list(benches))

    failures = []
    for name in selected:
        if args.skip_slow and name in slow:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            benches[name]()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)

    if failures:
        print(f"\nFAILED benchmarks: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
