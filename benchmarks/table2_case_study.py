"""Table 2 / §6.4 reproduction: on-device model selection under constraints.

The paper searches OFA-ResNet50 sub-networks by evolutionary search under
hard (Γ, γ, φ) budgets, with every candidate evaluated by the perf4sight
predictors (0.1 s) instead of on-device profiling (20 s) — a ~200× search
speed-up and no OOM risk.  Analogue here: the sub-network space is the
pruned-topology space of ResNet50 (per-group keep ratios = OFA sub-network
sampling).

Steps (mirroring the paper):
  1. Γ model: trained on the ResNet50 training grid (§6.2 protocol).
  2. γ/φ models: trained on profiled *inference* of N_TRAIN_SUB sampled
     sub-networks at small batch sizes (paper: 25 of 100 subnets, bs≤32),
     tested on held-out subnets (paper: 1.8 % γ, 4.4 % φ).
  3. ES under three constraint tiers (≈ MAX/A/B rows), predictor-gated.
  4. Search-time comparison: predictor evals/s vs measured profile time.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.dataset import DEFAULT_TRAIN_LEVELS
from repro.core.features import network_features
from repro.core.predictor import Perf4Sight, mape
from repro.core.profiler import profile_inference, profile_training
from repro.core.search import Constraints, evolutionary_search, sample_subnetwork
from repro.models.cnn import build_resnet50

from .common import CACHE_PATH, cache, csv_line, fit_predictor, grid_points

WM, HW = 0.25, 16
N_TRAIN_SUB, N_TEST_SUB = 10, 6
INFER_BS = (1, 2, 4, 8)
SUB_CACHE = os.path.join(os.path.dirname(CACHE_PATH), "ofa_subnets.json")


def _subnet_inference_data() -> list[dict]:
    if os.path.exists(SUB_CACHE):
        with open(SUB_CACHE) as f:
            return json.load(f)
    base = build_resnet50(width_mult=WM, input_hw=HW)
    data = []
    t_profile = []
    for i in range(N_TRAIN_SUB + N_TEST_SUB):
        rng = np.random.default_rng(2000 + i)
        widths = sample_subnetwork(base.widths, rng)
        m = build_resnet50(widths=widths, input_hw=HW)
        m.name = f"r50-sub{i}"
        spec = m.conv_specs()
        for bs in INFER_BS:
            t0 = time.perf_counter()
            res = profile_inference(m, bs)
            t_profile.append(time.perf_counter() - t0)
            data.append({
                "sub": i, "bs": bs,
                "gamma": res.gamma_mb, "phi": res.phi_ms,
                "features": [float(v) for v in network_features(spec, bs)],
                "profile_s": t_profile[-1],
            })
        # one training-Γ validation point per subnet at the search batch size
        t0 = time.perf_counter()
        tres = profile_training(m, 16)
        data.append({
            "sub": i, "bs": 16, "train": True,
            "gamma": tres.gamma_mb, "phi": tres.phi_ms,
            "features": [float(v) for v in network_features(spec, 16)],
            "profile_s": time.perf_counter() - t0,
        })
    os.makedirs(os.path.dirname(SUB_CACHE), exist_ok=True)
    with open(SUB_CACHE, "w") as f:
        json.dump(data, f)
    return data


def run(print_fn=print) -> dict:
    c = cache()
    # 1. Γ model from the §6.2 grid
    gamma_model = fit_predictor(
        grid_points(c, "resnet50", DEFAULT_TRAIN_LEVELS, "random"))

    # 2. γ/φ inference models from sampled sub-networks
    data = _subnet_inference_data()
    inf = [d for d in data if not d.get("train")]
    train_rows = [d for d in inf if d["sub"] < N_TRAIN_SUB]
    test_rows = [d for d in inf if d["sub"] >= N_TRAIN_SUB]
    Xtr = np.array([d["features"] for d in train_rows])
    infer_model = Perf4Sight(n_estimators=100, seed=0).fit_arrays(
        Xtr, np.array([d["gamma"] for d in train_rows]),
        np.array([d["phi"] for d in train_rows]))
    Xte = np.array([d["features"] for d in test_rows])
    pg, pp = infer_model.predict_features(Xte)
    g_err = mape(pg, np.array([d["gamma"] for d in test_rows])) * 100
    p_err = mape(pp, np.array([d["phi"] for d in test_rows])) * 100
    print_fn(csv_line("table2/infer_gamma_err_pct", g_err, "paper=1.8"))
    print_fn(csv_line("table2/infer_phi_err_pct", p_err, "paper=4.4"))

    # Γ generalisation to sampled subnets (paper: 4.28 % on OFA samples)
    tr_rows = [d for d in data if d.get("train")]
    Xg = np.array([d["features"] for d in tr_rows])
    pgt, _ = gamma_model.predict_features(Xg)
    g_sub_err = mape(pgt, np.array([d["gamma"] for d in tr_rows])) * 100
    print_fn(csv_line("table2/train_gamma_subnet_err_pct", g_sub_err,
                      "paper=4.28"))

    # 3. ES under constraint tiers (predictor-gated)
    mean_profile_s = float(np.mean([d["profile_s"] for d in data]))
    tiers = {
        "A": Constraints(gamma_mb=18.0, gamma_inf_mb=6.0, phi_inf_ms=20.0,
                         train_bs=16, infer_bs=1),
        "B": Constraints(gamma_mb=12.0, gamma_inf_mb=4.0, phi_inf_ms=10.0,
                         train_bs=16, infer_bs=1),
    }
    results = {"infer_gamma_err": g_err, "infer_phi_err": p_err,
               "train_gamma_subnet_err": g_sub_err}
    for name, cons in tiers.items():
        r = evolutionary_search(
            "resnet50", (gamma_model, infer_model), cons,
            population=32, iterations=40, width_mult=WM, input_hw=HW, seed=0)
        evals_s = r.evaluations / max(r.search_time_s, 1e-9)
        naive_s = r.evaluations * mean_profile_s
        speedup = naive_s / max(r.search_time_s, 1e-9)
        print_fn(csv_line(f"table2/ES_{name}/fitness", r.fitness,
                          f"gamma={r.gamma_mb:.1f}MB phi_inf={r.phi_inf_ms:.1f}ms"))
        print_fn(csv_line(f"table2/ES_{name}/search_time_s", r.search_time_s,
                          f"naive={naive_s:.0f}s speedup={speedup:.0f}x"))
        results[f"ES_{name}"] = {
            "fitness": r.fitness, "time_s": r.search_time_s,
            "naive_s": naive_s, "speedup": speedup,
            "evals_per_s": evals_s, "widths_sum": sum(r.widths.values()),
        }
    return results


if __name__ == "__main__":
    run()
