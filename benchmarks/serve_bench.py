"""Open-loop serving benchmark: continuous batching vs lockstep generate.

Replays one synthetic Poisson arrival trace (seeded: mixed prompt
lengths, mixed token budgets) through both engines at equal ``n_slots``
and reports, per engine, sustained requests/sec plus the continuous
engine's p50/p99 TTFT and per-token latency.  The lockstep baseline is
``ServeEngine.generate`` driven the only way a lockstep server can be:
grab up to ``n_slots`` arrived requests, decode until the *longest*
finishes, return the batch — short requests hold their slots, which is
exactly the idle time continuous batching reclaims.

Methodology (docs/serve.md):

* open-loop — arrivals follow the trace's wall-clock offsets whether or
  not the server keeps up, so queueing delay lands in TTFT;
* each engine runs the trace twice on one instance and the second pass
  is measured (first pass owns every jit trace: prefill buckets, decode
  table widths, the lockstep batch shapes);
* lockstep TTFT is batch-completion-based (the engine returns whole
  batches), which flatters nobody: it is reported, while the gate in
  ``check_thresholds.py`` compares sustained req/s and requires the
  continuous engine to be never worse;
* goodput = finished requests whose end-to-end per-output-token latency
  met ``GOODPUT_TPOT_MS``, per second of wall clock.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.registry import get_config
from repro.kernels.autotune import KernelTuner
from repro.models import transformer as T
from repro.serve import (
    ContinuousConfig,
    ContinuousEngine,
    Request,
    ServeConfig,
    ServeEngine,
)

from .common import csv_line

MAX_LEN = 64
N_SLOTS = 4
N_REQUESTS = 12
RATE_RPS = 30.0                # arrival intensity (keeps the cell loaded)
PROMPT_LENS = (5, 9, 13)       # few distinct widths → few lockstep traces
MAX_NEW = (4, 24)              # mixed budgets: what lockstep pads away
GOODPUT_TPOT_MS = 500.0        # host-CPU smoke scale
TUNING_CACHE = "/tmp/perf4sight_serve_bench_tuning.json"


def make_trace(seed: int = 0, n: int = N_REQUESTS, rate: float = RATE_RPS):
    """[(arrival_s, prompt, max_new)] with Poisson (exponential-gap)
    arrivals — the same seed replays the same trace for both engines."""
    rng = np.random.default_rng(seed)
    t, trace = 0.0, []
    vocab_lo, vocab_hi = 2, 128
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(vocab_lo, vocab_hi, (plen,)).astype(np.int32)
        trace.append((t, prompt, int(MAX_NEW[i % len(MAX_NEW)])))
    return trace


# ---------------------------------------------------------------------------


def run_lockstep(eng: ServeEngine, trace) -> dict:
    start = time.perf_counter()
    done, i = [], 0
    while i < len(trace):
        now = time.perf_counter() - start
        if trace[i][0] > now:
            time.sleep(trace[i][0] - now)
            now = time.perf_counter() - start
        n_due = sum(1 for a, _, _ in trace[i:] if a <= now)
        batch = trace[i: i + min(max(n_due, 1), eng.scfg.n_slots)]
        i += len(batch)
        out = eng.generate([p for _, p, _ in batch],
                           max_new_tokens=max(m for _, _, m in batch))
        t_done = time.perf_counter() - start
        for j, (arrival, _, _) in enumerate(batch):
            done.append({"latency_s": t_done - arrival,
                         "tokens": int(out["token_counts"][j])})
    wall = time.perf_counter() - start
    return {"wall_s": wall, "done": done}


def run_continuous(ce: ContinuousEngine, trace) -> dict:
    start = time.perf_counter()
    i = 0
    while i < len(trace) or not ce.idle:
        now = time.perf_counter() - start
        while i < len(trace) and trace[i][0] <= now:
            arrival, prompt, max_new = trace[i]
            req = Request(prompt=prompt, max_new_tokens=max_new)
            req.t_arrival = start + arrival
            ce.submit(req)
            i += 1
        if ce.idle and i < len(trace):
            time.sleep(max(0.0, trace[i][0] - now))
            continue
        ce.step()
    return {"wall_s": time.perf_counter() - start}


def _goodput(latencies_per_token_ms, wall_s: float) -> float:
    met = sum(1 for t in latencies_per_token_ms if t <= GOODPUT_TPOT_MS)
    return met / wall_s if wall_s > 0 else 0.0


def run(print_fn=print, seed: int = 0) -> dict:
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = T.init_params(cfg, 0)
    trace = make_trace(seed)

    lock = ServeEngine(cfg, params, ServeConfig(
        max_len=MAX_LEN, n_slots=N_SLOTS, eos_id=0))
    tuner = KernelTuner(cache=TUNING_CACHE)
    cont = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=MAX_LEN, n_slots=N_SLOTS, eos_id=0), tuner=tuner)

    # pass 1 warms every jit trace; pass 2 is measured
    run_lockstep(lock, trace)
    lk = run_lockstep(lock, trace)

    run_continuous(cont, trace)
    cont.finished.clear()
    cont.refused.clear()
    cont.decode_steps = 0
    ct = run_continuous(cont, trace)
    m = cont.metrics()
    assert m["finished"] == len(trace) and m["refused"] == 0

    lock_rps = len(lk["done"]) / lk["wall_s"]
    cont_rps = m["finished"] / ct["wall_s"]
    speedup = cont_rps / lock_rps

    lock_tpot = [1e3 * d["latency_s"] / max(d["tokens"], 1)
                 for d in lk["done"]]
    cont_tpot = [1e3 * (r.t_finished - r.t_arrival) / max(r.n_generated, 1)
                 for r in cont.finished]

    out = {
        "lockstep_rps": lock_rps,
        "continuous_rps": cont_rps,
        "speedup": speedup,
        "ttft_p50_ms": m["ttft_p50_ms"],
        "ttft_p99_ms": m["ttft_p99_ms"],
        "tpot_p50_ms": m["tpot_p50_ms"],
        "tpot_p99_ms": m["tpot_p99_ms"],
        "goodput_lockstep": _goodput(lock_tpot, lk["wall_s"]),
        "goodput_continuous": _goodput(cont_tpot, ct["wall_s"]),
        "kv_bytes": m["kv_bytes"],
        "kv_dense_bytes": m["kv_dense_bytes"],
        "block_size": m["block_size"],
        "n_requests": len(trace),
    }
    print_fn(csv_line("serve/lockstep_rps", lock_rps,
                      f"n={len(trace)} slots={N_SLOTS}"))
    print_fn(csv_line("serve/continuous_rps", cont_rps,
                      f"speedup={speedup:.2f}x"))
    print_fn(csv_line("serve/ttft_p50_ms", out["ttft_p50_ms"], "continuous"))
    print_fn(csv_line("serve/ttft_p99_ms", out["ttft_p99_ms"], "continuous"))
    print_fn(csv_line("serve/tpot_p50_ms", out["tpot_p50_ms"], "continuous"))
    print_fn(csv_line("serve/tpot_p99_ms", out["tpot_p99_ms"], "continuous"))
    print_fn(csv_line("serve/goodput_lockstep_rps", out["goodput_lockstep"],
                      f"tpot<= {GOODPUT_TPOT_MS}ms"))
    print_fn(csv_line("serve/goodput_continuous_rps",
                      out["goodput_continuous"],
                      f"tpot<= {GOODPUT_TPOT_MS}ms"))
    print_fn(csv_line("serve/kv_pool_mb", out["kv_bytes"] / 1e6,
                      f"dense={out['kv_dense_bytes'] / 1e6:.3g}MB "
                      f"block={out['block_size']}"))
    return out


if __name__ == "__main__":
    if os.path.exists(TUNING_CACHE):
        os.unlink(TUNING_CACHE)
    out = run()
    print(f"\ncontinuous vs lockstep speedup: {out['speedup']:.2f}x "
          f"(gate >= 1.0)")
