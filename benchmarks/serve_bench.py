"""Open-loop serving benchmark: continuous batching vs lockstep generate.

Replays one synthetic Poisson arrival trace (seeded: mixed prompt
lengths, mixed token budgets) through both engines at equal ``n_slots``
and reports, per engine, sustained requests/sec plus the continuous
engine's p50/p99 TTFT and per-token latency.  The lockstep baseline is
``ServeEngine.generate`` driven the only way a lockstep server can be:
grab up to ``n_slots`` arrived requests, decode until the *longest*
finishes, return the batch — short requests hold their slots, which is
exactly the idle time continuous batching reclaims.

Methodology (docs/serve.md):

* open-loop — arrivals follow the trace's wall-clock offsets whether or
  not the server keeps up, so queueing delay lands in TTFT;
* each engine runs the trace twice on one instance and the second pass
  is measured (first pass owns every jit trace: prefill buckets, decode
  table widths, the lockstep batch shapes);
* lockstep TTFT is batch-completion-based (the engine returns whole
  batches), which flatters nobody: it is reported, while the gate in
  ``check_thresholds.py`` compares sustained req/s and requires the
  continuous engine to be never worse;
* goodput = finished requests whose end-to-end per-output-token latency
  met ``GOODPUT_TPOT_MS``, per second of wall clock.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.registry import get_config
from repro.engine import CostEngine, EnsembleBackend, ForestBackend, get_device
from repro.kernels.autotune import KernelTuner
from repro.models import transformer as T
from repro.serve import (
    ContinuousConfig,
    ContinuousEngine,
    FaultPlan,
    Request,
    ServeConfig,
    ServeEngine,
)

from .common import csv_line

MAX_LEN = 64
N_SLOTS = 4
N_REQUESTS = 12
RATE_RPS = 30.0                # arrival intensity (keeps the cell loaded)
PROMPT_LENS = (5, 9, 13)       # few distinct widths → few lockstep traces
MAX_NEW = (4, 24)              # mixed budgets: what lockstep pads away
GOODPUT_TPOT_MS = 500.0        # host-CPU smoke scale
TUNING_CACHE = "/tmp/perf4sight_serve_bench_tuning.json"


def make_trace(seed: int = 0, n: int = N_REQUESTS, rate: float = RATE_RPS):
    """[(arrival_s, prompt, max_new)] with Poisson (exponential-gap)
    arrivals — the same seed replays the same trace for both engines."""
    rng = np.random.default_rng(seed)
    t, trace = 0.0, []
    vocab_lo, vocab_hi = 2, 128
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(vocab_lo, vocab_hi, (plen,)).astype(np.int32)
        trace.append((t, prompt, int(MAX_NEW[i % len(MAX_NEW)])))
    return trace


# ---------------------------------------------------------------------------


def run_lockstep(eng: ServeEngine, trace) -> dict:
    start = time.perf_counter()
    done, i = [], 0
    while i < len(trace):
        now = time.perf_counter() - start
        if trace[i][0] > now:
            time.sleep(trace[i][0] - now)
            now = time.perf_counter() - start
        n_due = sum(1 for a, _, _ in trace[i:] if a <= now)
        batch = trace[i: i + min(max(n_due, 1), eng.scfg.n_slots)]
        i += len(batch)
        out = eng.generate([p for _, p, _ in batch],
                           max_new_tokens=max(m for _, _, m in batch))
        t_done = time.perf_counter() - start
        for j, (arrival, _, _) in enumerate(batch):
            done.append({"latency_s": t_done - arrival,
                         "tokens": int(out["token_counts"][j])})
    wall = time.perf_counter() - start
    return {"wall_s": wall, "done": done}


def run_continuous(ce: ContinuousEngine, trace, *,
                   deadline_ms: float | None = None) -> dict:
    start = time.perf_counter()
    i = 0
    while i < len(trace) or not ce.idle:
        now = time.perf_counter() - start
        while i < len(trace) and trace[i][0] <= now:
            arrival, prompt, max_new = trace[i]
            req = Request(prompt=prompt, max_new_tokens=max_new,
                          deadline_ms=deadline_ms)
            req.t_arrival = start + arrival
            ce.submit(req)
            i += 1
        if ce.idle and i < len(trace):
            time.sleep(max(0.0, trace[i][0] - now))
            continue
        ce.step()
    return {"wall_s": time.perf_counter() - start}


def _goodput(latencies_per_token_ms, wall_s: float) -> float:
    met = sum(1 for t in latencies_per_token_ms if t <= GOODPUT_TPOT_MS)
    return met / wall_s if wall_s > 0 else 0.0


def run(print_fn=print, seed: int = 0) -> dict:
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = T.init_params(cfg, 0)
    trace = make_trace(seed)

    lock = ServeEngine(cfg, params, ServeConfig(
        max_len=MAX_LEN, n_slots=N_SLOTS, eos_id=0))
    tuner = KernelTuner(cache=TUNING_CACHE)
    cont = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=MAX_LEN, n_slots=N_SLOTS, eos_id=0), tuner=tuner)

    # pass 1 warms every jit trace; pass 2 is measured
    run_lockstep(lock, trace)
    lk = run_lockstep(lock, trace)

    run_continuous(cont, trace)
    cont.finished.clear()
    cont.refused.clear()
    cont.decode_steps = 0
    ct = run_continuous(cont, trace)
    m = cont.metrics()
    assert m["finished"] == len(trace) and m["refused"] == 0

    lock_rps = len(lk["done"]) / lk["wall_s"]
    cont_rps = m["finished"] / ct["wall_s"]
    speedup = cont_rps / lock_rps

    lock_tpot = [1e3 * d["latency_s"] / max(d["tokens"], 1)
                 for d in lk["done"]]
    cont_tpot = [1e3 * (r.t_finished - r.t_arrival) / max(r.n_generated, 1)
                 for r in cont.finished]

    out = {
        "lockstep_rps": lock_rps,
        "continuous_rps": cont_rps,
        "speedup": speedup,
        "ttft_p50_ms": m["ttft_p50_ms"],
        "ttft_p99_ms": m["ttft_p99_ms"],
        "tpot_p50_ms": m["tpot_p50_ms"],
        "tpot_p99_ms": m["tpot_p99_ms"],
        "goodput_lockstep": _goodput(lock_tpot, lk["wall_s"]),
        "goodput_continuous": _goodput(cont_tpot, ct["wall_s"]),
        "kv_bytes": m["kv_bytes"],
        "kv_dense_bytes": m["kv_dense_bytes"],
        "block_size": m["block_size"],
        "n_requests": len(trace),
    }
    print_fn(csv_line("serve/lockstep_rps", lock_rps,
                      f"n={len(trace)} slots={N_SLOTS}"))
    print_fn(csv_line("serve/continuous_rps", cont_rps,
                      f"speedup={speedup:.2f}x"))
    print_fn(csv_line("serve/ttft_p50_ms", out["ttft_p50_ms"], "continuous"))
    print_fn(csv_line("serve/ttft_p99_ms", out["ttft_p99_ms"], "continuous"))
    print_fn(csv_line("serve/tpot_p50_ms", out["tpot_p50_ms"], "continuous"))
    print_fn(csv_line("serve/tpot_p99_ms", out["tpot_p99_ms"], "continuous"))
    print_fn(csv_line("serve/goodput_lockstep_rps", out["goodput_lockstep"],
                      f"tpot<= {GOODPUT_TPOT_MS}ms"))
    print_fn(csv_line("serve/goodput_continuous_rps",
                      out["goodput_continuous"],
                      f"tpot<= {GOODPUT_TPOT_MS}ms"))
    print_fn(csv_line("serve/kv_pool_mb", out["kv_bytes"] / 1e6,
                      f"dense={out['kv_dense_bytes'] / 1e6:.3g}MB "
                      f"block={out['block_size']}"))
    return out


# ---------------------------------------------------------------------------
# chaos row: the same trace under a seeded fault plan (docs/serve.md
# "Failure semantics")
# ---------------------------------------------------------------------------

CHAOS_POOL_TOKENS = 96         # 6 usable blocks: real pool pressure
CHAOS_FAULT_STEPS = 80         # fault window (engine drains past it)
CHAOS_P_ALLOC = 0.25
CHAOS_P_BACKEND = 0.25
CHAOS_DEADLINE_MS = 60_000.0   # wired per request; never binds at bench scale


class _StaticForest:
    """Fitted-forest stand-in: keeps chaos admission zero-compile so the
    row measures fault handling, not compiler wall time."""

    fitted = True
    meta: dict = {}

    def __init__(self, tag):
        self.tag = tag
        self.default_device = get_device("host_cpu")

    def content_hash(self):
        return f"serve-bench-{self.tag}"

    def predict_queries(self, queries):
        n = len(queries)
        return (np.full(n, 50.0), np.full(n, 1.0))


def _chaos_engine(cfg, params, tuner, faults):
    # Two model-backed failover levels (primary → fallback forest) ahead
    # of the static floor, so injected backend crashes walk the whole
    # health chain.
    gate = CostEngine(EnsembleBackend([
        ForestBackend(lm=_StaticForest("primary")),
        ForestBackend(lm=_StaticForest("fallback")),
    ]))
    return ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=MAX_LEN, n_slots=N_SLOTS, eos_id=0, block_size=16,
        pool_tokens=CHAOS_POOL_TOKENS, gamma_budget_mb=1e6),
        cost_engine=gate, tuner=tuner, faults=faults)


def _chaos_plan(seed):
    return FaultPlan.seeded(seed + 1, n_steps=CHAOS_FAULT_STEPS,
                            p_alloc=CHAOS_P_ALLOC, p_backend=CHAOS_P_BACKEND)


def _reset(ce: ContinuousEngine) -> None:
    """Clear per-pass accounting (jit memos, tuner and health state stay
    warm) so the measured pass starts from a drained engine."""
    ce.finished.clear()
    ce.refused.clear()
    ce.expired.clear()
    ce.submitted = 0
    ce.decode_steps = 0
    ce._step = 0                # fault plans key on absolute step index
    ce._skew_s = 0.0
    ce._stall_run = 0
    ce.max_decode_stall_steps = 0
    ce.max_prefill_stall_tokens = 0
    ce.kv_gathered_bytes = 0.0
    ce.kv_touched_bytes = 0.0
    for k in ce.counters:
        ce.counters[k] = 0


def _arm(ce: ContinuousEngine, plan) -> None:
    """Point every injection site at a fresh plan for the measured pass."""
    ce.faults = plan
    ce.kv.faults = plan
    if ce.failover is not None:
        ce.failover.faults = plan


def run_chaos(print_fn=print, seed: int = 0) -> dict:
    """Serve the Poisson trace under a seeded fault plan and report the
    robustness row: zero lost requests, all terminal, goodput retention
    vs the identical fault-free cell."""
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = T.init_params(cfg, 0)
    trace = make_trace(seed)
    tuner = KernelTuner(cache=TUNING_CACHE)

    def measure(ce, plan):
        run_continuous(ce, trace, deadline_ms=CHAOS_DEADLINE_MS)  # warm jit
        _reset(ce)
        _arm(ce, plan)
        wall = run_continuous(ce, trace,
                              deadline_ms=CHAOS_DEADLINE_MS)["wall_s"]
        tpots = [1e3 * (r.t_finished - r.t_arrival) / max(r.n_generated, 1)
                 for r in ce.finished]
        return ce.metrics(), _goodput(tpots, wall)

    base_m, base_goodput = measure(
        _chaos_engine(cfg, params, tuner, faults=None), None)
    plan = _chaos_plan(seed)
    chaos_ce = _chaos_engine(cfg, params, tuner, faults=_chaos_plan(seed))
    chaos_m, chaos_goodput = measure(chaos_ce, plan)

    terminal = (chaos_m["finished"] + chaos_m["refused"]
                + chaos_m["expired"])
    ratio = (chaos_goodput / base_goodput if base_goodput > 0
             else float("inf"))
    out = {
        "n_requests": len(trace),
        "chaos_finished": chaos_m["finished"],
        "chaos_refused": chaos_m["refused"],
        "chaos_expired": chaos_m["expired"],
        "chaos_terminal": terminal,
        "chaos_lost": chaos_m["lost"],
        "faults_alloc_fired": chaos_m["faults"]["fired"]["alloc"],
        "faults_backend_fired": chaos_m["faults"]["fired"]["backend"],
        "preemptions": chaos_m["preemptions"],
        "resumes": chaos_m["resumes"],
        "failovers": chaos_m["health"]["failovers"],
        "degraded_steps": chaos_m["degraded_steps"],
        "goodput_faultfree": base_goodput,
        "goodput_chaos": chaos_goodput,
        "goodput_ratio": ratio,
        "pool_conserved": (chaos_ce.kv.n_free_blocks
                           == chaos_ce.kv.usable_blocks),
        "baseline_lost": base_m["lost"],
    }
    print_fn(csv_line("serve/chaos_lost", out["chaos_lost"],
                      f"terminal={terminal}/{len(trace)}"))
    print_fn(csv_line(
        "serve/chaos_faults_fired",
        out["faults_alloc_fired"] + out["faults_backend_fired"],
        f"alloc={out['faults_alloc_fired']} "
        f"backend={out['faults_backend_fired']}"))
    print_fn(csv_line(
        "serve/chaos_preemptions", out["preemptions"],
        f"resumes={out['resumes']} failovers={out['failovers']} "
        f"degraded_steps={out['degraded_steps']}"))
    print_fn(csv_line("serve/chaos_goodput_rps", chaos_goodput,
                      f"faultfree={base_goodput:.2f} ratio={ratio:.2f}"))
    return out


# ---------------------------------------------------------------------------
# chunked-prefill row: long prompts interleaved with running decodes
# (docs/serve.md "Chunked prefill")
# ---------------------------------------------------------------------------

CHUNK_MAX_LEN = 128
CHUNK_SLOTS = 4
CHUNK_SHORT = (5, 7, 9)        # decode-heavy requests already running…
CHUNK_LONG = (48, 80, 96)      # …when these long prompts arrive
CHUNK_SHORT_NEW = 110          # shorts pin their slots past the last long
CHUNK_LONG_NEW = 32            # decode budget >> chunk count (serving regime)
PREFILL_CHUNK = 32
CHUNK_REPEATS = 3              # interleaved measured repeats per mode


def _chunked_prompts(seed: int = 1):
    rng = np.random.default_rng(seed)
    mk = lambda n: rng.integers(2, 128, (n,)).astype(np.int32)
    return [mk(n) for n in CHUNK_SHORT], [mk(n) for n in CHUNK_LONG]


def _run_chunked_pass(ce: ContinuousEngine, shorts, longs):
    """Shorts admit first and start decoding; longs arrive three steps
    later, mid-stream — exactly the stall an unchunked prefill causes."""
    t0 = time.perf_counter()
    for p in shorts:
        ce.submit(Request(prompt=p, max_new_tokens=CHUNK_SHORT_NEW))
    for _ in range(3):
        ce.step()
    for p in longs:
        ce.submit(Request(prompt=p, max_new_tokens=CHUNK_LONG_NEW))
    while not ce.idle:
        ce.step()
    return time.perf_counter() - t0


def run_chunked(print_fn=print) -> dict:
    """One trace through two identically configured engines — chunked
    prefill on vs off.  Running-slot (short-request) p99 TPOT is the
    headline: unchunked, each long prompt's whole prefill lands between
    two of their tokens; chunked, the gap is bounded by one chunk.

    Both engines are warmed first, then the measured passes alternate
    between modes (``CHUNK_REPEATS`` each) and latency samples pool
    across repeats — host-CPU wall clock drifts enough within a process
    that back-to-back single passes mostly measure run order.  The
    *gated* quantities are deterministic step-count metrics (step-indexed
    TTFT, ``max_prefill_stall_tokens``); wall-clock ratios are reported
    for reference."""
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = T.init_params(cfg, 0)
    shorts, longs = _chunked_prompts()

    engines, out = {}, {}
    for tag, chunk in (("unchunked", None), ("chunked", PREFILL_CHUNK)):
        ce = ContinuousEngine(cfg, params, ContinuousConfig(
            max_len=CHUNK_MAX_LEN, n_slots=CHUNK_SLOTS, eos_id=0,
            prefill_chunk=chunk, seed=0))
        _run_chunked_pass(ce, shorts, longs)     # warm every jit trace
        engines[tag] = ce
        out[tag] = {"streams": sorted(tuple(r.tokens) for r in ce.finished),
                    "wall_s": 0.0, "ttft_ms": [], "short_tpot_ms": []}

    for _ in range(CHUNK_REPEATS):
        for tag, ce in engines.items():
            _reset(ce)
            out[tag]["wall_s"] += _run_chunked_pass(ce, shorts, longs)
            m = ce.metrics()
            assert (m["finished"] == len(shorts) + len(longs)
                    and m["lost"] == 0)
            out[tag]["ttft_ms"] += [1e3 * r.ttft_s for r in ce.finished
                                    if r.ttft_s is not None]
            # shorts were submitted first: identify by prompt length
            out[tag]["short_tpot_ms"] += [
                1e3 * r.tpot_s for r in ce.finished
                if r.prompt_len in CHUNK_SHORT and r.tpot_s is not None]

    for tag, ce in engines.items():
        m = ce.metrics()                         # last repeat's counters
        # step-indexed TTFT: deterministic (scheduler semantics, no
        # wall-clock noise) — identical on every repeat by construction
        ttft_steps = [r.step_first_token - r.step_submitted
                      for r in ce.finished if r.step_first_token is not None]
        out[tag].update({
            "short_tpot_p99_ms": float(np.percentile(
                out[tag].pop("short_tpot_ms"), 99)),
            "ttft_p99_ms": float(np.percentile(out[tag].pop("ttft_ms"), 99)),
            "ttft_steps_p99": float(np.percentile(ttft_steps, 99)),
            "prefill_chunks": m["prefill_chunks"],
            "max_decode_stall_steps": m["max_decode_stall_steps"],
            "max_prefill_stall_tokens": m["max_prefill_stall_tokens"],
            "kv_gathered_bytes": m["kv_gathered_bytes"],
            "kv_touched_bytes": m["kv_touched_bytes"],
            "lost": m["lost"],
        })

    out["tpot_ratio"] = (out["chunked"]["short_tpot_p99_ms"]
                         / max(out["unchunked"]["short_tpot_p99_ms"], 1e-9))
    out["ttft_ratio"] = (out["chunked"]["ttft_p99_ms"]
                         / max(out["unchunked"]["ttft_p99_ms"], 1e-9))
    out["ttft_steps_ratio"] = (out["chunked"]["ttft_steps_p99"]
                               / max(out["unchunked"]["ttft_steps_p99"], 1e-9))
    out["stall_tokens_ratio"] = (
        out["chunked"]["max_prefill_stall_tokens"]
        / max(out["unchunked"]["max_prefill_stall_tokens"], 1e-9))
    out["streams_equal"] = (out["chunked"]["streams"]
                            == out["unchunked"]["streams"])
    for tag in ("unchunked", "chunked"):
        del out[tag]["streams"]
    print_fn(csv_line("serve/chunked_short_tpot_p99_ms",
                      out["chunked"]["short_tpot_p99_ms"],
                      f"unchunked={out['unchunked']['short_tpot_p99_ms']:.2f} "
                      f"ratio={out['tpot_ratio']:.2f} (wall, reference)"))
    print_fn(csv_line("serve/chunked_ttft_p99_ms",
                      out["chunked"]["ttft_p99_ms"],
                      f"unchunked={out['unchunked']['ttft_p99_ms']:.2f} "
                      f"ratio={out['ttft_ratio']:.2f} (wall, reference)"))
    print_fn(csv_line("serve/chunked_ttft_steps_p99",
                      out["chunked"]["ttft_steps_p99"],
                      f"unchunked={out['unchunked']['ttft_steps_p99']:.0f} "
                      f"ratio={out['ttft_steps_ratio']:.3f} (gate <= 1.10)"))
    print_fn(csv_line("serve/chunked_prefill_stall_tokens",
                      out["chunked"]["max_prefill_stall_tokens"],
                      f"unchunked="
                      f"{out['unchunked']['max_prefill_stall_tokens']} "
                      f"(gate: chunked < unchunked — running-slot stall "
                      f"bounded by the chunk, not the prompt)"))
    print_fn(csv_line("serve/chunked_prefill_chunks",
                      out["chunked"]["prefill_chunks"],
                      f"chunk={PREFILL_CHUNK} stall="
                      f"{out['chunked']['max_decode_stall_steps']} "
                      f"streams_equal={out['streams_equal']}"))
    print_fn(csv_line(
        "serve/chunked_kv_touched_mb",
        out["chunked"]["kv_touched_bytes"] / 1e6,
        f"gathered={out['chunked']['kv_gathered_bytes'] / 1e6:.1f}MB "
        f"(decode kernel reads live blocks only)"))
    return out


if __name__ == "__main__":
    if os.path.exists(TUNING_CACHE):
        os.unlink(TUNING_CACHE)
    out = run()
    print(f"\ncontinuous vs lockstep speedup: {out['speedup']:.2f}x "
          f"(gate >= 1.0)")
    chunked = run_chunked()
    print(f"chunked prefill: ttft steps ratio="
          f"{chunked['ttft_steps_ratio']:.3f} (gate <= 1.10) "
          f"stall tokens {chunked['chunked']['max_prefill_stall_tokens']} vs "
          f"{chunked['unchunked']['max_prefill_stall_tokens']} "
          f"(gate: chunked < unchunked)")
    chaos = run_chaos()
    print(f"chaos: lost={chaos['chaos_lost']} "
          f"terminal={chaos['chaos_terminal']}/{chaos['n_requests']} "
          f"goodput ratio={chaos['goodput_ratio']:.2f} (gate >= 0.25)")
