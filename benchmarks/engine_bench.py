"""Engine benchmark: batched CostBackend.estimate vs per-candidate scalar
prediction, on a search-shaped workload (acceptance check for the unified
engine: ≥5× on a 100-candidate population).

Both paths do identical work per candidate — feature extraction + forest
prediction for (Γ, Φ) — but the batched path builds ONE feature matrix
(vectorized over every layer of every candidate) and walks the packed
forest once, while the scalar path pays N Python round-trips.  Also
reports the on-disk estimate cache hit path (second population visit) and
— so the bench trajectory records prediction ERROR, not just speed — the
calibrated-vs-uncalibrated AnalyticalBackend accuracy against the
checked-in profiler ground truth (ISSUE 2).

    PYTHONPATH=src python -m benchmarks.engine_bench
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core.dataset import Datapoint, DatasetCache
from repro.core.features import network_features
from repro.core.predictor import Perf4Sight
from repro.core.search import sample_subnetwork
from repro.engine import (
    AnalyticalBackend,
    CostEngine,
    CostQuery,
    EstimateCache,
    ForestBackend,
    ProfilerBackend,
    calibrate,
    default_workloads,
    evaluate_accuracy,
)
from repro.models.cnn import build_resnet50

from .common import csv_line

PROFILE_CACHE = os.path.join(os.path.dirname(__file__), "cache",
                             "cnn_profile.json")

POPULATION = 100
BS = 16
WM, HW = 0.25, 16


def _fitted_predictor(n_points: int = 60, seed: int = 0) -> Perf4Sight:
    """Fit on synthetic feature-driven targets (no profiling needed — this
    bench measures prediction throughput, not accuracy)."""
    from repro.core.pruning import pruned_model

    rng = np.random.default_rng(seed)
    dps = []
    for _ in range(n_points):
        level = float(rng.uniform(0, 0.9))
        bs = int(rng.integers(2, 33))
        m = pruned_model("resnet50", level, "uniform", seed=0,
                         width_mult=WM, input_hw=HW)
        f = network_features(m.conv_specs(), bs)
        dps.append(Datapoint(
            family="resnet50", level=level, strategy="uniform", bs=bs,
            width_mult=WM, input_hw=HW, seed=0,
            gamma_mb=5.0 + f[4] / 1e5, phi_ms=2.0 + f[14] / 1e7,
            features=[float(v) for v in f]))
    return Perf4Sight(n_estimators=100).fit(dps)


def run(print_fn=print, population: int = POPULATION, repeats: int = 3) -> dict:
    predictor = _fitted_predictor()
    base = build_resnet50(width_mult=WM, input_hw=HW)
    rng = np.random.default_rng(1)
    specs = [
        build_resnet50(widths=sample_subnetwork(base.widths, rng),
                       input_hw=HW).conv_specs()
        for _ in range(population)
    ]
    queries = [CostQuery(spec=s, bs=BS, stage="train") for s in specs]
    backend = ForestBackend(train=predictor)

    # warm both paths (forest packing, numpy dispatch)
    backend.estimate(queries[:2])
    predictor.predict(specs[0], BS)

    t_batch = min(
        _timed(lambda: backend.estimate(queries)) for _ in range(repeats))
    t_scalar = min(
        _timed(lambda: [predictor.predict(s, BS) for s in specs])
        for _ in range(repeats))
    speedup = t_scalar / t_batch

    # parity: the batched path must agree with the scalar path exactly
    ests = backend.estimate(queries)
    scalar = [predictor.predict(s, BS) for s in specs]
    max_dev = max(
        max(abs(e.gamma_mb - g), abs(e.phi_ms - p))
        for e, (g, p) in zip(ests, scalar))

    # cache path: second visit to the same population is pure dict lookups
    cache_path = "/tmp/perf4sight_engine_bench_cache.json"
    if os.path.exists(cache_path):
        os.unlink(cache_path)
    engine = CostEngine(backend, cache=EstimateCache(cache_path))
    engine.estimate(queries)
    t_cached = _timed(lambda: engine.estimate(queries))

    print_fn(csv_line("engine/scalar_ms_per_100", t_scalar * 1e3,
                      f"pop={population}"))
    print_fn(csv_line("engine/batched_ms_per_100", t_batch * 1e3,
                      f"speedup={speedup:.1f}x"))
    print_fn(csv_line("engine/cached_ms_per_100", t_cached * 1e3,
                      f"hits={engine.hits}"))
    print_fn(csv_line("engine/parity_max_abs_dev", max_dev, "expect=0"))
    ledger = ledger_breakdown_parity(print_fn)
    accuracy = calibration_accuracy(print_fn)
    return {"speedup": speedup, "t_scalar_s": t_scalar, "t_batch_s": t_batch,
            "t_cached_s": t_cached, "max_dev": max_dev,
            **ledger, **accuracy}


def ledger_breakdown_parity(print_fn=print) -> dict:
    """Cost-ledger parity on a compiled golden program: the per-op ledger's
    class sums must reproduce the legacy HloCost scalars (the costmodel
    contract every downstream breakdown relies on).  Reported as a
    RELATIVE deviation: the scalars are sequential ledger sums by
    construction, but the class-grouped re-sum associates float additions
    differently, which is only bit-exact while partial sums stay
    integer-representable (< 2^53) — production-scale cells can exceed
    that.  One tiny scan-over-dots compile — seconds, not minutes."""
    import jax
    import jax.numpy as jnp

    from repro.core.hlo_cost import parse_hlo_cost

    def f(x, ws):
        y = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]
        return y.sum()

    x = jnp.zeros((64, 64))
    ws = jnp.zeros((8, 64, 64))
    cost = parse_hlo_cost(jax.jit(jax.grad(f)).lower(x, ws).compile().as_text())
    sums = cost.by_class()
    dev = max(
        abs(sum(s["flops"] for s in sums.values()) - cost.flops)
        / max(abs(cost.flops), 1.0),
        abs(sum(s["hbm_bytes"] for s in sums.values()) - cost.hbm_bytes)
        / max(abs(cost.hbm_bytes), 1.0),
        abs(sum(s["collective_bytes"] for s in sums.values())
            - cost.collective_bytes) / max(abs(cost.collective_bytes), 1.0),
    )
    matmul_share = (sums.get("matmul", {}).get("flops", 0.0)
                    / cost.flops if cost.flops else 0.0)
    print_fn(csv_line("engine/ledger_breakdown_parity_dev", dev,
                      f"relative expect=0 records={len(cost.ledger)} "
                      f"matmul_flops_share={matmul_share:.2f}"))
    # Energy parity (docs/engine.md "Energy"): price the same ledger under
    # a power envelope and require the per-class joule sums to reproduce
    # the ledger aggregate (same relative tolerance, same reordering
    # caveat).
    from repro.engine import get_device
    from repro.engine.decompose import price_ledger_energy

    eled = price_ledger_energy(cost.ledger, get_device("tx2_like"))
    esums = eled.class_sums()
    edev = (abs(sum(s["energy_j"] for s in esums.values()) - eled.energy_j)
            / max(abs(eled.energy_j), 1e-30))
    print_fn(csv_line("engine/ledger_energy_parity_dev", edev,
                      f"relative expect=0 total={eled.energy_j:.3g}J"))
    return {"ledger_parity_dev": dev, "ledger_energy_parity_dev": edev}


def calibration_accuracy(print_fn=print) -> dict:
    """AnalyticalBackend prediction error vs profiler ground truth, before
    and after device calibration.

    Strictly read-only on the golden fixture: workloads missing from it are
    skipped (never live-profiled with bench-grade repeats and written back —
    that would pollute the ground truth tests/test_calibration.py asserts
    against)."""
    if not os.path.exists(PROFILE_CACHE):
        print_fn(csv_line("engine/calibration_skipped", 1.0, "no cache"))
        return {}
    cache = DatasetCache(PROFILE_CACHE)
    dps = [cache.get(w.key) for w in default_workloads()]
    missing = sum(d is None for d in dps)
    dps = [d for d in dps if d is not None]
    if missing:
        print_fn(csv_line("engine/calibration_workloads_missing", missing,
                          "fixture stale; skipped, not re-profiled"))
    if len(dps) < 3:
        print_fn(csv_line("engine/calibration_skipped", 1.0,
                          "fixture too sparse"))
        return {}
    backend = AnalyticalBackend()
    before = evaluate_accuracy(backend, dps)
    spec = calibrate(backend, ProfilerBackend(repeats=1, warmup=0), [],
                     datapoints=dps)
    after = evaluate_accuracy(backend, dps)
    print_fn(csv_line("engine/phi_mape_uncalibrated", before["phi_mape"],
                      f"device={spec.meta['base_device']}"))
    print_fn(csv_line("engine/phi_mape_calibrated", after["phi_mape"],
                      f"device={spec.name} fit={spec.meta['latency_fit']}"))
    # class-wise vs aggregate attribution rows (the cost-ledger refactor):
    # the applied fit is whichever MAPE is lower, so classwise-vs-aggregate
    # regressions show up here before they can skew phi_mape_calibrated
    print_fn(csv_line("engine/phi_mape_cal_aggregate",
                      spec.meta["phi_mape_aggregate"], "3-term fallback"))
    print_fn(csv_line("engine/phi_mape_cal_classwise",
                      spec.meta["phi_mape_classwise"],
                      "per-op-class columns"))
    print_fn(csv_line("engine/gamma_mape_uncalibrated", before["gamma_mape"],
                      f"n={before['n']}"))
    print_fn(csv_line("engine/gamma_mape_calibrated", after["gamma_mape"],
                      "target<=0.10"))
    out = {"phi_mape_uncal": before["phi_mape"],
           "phi_mape_cal": after["phi_mape"],
           "phi_mape_cal_aggregate": spec.meta["phi_mape_aggregate"],
           "phi_mape_cal_classwise": spec.meta["phi_mape_classwise"],
           "gamma_mape_uncal": before["gamma_mape"],
           "gamma_mape_cal": after["gamma_mape"]}
    # Energy fit accuracy (docs/engine.md "Energy"): same aggregate vs
    # class-wise pair as latency.  The golden fixture predates energy
    # measurement, so these targets are the watts-proxy integral —
    # energy_proxied says how many; the never-worse gate still binds.
    if spec.meta.get("energy_fit", "none") != "none":
        print_fn(csv_line("engine/energy_mape_cal_aggregate",
                          spec.meta["energy_mape_aggregate"],
                          f"proxied={spec.meta['energy_proxied']}"))
        print_fn(csv_line("engine/energy_mape_cal_classwise",
                          spec.meta["energy_mape_classwise"],
                          f"fit={spec.meta['energy_fit']}"))
        out["energy_mape_cal"] = spec.meta["energy_mape"]
        out["energy_mape_cal_aggregate"] = spec.meta["energy_mape_aggregate"]
    return out


def campaign_accuracy(print_fn=print, *, ledger_path: str | None = None,
                      subsample: int | None = None) -> dict:
    """LM-forest accuracy rows: run (or resume) the host-CPU smoke campaign,
    fit the forest, and compare held-out-cell MAPE against the uncalibrated
    analytical path (which pays an AOT compile per cell for its answer —
    the forest pays none).

    The ledger persists across bench runs (``/tmp``), so after the first
    nightly run this is resume + fit + a few analytical compiles.  Compiles
    real reduced-config steps: seconds per cold cell — nightly-gate
    territory, which is why ``run()`` doesn't call it."""
    from repro.campaign import (
        CampaignLedger,
        CampaignRunner,
        fit_lm_forest,
        smoke_plan,
    )
    from repro.engine.types import STAGE_INFER, STAGE_TRAIN

    ledger_path = ledger_path or "/tmp/perf4sight_campaign_smoke.jsonl"
    plan = smoke_plan(subsample=subsample)
    runner = CampaignRunner(plan, ledger_path, repeats=2, warmup=1)
    summary = runner.run_campaign(print_fn=lambda *_: None)
    print_fn(csv_line("campaign/cells_measured", summary["measured"],
                      f"grid={len(plan)} quarantined={summary['failed']}"))

    # Membership by cell KEY, not plan_hash: the persistent ledger may hold
    # records from an earlier plan revision whose cells still overlap this
    # one — those resumes are valid measurements of today's cells, while a
    # plan_hash filter would orphan them forever (the runner never
    # re-measures a recorded key).
    plan_keys = {c.key for c in plan.cells}
    records = [r for r in runner.ledger.records("ok")
               if r.get("key") in plan_keys]
    if len(records) < 6:
        print_fn(csv_line("campaign/skipped", 1.0, "grid too sparse"))
        return {}
    try:
        forest = fit_lm_forest(records, holdout_frac=0.25, seed=0)
    except ValueError as e:
        # The /tmp ledger deliberately persists across bench runs; a stale
        # one (fingerprint drift after a DeviceSpec change) must degrade to
        # the documented SKIP, not crash the gate.  Deleting the ledger
        # re-measures from scratch.
        print_fn(csv_line("campaign/skipped", 1.0, f"fit refused: {e}"))
        return {}
    meta = forest.meta

    # Cost-ledger rows: per-record breakdown parity (class sums re-sum to
    # the scalar aggregates; relative dev, since grouped float addition is
    # only bit-exact while partial sums stay integer-representable) +
    # class-wise vs aggregate HLO-constant fit MAPE.  Records predating
    # the v2 schema carry no breakdown; they are skipped (re-measuring
    # them is just deleting the ledger).
    with_classes = [r for r in records if r.get("cost_classes")]
    extra = {}
    if with_classes:
        def rel_dev(rec, key):
            total = sum(s.get(key, 0.0) for s in rec["cost_classes"].values())
            return abs(total - rec[key]) / max(abs(rec[key]), 1.0)

        parity_dev = max(
            max(rel_dev(r, k) for k in ("flops", "hbm_bytes",
                                        "collective_bytes"))
            for r in with_classes)
        print_fn(csv_line("campaign/breakdown_parity_dev", parity_dev,
                          f"relative expect=0 n={len(with_classes)}"))
        extra["breakdown_parity_dev"] = parity_dev
        from repro.campaign import fit_hlo_constants

        try:
            spec = fit_hlo_constants(with_classes)
        except ValueError as e:
            # e.g. a mixed v1/v2 ledger leaving < 4 executed v2 cells
            print_fn(csv_line("campaign/hlo_fit_skipped", 1.0, str(e)))
            spec = None
        if spec is not None:
            print_fn(csv_line("campaign/hlo_phi_mape_aggregate",
                              spec.meta["phi_mape_aggregate"],
                              "4-term fallback"))
            if spec.meta["phi_mape_classwise"] is not None:
                print_fn(csv_line("campaign/hlo_phi_mape_classwise",
                                  spec.meta["phi_mape_classwise"],
                                  f"fit={spec.meta['latency_fit']}"))
            # the APPLIED fit, RE-PRICED through the same decompose paths
            # the analytical backend uses (classwise_seconds for a
            # class-wise spec, the roofline terms for the fallback) — an
            # independent recomputation, so the never-worse gate catches a
            # pricing regression instead of comparing fit-time meta to
            # itself
            from repro.core.predictor import mape
            from repro.engine.decompose import (
                classwise_seconds,
                ledger_latency_columns,
                lm_roofline_terms,
            )

            executed = [r for r in with_classes if r.get("phi_ms", 0) > 0]
            phi_true = np.array([r["phi_ms"] for r in executed]) / 1e3
            coeffs = spec.class_coeffs.get("lm_latency")
            if coeffs:
                pred = classwise_seconds(ledger_latency_columns(
                    [r["cost_classes"] for r in executed]), coeffs)
            else:
                terms = lm_roofline_terms(
                    np.array([r["flops"] for r in executed]),
                    np.array([r["hbm_bytes"] for r in executed]),
                    np.array([r["collective_bytes"] for r in executed]),
                    spec)
                pred = spec.launch_overhead_s + sum(terms)
            applied = float(mape(np.asarray(pred), phi_true))
            print_fn(csv_line("campaign/hlo_phi_mape_applied", applied,
                              f"fit={spec.meta['latency_fit']} re-priced"))
            extra["hlo_phi_mape_applied"] = applied
            extra["hlo_phi_mape_aggregate"] = spec.meta["phi_mape_aggregate"]
            # Energy fit rows (v3 ledgers; v2 records carry no energy and
            # gate the fit off — skip, never fail, on a stale /tmp ledger).
            if spec.meta.get("energy_fit", "none") != "none":
                print_fn(csv_line("campaign/hlo_energy_mape_aggregate",
                                  spec.meta["energy_mape_aggregate"],
                                  "tied fallback"))
                print_fn(csv_line("campaign/hlo_energy_mape_applied",
                                  spec.meta["energy_mape"],
                                  f"fit={spec.meta['energy_fit']}"))
                extra["hlo_energy_mape_applied"] = spec.meta["energy_mape"]
                extra["hlo_energy_mape_aggregate"] = \
                    spec.meta["energy_mape_aggregate"]

    # Held-out cells through BOTH paths.  Same split seed as the fit, so
    # the forest has never seen these cells.
    from repro.campaign.fit import split_records

    _, heldout = split_records(records, holdout_frac=0.25, seed=0)
    queries = [
        CostQuery(arch=r["arch"], bs=r["shape"]["global_batch"],
                  seq=r["shape"]["seq_len"],
                  stage=STAGE_TRAIN if r["shape"]["kind"] == "train"
                  else STAGE_INFER,
                  reduced=True)
        for r in heldout
    ]
    analytical = AnalyticalBackend(reduced=True, lm_device="host_cpu")
    ests = analytical.estimate(queries)
    phi_true = np.array([r["phi_ms"] for r in heldout])
    gamma_true = np.array([r["gamma_mb"] for r in heldout])
    from repro.core.predictor import mape

    anal_phi = mape(np.array([e.phi_ms for e in ests]), phi_true)
    anal_gamma = mape(np.array([e.gamma_mb for e in ests]), gamma_true)
    out = {
        "forest_phi_mape": meta["holdout_phi_mape"],
        "forest_gamma_mape": meta["holdout_gamma_mape"],
        "analytical_phi_mape": anal_phi,
        "analytical_gamma_mape": anal_gamma,
        "n_heldout": len(heldout),
        **extra,
    }
    print_fn(csv_line("campaign/phi_mape_forest", out["forest_phi_mape"],
                      f"heldout={len(heldout)} zero-compile"))
    print_fn(csv_line("campaign/phi_mape_analytical", anal_phi,
                      "AOT compile per cell"))
    print_fn(csv_line("campaign/gamma_mape_forest", out["forest_gamma_mape"],
                      ""))
    print_fn(csv_line("campaign/gamma_mape_analytical", anal_gamma, ""))
    if meta.get("holdout_energy_mape") is not None:
        print_fn(csv_line("campaign/energy_mape_forest",
                          meta["holdout_energy_mape"], "zero-compile"))
        out["forest_energy_mape"] = meta["holdout_energy_mape"]
    return out


def planner_bench(print_fn=print, *, n_devices: int = 256) -> dict:
    """Auto-sharding planner rows (docs/planner.md): size of the layout
    space, wall-clock to price ALL of it through the engine, predicted
    speedup of the chosen layout over the hard-coded production mesh
    (1x16x16) — with the jax compiler booby-trapped for the whole run, so
    the zero-compile guarantee is measured, not assumed.

    The base query is answered by a planted forest (known Γ/Φ), making the
    rows deterministic and engine-path-realistic: the planner sees exactly
    what a campaign-fitted deployment would hand it."""
    from repro.engine import EnsembleBackend, get_device
    from repro.engine.backends import AnalyticalBackend as _AB
    from repro.planner import LayoutPlanner

    class _PlantedLMForest:
        """Fitted-forest stand-in: constant (Γ, Φ), no jax anywhere."""

        fitted = True
        meta: dict = {}

        def __init__(self, gamma_mb, phi_ms):
            self.gamma_mb, self.phi_ms = gamma_mb, phi_ms
            self.default_device = get_device("tpu_v5e")

        def content_hash(self):
            return f"planted-{self.gamma_mb}-{self.phi_ms}"

        def predict_queries(self, queries):
            n = len(queries)
            return (np.full(n, self.gamma_mb), np.full(n, self.phi_ms))

    compiles = {"n": 0}
    orig = _AB._compile_arch

    def boom(*a, **k):
        compiles["n"] += 1
        raise AssertionError("planner pricing invoked the jax compiler")

    _AB._compile_arch = boom
    try:
        engine = CostEngine(
            EnsembleBackend([
                ForestBackend(lm=_PlantedLMForest(40_000.0, 1000.0)),
                AnalyticalBackend(),
            ]),
            device=get_device("tpu_v5e"))
        planner = LayoutPlanner(engine)
        t0 = time.perf_counter()
        plan = planner.plan("qwen3-4b", "train_4k", n_devices, n_micro=8)
        wall_s = time.perf_counter() - t0
    finally:
        _AB._compile_arch = orig

    chosen = plan.chosen
    default = plan.decision_for("1x16x16") if n_devices == 256 else None
    speedup = (default.phi_ms / chosen.phi_ms
               if (chosen and default) else float("nan"))
    print_fn(csv_line("planner/layouts_enumerated", plan.meta["n_layouts"],
                      f"devices={n_devices} ranked={plan.meta['n_ranked']} "
                      f"refused={plan.meta['n_refused']}"))
    print_fn(csv_line("planner/pricing_wall_ms", wall_s * 1e3,
                      f"target<1000 compiles={compiles['n']}"))
    if chosen and default:
        print_fn(csv_line("planner/chosen_vs_default_speedup", speedup,
                          f"chosen={chosen.layout.descriptor} "
                          f"phi={chosen.phi_ms:.2f}ms vs 1x16x16 "
                          f"{default.phi_ms:.2f}ms"))
    return {
        "layouts": plan.meta["n_layouts"],
        "wall_s": wall_s,
        "compiles": compiles["n"],
        "chosen": chosen.layout.descriptor if chosen else None,
        "chosen_phi_ms": chosen.phi_ms if chosen else float("inf"),
        "default_phi_ms": default.phi_ms if default else float("nan"),
        "speedup": speedup,
    }


def collective_calibration(print_fn=print, *, ledger_path: str | None = None
                           ) -> dict:
    """Collective-coefficient rows: run the >1-device calibration grid
    (``campaign.plan.collective_smoke_plan`` — the same cells on 1x1,
    2x1 and 1x2 meshes) in a subprocess with a forced 2-device host, then
    fit the HLO constants over the ledger and report whether the
    collective column entered the fit on real measurements.

    Subprocess because ``xla_force_host_platform_device_count`` must be
    set before jax initializes — this process has already done so.  The
    /tmp ledger persists, so after the first nightly run this is
    resume + fit.  Skips (empty dict) instead of failing when the
    subprocess or the fit can't run — same degraded contract as
    ``campaign_accuracy``."""
    import json
    import subprocess
    import sys
    import textwrap

    ledger_path = ledger_path or "/tmp/perf4sight_campaign_collective.jsonl"
    script = textwrap.dedent(f"""
        from repro.campaign import CampaignRunner
        from repro.campaign.plan import collective_smoke_plan
        plan = collective_smoke_plan()
        runner = CampaignRunner(plan, {ledger_path!r}, repeats=2, warmup=1)
        out = runner.run_campaign()
        print("CELLS", out["measured"], out["failed"], out["remaining"])
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        print_fn(csv_line("campaign/collective_skipped", 1.0,
                          f"measure subprocess failed: "
                          f"{proc.stderr.strip().splitlines()[-1:] or '?'}"))
        return {}

    from repro.campaign import CampaignLedger, fit_hlo_constants
    from repro.campaign.plan import collective_smoke_plan

    plan_keys = {c.key for c in collective_smoke_plan().cells}
    records = [r for r in CampaignLedger(ledger_path).records("ok")
               if r.get("key") in plan_keys]
    try:
        spec = fit_hlo_constants(records)
    except ValueError as e:
        print_fn(csv_line("campaign/collective_skipped", 1.0,
                          f"fit refused: {e}"))
        return {}
    meta = spec.meta
    coeff = (meta["collective_coeff_classwise"]
             if meta["collective_coeff_classwise"] is not None
             else meta["collective_coeff_aggregate"])
    print_fn(csv_line("campaign/collective_cells", meta["collective_cells"],
                      f"of {len(records)} fitted (meshes 1x1/2x1/1x2)"))
    print_fn(csv_line("campaign/collective_column_fitted",
                      float(meta["collective_column_fitted"]),
                      f"classwise_columns={len(meta['classwise_columns'])}"))
    print_fn(csv_line("campaign/collective_coeff_s_per_byte", coeff,
                      json.dumps({"aggregate":
                                  meta["collective_coeff_aggregate"]})))
    return {
        "collective_cells": meta["collective_cells"],
        "collective_column_fitted": meta["collective_column_fitted"],
        "collective_coeff": coeff,
        "collective_coeff_aggregate": meta["collective_coeff_aggregate"],
        "n_records": len(records),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    out = run()
    planner_bench()
    print(f"\nbatched speedup: {out['speedup']:.1f}x "
          f"(target >=5x on {POPULATION} candidates)")
