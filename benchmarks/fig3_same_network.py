"""Fig. 3 reproduction: same base network in training and test sets.

Per network: train the forests on random-pruned levels {0,30,50,70,90}%,
test on held-out levels with (a) random pruning (bars "Rand") and (b) L1
pruning (bars "L1").  Paper result: mean Γ error ≤ 9.15 %, Φ ≤ 14.7 %
(overall means 5.53 % / 9.37 %)."""

from __future__ import annotations

from repro.core.dataset import DEFAULT_TEST_LEVELS, DEFAULT_TRAIN_LEVELS

from .common import cache, csv_line, fit_predictor, grid_points

NETWORKS = ("resnet18", "mobilenetv2", "squeezenet", "mnasnet")


def run(print_fn=print) -> dict:
    """Two fits per network: the paper-faithful pure random forest
    (``forest``) and the beyond-paper ridge+forest hybrid (``hybrid``,
    default predictor) — both reported, per the reproduce-then-improve
    protocol."""
    from repro.core.predictor import Perf4Sight

    c = cache()
    results = {}
    all_errs = {("forest", "gamma"): [], ("forest", "phi"): [],
                ("hybrid", "gamma"): [], ("hybrid", "phi"): []}
    for net in NETWORKS:
        train = grid_points(c, net, DEFAULT_TRAIN_LEVELS, "random")
        models = {
            "forest": Perf4Sight(n_estimators=100, hybrid=False).fit(train),
            "hybrid": Perf4Sight(n_estimators=100, hybrid=True).fit(train),
        }
        for strat in ("random", "l1"):
            test = grid_points(c, net, DEFAULT_TEST_LEVELS, strat)
            tag = "Rand" if strat == "random" else "L1"
            for mname, model in models.items():
                rep = model.evaluate(test)
                results[(net, tag, mname)] = rep
                all_errs[(mname, "gamma")].append(rep.gamma_mape)
                all_errs[(mname, "phi")].append(rep.phi_mape)
                print_fn(csv_line(f"fig3/{net}/{tag}/{mname}/gamma_err_pct",
                                  rep.gamma_mape * 100, f"n={rep.n}"))
                print_fn(csv_line(f"fig3/{net}/{tag}/{mname}/phi_err_pct",
                                  rep.phi_mape * 100, f"n={rep.n}"))
    for mname in ("forest", "hybrid"):
        g = float(sum(all_errs[(mname, "gamma")]) / 8 * 100)
        p = float(sum(all_errs[(mname, "phi")]) / 8 * 100)
        print_fn(csv_line(f"fig3/mean/{mname}/gamma_err_pct", g, "paper=5.53"))
        print_fn(csv_line(f"fig3/mean/{mname}/phi_err_pct", p, "paper=9.37"))
        results[("mean", mname)] = (g, p)
    return results


if __name__ == "__main__":
    run()
