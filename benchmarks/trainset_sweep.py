"""§6.1 reproduction: tuning the training-set size on AlexNet.

Train-set sizes 1..8 pruning levels (T₁={0} … T₈={0,10,20,30,50,60,70,90}),
test on the remaining levels.  Paper: error falls from 33–74 % at |T|=1 and
plateaus at 3–6 % from T={0,30,50,70,90} — which is why T₅ is the training
set everywhere else."""

from __future__ import annotations

from repro.core.dataset import PAPER_ALL_LEVELS

from .common import cache, csv_line, fit_predictor, grid_points

T_SETS = [
    (0.0,),
    (0.0, 0.50),
    (0.0, 0.50, 0.90),
    (0.0, 0.30, 0.50, 0.90),
    (0.0, 0.30, 0.50, 0.70, 0.90),
    (0.0, 0.20, 0.30, 0.50, 0.70, 0.90),
    (0.0, 0.10, 0.20, 0.30, 0.50, 0.70, 0.90),
    (0.0, 0.10, 0.20, 0.30, 0.50, 0.60, 0.70, 0.90),
]


def run(print_fn=print) -> list[tuple[int, float, float]]:
    c = cache()
    all_pts = grid_points(c, "alexnet", PAPER_ALL_LEVELS, "random")
    by_level = {}
    for dp in all_pts:
        by_level.setdefault(round(dp.level, 2), []).append(dp)
    out = []
    for T in T_SETS:
        train, test = [], []
        tset = {round(l, 2) for l in T}
        for lvl, dps in by_level.items():
            (train if lvl in tset else test).extend(dps)
        rep = fit_predictor(train).evaluate(test)
        out.append((len(T), rep.gamma_mape * 100, rep.phi_mape * 100))
        print_fn(csv_line(f"trainset/|T|={len(T)}/gamma_err_pct",
                          rep.gamma_mape * 100))
        print_fn(csv_line(f"trainset/|T|={len(T)}/phi_err_pct",
                          rep.phi_mape * 100))
    return out


if __name__ == "__main__":
    run()
