"""Shared helpers for the paper-table benchmarks (cache IO, fitting,
error reporting)."""

from __future__ import annotations

import os

import numpy as np

from repro.core.dataset import (
    DEFAULT_BATCH_SIZES,
    DEFAULT_TEST_LEVELS,
    DEFAULT_TRAIN_LEVELS,
    DatasetCache,
    Datapoint,
    GridSpec,
    collect_grid,
)
from repro.core.predictor import Perf4Sight

CACHE_PATH = os.path.join(os.path.dirname(__file__), "cache", "cnn_profile.json")
DRYRUN_PATH = os.path.join(os.path.dirname(__file__), "cache", "dryrun.jsonl")


def cache() -> DatasetCache:
    return DatasetCache(CACHE_PATH)


def grid_points(c: DatasetCache, family: str, levels, strategy: str,
                batch_sizes=DEFAULT_BATCH_SIZES, *, collect_missing: bool = True,
                ) -> list[Datapoint]:
    """Fetch (or lazily profile) the datapoints of one grid."""
    spec = GridSpec(family, tuple(levels), strategy, tuple(batch_sizes))
    return collect_grid(spec, c, verbose=False) if collect_missing else [
        d for d in (c.get(Datapoint(
            family=family, level=l, strategy=strategy, bs=b,
            width_mult=spec.width_mult, input_hw=spec.input_hw, seed=spec.seed,
            gamma_mb=0, phi_ms=0).key) for l in levels for b in batch_sizes)
        if d is not None
    ]


def fit_predictor(train_dps, seed=0, n_estimators=100) -> Perf4Sight:
    return Perf4Sight(n_estimators=n_estimators, seed=seed).fit(train_dps)


def csv_line(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"
