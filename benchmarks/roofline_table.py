"""Roofline table (deliverable g): reads the dry-run JSONL and prints the
per-(arch × shape × mesh) roofline terms, dominant bottleneck, usefulness
ratio and HBM fit."""

from __future__ import annotations

import json
import os

from .common import DRYRUN_PATH, csv_line


def load_reports(path: str = DRYRUN_PATH) -> list[dict]:
    if not os.path.exists(path):
        return []
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            key = (d.get("arch"), d.get("shape"), d.get("mesh"))
            out[key] = d  # last write wins (re-runs supersede)
    return list(out.values())


def run(print_fn=print) -> list[dict]:
    reports = load_reports()
    if not reports:
        print_fn("roofline/no_data,0,run repro.launch.dryrun first")
        return []
    header = (f"{'arch':>24s} {'shape':<12s} {'mesh':<9s} "
              f"{'C(ms)':>10s} {'M(ms)':>10s} {'X(ms)':>10s} "
              f"{'dom':<10s} {'useful':>6s} {'HBM(GB)':>8s} fit")
    print_fn(header)
    for d in sorted(reports, key=lambda d: (d.get("mesh", ""), d.get("arch", ""),
                                            d.get("shape", ""))):
        if d.get("skipped"):
            print_fn(f"{d['arch']:>24s} {d['shape']:<12s} {d['mesh']:<9s} "
                     f"SKIP: {d['skipped']}")
            continue
        if d.get("failed"):
            print_fn(f"{d['arch']:>24s} {d['shape']:<12s} {d['mesh']:<9s} FAILED")
            continue
        print_fn(
            f"{d['arch']:>24s} {d['shape']:<12s} {d['mesh']:<9s} "
            f"{d['compute_s'] * 1e3:10.2f} {d['memory_s'] * 1e3:10.2f} "
            f"{d['collective_s'] * 1e3:10.2f} {d['dominant']:<10s} "
            f"{d['useful_ratio']:6.2f} {d['per_device_hbm_gb']:8.2f} "
            f"{'OK' if d['fits_hbm'] else 'OVER'}"
        )
        print_fn(csv_line(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/step_ms",
            d["step_s"] * 1e3,
            f"dom={d['dominant']} useful={d['useful_ratio']:.2f}",
        ))
    return reports


if __name__ == "__main__":
    run()
