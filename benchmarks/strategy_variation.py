"""§6.2 reproduction: 100 random pruning strategies on MobileNetV2 @ 50 %.

The paper prunes MobileNetV2 to 50 % with 100 random strategies (uniform +
early/middle/late-biased) at batch size 80, and the models — trained on the
uniform-random strategy only — predict Γ and Φ with 1.32 % / 9.90 % mean
error despite 4423±1597 MB / 1741±871 ms attribute spread.

Scaled: ``N_STRATEGIES`` strategies at one batch size (each needs a real
profile, ~20 s/pt on this host)."""

from __future__ import annotations

import numpy as np

from repro.core import pruning as pr
from repro.core.dataset import DEFAULT_TRAIN_LEVELS, Datapoint
from repro.core.features import network_features
from repro.core.profiler import profile_training
from repro.engine import CostQuery, ForestBackend
from repro.models.cnn import build_mobilenetv2

from .common import cache, csv_line, fit_predictor, grid_points

N_STRATEGIES = 8
BS = 16
LEVEL = 0.5
WM, HW = 0.25, 16


def _strategy_widths(canonical, i: int, rng) -> dict:
    profiles = ("uniform", "early", "middle", "late")
    if i < len(profiles):
        if profiles[i] == "uniform":
            return pr.prune_widths(canonical, LEVEL, "uniform", rng)
        return pr.prune_widths(canonical, LEVEL, profiles[i], rng)
    return pr.random_profile_widths(canonical, LEVEL, rng)


def run(print_fn=print) -> dict:
    c = cache()
    train = grid_points(c, "mobilenetv2", DEFAULT_TRAIN_LEVELS, "random")
    model = fit_predictor(train)

    base = build_mobilenetv2(width_mult=WM, input_hw=HW)
    gammas, phis, specs = [], [], []
    for i in range(N_STRATEGIES):
        rng = np.random.default_rng(1000 + i)
        widths = _strategy_widths(base.widths, i, rng)
        m = build_mobilenetv2(widths=widths, input_hw=HW)
        m.name = f"mbv2-strat{i}"
        key = Datapoint(family="mobilenetv2", level=LEVEL, strategy=f"strat{i}",
                        bs=BS, width_mult=WM, input_hw=HW, seed=0,
                        gamma_mb=0, phi_ms=0)
        hit = c.get(key.key)
        if hit is None:
            res = profile_training(m, BS)
            key.gamma_mb, key.phi_ms = res.gamma_mb, res.phi_ms
            key.features = [float(v) for v in
                            network_features(m.conv_specs(), BS)]
            c.put(key)
            c.flush()
            hit = key
        specs.append(m.conv_specs())
        gammas.append(hit.gamma_mb)
        phis.append(hit.phi_ms)

    # one batched engine call for all strategies (no scalar round-trips)
    ests = ForestBackend(train=model).estimate(
        [CostQuery(spec=s, bs=BS, stage="train") for s in specs])
    errs_g = [abs(e.gamma_mb - g) / g for e, g in zip(ests, gammas)]
    errs_p = [abs(e.phi_ms - p) / p for e, p in zip(ests, phis)]

    out = {
        "gamma_mean": float(np.mean(gammas)), "gamma_std": float(np.std(gammas)),
        "phi_mean": float(np.mean(phis)), "phi_std": float(np.std(phis)),
        "gamma_err": float(np.mean(errs_g)) * 100,
        "phi_err": float(np.mean(errs_p)) * 100,
    }
    print_fn(csv_line("strategies/gamma_spread_mb", out["gamma_std"],
                      f"mean={out['gamma_mean']:.1f}"))
    print_fn(csv_line("strategies/phi_spread_ms", out["phi_std"],
                      f"mean={out['phi_mean']:.1f}"))
    print_fn(csv_line("strategies/gamma_err_pct", out["gamma_err"], "paper=1.32"))
    print_fn(csv_line("strategies/phi_err_pct", out["phi_err"], "paper=9.90"))
    return out


if __name__ == "__main__":
    run()
