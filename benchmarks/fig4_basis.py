"""Fig. 4 reproduction: training on a *basis* of networks (ResNet18,
MobileNetV2, SqueezeNet), predicting for networks not in the basis
(ResNet50, MnasNet, GoogLeNet) as well as the basis networks themselves.

Paper finding: basis networks stay close to Fig. 3 error; unseen networks
degrade by +5.6 pp (ResNet50), +2.55 pp (MnasNet), +16 pp (GoogLeNet) —
sharing building blocks with the basis is what matters (App. C)."""

from __future__ import annotations

from repro.core.dataset import DEFAULT_TEST_LEVELS, DEFAULT_TRAIN_LEVELS

from .common import cache, csv_line, fit_predictor, grid_points

BASIS = ("resnet18", "mobilenetv2", "squeezenet")
UNSEEN = ("mnasnet", "resnet50", "googlenet")


def run(print_fn=print) -> dict:
    c = cache()
    train = []
    for net in BASIS:
        train += grid_points(c, net, DEFAULT_TRAIN_LEVELS, "random")
    model = fit_predictor(train)
    results = {}
    for net in BASIS + UNSEEN:
        for strat in ("random", "l1"):
            test = grid_points(c, net, DEFAULT_TEST_LEVELS, strat)
            rep = model.evaluate(test)
            tag = "Rand" if strat == "random" else "L1"
            kind = "basis" if net in BASIS else "unseen"
            results[(net, tag)] = rep
            print_fn(csv_line(f"fig4/{net}/{tag}/gamma_err_pct",
                              rep.gamma_mape * 100, kind))
            print_fn(csv_line(f"fig4/{net}/{tag}/phi_err_pct",
                              rep.phi_mape * 100, kind))
    return results


if __name__ == "__main__":
    run()
