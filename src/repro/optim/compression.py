"""Top-k gradient compression with error feedback (distributed-optimization
trick for DCI-limited cross-pod gradient exchange).

Only the largest-|g| ``ratio`` fraction of each gradient tensor is exchanged;
the residual is accumulated locally into an error-feedback buffer and added
back next step (Stich et al.-style memory), which preserves convergence.

At 2-pod scale the pod-axis all-reduce moves ``ratio`` of the bytes (values +
indices); the sparsification itself is expressed with jnp.top_k so GSPMD can
run it shard-locally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_grads", "compression_stats"]


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(g: jax.Array, ratio: float) -> jax.Array:
    if g.ndim == 0 or g.size <= 8:
        return jnp.ones_like(g, dtype=bool)
    k = max(1, int(g.size * ratio))
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh)


def compress_grads(grads, error_state, *, ratio: float = 0.1):
    """Returns (sparse_grads, new_error_state).  sparse = dense tensor with
    (1-ratio) of entries zeroed — zeros cost nothing after RLE/indices on the
    wire; the roofline models bytes as ratio × dense."""

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        mask = _topk_mask(acc, ratio)
        sent = jnp.where(mask, acc, 0.0)
        return sent.astype(g.dtype), acc - sent

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    err = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return sent, err


def compression_stats(grads, ratio: float) -> dict:
    total = sum(g.size for g in jax.tree.leaves(grads))
    return {
        "dense_bytes": total * 4,
        "compressed_bytes": int(total * ratio) * (4 + 4),  # value + index
        "ratio": ratio,
    }
