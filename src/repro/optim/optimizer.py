"""Optimizers: AdamW and SGD-momentum, with global-norm clipping and
schedules.  Pure ``jax.tree`` transforms so GSPMD shards the optimizer state
exactly like (or more finely than) the parameters — see
``repro.distributed.sharding.zero_extend`` for the ZeRO-style state sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "init_opt_state", "apply_updates", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"          # adamw | sgdm
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9        # sgdm
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def init_opt_state(params, cfg: OptimizerConfig) -> dict:
    """f32 slots.  AdamW: m, v; SGD-m: m only.  ``step`` is a scalar."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state: dict = {"step": jnp.zeros((), jnp.int32), "m": jax.tree.map(f32, params)}
    if cfg.kind == "adamw":
        state["v"] = jax.tree.map(f32, params)
    return state


def apply_updates(params, grads, opt_state, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gn
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    metrics["lr"] = lr

    if cfg.kind == "adamw":
        b1, b2 = cfg.beta1, cfg.beta2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         opt_state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         opt_state["v"], grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}, metrics

    if cfg.kind == "sgdm":
        m = jax.tree.map(lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
                         opt_state["m"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, m
        )
        return new_params, {"step": step, "m": m}, metrics

    raise ValueError(cfg.kind)
