"""The per-op cost IR: one record schema from HLO parse to autotuner rank.

perf4sight's defining move is modelling training cost from a *per-layer
decomposition* of the network (paper §5.2); this module is that idea
applied to our own pipeline.  Every cost producer — the trip-count-aware
HLO parse (``core/hlo_cost``), the kernel tiling models
(``kernels/autotune.KernelCost`` is a thin view over :class:`OpCost`) —
emits the same record, and every consumer — calibration NNLS columns,
campaign features, roofline breakdowns, tuner ranking — reads it, so a
blown prediction can finally be attributed to an op class instead of
disappearing into three whole-step aggregates.

Contracts:

* **Parity** — summing a ledger's records left-to-right reproduces the
  legacy ``HloCost`` aggregates exactly (``CostLedger.flops`` et al. ARE
  how ``parse_hlo_cost`` computes its scalars; tests assert the sums are
  bit-identical on the golden HLO fixtures).  Record ``flops``/``bytes``
  are *effective* totals — the trip multiplier is already applied — with
  ``trip_multiplier`` kept alongside for attribution.
* **Taxonomy** — :data:`OP_CLASSES` is the closed op-class vocabulary;
  :func:`classify_op` is the single mapping from an HLO opcode (plus any
  fused-in flops) to a class.  Calibration columns, campaign histogram
  features and the breakdown CLI all iterate this tuple, in this order.
* **Persistence** — NPZ (packed columns) or JSON (inspectable), chosen by
  extension, written atomically via ``core/fileio``.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, replace

__all__ = [
    "OP_CLASSES",
    "OpCost",
    "CostLedger",
    "classify_op",
]

# Closed vocabulary, most-structured first.  "matmul" and "conv" carry the
# MXU/FMA work; "collective" the inter-device traffic; "reduction" the
# tree-shaped ops; "data_movement" pure layout/copy traffic; "elementwise"
# the fused pointwise bulk (XLA loop fusions land here); "other" anything
# opaque (custom calls).
OP_CLASSES: tuple[str, ...] = (
    "matmul", "conv", "collective", "reduction", "data_movement",
    "elementwise", "other",
)

_COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}
_REDUCTION_OPS = {"reduce", "reduce-window", "sort", "select-and-scatter"}
_DATA_MOVEMENT_OPS = {
    "copy", "copy-start", "copy-done", "transpose", "broadcast", "reshape",
    "slice", "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "pad", "reverse", "iota",
}
_OPAQUE_OPS = {"custom-call", "infeed", "outfeed", "rng", "rng-bit-generator"}


def classify_op(opcode: str, *, dot_flops: float = 0.0,
                conv_flops: float = 0.0) -> str:
    """Map an HLO opcode to its :data:`OP_CLASSES` entry.

    ``dot_flops``/``conv_flops`` let a wrapper instruction (fusion, call)
    that *contains* contraction work classify as the work it feeds: a
    fused matmul's HBM traffic belongs to the matmul class, not to
    "whatever a fusion is".

    Both async halves classify together: ``all-reduce-start`` and
    ``all-reduce-done`` are collective-class (the ring-model collective
    *bytes* are still counted once, on the start — only the done op's HBM
    traffic attribution is at stake here).
    """
    base = opcode.replace("-start", "").replace("-done", "")
    if base in _COLLECTIVE_OPS:
        return "collective"
    if base == "dot" or (dot_flops > 0 and dot_flops >= conv_flops):
        return "matmul"
    if base == "convolution" or conv_flops > 0:
        return "conv"
    if base in _REDUCTION_OPS:
        return "reduction"
    if base in _DATA_MOVEMENT_OPS:
        return "data_movement"
    if base in _OPAQUE_OPS or not base:
        return "other"
    return "elementwise"


@dataclass(frozen=True, kw_only=True)
class OpCost:
    """Cost of one op (one scheduled HLO instruction, or one kernel launch).

    Keyword-only: every field has a default, so a positional call could
    silently bind costs to the wrong slots (``OpCost(1e9, ...)`` putting
    flops into ``op``) — and subclasses (``kernels.autotune.KernelCost``)
    inherit the same guarantee.

    ``flops``/``hbm_bytes``/``collective_bytes`` are effective totals with
    ``trip_multiplier`` already applied (a dot inside a 12-trip scanned
    layer records its full 12× contribution and ``trip_multiplier=12``).
    ``energy_j`` is the op's *dynamic* energy in joules — zero until a
    device prices the ledger (``engine.decompose.price_ledger_energy``);
    the static/idle term is per-step, not per-op, so it never appears in
    a record.  ``vmem_bytes`` is the on-chip working set — zero for parsed
    HLO records, populated by the kernel tiling models.  ``origin`` names
    the computation (or kernel) the op came from; ``count`` supports
    merged group records (``CostLedger.class_sums``)."""

    op: str = ""
    op_class: str = "other"
    dtype: str = ""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    energy_j: float = 0.0
    vmem_bytes: float = 0.0
    trip_multiplier: float = 1.0
    origin: str = ""
    count: int = 1

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "OpCost":
        known = set(cls.__dataclass_fields__)
        return cls(**{k: v for k, v in d.items() if k in known})


# Numeric NPZ columns (strings ride in the JSON header).  ``energy_j``
# is last so pre-energy NPZ files load with the column defaulted.
_NUM_COLS = ("flops", "hbm_bytes", "collective_bytes", "vmem_bytes",
             "trip_multiplier", "count", "energy_j")
_STR_COLS = ("op", "op_class", "dtype", "origin")

# One class bucket — what class_sums/merge_class_sums accumulate.
_ZERO_BUCKET = {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0,
                "energy_j": 0.0, "count": 0}


def _empty_class_sums() -> dict[str, dict]:
    return {cls: dict(_ZERO_BUCKET) for cls in OP_CLASSES}


def _drop_zero_classes(sums: dict[str, dict]) -> dict[str, dict]:
    return {cls: s for cls, s in sums.items() if any(s.values())}


class CostLedger:
    """Ordered container of :class:`OpCost` records with groupby views.

    Aggregates (``flops``, ``hbm_bytes``, ``collective_bytes``) are plain
    left-to-right sums over the records — the parity contract with the
    legacy scalar totals.  ``class_sums`` / ``top_k`` are the attribution
    views every downstream consumer shares."""

    def __init__(self, records: "list[OpCost] | None" = None):
        self.records: list[OpCost] = list(records) if records else []

    # -- building ----------------------------------------------------------

    def append(self, record: OpCost) -> None:
        self.records.append(record)

    def extend(self, records) -> None:
        self.records.extend(records)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    def __eq__(self, other) -> bool:
        return isinstance(other, CostLedger) and self.records == other.records

    # -- aggregates (the parity contract) ----------------------------------

    @property
    def flops(self) -> float:
        total = 0.0
        for r in self.records:
            total += r.flops
        return total

    @property
    def hbm_bytes(self) -> float:
        total = 0.0
        for r in self.records:
            total += r.hbm_bytes
        return total

    @property
    def collective_bytes(self) -> float:
        total = 0.0
        for r in self.records:
            total += r.collective_bytes
        return total

    @property
    def energy_j(self) -> float:
        total = 0.0
        for r in self.records:
            total += r.energy_j
        return total

    def totals(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "energy_j": self.energy_j}

    # -- attribution views --------------------------------------------------

    def class_sums(self, *, keep_zero: bool = False) -> dict[str, dict]:
        """Per-class aggregate: ``{cls: {flops, hbm_bytes, collective_bytes,
        count}}`` in :data:`OP_CLASSES` order (zero classes dropped unless
        ``keep_zero``)."""
        sums = _empty_class_sums()
        for r in self.records:
            s = sums.setdefault(r.op_class, dict(_ZERO_BUCKET))
            s["flops"] += r.flops
            s["hbm_bytes"] += r.hbm_bytes
            s["collective_bytes"] += r.collective_bytes
            s["energy_j"] += r.energy_j
            s["count"] += r.count
        return sums if keep_zero else _drop_zero_classes(sums)

    @staticmethod
    def merge_class_sums(sums_list, *, keep_zero: bool = False
                         ) -> dict[str, dict]:
        """Merge many ``class_sums()``-shaped dicts (e.g. the
        ``cost_classes`` of every campaign record) into one — the same
        bucket fields and zero-class filter as :meth:`class_sums`, so an
        aggregated view can never drift from the ledger's own."""
        merged = _empty_class_sums()
        for sums in sums_list:
            for cls, s in (sums or {}).items():
                t = merged.setdefault(cls, dict(_ZERO_BUCKET))
                for k in _ZERO_BUCKET:
                    t[k] += s.get(k, 0)
        return merged if keep_zero else _drop_zero_classes(merged)

    def top_k(self, k: int = 5, by: str = "hbm_bytes") -> list[OpCost]:
        """The ``k`` most expensive records by one attribute — 'which op
        blew the prediction' in one call."""
        if by not in OpCost.__dataclass_fields__:
            raise KeyError(f"unknown OpCost attribute {by!r}")
        return sorted(self.records, key=lambda r: getattr(r, by),
                      reverse=True)[:k]

    def scaled(self, mult: float) -> "CostLedger":
        """A copy with every record's effective totals × ``mult`` (e.g.
        whole-module ledger → per-microbatch)."""
        return CostLedger([
            replace(r, flops=r.flops * mult, hbm_bytes=r.hbm_bytes * mult,
                    collective_bytes=r.collective_bytes * mult,
                    energy_j=r.energy_j * mult)
            for r in self.records
        ])

    # -- persistence (core/fileio contract) ---------------------------------

    def to_json_dict(self) -> dict:
        return {"schema": 1, "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_json_dict(cls, d: dict) -> "CostLedger":
        return cls([OpCost.from_dict(r) for r in d.get("records", [])])

    def save(self, path: str) -> None:
        """Atomic persist: ``.npz`` packs numeric columns (+ JSON header
        for the string columns), anything else writes inspectable JSON."""
        from repro.core.fileio import atomic_write_bytes, atomic_write_json

        if path.endswith(".npz"):
            import numpy as np

            arrays = {
                col: np.asarray([getattr(r, col) for r in self.records],
                                dtype=np.int64 if col == "count"
                                else np.float64)
                for col in _NUM_COLS
            }
            header = json.dumps({
                col: [getattr(r, col) for r in self.records]
                for col in _STR_COLS
            })
            arrays["ledger_header"] = np.frombuffer(header.encode(),
                                                    dtype=np.uint8)
            atomic_write_bytes(path, lambda f: np.savez_compressed(f, **arrays),
                               suffix=".npz")
            return
        atomic_write_json(path, self.to_json_dict())

    @classmethod
    def load(cls, path: str) -> "CostLedger":
        if path.endswith(".npz"):
            import numpy as np

            with np.load(path) as z:
                header = json.loads(bytes(z["ledger_header"].tobytes()).decode())
                n = len(header[_STR_COLS[0]]) if header[_STR_COLS[0]] else \
                    int(z[_NUM_COLS[0]].shape[0])
                # Tolerant of columns added after a file was written
                # (pre-energy NPZs lack "energy_j" — defaulted to 0).
                cols = {c: z[c] for c in _NUM_COLS if c in z}
                return cls([
                    OpCost(
                        op=header["op"][i], op_class=header["op_class"][i],
                        dtype=header["dtype"][i], origin=header["origin"][i],
                        flops=float(cols["flops"][i]),
                        hbm_bytes=float(cols["hbm_bytes"][i]),
                        collective_bytes=float(cols["collective_bytes"][i]),
                        energy_j=float(cols["energy_j"][i])
                        if "energy_j" in cols else 0.0,
                        vmem_bytes=float(cols["vmem_bytes"][i]),
                        trip_multiplier=float(cols["trip_multiplier"][i]),
                        count=int(cols["count"][i]),
                    )
                    for i in range(n)
                ])
        with open(path) as f:
            return cls.from_json_dict(json.load(f))
