"""Per-op cost IR shared by every cost producer and consumer (see ledger.py)."""

from repro.costmodel.ledger import OP_CLASSES, CostLedger, OpCost, classify_op

__all__ = ["OP_CLASSES", "CostLedger", "OpCost", "classify_op"]
