"""mamba2-780m — 48L d_model=1536, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280.  [arXiv:2405.21060; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # no attention heads (attn-free)
    n_kv_heads=1,
    d_ff=0,               # SSD blocks replace MLPs (mamba2 has no FFN)
    vocab=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,      # d_inner = 2*1536 = 3072 → 48 SSD heads
    ssm_expand=2,
    tie_embeddings=True,
)
