"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; every workload
shape is a :class:`ShapeSpec`.  The dry-run, roofline and perf4sight-LM
layers all consume (ArchConfig × ShapeSpec × mesh) cells.

``reduced()`` derives the same-family smoke-test config (small layers/width,
few experts, tiny vocab) that runs a real step on CPU.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "TRAIN_SHAPES", "DECODE_SHAPES",
           "mesh_split"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def mesh_split(mesh_dims: tuple[int, ...]) -> tuple[int, int, int]:
    """(n_devices, n_data, n_model) of a mesh-dims tuple.

    THE single statement of the mesh convention: the model axis is last,
    everything before it (pod, data) is data parallelism.  Registry cell
    filtering, campaign featurization and the dry-run axis naming all
    assume this order — change it here or nowhere."""
    n_model = mesh_dims[-1]
    n_data = 1
    for d in mesh_dims[:-1]:
        n_data *= d
    return n_data * n_model, n_data, n_model


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # None → d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    attn_bias: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int | None = None      # per-expert FFN width (defaults to d_ff)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- hybrid interleave (jamba): 1 attention mixer per `period` layers ---
    hybrid_period: int = 0           # 0 → not hybrid
    hybrid_attn_index: int = 4       # which sublayer in the period is attention
    moe_every: int = 0               # every Nth sublayer uses MoE FFN (jamba: 2)
    # --- attention variant ---
    attention: str = "full"          # full | chunked | none
    chunk_size: int = 8192           # local-attention window (llama4 long ctx)
    # --- modality frontends (stubs per brief) ---
    frontend: str | None = None      # vision_stub | audio_stub
    n_prefix: int = 0                # prefix embeddings (vlm patches)
    n_encoder_layers: int = 0        # enc-dec (whisper)
    n_audio_frames: int = 0          # encoder input length (whisper stub)
    # --- numerics ---
    dtype: str = "bfloat16"
    max_seq_len: int = 524_288

    # ------------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def moe_d_ff_(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab rounded up so the (vocab, d) embedding shards evenly on any
        mesh axis up to ``multiple`` — standard practice (noted in DESIGN)."""
        return _round_up(self.vocab, multiple)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token cell? (SSM/hybrid/chunked attn)"""
        return self.family in ("ssm", "hybrid") or self.attention == "chunked"

    # --- parameter counting (MODEL_FLOPS = 6·N·D needs N) ------------------

    def param_count(self, active_only: bool = False) -> int:
        D, Dh = self.d_model, self.head_dim_
        V = self.padded_vocab()
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V
        per_attn = D * self.n_heads * Dh + 2 * D * self.n_kv_heads * Dh \
            + self.n_heads * Dh * D
        per_mlp = 3 * D * self.d_ff  # SwiGLU
        e_count = self.experts_per_token if active_only else self.n_experts
        per_moe = D * self.n_experts + 3 * D * self.moe_d_ff_ * max(e_count, 1)
        d_inner = self.ssm_expand * D
        ssm_heads = d_inner // self.ssm_head_dim if self.ssm_state else 0
        per_ssm = (
            D * (2 * d_inner + 2 * self.ssm_state + ssm_heads)  # in_proj
            + self.ssm_conv_width * (d_inner + 2 * self.ssm_state)
            + d_inner * D                                        # out_proj
            + 2 * ssm_heads                                      # A_log, D
        ) if self.ssm_state else 0

        if self.family == "ssm":
            n += self.n_layers * (per_ssm + 2 * D)
        elif self.hybrid_period:
            n_attn = self.n_layers // self.hybrid_period
            n_ssm = self.n_layers - n_attn
            n_moe = self.n_layers // self.moe_every if self.moe_every else 0
            n_mlp = self.n_layers - n_moe
            n += n_attn * per_attn + n_ssm * per_ssm + n_moe * per_moe \
                + n_mlp * per_mlp + self.n_layers * 2 * D
        elif self.is_moe:
            n += self.n_layers * (per_attn + per_moe + 2 * D)
        else:
            n += self.n_layers * (per_attn + per_mlp + 2 * D)
        if self.n_encoder_layers:
            n += self.n_encoder_layers * (per_attn + per_mlp + 2 * D)
            n += self.n_layers * per_attn  # decoder cross-attention
        return int(n)

    # --- smoke-scale config -------------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, self.hybrid_period or 2),
            d_model=128,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=128 if self.is_moe else None,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            chunk_size=64,
            n_prefix=8 if self.n_prefix else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_audio_frames=16 if self.n_audio_frames else 0,
            max_seq_len=256,
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

TRAIN_SHAPES = ("train_4k",)
DECODE_SHAPES = ("decode_32k", "long_500k")
