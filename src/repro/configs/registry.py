"""Registry of assigned architectures (``--arch <id>``)."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec

__all__ = ["ARCH_IDS", "get_config", "get_shape", "all_cells"]

_MODULES = {
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "command-r-35b": "repro.configs.command_r_35b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_MODULES)}")
    cfg = importlib.import_module(_MODULES[arch]).CONFIG
    return cfg.reduced() if reduced else cfg


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def cell_supported(
    cfg: ArchConfig, shape: ShapeSpec, mesh_shape: tuple[int, ...] | None = None
) -> tuple[bool, str]:
    """Whether (arch × shape) is runnable; reason when skipped (DESIGN §4).

    With ``mesh_shape`` (the mesh dims, model axis last, data/pod axes
    before it) the check also covers GSPMD layout constraints, so the
    profiling campaign can drop unlowered-able cells at *plan* time instead
    of quarantining them one compile failure at a time."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    if mesh_shape:
        from repro.configs.base import mesh_split

        _, n_data, n_model = mesh_split(tuple(mesh_shape))
        if shape.global_batch % max(n_data, 1):
            return False, (f"batch {shape.global_batch} not divisible by "
                           f"{n_data} data-parallel devices")
        if cfg.n_kv_heads % max(n_model, 1) and cfg.d_model % max(n_model, 1):
            return False, (f"neither kv heads ({cfg.n_kv_heads}) nor d_model "
                           f"({cfg.d_model}) shard over {n_model} model devices")
    return True, ""


def all_cells() -> list[tuple[str, str, bool, str]]:
    """All 40 (arch × shape) cells with their supported/skip status."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, why = cell_supported(cfg, shape)
            out.append((arch, shape_name, ok, why))
    return out
