"""whisper-tiny — enc-dec, 4L encoder + 4L decoder, d_model=384 6H d_ff=1536
vocab=51865; conv audio frontend is a STUB (input_specs provides frame
embeddings).  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    frontend="audio_stub",
    n_audio_frames=1500,      # 30 s of audio after the conv frontend
    attn_bias=True,
    tie_embeddings=True,
)
