"""paligemma-3b — 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216;
SigLIP vision frontend is a STUB (input_specs provides patch embeddings).
[arXiv:2407.07726; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    frontend="vision_stub",
    n_prefix=256,             # 16x16 SigLIP patches at 224px
    tie_embeddings=True,
    rope_theta=1e4,
)
