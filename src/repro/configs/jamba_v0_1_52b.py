"""jamba-v0.1-52b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
Mamba+attention 1:7 interleave, MoE 16 experts top-2 every other layer.
[arXiv:2403.19887; hf]

Note (DESIGN §4): Jamba's mamba sublayers are Mamba-1; we implement the
Mamba-2 SSD form for all SSM mixers in this framework (the assigned
mamba2-780m fixes the SSD formulation; using it uniformly keeps one
well-tested kernel).  State size matches Jamba (16).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,              # MoE FFN on every 2nd sublayer
    hybrid_period=8,          # 1 attention mixer per 8 layers
    hybrid_attn_index=4,
    ssm_state=16,
    ssm_head_dim=64,          # d_inner = 8192 → 128 SSD heads
    ssm_expand=2,
)
