"""llama4-scout-17b-a16e — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, chunked (iRoPE-style) local attention
enabling long context.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab=202048,
    n_experts=16,
    experts_per_token=1,
    attention="chunked",      # TPU-idiomatic analogue of iRoPE chunking
    chunk_size=8192,
    rope_theta=5e5,
)
