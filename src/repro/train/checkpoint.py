"""Fault-tolerant sharded checkpointing.

Layout (one directory per step)::

    <dir>/step_000100.tmp/   — written first
        shard_00000.npz      — flattened {path: array} chunks
        manifest.json        — tree structure, shapes, dtypes, step
    <dir>/step_000100/       — atomic rename when complete

Properties required at 1000-node scale, all tested:
  * atomic visibility (a crash mid-write never leaves a readable-but-corrupt
    checkpoint; the .tmp suffix is ignored by ``latest_step``),
  * keep-N garbage collection,
  * mesh-shape-agnostic restore: arrays are stored logically (unsharded) and
    re-placed under any new mesh/sharding on load — elastic re-scaling is a
    restore with different shardings,
  * exact resume (step counter stored in the manifest).

bfloat16 leaves are stored via a uint16 view (npz has no native bf16).
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_STEP_RE = re.compile(r"^step_(\d{9})$")
_SHARD_LEAVES = 64  # leaves per npz shard file


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, state, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    keys = sorted(flat)
    manifest = {"step": step, "leaves": {}, "n_shards": 0}
    shard, shard_idx = {}, 0
    for i, k in enumerate(keys):
        a = flat[k]
        entry = {"shape": list(a.shape), "dtype": str(a.dtype), "shard": shard_idx}
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
            entry["bf16"] = True
        manifest["leaves"][k] = entry
        shard[k.replace("/", "__")] = a
        if len(shard) >= _SHARD_LEAVES or i == len(keys) - 1:
            np.savez(os.path.join(tmp, f"shard_{shard_idx:05d}.npz"), **shard)
            shard, shard_idx = {}, shard_idx + 1
    manifest["n_shards"] = shard_idx
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit

    # keep-N GC (never deletes the one just written)
    steps = list_steps(directory)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
    return final


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for n in os.listdir(directory):
        m = _STEP_RE.match(n)
        if m and os.path.exists(os.path.join(directory, n, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int | None = None, *,
                       template=None, shardings=None):
    """Load a checkpoint.  ``template`` (a pytree with the target structure)
    rebuilds the tree; ``shardings`` (matching pytree of Sharding) re-places
    arrays on a possibly different mesh (elastic restore)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    shards: dict[int, dict] = {}
    flat = {}
    for key, entry in manifest["leaves"].items():
        si = entry["shard"]
        if si not in shards:
            shards[si] = np.load(os.path.join(path, f"shard_{si:05d}.npz"))
        a = shards[si][key.replace("/", "__")]
        if entry.get("bf16"):
            a = a.view(jnp.bfloat16)
        flat[key] = a

    if template is None:
        return manifest["step"], flat

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    flat_shardings = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (path_keys, leaf) in enumerate(paths):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = flat[key]
        if flat_shardings is not None:
            a = jax.device_put(a, flat_shardings[i])
        leaves.append(a)
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)
