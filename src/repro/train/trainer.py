"""Fault-tolerant training loop.

Production behaviours, all exercised by tests:
  * auto-resume from the latest valid checkpoint (atomic dirs — a killed run
    restarts exactly),
  * periodic checkpointing with keep-N GC,
  * straggler monitor: per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the EWMA are logged and counted (on a real fleet
    this feeds the scheduler's replace-node decision),
  * failure injection (``fail_at_step``) for crash/restart tests,
  * optional top-k gradient compression with error feedback across the
    slow (pod/DCI) axis,
  * perf4sight admission gate: refuse to even build the jitted step when the
    predicted per-device HBM exceeds the budget (the paper's §6.4 safety
    argument, applied to the launcher).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import TokenPipeline, make_batch
from repro.models import transformer as T
from repro.optim.compression import compress_grads, init_error_state
from repro.optim.optimizer import OptimizerConfig, apply_updates, init_opt_state
from repro.train import checkpoint as ckpt

__all__ = ["TrainerConfig", "Trainer", "StragglerMonitor"]


@dataclass
class TrainerConfig:
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    grad_compression: float | None = None    # top-k ratio, None = off
    fail_at_step: int | None = None          # failure injection (tests)
    seed: int = 0


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (straggler mitigation's
    detection half — the mitigation itself is a scheduler action)."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2):
        self.factor, self.alpha = factor, alpha
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged.append((step, dt))
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt
        )
        return slow


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        opt_cfg: OptimizerConfig | None = None,
        tcfg: TrainerConfig | None = None,
        *,
        mesh=None,
        state_shardings=None,
        admission=None,   # callable(cfg, shape) -> (ok, info)
    ):
        self.cfg = cfg
        self.shape = shape
        self.opt_cfg = opt_cfg or OptimizerConfig(kind="adamw", warmup_steps=10,
                                                  total_steps=1000)
        self.tcfg = tcfg or TrainerConfig()
        self.mesh = mesh
        self.monitor = StragglerMonitor(self.tcfg.straggler_factor)
        self.history: list[dict] = []

        if admission is not None:
            ok, info = admission(cfg, shape)
            if not ok:
                raise RuntimeError(f"admission denied: {info}")

        self._compression = self.tcfg.grad_compression
        self._step_fn = jax.jit(self._make_step(), donate_argnums=(0,))

    # ------------------------------------------------------------------

    def _make_step(self):
        cfg, opt_cfg, ratio = self.cfg, self.opt_cfg, self._compression

        def step_fn(state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                T.loss_fn, has_aux=True)(state["params"], batch, cfg)
            if ratio is not None:
                grads, err = compress_grads(grads, state["err"], ratio=ratio)
            new_params, new_opt, om = apply_updates(
                state["params"], grads, state["opt"], opt_cfg)
            out = {"params": new_params, "opt": new_opt}
            if ratio is not None:
                out["err"] = err
            return out, {"loss": loss, "ce": metrics["ce"], **om}

        return step_fn

    def init_state(self) -> dict:
        params = T.init_params(self.cfg, self.tcfg.seed)
        params = jax.tree.map(jnp.asarray, params)
        state = {"params": params,
                 "opt": init_opt_state(params, self.opt_cfg)}
        if self._compression is not None:
            state["err"] = init_error_state(params)
        return state

    def restore_or_init(self) -> tuple[int, dict]:
        d = self.tcfg.ckpt_dir
        if d and ckpt.latest_step(d) is not None:
            template = self.init_state()
            step, state = ckpt.restore_checkpoint(d, template=template)
            return step + 1, state
        return 0, self.init_state()

    # ------------------------------------------------------------------

    def train(self, num_steps: int) -> dict:
        start, state = self.restore_or_init()
        for step in range(start, num_steps):
            if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = make_batch(self.cfg, self.shape, step, self.tcfg.seed)
            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.monitor.observe(step, dt)
            rec = {"step": step, "loss": float(metrics["loss"]),
                   "ce": float(metrics["ce"]), "dt": dt, "straggler": slow}
            self.history.append(rec)
            if self.tcfg.ckpt_dir and (step + 1) % self.tcfg.ckpt_every == 0:
                ckpt.save_checkpoint(self.tcfg.ckpt_dir, step, state,
                                     keep=self.tcfg.keep)
        if self.tcfg.ckpt_dir and num_steps > start:
            ckpt.save_checkpoint(self.tcfg.ckpt_dir, num_steps - 1, state,
                                 keep=self.tcfg.keep)
        return {"state": state, "history": self.history,
                "stragglers": self.monitor.flagged}
