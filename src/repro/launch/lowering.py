"""AOT lowering of (arch × shape × mesh) cells — shared by dry-run and
profiling campaigns.

This is the compile machinery that used to live inside ``launch/dryrun.py``,
extracted so library callers (``repro.campaign.runner``) can lower cells
without importing the dry-run module — whose import mutates ``XLA_FLAGS``
to fake a 512-device host, exactly what a timing campaign on the real
device must NOT inherit.  Importing this module never touches jax device
state.

``compile_cell`` returns the compiled executable (for timing /
``memory_analysis`` / HLO parsing); ``lower_cell`` wraps it into the
roofline report the dry-run prints.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ShapeSpec
from repro.configs.registry import get_config
from repro.core.roofline import model_flops_for_cell, roofline_from_compiled
from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.optim.optimizer import OptimizerConfig, apply_updates

__all__ = ["make_train_step", "compile_cell", "lower_cell"]


def _opt_state_specs_like(cfg, opt_cfg: OptimizerConfig):
    """ShapeDtypeStructs for the optimizer state (f32 slots)."""
    pspecs = T.param_specs(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    opt = {"step": jax.ShapeDtypeStruct((), jnp.int32), "m": jax.tree.map(f32, pspecs)}
    if opt_cfg.kind == "adamw":
        opt["v"] = jax.tree.map(f32, pspecs)
    return opt


def make_train_step(cfg, opt_cfg: OptimizerConfig, *, microbatches: int = 1,
                    seq_chunk: int | None = None):
    """Real train step; perf knobs:

    microbatches — gradient accumulation via lax.scan over batch slices
        (activation temp ∝ 1/M; the per-microbatch gradient all-reduce
        overlaps the next microbatch's compute in XLA's schedule).
    seq_chunk — chunked CE loss (see transformer.loss_fn).
    """

    def loss(params, batch):
        return T.loss_fn(params, batch, cfg, seq_chunk=seq_chunk)

    def train_step(state, batch):
        if microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                state["params"], batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]),
                batch)

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(
                    state["params"], mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, l_sum), _ = jax.lax.scan(acc_fn, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            l = l_sum / microbatches
        new_params, new_opt, om = apply_updates(
            state["params"], grads, state["opt"], opt_cfg
        )
        return {"params": new_params, "opt": new_opt}, {"loss": l, **om}

    return train_step


def compile_cell(
    cfg,
    shape: ShapeSpec,
    mesh,
    *,
    opt_kind: str = "adamw",
    fsdp: bool | None = None,
    microbatches: int = 1,
    seq_chunk: int | None = None,
    sp: bool = True,
    donate: bool = True,
):
    """Lower + compile one (cfg × shape) cell on ``mesh``.

    Returns ``(compiled, input_specs, compile_s)``: the AOT executable, the
    ShapeDtypeStruct tree of its positional arguments (so a caller can
    materialize inputs and time real executions), and the wall-clock
    compile time.  ``donate=False`` keeps every input buffer alive across
    calls — required when the same materialized arguments are executed
    repeatedly for timing.
    """
    opt_cfg = OptimizerConfig(kind=opt_kind)
    from repro.models import layers as L

    L.set_hint_mesh(mesh, sp=sp)  # activation sharding hints (MoE buffers etc.)

    t0 = time.perf_counter()
    if shape.kind == "train":
        specs = T.input_specs(cfg, shape)
        state_specs = {"params": specs["params"],
                       "opt": _opt_state_specs_like(cfg, opt_cfg)}
        state_sh = sh.to_named(mesh, sh.state_pspecs(cfg, mesh, kind=opt_kind, fsdp=fsdp))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=microbatches,
                            seq_chunk=seq_chunk),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else (),
        )
        args = (state_specs, specs["batch"])
    elif shape.kind == "prefill":
        specs = T.input_specs(cfg, shape)
        param_sh = sh.to_named(mesh, sh.param_pspecs(cfg, mesh, fsdp=bool(fsdp)))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        cache_sh = sh.to_named(mesh, sh.cache_pspecs(cfg, shape, mesh))
        max_len = shape.seq_len + cfg.n_prefix

        def prefill_fn(params, batch):
            return T.prefill(params, batch, cfg, max_len=max_len)

        out_sh = {"logits": None, "cache": cache_sh, "cache_len": None}
        if cfg.n_encoder_layers:
            out_sh["memory"] = None
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh),
                     out_shardings=out_sh)
        args = (specs["params"], specs["batch"])
    else:  # decode
        specs = T.input_specs(cfg, shape)
        param_sh = sh.to_named(mesh, sh.param_pspecs(cfg, mesh, fsdp=False))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        cache_sh = sh.to_named(mesh, sh.cache_pspecs(cfg, shape, mesh))

        def decode_fn(params, cache, batch):
            return T.decode_step(params, cache, batch, cfg)

        fn = jax.jit(
            decode_fn,
            in_shardings=(param_sh, cache_sh, batch_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,) if donate else (),
        )
        args = (specs["params"], specs["cache"], specs["batch"])

    with mesh:
        compiled = fn.lower(*args).compile()
    compile_s = time.perf_counter() - t0
    return compiled, args, compile_s


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    mesh_desc: str,
    *,
    opt_kind: str = "adamw",
    remat: bool = True,
    fsdp: bool | None = None,
    print_analysis: bool = True,
    microbatches: int = 1,
    seq_chunk: int | None = None,
    sp: bool = True,
):
    """Lower + compile one cell on ``mesh``; return the roofline report."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    compiled, _, compile_s = compile_cell(
        cfg, shape, mesh, opt_kind=opt_kind, fsdp=fsdp,
        microbatches=microbatches, seq_chunk=seq_chunk, sp=sp,
    )

    if print_analysis:
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        print({k: v for k, v in dict(ca).items()
               if k in ("flops", "bytes accessed")})

    return roofline_from_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_desc=mesh_desc,
        n_devices=mesh.devices.size,
        model_flops_total=model_flops_for_cell(cfg, shape),
        compile_s=compile_s,
    )
