"""Training launcher with perf4sight admission control.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --ckpt-dir /tmp/ck

Before building the jitted step, the launcher asks the unified cost engine
(``repro.engine``) for the training-step footprint — the AnalyticalBackend's
AOT ``lower().compile()`` + trip-count-aware HLO roofline, no execution —
and refuses jobs over the budget: the paper's §6.4 safety property.
Estimates are cached on disk (``--estimate-cache``), so re-launching the
same cell readmits instantly without recompiling.
"""

from __future__ import annotations

import argparse
import json

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCH_IDS, get_config
from repro.optim.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", type=float, default=None)
    ap.add_argument("--device", default=None,
                    help="device registry name (host_cpu, tx2_like, tpu_v5e) "
                         "or path to a calibrated DeviceSpec (.json/.npz) — "
                         "sets the admission roofline constants and, absent "
                         "--memory-budget-gb, the memory capacity budget")
    ap.add_argument("--memory-budget-gb", type=float, default=None,
                    help="admission gate: refuse if predicted HBM (inflated "
                         "by --admission-margin) exceeds this; defaults to "
                         "the --device capacity when a device is given")
    ap.add_argument("--energy-budget-j", type=float, default=None,
                    help="admission gate: refuse if the predicted step "
                         "energy (inflated by --admission-margin) exceeds "
                         "this many joules — the edge power/thermal "
                         "envelope check")
    ap.add_argument("--admission-margin", type=float, default=0.1,
                    help="safety margin applied to the predicted footprint "
                         "before comparing to the budget (0 = exact)")
    ap.add_argument("--estimate-cache", default=None,
                    help="JSON path for the engine's on-disk estimate cache")
    ap.add_argument("--lm-forest", default=None,
                    help="campaign-fitted LM forest (.npz/.json from "
                         "`python -m repro.campaign fit`): admission is then "
                         "answered by the forest with zero compiles, falling "
                         "back to the analytical AOT path only for cells the "
                         "forest cannot answer")
    ap.add_argument("--auto-mesh", type=int, default=None, metavar="N",
                    help="let the auto-sharding planner (repro.planner) pick "
                         "the cheapest data×model layout of N devices for "
                         "this cell (max_pipe=1: the trainer has no pipeline "
                         "schedule); builds the winning mesh when N devices "
                         "are visible, otherwise reports the plan and trains "
                         "unsharded")
    ap.add_argument("--n-micro", type=int, default=8,
                    help="microbatches per step assumed by the planner's "
                         "pipeline-bubble model")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    admission = None
    plan = None
    if (args.memory_budget_gb is not None or args.device is not None
            or args.lm_forest is not None or args.energy_budget_j is not None
            or args.auto_mesh is not None):
        from repro.engine import (
            AnalyticalBackend,
            CostEngine,
            CostQuery,
            EnsembleBackend,
            ForestBackend,
            resolve_device,
        )

        device = resolve_device(args.device) if args.device else None
        chain = []
        if args.lm_forest:
            from repro.campaign import LMForest

            chain.append(ForestBackend(lm=LMForest.load(args.lm_forest)))
        chain.append(AnalyticalBackend(reduced=args.reduced, lm_device=device))
        engine = CostEngine(
            EnsembleBackend(chain),
            cache=args.estimate_cache,
            device=device,
        )

        if args.auto_mesh is not None:
            from repro.planner import LayoutPlanner

            plan = LayoutPlanner(engine, reduced=args.reduced).plan(
                args.arch, shape, args.auto_mesh,
                max_pipe=1, n_micro=args.n_micro)
            print(plan.table(top=5))
            if plan.chosen is None:
                raise RuntimeError(
                    f"auto-mesh: no runnable layout of {args.auto_mesh} "
                    f"devices for {args.arch} × {shape.name}; refused: "
                    + "; ".join(f"{r.layout.descriptor}: {r.reason}"
                                for r in plan.refused))

        def admission(cfg, shape):
            ok, info = engine.admit(
                CostQuery(arch=args.arch, bs=shape.global_batch,
                          seq=shape.seq_len, stage="train",
                          reduced=args.reduced),
                gamma_budget_mb=(args.memory_budget_gb * 1e3
                                 if args.memory_budget_gb is not None else None),
                energy_budget_j=args.energy_budget_j,
                safety_margin=args.admission_margin,
            )
            info["predicted_gb"] = info["gamma_mb"] / 1e3
            info["predicted_energy_j"] = info["energy_j"]
            if device is not None:
                info["device"] = device.name
            if plan is not None and plan.chosen is not None:
                # The planner-selected layout's predicted costs, reported
                # at admission time alongside the single-device gate.
                c = plan.chosen
                info["auto_mesh"] = {
                    "layout": c.layout.descriptor,
                    "phi_ms": c.phi_ms,
                    "gamma_mb": c.gamma_mb,
                    "energy_j": c.energy_j,
                }
            return ok, info

    # Pre-tune kernel block sizes for this cell (abstract trace, no
    # compile): the jitted step then reads every block size from the
    # device-keyed tuning cache instead of the hand-picked constants.
    from repro.models.transformer import warm_autotune

    warm = warm_autotune(cfg, batch_size=args.batch, seq_len=args.seq,
                         stages=("train",))
    if warm["misses"]:
        print(f"autotune: {warm['misses']} kernel configs tuned "
              f"({warm['hits']} cached)")

    # Build the planner's winning mesh when the host actually has the
    # devices; a short host still gets the full plan report above (the
    # structured MeshSpecError names the deficit if forced).
    mesh = None
    if plan is not None and plan.chosen is not None:
        import jax

        from repro.launch.mesh import make_mesh

        chosen = plan.chosen.layout
        if len(jax.devices()) >= chosen.n_devices:
            mesh = make_mesh(chosen.mesh_shape, chosen.mesh_axes)
            print(f"auto-mesh: built {chosen.descriptor} "
                  f"({chosen.data}-way data × {chosen.model}-way model)")
        else:
            print(f"auto-mesh: {chosen.descriptor} needs "
                  f"{chosen.n_devices} devices, host has "
                  f"{len(jax.devices())} — plan reported, training unsharded")

    opt = OptimizerConfig(kind="adamw", lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 100))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         grad_compression=args.grad_compression)
    trainer = Trainer(cfg, shape, opt, tcfg, mesh=mesh, admission=admission)
    out = trainer.train(args.steps)
    h = out["history"]
    print(json.dumps({
        "arch": cfg.name,
        "steps": len(h),
        "first_loss": h[0]["loss"] if h else None,
        "last_loss": h[-1]["loss"] if h else None,
        "mean_step_ms": sum(r["dt"] for r in h) / max(len(h), 1) * 1e3,
        "stragglers": len(out["stragglers"]),
    }, indent=2))


if __name__ == "__main__":
    main()
