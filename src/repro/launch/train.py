"""Training launcher with perf4sight admission control.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --ckpt-dir /tmp/ck

Before building the jitted step, the launcher predicts the training-step
memory footprint (AOT ``lower().compile().memory_analysis()`` at smoke
scale, or the fitted perf4sight forest when a model file is supplied) and
refuses jobs over the budget — the paper's §6.4 safety property.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCH_IDS, get_config
from repro.optim.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", type=float, default=None)
    ap.add_argument("--memory-budget-gb", type=float, default=None,
                    help="admission gate: refuse if predicted HBM exceeds this")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    admission = None
    if args.memory_budget_gb is not None:
        def admission(cfg, shape):
            from repro.launch.dryrun import lower_cell  # noqa: PLC0415
            # smoke-scale AOT estimate on the local device
            from repro.models import transformer as T
            from repro.optim.optimizer import apply_updates, init_opt_state

            params = T.init_params(cfg, 0)
            opt_cfg = OptimizerConfig()

            def step(state, batch):
                (l, _), g = jax.value_and_grad(T.loss_fn, has_aux=True)(
                    state["params"], batch, cfg)
                p2, o2, _ = apply_updates(state["params"], g, state["opt"], opt_cfg)
                return {"params": p2, "opt": o2}, l

            from repro.data.pipeline import make_batch
            state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
            batch = make_batch(cfg, shape, 0)
            compiled = jax.jit(step).lower(state, batch).compile()
            ma = compiled.memory_analysis()
            gb = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                  + ma.temp_size_in_bytes) / 1e9
            return gb <= args.memory_budget_gb, {"predicted_gb": gb}

    opt = OptimizerConfig(kind="adamw", lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 100))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         grad_compression=args.grad_compression)
    trainer = Trainer(cfg, shape, opt, tcfg, admission=admission)
    out = trainer.train(args.steps)
    h = out["history"]
    print(json.dumps({
        "arch": cfg.name,
        "steps": len(h),
        "first_loss": h[0]["loss"] if h else None,
        "last_loss": h[-1]["loss"] if h else None,
        "mean_step_ms": sum(r["dt"] for r in h) / max(len(h), 1) * 1e3,
        "stragglers": len(out["stragglers"]),
    }, indent=2))


if __name__ == "__main__":
    main()
