import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.
# This is dry-run-only; smoke tests and benches see the single real device.
# Library callers that must NOT fake the device count (the profiling
# campaign, tests) import repro.launch.lowering instead — the compile
# machinery lives there now.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, AOT-lower and compile the real
step function (train_step / prefill / decode_step) under GSPMD on the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — and record:

  * ``compiled.memory_analysis()``  — proves the per-device HBM plan fits
  * ``compiled.cost_analysis()``    — FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out benchmarks/cache/dryrun.jsonl

``--out`` is an append-only JSONL ledger with one record per cell, written
through the ``core/fileio`` durable-append path (O_APPEND + fsync): an
interrupted run never leaves a torn ledger, and a restarted run skips the
cells already recorded instead of double-counting them.
"""

import argparse
import traceback

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.core.fileio import append_jsonl, load_jsonl_tolerant
from repro.launch.lowering import compile_cell, lower_cell, make_train_step  # noqa: F401 — re-exported for b/c
from repro.launch.mesh import make_mesh, make_production_mesh


def _cell_id(arch: str, shape: str, mesh_desc: str) -> str:
    return f"{arch}|{shape}|{mesh_desc}"


def _recorded_cells(path: str | None) -> set[str]:
    """Cell ids already present in the --out ledger (any status): a restart
    resumes where the interrupted run stopped instead of recompiling —
    and re-appending — every earlier cell."""
    if not path:
        return set()
    done = set()
    for rec in load_jsonl_tolerant(path):
        if {"arch", "shape", "mesh"} <= rec.keys():
            done.add(_cell_id(rec["arch"], rec["shape"], rec["mesh"]))
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"), default="off")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 32x8 or 2x16x16 (axes inferred)")
    ap.add_argument("--out", default=None, help="append JSONL reports here")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--opt", default="adamw", choices=("adamw", "sgdm"))
    ap.add_argument("--fsdp", default=None, choices=(None, "on", "off"))
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residual-stream hint")
    ap.add_argument("--redo", action="store_true",
                    help="recompile cells already present in --out (the new "
                         "record is appended; readers keep the last one)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    meshes: list[tuple[object, str]] = []
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        meshes.append((make_mesh(dims, axes), args.mesh))
    else:
        if args.multi_pod in ("off", "both"):
            meshes.append((make_production_mesh(), "16x16"))
        if args.multi_pod in ("on", "both"):
            meshes.append((make_production_mesh(multi_pod=True), "2x16x16"))

    recorded = set() if args.redo else _recorded_cells(args.out)
    fsdp = None if args.fsdp is None else (args.fsdp == "on")
    results = []
    for mesh, mesh_desc in meshes:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                if _cell_id(arch, shape_name, mesh_desc) in recorded:
                    print(f"DONE {arch} × {shape_name} [{mesh_desc}] "
                          "(in ledger; --redo to recompile)", flush=True)
                    continue
                ok, why = cell_supported(cfg, SHAPES[shape_name])
                if not ok:
                    print(f"SKIP {arch} × {shape_name} [{mesh_desc}]: {why}",
                          flush=True)
                    if args.out:
                        append_jsonl(args.out, {
                            "arch": arch, "shape": shape_name,
                            "mesh": mesh_desc, "skipped": why,
                        })
                    continue
                print(f"=== {arch} × {shape_name} [{mesh_desc}] ===", flush=True)
                try:
                    rep = lower_cell(
                        arch, shape_name, mesh, mesh_desc,
                        opt_kind=args.opt, remat=not args.no_remat, fsdp=fsdp,
                        microbatches=args.microbatch, seq_chunk=args.loss_chunk,
                        sp=not args.no_sp,
                    )
                except Exception:
                    traceback.print_exc()
                    print(f"FAILED {arch} × {shape_name} [{mesh_desc}]", flush=True)
                    if args.out:
                        append_jsonl(args.out, {
                            "arch": arch, "shape": shape_name,
                            "mesh": mesh_desc, "failed": True,
                        })
                    continue
                print(rep.summary(), flush=True)
                results.append(rep)
                if args.out:
                    append_jsonl(args.out, rep.to_dict())

    print(f"\n{len(results)} cells compiled OK")


if __name__ == "__main__":
    main()
