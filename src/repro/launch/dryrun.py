import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.
# This is dry-run-only; smoke tests and benches see the single real device.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, AOT-lower and compile the real
step function (train_step / prefill / decode_step) under GSPMD on the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — and record:

  * ``compiled.memory_analysis()``  — proves the per-device HBM plan fits
  * ``compiled.cost_analysis()``    — FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out benchmarks/cache/dryrun.jsonl
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.core.roofline import model_flops_for_cell, roofline_from_compiled
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim.optimizer import OptimizerConfig, apply_updates, init_opt_state


def _opt_state_specs_like(cfg, opt_cfg: OptimizerConfig):
    """ShapeDtypeStructs for the optimizer state (f32 slots)."""
    pspecs = T.param_specs(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    opt = {"step": jax.ShapeDtypeStruct((), jnp.int32), "m": jax.tree.map(f32, pspecs)}
    if opt_cfg.kind == "adamw":
        opt["v"] = jax.tree.map(f32, pspecs)
    return opt


def make_train_step(cfg, opt_cfg: OptimizerConfig, *, microbatches: int = 1,
                    seq_chunk: int | None = None):
    """Real train step; perf knobs:

    microbatches — gradient accumulation via lax.scan over batch slices
        (activation temp ∝ 1/M; the per-microbatch gradient all-reduce
        overlaps the next microbatch's compute in XLA's schedule).
    seq_chunk — chunked CE loss (see transformer.loss_fn).
    """

    def loss(params, batch):
        return T.loss_fn(params, batch, cfg, seq_chunk=seq_chunk)

    def train_step(state, batch):
        if microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                state["params"], batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]),
                batch)

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(
                    state["params"], mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, l_sum), _ = jax.lax.scan(acc_fn, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            l = l_sum / microbatches
        new_params, new_opt, om = apply_updates(
            state["params"], grads, state["opt"], opt_cfg
        )
        return {"params": new_params, "opt": new_opt}, {"loss": l, **om}

    return train_step


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    mesh_desc: str,
    *,
    opt_kind: str = "adamw",
    remat: bool = True,
    fsdp: bool | None = None,
    print_analysis: bool = True,
    microbatches: int = 1,
    seq_chunk: int | None = None,
    sp: bool = True,
):
    """Lower + compile one cell on ``mesh``; return the roofline report."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    opt_cfg = OptimizerConfig(kind=opt_kind)
    n_dev = mesh.devices.size
    from repro.models import layers as L

    L.set_hint_mesh(mesh, sp=sp)  # activation sharding hints (MoE buffers etc.)

    t0 = time.perf_counter()
    if shape.kind == "train":
        specs = T.input_specs(cfg, shape)
        state_specs = {"params": specs["params"],
                       "opt": _opt_state_specs_like(cfg, opt_cfg)}
        state_sh = sh.to_named(mesh, sh.state_pspecs(cfg, mesh, kind=opt_kind, fsdp=fsdp))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=microbatches,
                            seq_chunk=seq_chunk),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = fn.lower(state_specs, specs["batch"])
    elif shape.kind == "prefill":
        specs = T.input_specs(cfg, shape)
        param_sh = sh.to_named(mesh, sh.param_pspecs(cfg, mesh, fsdp=bool(fsdp)))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        cache_sh = sh.to_named(mesh, sh.cache_pspecs(cfg, shape, mesh))
        max_len = shape.seq_len + cfg.n_prefix

        def prefill_fn(params, batch):
            return T.prefill(params, batch, cfg, max_len=max_len)

        out_sh = {"logits": None, "cache": cache_sh, "cache_len": None}
        if cfg.n_encoder_layers:
            out_sh["memory"] = None
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh),
                     out_shardings=out_sh)
        with mesh:
            lowered = fn.lower(specs["params"], specs["batch"])
    else:  # decode
        specs = T.input_specs(cfg, shape)
        param_sh = sh.to_named(mesh, sh.param_pspecs(cfg, mesh, fsdp=False))
        batch_sh = sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))
        cache_sh = sh.to_named(mesh, sh.cache_pspecs(cfg, shape, mesh))

        def decode_fn(params, cache, batch):
            return T.decode_step(params, cache, batch, cfg)

        fn = jax.jit(
            decode_fn,
            in_shardings=(param_sh, cache_sh, batch_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = fn.lower(specs["params"], specs["cache"], specs["batch"])

    with mesh:
        compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    if print_analysis:
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        print({k: v for k, v in dict(ca).items()
               if k in ("flops", "bytes accessed")})

    report = roofline_from_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_desc=mesh_desc,
        n_devices=n_dev,
        model_flops_total=model_flops_for_cell(cfg, shape),
        compile_s=compile_s,
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"), default="off")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 32x8 or 2x16x16 (axes inferred)")
    ap.add_argument("--out", default=None, help="append JSONL reports here")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--opt", default="adamw", choices=("adamw", "sgdm"))
    ap.add_argument("--fsdp", default=None, choices=(None, "on", "off"))
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residual-stream hint")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    meshes: list[tuple[object, str]] = []
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        meshes.append((make_mesh(dims, axes), args.mesh))
    else:
        if args.multi_pod in ("off", "both"):
            meshes.append((make_production_mesh(), "16x16"))
        if args.multi_pod in ("on", "both"):
            meshes.append((make_production_mesh(multi_pod=True), "2x16x16"))

    fsdp = None if args.fsdp is None else (args.fsdp == "on")
    results = []
    for mesh, mesh_desc in meshes:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                ok, why = cell_supported(cfg, SHAPES[shape_name])
                if not ok:
                    print(f"SKIP {arch} × {shape_name} [{mesh_desc}]: {why}",
                          flush=True)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps({
                                "arch": arch, "shape": shape_name,
                                "mesh": mesh_desc, "skipped": why,
                            }) + "\n")
                    continue
                print(f"=== {arch} × {shape_name} [{mesh_desc}] ===", flush=True)
                try:
                    rep = lower_cell(
                        arch, shape_name, mesh, mesh_desc,
                        opt_kind=args.opt, remat=not args.no_remat, fsdp=fsdp,
                        microbatches=args.microbatch, seq_chunk=args.loss_chunk,
                        sp=not args.no_sp,
                    )
                except Exception:
                    traceback.print_exc()
                    print(f"FAILED {arch} × {shape_name} [{mesh_desc}]", flush=True)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps({
                                "arch": arch, "shape": shape_name,
                                "mesh": mesh_desc, "failed": True,
                            }) + "\n")
                    continue
                print(rep.summary(), flush=True)
                results.append(rep)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rep.to_dict()) + "\n")

    print(f"\n{len(results)} cells compiled OK")


if __name__ == "__main__":
    main()
