"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
while smoke tests and benches must keep seeing the single real device.
"""

from __future__ import annotations

import jax

__all__ = ["MeshSpecError", "validate_mesh_spec", "make_production_mesh",
           "make_mesh", "dp_axes", "TPU_V5E"]


class MeshSpecError(ValueError):
    """Structured mesh-spec rejection: what was asked, what was wrong.

    ``needed``/``available``/``deficit`` are populated for device-count
    failures so callers (the planner, the launcher) can report or recover
    programmatically instead of parsing the message."""

    def __init__(self, message: str, *, shape=None, axes=None,
                 needed: int | None = None, available: int | None = None):
        super().__init__(message)
        self.shape = tuple(shape) if shape is not None else None
        self.axes = tuple(axes) if axes is not None else None
        self.needed = needed
        self.available = available
        self.deficit = (needed - available
                        if needed is not None and available is not None
                        else None)


def validate_mesh_spec(shape, axes, available: int | None = None) -> int:
    """Validate a ``(shape, axes)`` mesh request; returns the device count
    it needs.  The ONE validator shared by :func:`make_mesh` and the
    auto-sharding planner (``repro.planner``) — positive dims, matching
    lengths, unique non-empty axis names, and (when ``available`` is
    given) enough devices, with the deficit named in the error."""
    shape = tuple(shape)
    axes = tuple(axes)
    if not shape:
        raise MeshSpecError("empty mesh shape", shape=shape, axes=axes)
    if len(shape) != len(axes):
        raise MeshSpecError(
            f"mesh shape {shape} has {len(shape)} dims but axes {axes} "
            f"name {len(axes)}", shape=shape, axes=axes)
    bad = [d for d in shape if not (isinstance(d, int) and d >= 1)]
    if bad:
        raise MeshSpecError(
            f"mesh shape {shape} has non-positive dim(s) {bad}; every axis "
            "must be an int >= 1", shape=shape, axes=axes)
    if len(set(axes)) != len(axes) or any(not a for a in axes):
        raise MeshSpecError(
            f"mesh axes {axes} must be unique non-empty names",
            shape=shape, axes=axes)
    n = 1
    for d in shape:
        n *= d
    if available is not None and available < n:
        raise MeshSpecError(
            f"mesh {shape} over axes {axes} needs {n} devices but only "
            f"{available} are visible ({n - available} short) — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before any "
            "jax import (see launch/dryrun.py) or plan a smaller layout",
            shape=shape, axes=axes, needed=n, available=available)
    return n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (hillclimbing explores non-default layouts).  Uses the
    first prod(shape) devices — documented behaviour, so a 512-device
    dry-run host can build both the 256-chip single-pod and the 512-chip
    multi-pod mesh — after :func:`validate_mesh_spec` has vetted the
    request (raising :class:`MeshSpecError` naming the deficit when the
    host is short on devices)."""
    devs = jax.devices()
    n = validate_mesh_spec(shape, axes, available=len(devs))
    import numpy as _np

    return jax.sharding.Mesh(
        _np.array(devs[:n]).reshape(tuple(shape)), tuple(axes)
    )


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes present on this mesh (pod is outer DP)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# TPU v5e hardware constants (per chip) — roofline denominators.
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~45-50 GB/s each direction)
    "hbm_bytes": 16e9,           # capacity
}
