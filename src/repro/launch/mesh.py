"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
while smoke tests and benches must keep seeing the single real device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "TPU_V5E"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (hillclimbing explores non-default layouts).  Uses the
    first prod(shape) devices so a 512-device dry-run host can build both the
    256-chip single-pod and the 512-chip multi-pod mesh."""
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "any jax import (see launch/dryrun.py)"
        )
    import numpy as _np

    return jax.sharding.Mesh(
        _np.array(devs[:n]).reshape(shape), axes
    )


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes present on this mesh (pod is outer DP)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# TPU v5e hardware constants (per chip) — roofline denominators.
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~45-50 GB/s each direction)
    "hbm_bytes": 16e9,           # capacity
}
