"""Profiling-campaign subsystem (see docs/campaign.md).

perf4sight's toolflow in one sentence: profile a configuration grid once
on the target device, fit models, then answer every future cost question
without touching the device.  This package is that loop for the LM
workloads, over ``(ArchConfig × ShapeSpec × mesh × DeviceSpec)`` cells:

* :mod:`repro.campaign.plan`        — reproducible grid enumeration
  (``plan_grid``/``smoke_plan``, seeded stratified subsampling, plan hash)
* :mod:`repro.campaign.runner`      — resumable, sharded execution into a
  durable JSONL ledger with per-cell quarantine
* :mod:`repro.campaign.lm_features` — compile-free featurization (device
  constants are features: one forest serves a fleet)
* :mod:`repro.campaign.fit`         — LM forests + NNLS ``parse_hlo_cost``
  constants, registered with the engine's ``ForestBackend``

CLI: ``python -m repro.campaign {plan,run,fit,status} ...``
"""

from repro.campaign.fit import (
    LMForest,
    check_device_fingerprints,
    fit_hlo_constants,
    fit_lm_forest,
    register_lm_forest,
    split_records,
)
from repro.campaign.lm_features import (
    CLASS_FEATURE_NAMES,
    LM_FEATURE_NAMES,
    cell_features,
    class_histogram,
    ledger_class_features,
)
from repro.campaign.plan import (
    SMOKE_SHAPES,
    CampaignCell,
    CampaignPlan,
    load_plan,
    mesh_dims,
    plan_grid,
    smoke_plan,
)
from repro.campaign.runner import (
    CampaignLedger,
    CampaignRunner,
    CellTimeout,
    measure_cell,
)

__all__ = [
    "CampaignCell",
    "CampaignLedger",
    "CampaignPlan",
    "CampaignRunner",
    "CellTimeout",
    "CLASS_FEATURE_NAMES",
    "LMForest",
    "LM_FEATURE_NAMES",
    "SMOKE_SHAPES",
    "cell_features",
    "check_device_fingerprints",
    "class_histogram",
    "ledger_class_features",
    "fit_hlo_constants",
    "fit_lm_forest",
    "load_plan",
    "measure_cell",
    "mesh_dims",
    "plan_grid",
    "register_lm_forest",
    "smoke_plan",
    "split_records",
]
