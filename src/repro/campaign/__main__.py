"""Campaign CLI: plan → run (resumable, shardable) → fit → status.

    # 1. plan a grid (reproducible: same args + seed → same plan hash)
    PYTHONPATH=src python -m repro.campaign plan --smoke \
        --out /tmp/camp/plan.json

    # 2. run it — restartable; shard across workers with --shard/--num-shards
    PYTHONPATH=src python -m repro.campaign run --plan /tmp/camp/plan.json \
        --ledger /tmp/camp/ledger.jsonl

    # 3. fit the LM forest (+ optionally the HLO device constants)
    PYTHONPATH=src python -m repro.campaign fit --ledger /tmp/camp/ledger.jsonl \
        --out /tmp/camp/lm_forest.npz --hlo-device-out /tmp/camp/device.json

    # 4. where are we?
    PYTHONPATH=src python -m repro.campaign status --plan /tmp/camp/plan.json \
        --ledger /tmp/camp/ledger.jsonl
"""

from __future__ import annotations

import argparse
import json

from repro.campaign.fit import fit_hlo_constants, fit_lm_forest
from repro.campaign.plan import (
    SMOKE_SHAPES,
    load_plan,
    plan_grid,
    smoke_plan,
)
from repro.campaign.runner import CampaignLedger, CampaignRunner
from repro.configs.base import SHAPES


def _cmd_plan(args) -> int:
    if args.smoke:
        plan = smoke_plan(subsample=args.subsample, seed=args.seed)
    else:
        plan = plan_grid(
            archs=tuple(args.arch) or None,
            shapes=tuple(args.shape) or None,
            meshes=tuple(args.mesh),
            device=args.device,
            reduced=not args.full_scale,
            subsample=args.subsample,
            seed=args.seed,
        )
    plan.save(args.out)
    print(f"plan {plan.plan_hash}: {len(plan)} cells "
          f"({len(plan.skipped)} skipped unsupported) -> {args.out}")
    return 0


def _cmd_run(args) -> int:
    plan = load_plan(args.plan)
    runner = CampaignRunner(
        plan, args.ledger, repeats=args.repeats, warmup=args.warmup,
        run=not args.compile_only, retry_failed=args.retry_failed)
    out = runner.run_campaign(args.shard, args.num_shards,
                              max_cells=args.max_cells, print_fn=print)
    print(json.dumps(out))
    return 0 if out["remaining"] == 0 else 3  # 3 = come back for more


def _cmd_fit(args) -> int:
    ledger = CampaignLedger(args.ledger)
    records = ledger.records()
    forest = fit_lm_forest(records, device=args.device,
                           holdout_frac=args.holdout, seed=args.seed,
                           allow_mixed=args.allow_mixed)
    forest.save(args.out)
    print(f"LM forest -> {args.out}")
    print(json.dumps({k: v for k, v in forest.meta.items()
                      if k != "device_spec"}, indent=2, default=str))
    if args.hlo_device_out:
        from repro.engine.devices import save_device_spec

        spec = fit_hlo_constants(records, base_device=args.device,
                                 allow_mixed=args.allow_mixed)
        save_device_spec(args.hlo_device_out, spec)
        print(f"calibrated LM DeviceSpec ({spec.name}, "
              f"{spec.meta['latency_fit']} fit, "
              f"phi MAPE {spec.meta['phi_mape']:.3f}, "
              f"energy {spec.meta.get('energy_fit', 'none')} fit)"
              f" -> {args.hlo_device_out}")
    return 0


def _breakdown(records: list[dict]) -> dict:
    """Aggregate the per-op-class ledger breakdown across ok-records: the
    'which op class is the money going to' view of a campaign.  The merge
    itself is ``CostLedger.merge_class_sums`` — one definition of a class
    bucket, shared with the ledger."""
    from repro.costmodel import CostLedger

    with_classes = [r["cost_classes"] for r in records
                    if r.get("cost_classes")]
    totals = CostLedger.merge_class_sums(with_classes)
    flops_tot = sum(t["flops"] for t in totals.values()) or 1.0
    hbm_tot = sum(t["hbm_bytes"] for t in totals.values()) or 1.0
    # Schema-v3 records bucket per-class dynamic joules too; v2 buckets
    # merge as zero energy and the share column just stays 0.
    energy_tot = sum(t.get("energy_j", 0.0) for t in totals.values()) or 1.0
    return {
        "records_with_breakdown": len(with_classes),
        "classes": {
            cls: {
                **t,
                "flops_share": round(t["flops"] / flops_tot, 4),
                "hbm_share": round(t["hbm_bytes"] / hbm_tot, 4),
                "energy_share": round(t.get("energy_j", 0.0) / energy_tot, 4),
            }
            for cls, t in totals.items()
        },
    }


def _cmd_status(args) -> int:
    ledger = CampaignLedger(args.ledger)
    ok_recs = ledger.records("ok")
    out = {"ledger_records": len(ledger),
           "ok": len(ledger.ok_keys),
           "energy_j_total": round(sum(
               r.get("energy_j", 0.0) or 0.0 for r in ok_recs), 6),
           "quarantined": sorted(
               f"{r['arch']}×{r['shape']['name']}[{r['mesh']}]"
               for r in ledger.records("failed"))}
    if args.plan:
        plan = load_plan(args.plan)
        keys = {c.key for c in plan.cells}
        out.update(
            plan_hash=plan.plan_hash, plan_cells=len(plan),
            pending=len(keys - ledger.ok_keys - ledger.failed_keys),
            foreign_records=len(set(ledger._by_key) - keys),
        )
    if args.breakdown:
        out["breakdown"] = _breakdown(ok_recs)
    print(json.dumps(out, indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.campaign")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="enumerate + subsample a grid")
    p.add_argument("--arch", action="append", default=[],
                   help="arch id (repeatable; default: all)")
    p.add_argument("--shape", action="append", default=[],
                   help=f"shape name (repeatable; default: production SHAPES). "
                        f"known: {sorted(SHAPES) + sorted(SMOKE_SHAPES)}")
    p.add_argument("--mesh", action="append", default=["1x1"],
                   help="mesh dims like 1x1 or 2x16x16 (repeatable)")
    p.add_argument("--device", default="host_cpu")
    p.add_argument("--full-scale", action="store_true",
                   help="full (non-reduced) configs — production dry-run scale")
    p.add_argument("--subsample", type=float, default=None,
                   help="keep N cells (>=1) or a fraction (0..1), "
                        "stratified by arch")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="the canonical host-CPU smoke grid (ignores "
                        "--arch/--shape/--mesh)")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=_cmd_plan)

    p = sub.add_parser("run", help="measure pending cells (resumable)")
    p.add_argument("--plan", required=True)
    p.add_argument("--ledger", required=True)
    p.add_argument("--shard", type=int, default=0)
    p.add_argument("--num-shards", type=int, default=1)
    p.add_argument("--max-cells", type=int, default=None)
    p.add_argument("--repeats", type=int, default=2)
    p.add_argument("--warmup", type=int, default=1)
    p.add_argument("--compile-only", action="store_true",
                   help="no execution: HLO/memory analysis only (phi_ms=0)")
    p.add_argument("--retry-failed", action="store_true",
                   help="re-measure quarantined cells too")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("fit", help="fit LM forest (+ HLO constants)")
    p.add_argument("--ledger", required=True)
    p.add_argument("--out", required=True, help=".npz (packed) or .json")
    p.add_argument("--device", default=None,
                   help="featurize under this device (default: per-record)")
    p.add_argument("--holdout", type=float, default=0.25)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--hlo-device-out", default=None,
                   help="also NNLS-fit parse_hlo_cost constants into a "
                        "calibrated DeviceSpec at this path")
    p.add_argument("--allow-mixed", action="store_true",
                   help="fit even when records were measured under different "
                        "device constants than the fit would featurize with "
                        "(the per-record fingerprint guard)")
    p.set_defaults(fn=_cmd_fit)

    p = sub.add_parser("status", help="ledger/plan progress")
    p.add_argument("--ledger", required=True)
    p.add_argument("--plan", default=None)
    p.add_argument("--breakdown", action="store_true",
                   help="also print the per-op-class cost breakdown "
                        "aggregated over ok records")
    p.set_defaults(fn=_cmd_status)

    args = ap.parse_args(argv)
    if args.cmd == "plan" and args.subsample is not None and args.subsample >= 1:
        args.subsample = int(args.subsample)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
