"""Compile-free featurization of LM campaign cells.

perf4sight's CNN path featurizes a topology analytically (App. B) and lets
the forest learn the device/framework nonlinearity.  This module is the LM
analogue: every feature is a pure function of
``(ArchConfig × ShapeSpec × mesh × DeviceSpec)`` — architecture widths and
counts, workload token geometry, mesh split, and *device-scaled roofline
terms* built from the same :func:`repro.engine.decompose.lm_roofline_terms`
denominators the analytical backend and the constant fit divide by.

Because the calibrated device constants enter as features (and scale the
roofline terms), one forest fitted over a multi-device campaign serves the
whole fleet: a query for a new device re-featurizes with that device's
constants instead of needing its own forest.

Nothing here touches jax — a fitted forest answers admission queries with
zero compiles, which is the entire point of the campaign.
"""

from __future__ import annotations

import math

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec, mesh_split
from repro.configs.registry import get_config
from repro.core.roofline import model_flops_for_cell
from repro.engine.decompose import lm_roofline_terms
from repro.engine.devices import DeviceSpec, resolve_device

__all__ = [
    "LM_FEATURE_NAMES",
    "cell_features",
    "feature_matrix",
    "query_cell",
]

_BYTES_PER_EL = {"bfloat16": 2, "float16": 2, "float32": 4}

LM_FEATURE_NAMES: tuple[str, ...] = (
    # --- architecture ---
    "n_layers", "d_model", "n_heads", "n_kv_heads", "head_dim", "d_ff",
    "padded_vocab", "n_experts", "experts_per_token", "moe_d_ff",
    "ssm_state", "n_encoder_layers", "hybrid_period",
    "params_total", "params_active",
    "is_moe", "is_ssm", "is_hybrid", "is_encdec",
    # --- workload shape ---
    "seq_len", "global_batch", "tokens",
    "kind_train", "kind_prefill", "kind_decode",
    # --- mesh ---
    "n_devices", "n_data", "n_model",
    # --- analytic per-device compute/byte decomposition ---
    "model_flops_dev", "param_bytes_dev", "act_bytes_dev", "kv_bytes_dev",
    "opt_bytes_dev", "coll_bytes_dev", "arithmetic_intensity",
    # --- device-scaled roofline terms (decompose.lm_roofline_terms) ---
    "compute_s", "memory_s", "collective_s", "roofline_ms",
    # --- raw device constants (fleet transfer) ---
    "log_peak_flops", "log_hbm_bw", "log_ici_bw", "launch_overhead_ms",
    "device_calibrated",
)


def cell_features(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh_dims: tuple[int, ...],
    device: DeviceSpec,
) -> np.ndarray:
    """One feature row (``LM_FEATURE_NAMES`` order) — numpy only, no jax."""
    n_dev, n_data, n_model = mesh_split(tuple(mesh_dims))
    bpe = _BYTES_PER_EL.get(cfg.dtype, 2)
    V = cfg.padded_vocab()
    params = cfg.param_count()
    active = cfg.param_count(active_only=True)
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch

    # Per-device analytic decomposition.  These are deliberately coarse —
    # the forest corrects them from profiled ground truth; their job is to
    # carry the right *scaling* (linear in tokens, 1/n_dev in splits).
    model_flops_dev = model_flops_for_cell(cfg, shape) / n_dev
    param_bytes_dev = bpe * params / max(n_model, 1)
    act_bytes_dev = bpe * (tokens / max(n_data, 1)) * cfg.d_model \
        * max(cfg.n_layers, 1)
    kv_bytes_dev = 0.0
    if shape.kind != "train":
        kv_len = shape.seq_len + cfg.n_prefix
        kv_bytes_dev = (
            2.0 * bpe * (shape.global_batch / max(n_data, 1)) * kv_len
            * max(cfg.n_kv_heads, 1) * cfg.head_dim_ * max(cfg.n_layers, 1)
            / max(n_model, 1))
    opt_bytes_dev = 0.0
    if shape.kind == "train":
        # grads (model dtype) + adamw m/v slots (f32) per device
        opt_bytes_dev = (bpe + 2 * 4) * params / max(n_model, 1)
    # ring-model gradient/activation exchange: zero on a single device
    coll_bytes_dev = (
        2.0 * bpe * params / n_dev * (n_dev - 1) / n_dev if n_dev > 1 else 0.0)

    bytes_moved = param_bytes_dev + act_bytes_dev + kv_bytes_dev + opt_bytes_dev
    compute_s, memory_s, coll_s = (
        float(v) for v in lm_roofline_terms(
            model_flops_dev, bytes_moved, coll_bytes_dev, device))
    roofline_ms = device.combine_terms(compute_s, memory_s, coll_s) * 1e3

    vals = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
        cfg.d_ff, V, cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff_,
        cfg.ssm_state, cfg.n_encoder_layers, cfg.hybrid_period,
        params, active,
        float(cfg.is_moe), float(cfg.family == "ssm"),
        float(cfg.hybrid_period > 0), float(cfg.n_encoder_layers > 0),
        shape.seq_len, shape.global_batch, tokens,
        float(shape.kind == "train"), float(shape.kind == "prefill"),
        float(shape.kind == "decode"),
        n_dev, n_data, n_model,
        model_flops_dev, param_bytes_dev, act_bytes_dev, kv_bytes_dev,
        opt_bytes_dev, coll_bytes_dev,
        model_flops_dev / max(bytes_moved, 1.0),
        compute_s, memory_s, coll_s, roofline_ms,
        math.log10(device.peak_flops), math.log10(device.hbm_bw),
        math.log10(device.ici_bw), device.launch_overhead_s * 1e3,
        float(device.calibrated),
    )
    x = np.asarray(vals, dtype=np.float64)
    assert x.shape == (len(LM_FEATURE_NAMES),)
    return x


def query_cell(query, *, reduced_default: bool = True):
    """(cfg, shape) a :class:`~repro.engine.types.CostQuery` LM-cell query
    describes — the bridge from the engine's query language to campaign
    coordinates.  ``stage`` maps train→train and infer→prefill (admission
    asks about whole forward passes, not single decode steps)."""
    if query.arch is None:
        raise ValueError("not an LM-cell query (no arch id)")
    reduced = reduced_default if query.reduced is None else query.reduced
    cfg = get_config(query.arch, reduced=reduced)
    kind = "train" if query.stage == "train" else "prefill"
    return cfg, ShapeSpec("query", query.seq, query.bs, kind)


def feature_matrix(
    records: list[dict],
    *,
    device: "DeviceSpec | str | None" = None,
) -> np.ndarray:
    """(N, F) matrix from campaign ledger records (see ``runner.py`` for the
    schema).  ``device`` overrides the per-record device name — used to
    re-featurize one campaign under another device's constants."""
    from repro.campaign.plan import CampaignCell, mesh_dims

    rows = []
    for rec in records:
        cell = CampaignCell.from_dict(rec)
        cfg = get_config(cell.arch, reduced=cell.reduced)
        dev = resolve_device(device if device is not None else cell.device)
        rows.append(cell_features(cfg, cell.shape, mesh_dims(cell.mesh), dev))
    return np.stack(rows) if rows else np.zeros((0, len(LM_FEATURE_NAMES)))
