"""Compile-free featurization of LM campaign cells.

perf4sight's CNN path featurizes a topology analytically (App. B) and lets
the forest learn the device/framework nonlinearity.  This module is the LM
analogue: every feature is a pure function of
``(ArchConfig × ShapeSpec × mesh × DeviceSpec)`` — architecture widths and
counts, workload token geometry, mesh split, and *device-scaled roofline
terms* built from the same :func:`repro.engine.decompose.lm_roofline_terms`
denominators the analytical backend and the constant fit divide by.

Because the calibrated device constants enter as features (and scale the
roofline terms), one forest fitted over a multi-device campaign serves the
whole fleet: a query for a new device re-featurizes with that device's
constants instead of needing its own forest.

Nothing here touches jax — a fitted forest answers admission queries with
zero compiles, which is the entire point of the campaign.
"""

from __future__ import annotations

import math

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec, mesh_split
from repro.configs.registry import get_config
from repro.core.roofline import model_flops_for_cell
from repro.costmodel import OP_CLASSES
from repro.engine.decompose import lm_roofline_terms
from repro.engine.devices import DeviceSpec, resolve_device

__all__ = [
    "LM_FEATURE_NAMES",
    "CLASS_FEATURE_NAMES",
    "class_histogram",
    "ledger_class_features",
    "cell_features",
    "feature_matrix",
    "query_cell",
]

_BYTES_PER_EL = {"bfloat16": 2, "float16": 2, "float32": 4}

# Per-op-class histogram features (the cost-ledger taxonomy): what share
# of a cell's compute and traffic each class carries.  ONE histogram
# function (:func:`class_histogram`) serves two providers — the analytic
# class decomposition below (query time: compile-free, the serving
# contract) and the measured ``cost_classes`` a v2 campaign record stores
# (:func:`ledger_class_features`, for fit-time diagnostics and breakdown
# reporting) — so the two can never disagree about what a feature means.
CLASS_FEATURE_NAMES: tuple[str, ...] = tuple(
    [f"flops_frac_{cls}" for cls in OP_CLASSES]
    + [f"hbm_frac_{cls}" for cls in OP_CLASSES]
)


def class_histogram(class_sums: dict) -> np.ndarray:
    """(``CLASS_FEATURE_NAMES`` order) normalized per-class shares of a
    ``CostLedger.class_sums()``-shaped dict.  All-zero totals yield zero
    fractions (a compile-only or analytic cell with no traffic modeled)."""
    flops_tot = sum(s.get("flops", 0.0) for s in class_sums.values())
    hbm_tot = sum(s.get("hbm_bytes", 0.0) for s in class_sums.values())
    vals = [
        (class_sums.get(cls, {}).get("flops", 0.0) / flops_tot)
        if flops_tot else 0.0
        for cls in OP_CLASSES
    ] + [
        (class_sums.get(cls, {}).get("hbm_bytes", 0.0) / hbm_tot)
        if hbm_tot else 0.0
        for cls in OP_CLASSES
    ]
    return np.asarray(vals, dtype=np.float64)


def ledger_class_features(record: dict) -> np.ndarray:
    """The measured-ledger histogram of one campaign record (empty/missing
    ``cost_classes`` → all zeros) — diagnostics and breakdown reporting,
    NOT the forest's serving features (those stay analytic so a query
    needs no measurement)."""
    return class_histogram(record.get("cost_classes") or {})

LM_FEATURE_NAMES: tuple[str, ...] = (
    # --- architecture ---
    "n_layers", "d_model", "n_heads", "n_kv_heads", "head_dim", "d_ff",
    "padded_vocab", "n_experts", "experts_per_token", "moe_d_ff",
    "ssm_state", "n_encoder_layers", "hybrid_period",
    "params_total", "params_active",
    "is_moe", "is_ssm", "is_hybrid", "is_encdec",
    # --- workload shape ---
    "seq_len", "global_batch", "tokens",
    "kind_train", "kind_prefill", "kind_decode",
    # --- mesh ---
    "n_devices", "n_data", "n_model",
    # --- analytic per-device compute/byte decomposition ---
    "model_flops_dev", "param_bytes_dev", "act_bytes_dev", "kv_bytes_dev",
    "opt_bytes_dev", "coll_bytes_dev", "arithmetic_intensity",
    # --- device-scaled roofline terms (decompose.lm_roofline_terms) ---
    "compute_s", "memory_s", "collective_s", "roofline_ms",
    # --- raw device constants (fleet transfer) ---
    "log_peak_flops", "log_hbm_bw", "log_ici_bw", "launch_overhead_ms",
    "device_calibrated", "idle_w", "peak_w",
    # --- per-op-class histogram (cost-ledger taxonomy, analytic provider) ---
) + CLASS_FEATURE_NAMES


def analytic_class_sums(
    model_flops_dev: float,
    param_bytes_dev: float,
    act_bytes_dev: float,
    kv_bytes_dev: float,
    opt_bytes_dev: float,
    coll_bytes_dev: float,
) -> dict:
    """Compile-free per-class decomposition of a cell, in the
    ``CostLedger.class_sums()`` shape: model FLOPs are matmul-class work
    streaming the weights, activations/optimizer state are elementwise
    traffic, KV-cache movement is data movement, collectives are
    collectives.  Deliberately coarse — the forest corrects it; its job is
    carrying the right *shares* across architectures."""
    return {
        "matmul": {"flops": model_flops_dev, "hbm_bytes": param_bytes_dev,
                   "collective_bytes": 0.0},
        "elementwise": {"flops": 0.0,
                        "hbm_bytes": act_bytes_dev + opt_bytes_dev,
                        "collective_bytes": 0.0},
        "data_movement": {"flops": 0.0, "hbm_bytes": kv_bytes_dev,
                          "collective_bytes": 0.0},
        "collective": {"flops": 0.0, "hbm_bytes": 0.0,
                       "collective_bytes": coll_bytes_dev},
    }


def cell_features(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh_dims: tuple[int, ...],
    device: DeviceSpec,
) -> np.ndarray:
    """One feature row (``LM_FEATURE_NAMES`` order) — numpy only, no jax."""
    n_dev, n_data, n_model = mesh_split(tuple(mesh_dims))
    bpe = _BYTES_PER_EL.get(cfg.dtype, 2)
    V = cfg.padded_vocab()
    params = cfg.param_count()
    active = cfg.param_count(active_only=True)
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch

    # Per-device analytic decomposition.  These are deliberately coarse —
    # the forest corrects them from profiled ground truth; their job is to
    # carry the right *scaling* (linear in tokens, 1/n_dev in splits).
    model_flops_dev = model_flops_for_cell(cfg, shape) / n_dev
    param_bytes_dev = bpe * params / max(n_model, 1)
    act_bytes_dev = bpe * (tokens / max(n_data, 1)) * cfg.d_model \
        * max(cfg.n_layers, 1)
    kv_bytes_dev = 0.0
    if shape.kind != "train":
        kv_len = shape.seq_len + cfg.n_prefix
        kv_bytes_dev = (
            2.0 * bpe * (shape.global_batch / max(n_data, 1)) * kv_len
            * max(cfg.n_kv_heads, 1) * cfg.head_dim_ * max(cfg.n_layers, 1)
            / max(n_model, 1))
    opt_bytes_dev = 0.0
    if shape.kind == "train":
        # grads (model dtype) + adamw m/v slots (f32) per device
        opt_bytes_dev = (bpe + 2 * 4) * params / max(n_model, 1)
    # ring-model gradient/activation exchange: zero on a single device
    coll_bytes_dev = (
        2.0 * bpe * params / n_dev * (n_dev - 1) / n_dev if n_dev > 1 else 0.0)

    bytes_moved = param_bytes_dev + act_bytes_dev + kv_bytes_dev + opt_bytes_dev
    compute_s, memory_s, coll_s = (
        float(v) for v in lm_roofline_terms(
            model_flops_dev, bytes_moved, coll_bytes_dev, device))
    roofline_ms = device.combine_terms(compute_s, memory_s, coll_s) * 1e3

    vals = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
        cfg.d_ff, V, cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff_,
        cfg.ssm_state, cfg.n_encoder_layers, cfg.hybrid_period,
        params, active,
        float(cfg.is_moe), float(cfg.family == "ssm"),
        float(cfg.hybrid_period > 0), float(cfg.n_encoder_layers > 0),
        shape.seq_len, shape.global_batch, tokens,
        float(shape.kind == "train"), float(shape.kind == "prefill"),
        float(shape.kind == "decode"),
        n_dev, n_data, n_model,
        model_flops_dev, param_bytes_dev, act_bytes_dev, kv_bytes_dev,
        opt_bytes_dev, coll_bytes_dev,
        model_flops_dev / max(bytes_moved, 1.0),
        compute_s, memory_s, coll_s, roofline_ms,
        math.log10(device.peak_flops), math.log10(device.hbm_bw),
        math.log10(device.ici_bw), device.launch_overhead_s * 1e3,
        float(device.calibrated), device.idle_w, device.peak_w,
    )
    hist = class_histogram(analytic_class_sums(
        model_flops_dev, param_bytes_dev, act_bytes_dev, kv_bytes_dev,
        opt_bytes_dev, coll_bytes_dev))
    x = np.concatenate([np.asarray(vals, dtype=np.float64), hist])
    assert x.shape == (len(LM_FEATURE_NAMES),)
    return x


def query_cell(query, *, reduced_default: bool = True):
    """(cfg, shape) a :class:`~repro.engine.types.CostQuery` LM-cell query
    describes — the bridge from the engine's query language to campaign
    coordinates.  ``stage`` maps train→train and infer→prefill (admission
    asks about whole forward passes, not single decode steps)."""
    if query.arch is None:
        raise ValueError("not an LM-cell query (no arch id)")
    reduced = reduced_default if query.reduced is None else query.reduced
    cfg = get_config(query.arch, reduced=reduced)
    kind = "train" if query.stage == "train" else "prefill"
    return cfg, ShapeSpec("query", query.seq, query.bs, kind)


def feature_matrix(
    records: list[dict],
    *,
    device: "DeviceSpec | str | None" = None,
    classes_from: str = "analytic",
) -> np.ndarray:
    """(N, F) matrix from campaign ledger records (see ``runner.py`` for the
    schema).  ``device`` overrides the per-record device name — used to
    re-featurize one campaign under another device's constants.

    ``classes_from`` picks the provider of the per-class histogram block:
    ``"analytic"`` (default — what a bare query can also compute, the
    serving contract) or ``"ledger"`` (each record's measured
    ``cost_classes`` breakdown, for fit-time diagnostics and feature-
    importance studies; records without one keep the analytic row)."""
    if classes_from not in ("analytic", "ledger"):
        raise ValueError(f"classes_from must be 'analytic' or 'ledger', "
                         f"got {classes_from!r}")
    from repro.campaign.plan import CampaignCell, mesh_dims

    n_cls = len(CLASS_FEATURE_NAMES)
    rows = []
    for rec in records:
        cell = CampaignCell.from_dict(rec)
        cfg = get_config(cell.arch, reduced=cell.reduced)
        dev = resolve_device(device if device is not None else cell.device)
        row = cell_features(cfg, cell.shape, mesh_dims(cell.mesh), dev)
        if classes_from == "ledger" and rec.get("cost_classes"):
            row = row.copy()
            row[-n_cls:] = ledger_class_features(rec)
        rows.append(row)
    return np.stack(rows) if rows else np.zeros((0, len(LM_FEATURE_NAMES)))
