"""Resumable campaign execution over a durable JSONL ledger.

The runner is deliberately dumb-robust, in the way a long profiling
campaign on a flaky edge fleet has to be (paper §5.1.1's "profile once,
reuse forever" only works if "once" survives interruption):

* **Durable append-only ledger** — every measured cell is appended through
  ``core/fileio.append_jsonl`` (O_APPEND + fsync) the moment it finishes.
  A killed runner loses at most the cell in flight; a torn final line is
  dropped by the tolerant loader and simply re-measured.
* **Resume** — on start the runner loads the ledger and skips every cell
  already recorded (``ok`` *or* quarantined), so a restart continues where
  the previous run died instead of recompiling the grid.
* **Quarantine, don't abort** — a cell whose lowering/measurement raises is
  recorded as ``status:"failed"`` with the error and the campaign moves
  on.  Failed cells are NOT retried on restart (the failure is almost
  always deterministic — an unlowerable layout); ``retry_failed=True``
  opts back in after a fix.  ``cell_timeout_s`` extends the same
  policy to cells that *hang* instead of raising: the measurement is
  fenced on a daemon thread and a blown budget quarantines the cell as
  ``error:"timeout"``.
* **Sharding** — ``shard_index/num_shards`` split cells by a stable hash
  of the cell key, so N workers given the same plan partition the grid
  without coordination and may share one ledger file (appends from
  different processes never interleave).

The default measurement compiles the real step through
``launch/lowering.compile_cell`` (the dry-run machinery), records the
memory plan + trip-count-aware HLO cost parse, and — ProfilerBackend
style — times real executions of the compiled step.
"""

from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field

import numpy as np

from repro.campaign.plan import CampaignCell, CampaignPlan, mesh_dims
from repro.core.fileio import append_jsonl, load_jsonl_tolerant

__all__ = ["CampaignLedger", "CampaignRunner", "CellTimeout", "measure_cell"]


class CellTimeout(RuntimeError):
    """A cell's measurement exceeded the runner's ``cell_timeout_s``."""

# v2: records carry ``cost_classes`` (the per-op-class ledger breakdown)
# and ``device_fingerprint`` (checked at fit time — campaign/fit.py).
# v3: executed records add ``watts_proxy`` / ``energy_j`` (the device
# envelope's modelled draw at the measured phi) and the ``cost_classes``
# buckets gain a per-class dynamic ``energy_j``.  Loads are tolerant:
# v2 records simply lack the columns and the energy fits skip them.
# v4: records carry the planner's ``layout`` block (per-class collective
# bytes + memory split predicted by the sharding rules for this cell's
# mesh — distributed/collectives.layout_collectives), so fitted
# collective coefficients can be audited against the byte model that
# will consume them.
LEDGER_SCHEMA_VERSION = 4


class CampaignLedger:
    """Read/append view of a campaign's JSONL ledger.

    One record per measured cell attempt; the *last* record per cell key
    wins (a ``--retry-failed`` re-measurement supersedes the quarantined
    one).  See docs/campaign.md for the record schema."""

    def __init__(self, path: str):
        self.path = path
        self._by_key: dict[str, dict] = {}
        for rec in load_jsonl_tolerant(path):
            key = rec.get("key")
            if key:
                self._by_key[key] = rec

    # -- queries -----------------------------------------------------------

    def get(self, key: str) -> dict | None:
        return self._by_key.get(key)

    def records(self, status: str | None = None) -> list[dict]:
        recs = list(self._by_key.values())
        return recs if status is None else [
            r for r in recs if r.get("status") == status]

    @property
    def ok_keys(self) -> set[str]:
        return {k for k, r in self._by_key.items() if r.get("status") == "ok"}

    @property
    def failed_keys(self) -> set[str]:
        """The quarantine list — persisted in the ledger itself."""
        return {k for k, r in self._by_key.items()
                if r.get("status") == "failed"}

    # -- writes ------------------------------------------------------------

    def append(self, record: dict) -> None:
        append_jsonl(self.path, record)
        self._by_key[record["key"]] = record

    def __len__(self) -> int:
        return len(self._by_key)


def _materialize(spec_tree):
    """Zero-filled numpy inputs for a ShapeDtypeStruct tree (timing only
    exercises the compute graph; values are irrelevant)."""
    import jax

    return jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), spec_tree)


def measure_cell(
    cell: CampaignCell,
    *,
    repeats: int = 2,
    warmup: int = 1,
    run: bool = True,
) -> dict:
    """Ground truth for one cell: compile (via the shared dry-run lowering),
    read the memory plan + HLO cost parse, and time real executions.

    ``run=False`` skips execution (compile-only campaign — e.g. planning
    meshes far larger than the host): ``phi_ms`` is then 0 and the fit
    must use the HLO terms only."""
    import jax

    from repro.configs.registry import get_config
    from repro.core.hlo_cost import parse_hlo_cost
    from repro.core.profiler import memory_analysis_bytes
    from repro.engine.devices import resolve_device
    from repro.launch.lowering import compile_cell
    from repro.launch.mesh import make_mesh

    cfg = get_config(cell.arch, reduced=cell.reduced)
    dims = mesh_dims(cell.mesh)
    axes = ("pod", "data", "model")[-len(dims):]
    mesh = make_mesh(dims, axes)

    # donate=False: timing calls the executable repeatedly with the same
    # buffers — donation would invalidate them after the first call.
    compiled, arg_specs, compile_s = compile_cell(
        cfg, cell.shape, mesh, donate=not run)

    mb = memory_analysis_bytes(compiled)
    cost = parse_hlo_cost(compiled.as_text())

    phi_ms = 0.0
    if run:
        args = tuple(_materialize(s) for s in arg_specs)
        with mesh:
            out = compiled(*args)
            jax.block_until_ready(out)  # warm transfer + dispatch path
            for _ in range(max(warmup - 1, 0)):
                jax.block_until_ready(compiled(*args))
            times = []
            for _ in range(max(repeats, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(compiled(*args))
                times.append(time.perf_counter() - t0)
        phi_ms = float(np.median(times)) * 1e3

    # Watts proxy (schema v3): the device envelope's modelled average draw
    # at the measured wall time, and the step energy it implies.  Zero
    # when the cell didn't execute (no wall time to integrate over) or
    # the spec declares no envelope — fits skip zero energy columns.
    from repro.engine.decompose import price_ledger_energy, watts_proxy

    dev = resolve_device(cell.device)
    watts = float(watts_proxy(cost.flops, phi_ms / 1e3, dev)) if run else 0.0

    # Planner accounting for this cell: the per-class collective bytes and
    # memory split the sharding rules *predict* for this layout, logged
    # next to the measured HLO counts so the fitted collective coefficient
    # and the planner's byte model can be compared cell-by-cell (the
    # planner's decisions feed back into the fit via this block).  A real
    # jax Mesh satisfies the abstract-mesh protocol (axis_names +
    # devices.shape are all that's read).
    from repro.distributed.collectives import layout_collectives

    layout = layout_collectives(cfg, cell.shape, mesh).to_dict()
    return {
        "gamma_mb": (mb["arg"] + mb["out"] + mb["temp"] + mb["code"]) / 1e6,
        "phi_ms": phi_ms,
        "compile_s": compile_s,
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "watts_proxy": watts,
        "energy_j": watts * phi_ms / 1e3,
        # Per-op-class ledger breakdown (sums reproduce the three scalars
        # above exactly — the costmodel parity contract; the energy bucket
        # is the envelope-priced per-op dynamic joules) + the fingerprint
        # of the device constants this cell was measured under, checked at
        # fit time against the spec that will featurize it.
        "cost_classes": price_ledger_energy(cost.ledger, dev).class_sums(),
        "layout": layout,
        "device_fingerprint": dev.fingerprint(),
        "temp_mb": mb["temp"] / 1e6,
        "arg_mb": mb["arg"] / 1e6,
        "n_devices": int(mesh.devices.size),
        "executed": bool(run),
    }


@dataclass
class CampaignRunner:
    """Drive a plan's cells through ``measure`` into the ledger.

    ``measure`` is injectable (tests use a deterministic fake; a TPU
    campaign could wrap ``measure_cell`` with device pinning); it takes a
    :class:`CampaignCell` and returns the measurement dict merged into the
    ledger record."""

    plan: CampaignPlan
    ledger: "CampaignLedger | str"
    measure: "callable" = None
    repeats: int = 2
    warmup: int = 1
    run: bool = True
    retry_failed: bool = False
    cell_timeout_s: "float | None" = None
    extra_meta: dict = field(default_factory=dict)

    def __post_init__(self):
        if isinstance(self.ledger, str):
            self.ledger = CampaignLedger(self.ledger)
        if self.measure is None:
            self.measure = lambda cell: measure_cell(
                cell, repeats=self.repeats, warmup=self.warmup, run=self.run)

    # -- timeout fence -----------------------------------------------------

    def _measure_fenced(self, cell: CampaignCell) -> dict:
        """``measure(cell)`` under the per-cell wall-clock budget.

        A hung cell (an XLA compile that never returns, a wedged device)
        would otherwise stall the whole campaign — the one failure mode
        quarantine-on-exception can't catch.  The measurement runs on a
        daemon thread; past ``cell_timeout_s`` the runner abandons it
        (the thread can't be killed, but daemon threads don't block
        process exit) and raises :class:`CellTimeout`, which the loop
        quarantines like any other deterministic failure."""
        if self.cell_timeout_s is None:
            return self.measure(cell)
        box: dict = {}

        def work():
            try:
                box["result"] = self.measure(cell)
            except BaseException as e:          # noqa: BLE001 — re-raised below
                box["error"] = e

        t = threading.Thread(target=work, daemon=True,
                             name=f"cell-{cell.key[:8]}")
        t.start()
        t.join(self.cell_timeout_s)
        if t.is_alive():
            raise CellTimeout(
                f"cell {cell.key[:8]} ({cell.arch} × {cell.shape.name}) "
                f"exceeded {self.cell_timeout_s:.1f}s")
        if "error" in box:
            raise box["error"]
        return box["result"]

    # -- work selection ----------------------------------------------------

    def shard_cells(self, shard_index: int = 0, num_shards: int = 1) -> list[CampaignCell]:
        """Deterministic partition by cell-key hash: independent of ledger
        state, so workers never race for (or orphan) a cell."""
        if not (0 <= shard_index < num_shards):
            raise ValueError(f"shard {shard_index} outside 0..{num_shards - 1}")
        return [c for c in self.plan.cells
                if int(c.key[:8], 16) % num_shards == shard_index]

    def pending(self, shard_index: int = 0, num_shards: int = 1) -> list[CampaignCell]:
        done = self.ledger.ok_keys
        if not self.retry_failed:
            done = done | self.ledger.failed_keys
        return [c for c in self.shard_cells(shard_index, num_shards)
                if c.key not in done]

    # -- the loop ----------------------------------------------------------

    def run_campaign(
        self,
        shard_index: int = 0,
        num_shards: int = 1,
        *,
        max_cells: int | None = None,
        print_fn=None,
    ) -> dict:
        """Measure every pending cell of this shard; returns a summary.

        ``max_cells`` bounds the number of *measurements this call* makes
        (not the grid) — used by tests to simulate a mid-grid kill and by
        budgeted overnight runs."""
        say = print_fn or (lambda *_: None)
        shard = self.shard_cells(shard_index, num_shards)
        pending = self.pending(shard_index, num_shards)
        say(f"campaign {self.plan.plan_hash}: shard {shard_index + 1}/"
            f"{num_shards} has {len(shard)} cells, {len(pending)} pending, "
            f"{len(self.ledger.failed_keys)} quarantined")
        measured = failed = 0
        for cell in pending:
            if max_cells is not None and measured + failed >= max_cells:
                break
            base = {
                **cell.to_dict(),
                "key": cell.key,
                "plan_hash": self.plan.plan_hash,
                "schema": LEDGER_SCHEMA_VERSION,
                **self.extra_meta,
            }
            try:
                result = self._measure_fenced(cell)
            except KeyboardInterrupt:
                raise
            except Exception as e:
                failed += 1
                say(f"QUARANTINE {cell.arch} × {cell.shape.name} "
                    f"[{cell.mesh}]: {e}")
                # Timeouts get a stable machine-readable error tag (the
                # human detail lives in the trace) so downstream tooling
                # can count hung cells apart from crashed ones.
                err = ("timeout" if isinstance(e, CellTimeout)
                       else f"{type(e).__name__}: {e}")
                self.ledger.append({
                    **base, "status": "failed", "error": err,
                    "trace": (str(e) if isinstance(e, CellTimeout)
                              else traceback.format_exc(limit=5)),
                })
                continue
            measured += 1
            self.ledger.append({**base, "status": "ok", **result})
            say(f"ok {cell.arch} × {cell.shape.name} [{cell.mesh}]: "
                f"gamma={result['gamma_mb']:.1f}MB phi={result['phi_ms']:.2f}ms"
                f" (compile {result.get('compile_s', 0):.1f}s)")
        return {
            "shard_cells": len(shard),
            "measured": measured,
            "failed": failed,
            "remaining": len(self.pending(shard_index, num_shards)),
            "ledger_records": len(self.ledger),
        }
