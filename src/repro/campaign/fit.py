"""Fit campaign ledgers into serveable artifacts.

Two fits come out of one ledger:

* :func:`fit_lm_forest` — an :class:`LMForest` (one hybrid ridge+forest per
  attribute, the same ``core/forest`` machinery as the CNN predictor) over
  the compile-free ``lm_features`` rows.  Registered with
  :class:`~repro.engine.backends.ForestBackend`, it answers LM-cell
  ``CostQuery``s in microseconds with **zero jax compiles** — the paper's
  "fit once, predict forever" loop closed for the LM workloads.
* :func:`fit_hlo_constants` — NNLS of the ``parse_hlo_cost`` roofline terms
  (the ROADMAP's "calibrate the LM/HLO path" item): solves for effective
  peak FLOP/s, HBM bandwidth, ICI bandwidth and launch overhead from the
  same ledger, returning a ``calibrated=True`` DeviceSpec for the
  analytical backend's LM path.

Both artifacts persist atomically (``core/fileio``) — NPZ for the packed
forest arrays, JSON for metadata/constants — and carry the plan hash +
device fingerprint they were fitted from.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import replace

import numpy as np

from repro.campaign.lm_features import (
    LM_FEATURE_NAMES,
    cell_features,
    feature_matrix,
    query_cell,
)
from repro.campaign.plan import mesh_dims
from repro.core.fileio import atomic_write_bytes, atomic_write_json
from repro.core.predictor import HybridRegressor, mape
from repro.engine.calibrate import nnls
from repro.engine.decompose import lm_roofline_terms
from repro.engine.devices import DeviceSpec, resolve_device

__all__ = [
    "LMForest",
    "split_records",
    "fit_lm_forest",
    "fit_hlo_constants",
    "register_lm_forest",
]


class LMForest:
    """Campaign-fitted (Γ, Φ) predictor for LM cells.

    Prediction is numpy-only: features come from ``lm_features`` (no jax,
    no lowering), the regressors are the repo's own ridge+forest hybrids.
    ``meta`` records provenance (plan hash, device, mesh, holdout MAPEs);
    ``default_device``/``default_mesh`` fill in the coordinates a bare
    ``CostQuery`` doesn't carry."""

    def __init__(self, *, n_estimators: int = 60, min_samples_leaf: int = 1,
                 seed: int = 0):
        kw = dict(n_estimators=n_estimators,
                  min_samples_leaf=min_samples_leaf, max_features="third")
        self.gamma_model = HybridRegressor(seed=seed, **kw)
        self.phi_model = HybridRegressor(seed=seed + 1, **kw)
        self.meta: dict = {}
        self.fitted = False

    # -- coordinates -------------------------------------------------------

    @property
    def default_device(self) -> DeviceSpec:
        d = self.meta.get("device_spec")
        return DeviceSpec.from_dict(d) if d else resolve_device(
            self.meta.get("device", "host_cpu"))

    @property
    def default_mesh(self) -> tuple[int, ...]:
        return tuple(self.meta.get("mesh_dims", (1, 1)))

    # -- prediction --------------------------------------------------------

    def predict_features(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        return self.gamma_model.predict(X), self.phi_model.predict(X)

    def predict_queries(self, queries, *, device: DeviceSpec | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Batched (Γ MB, Φ ms) for engine ``CostQuery``s — one feature
        build + one packed traversal per attribute, zero compiles."""
        dev = device or self.default_device
        mesh = self.default_mesh
        reduced_default = bool(self.meta.get("reduced", True))
        X = np.stack([
            cell_features(*query_cell(q, reduced_default=reduced_default),
                          mesh, dev)
            for q in queries
        ])
        return self.predict_features(X)

    # -- identity / persistence -------------------------------------------

    def content_hash(self) -> str:
        h = hashlib.sha1()
        h.update(self.gamma_model.content_hash().encode())
        h.update(self.phi_model.content_hash().encode())
        h.update(json.dumps(self.meta.get("device_spec", {}),
                            sort_keys=True, default=str).encode())
        return h.hexdigest()

    def save(self, path: str) -> None:
        """Atomic persist; ``.npz`` packs the forest arrays (compact),
        ``.json`` keeps the nested dicts (inspectable).  Metadata rides in
        both."""
        if path.endswith(".npz"):
            arrays: dict[str, np.ndarray] = {}
            for prefix, model in (("gamma_", self.gamma_model),
                                  ("phi_", self.phi_model)):
                arrays.update(model.to_arrays(prefix))
            meta = json.dumps({"meta": self.meta,
                               "feature_names": list(LM_FEATURE_NAMES)})
            arrays["campaign_meta"] = np.frombuffer(meta.encode(), dtype=np.uint8)
            atomic_write_bytes(path, lambda f: np.savez_compressed(f, **arrays),
                               suffix=".npz")
            return
        atomic_write_json(path, {
            "meta": self.meta, "feature_names": list(LM_FEATURE_NAMES),
            "gamma": self.gamma_model.to_dict(),
            "phi": self.phi_model.to_dict(),
        })

    @classmethod
    def load(cls, path: str) -> "LMForest":
        self = cls()
        if path.endswith(".npz"):
            with np.load(path) as arrays:
                header = json.loads(
                    bytes(arrays["campaign_meta"].tobytes()).decode())
                self.gamma_model = HybridRegressor.from_arrays(arrays, "gamma_")
                self.phi_model = HybridRegressor.from_arrays(arrays, "phi_")
        else:
            with open(path) as f:
                blob = json.load(f)
            header = blob
            self.gamma_model = HybridRegressor.from_dict(blob["gamma"])
            self.phi_model = HybridRegressor.from_dict(blob["phi"])
        names = header.get("feature_names", [])
        if names and list(names) != list(LM_FEATURE_NAMES):
            raise ValueError(
                f"{path} was fitted on a different feature set "
                f"({len(names)} features vs {len(LM_FEATURE_NAMES)}); refit "
                "the campaign with `python -m repro.campaign fit`")
        self.meta = header.get("meta", {})
        self.fitted = True
        return self


def _ok_records(records) -> list[dict]:
    recs = [r for r in records if r.get("status") == "ok"]
    if not recs:
        raise ValueError("no status:'ok' records in the ledger — run the "
                         "campaign first (python -m repro.campaign run)")
    return recs


def split_records(records, *, holdout_frac: float = 0.25, seed: int = 0
                  ) -> tuple[list[dict], list[dict]]:
    """Deterministic train/holdout split of ok-records, stratified nowhere —
    cells are i.i.d. grid points; the seed makes the held-out MAPE a stable
    regression metric."""
    recs = _ok_records(records)
    n_hold = int(round(holdout_frac * len(recs)))
    if len(recs) >= 4:
        n_hold = max(n_hold, 1)
    n_hold = min(n_hold, len(recs) - 2) if len(recs) > 2 else 0
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(recs))
    hold = {int(i) for i in idx[:n_hold]}
    train = [r for i, r in enumerate(recs) if i not in hold]
    heldout = [r for i, r in enumerate(recs) if i in hold]
    return train, heldout


def fit_lm_forest(
    records: list[dict],
    *,
    device: "DeviceSpec | str | None" = None,
    holdout_frac: float = 0.25,
    seed: int = 0,
    n_estimators: int = 60,
) -> LMForest:
    """Grow the (Γ, Φ) forests from ledger records.

    The model is fitted on the train split only; the held-out MAPEs in
    ``meta`` are therefore honest generalization numbers (the acceptance
    gate ``benchmarks/check_thresholds.py`` compares them against the
    uncalibrated analytical path).

    ``device=None`` (the default) featurizes each record under its OWN
    recorded device — the fleet case: a multi-device campaign keeps every
    row's constants truthful, and the forest learns the device dimension.
    Pass a device only to deliberately re-featurize one campaign under
    another spec (e.g. a freshly calibrated one)."""
    train, heldout = split_records(records, holdout_frac=holdout_frac,
                                   seed=seed)
    # Query-time default coordinates: the explicit override, else the
    # (single) campaign device; a mixed-device ledger keeps per-row truth
    # in the features and the first device only as the query default.
    dev = resolve_device(device if device is not None
                         else train[0].get("device", "host_cpu"))

    def targets(recs):
        return (np.array([r["gamma_mb"] for r in recs], dtype=np.float64),
                np.array([r["phi_ms"] for r in recs], dtype=np.float64))

    X = feature_matrix(train, device=device)
    g, p = targets(train)
    forest = LMForest(n_estimators=n_estimators, seed=seed)
    forest.gamma_model.fit(X, g)
    forest.phi_model.fit(X, p)
    forest.fitted = True

    meta = {
        "n_train": len(train), "n_heldout": len(heldout),
        "plan_hash": train[0].get("plan_hash"),
        "devices": sorted({r.get("device", "host_cpu") for r in train}),
        "device": dev.name, "device_spec": dev.to_dict(),
        "device_fingerprint": dev.fingerprint(),
        "mesh_dims": list(mesh_dims(train[0].get("mesh", "1x1"))),
        "reduced": bool(train[0].get("reduced", True)),
        "oob_gamma_mape": forest.gamma_model.oob_mape_,
        "oob_phi_mape": forest.phi_model.oob_mape_,
    }
    if heldout:
        Xh = feature_matrix(heldout, device=device)
        gh, ph = targets(heldout)
        pg, pp = forest.predict_features(Xh)
        meta["holdout_gamma_mape"] = mape(pg, gh)
        meta["holdout_phi_mape"] = mape(pp, ph)
    forest.meta = meta
    return forest


def fit_hlo_constants(
    records: list[dict],
    *,
    base_device: "DeviceSpec | str | None" = None,
    name: str | None = None,
) -> DeviceSpec:
    """NNLS-fit the ``parse_hlo_cost`` roofline constants from the ledger.

    Solves  phi_s = c0 + c1·flops + c2·hbm_bytes + c3·collective_bytes
    with c ≥ 0 over the executed cells, then inverts the coefficients into
    the DeviceSpec denominators (``lm_roofline_terms`` divides by exactly
    these) — the same Lawson–Hanson machinery as the CNN calibration
    (``engine/calibrate.nnls``), applied to the LM/HLO decomposition."""
    recs = [r for r in _ok_records(records) if r.get("phi_ms", 0) > 0]
    if len(recs) < 4:
        raise ValueError(f"need >= 4 executed cells to fit 4 constants, "
                         f"have {len(recs)}")
    base = resolve_device(base_device if base_device is not None
                          else recs[0].get("device", "host_cpu"))
    flops = np.array([r["flops"] for r in recs], dtype=np.float64)
    hbm = np.array([r["hbm_bytes"] for r in recs], dtype=np.float64)
    coll = np.array([r["collective_bytes"] for r in recs], dtype=np.float64)
    phi_s = np.array([r["phi_ms"] for r in recs], dtype=np.float64) / 1e3

    A = np.stack([np.ones_like(phi_s), flops, hbm, coll], axis=1)
    c = nnls(A, phi_s)
    # Inert (never-binding) terms keep a finite, serializable denominator.
    spec = replace(
        base,
        name=name or f"{base.name}_lm_calibrated",
        peak_flops=1.0 / c[1] if c[1] > 0 else 1e18,
        hbm_bw=1.0 / c[2] if c[2] > 0 else 1e18,
        ici_bw=1.0 / c[3] if c[3] > 0 else 1e18,
        launch_overhead_s=float(c[0]),
        combine="sum",
        calibrated=True,
        meta={
            "base_device": base.name,
            "n_cells": len(recs),
            "plan_hash": recs[0].get("plan_hash"),
            "phi_mape": float(mape(A @ c, phi_s)),
            "fit": "campaign_hlo_nnls",
        },
    )
    # Self-check through the shared terms: predictions must reproduce A @ c.
    t = lm_roofline_terms(flops, hbm, coll, spec)
    assert np.allclose(spec.launch_overhead_s + sum(t), A @ c, rtol=1e-6)
    return spec


def register_lm_forest(target, forest: LMForest):
    """Attach a fitted forest to the engine's prediction path.

    ``target`` may be a :class:`~repro.engine.engine.CostEngine`, an
    :class:`~repro.engine.backends.EnsembleBackend`, or a
    :class:`~repro.engine.backends.ForestBackend`; the first ForestBackend
    found gets ``forest`` as its LM model (its ``cache_salt`` changes with
    it, so stale on-disk estimates can't be served).  Returns the backend
    that now owns the forest."""
    from repro.engine.backends import EnsembleBackend, ForestBackend
    from repro.engine.engine import CostEngine

    if isinstance(target, CostEngine):
        return register_lm_forest(target.backend, forest)
    if isinstance(target, EnsembleBackend):
        for b in target.backends:
            if isinstance(b, ForestBackend):
                return register_lm_forest(b, forest)
        raise ValueError("no ForestBackend in the ensemble chain to attach "
                         "the LM forest to")
    if isinstance(target, ForestBackend):
        target.lm = forest
        return target
    raise TypeError(f"cannot register an LM forest on {type(target).__name__}")
