"""Fit campaign ledgers into serveable artifacts.

Two fits come out of one ledger:

* :func:`fit_lm_forest` — an :class:`LMForest` (one hybrid ridge+forest per
  attribute, the same ``core/forest`` machinery as the CNN predictor) over
  the compile-free ``lm_features`` rows.  Registered with
  :class:`~repro.engine.backends.ForestBackend`, it answers LM-cell
  ``CostQuery``s in microseconds with **zero jax compiles** — the paper's
  "fit once, predict forever" loop closed for the LM workloads.
* :func:`fit_hlo_constants` — NNLS of the ``parse_hlo_cost`` roofline terms
  (the ROADMAP's "calibrate the LM/HLO path" item): solves for effective
  peak FLOP/s, HBM bandwidth, ICI bandwidth and launch overhead from the
  same ledger, returning a ``calibrated=True`` DeviceSpec for the
  analytical backend's LM path.

Both artifacts persist atomically (``core/fileio``) — NPZ for the packed
forest arrays, JSON for metadata/constants — and carry the plan hash +
device fingerprint they were fitted from.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import replace

import numpy as np

from repro.campaign.lm_features import (
    LM_FEATURE_NAMES,
    cell_features,
    feature_matrix,
    query_cell,
)
from repro.campaign.plan import mesh_dims
from repro.core.fileio import atomic_write_bytes, atomic_write_json
from repro.core.predictor import HybridRegressor, mape
from repro.engine.calibrate import nnls
from repro.engine.decompose import (
    classwise_seconds,
    ledger_latency_columns,
    lm_roofline_terms,
)
from repro.engine.devices import DeviceSpec, resolve_device

__all__ = [
    "LMForest",
    "split_records",
    "check_device_fingerprints",
    "fit_lm_forest",
    "fit_hlo_constants",
    "register_lm_forest",
]


class LMForest:
    """Campaign-fitted (Γ, Φ) predictor for LM cells.

    Prediction is numpy-only: features come from ``lm_features`` (no jax,
    no lowering), the regressors are the repo's own ridge+forest hybrids.
    ``meta`` records provenance (plan hash, device, mesh, holdout MAPEs);
    ``default_device``/``default_mesh`` fill in the coordinates a bare
    ``CostQuery`` doesn't carry."""

    def __init__(self, *, n_estimators: int = 60, min_samples_leaf: int = 1,
                 seed: int = 0):
        kw = dict(n_estimators=n_estimators,
                  min_samples_leaf=min_samples_leaf, max_features="third")
        self.gamma_model = HybridRegressor(seed=seed, **kw)
        self.phi_model = HybridRegressor(seed=seed + 1, **kw)
        # Energy is optional: only campaigns whose ledgers carry the v3
        # watts-proxy column grow it; ``energy_fitted`` gates prediction
        # (and persistence) so pre-energy artifacts stay loadable.
        self.energy_model = HybridRegressor(seed=seed + 2, **kw)
        self.energy_fitted = False
        self.meta: dict = {}
        self.fitted = False

    # -- coordinates -------------------------------------------------------

    @property
    def default_device(self) -> DeviceSpec:
        d = self.meta.get("device_spec")
        return DeviceSpec.from_dict(d) if d else resolve_device(
            self.meta.get("device", "host_cpu"))

    @property
    def default_mesh(self) -> tuple[int, ...]:
        return tuple(self.meta.get("mesh_dims", (1, 1)))

    # -- prediction --------------------------------------------------------

    def predict_features(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        return self.gamma_model.predict(X), self.phi_model.predict(X)

    def predict_queries(self, queries, *, device: DeviceSpec | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Batched (Γ MB, Φ ms) for engine ``CostQuery``s — one feature
        build + one packed traversal per attribute, zero compiles."""
        dev = device or self.default_device
        mesh = self.default_mesh
        reduced_default = bool(self.meta.get("reduced", True))
        X = np.stack([
            cell_features(*query_cell(q, reduced_default=reduced_default),
                          mesh, dev)
            for q in queries
        ])
        return self.predict_features(X)

    def predict_energy(self, queries, *, device: DeviceSpec | None = None
                       ) -> np.ndarray:
        """Batched per-step energy (J) for engine ``CostQuery``s — zeros
        when the fitting campaign carried no energy column."""
        dev = device or self.default_device
        mesh = self.default_mesh
        reduced_default = bool(self.meta.get("reduced", True))
        if not self.energy_fitted:
            return np.zeros(len(list(queries)), dtype=np.float64)
        X = np.stack([
            cell_features(*query_cell(q, reduced_default=reduced_default),
                          mesh, dev)
            for q in queries
        ])
        return self.energy_model.predict(np.atleast_2d(X))

    # -- identity / persistence -------------------------------------------

    def content_hash(self) -> str:
        h = hashlib.sha1()
        h.update(self.gamma_model.content_hash().encode())
        h.update(self.phi_model.content_hash().encode())
        if self.energy_fitted:  # pre-energy forests keep their old hash
            h.update(self.energy_model.content_hash().encode())
        h.update(json.dumps(self.meta.get("device_spec", {}),
                            sort_keys=True, default=str).encode())
        return h.hexdigest()

    def save(self, path: str) -> None:
        """Atomic persist; ``.npz`` packs the forest arrays (compact),
        ``.json`` keeps the nested dicts (inspectable).  Metadata rides in
        both."""
        if path.endswith(".npz"):
            arrays: dict[str, np.ndarray] = {}
            models = [("gamma_", self.gamma_model), ("phi_", self.phi_model)]
            if self.energy_fitted:
                models.append(("energy_", self.energy_model))
            for prefix, model in models:
                arrays.update(model.to_arrays(prefix))
            meta = json.dumps({"meta": self.meta,
                               "energy_fitted": self.energy_fitted,
                               "feature_names": list(LM_FEATURE_NAMES)})
            arrays["campaign_meta"] = np.frombuffer(meta.encode(), dtype=np.uint8)
            atomic_write_bytes(path, lambda f: np.savez_compressed(f, **arrays),
                               suffix=".npz")
            return
        blob = {
            "meta": self.meta, "feature_names": list(LM_FEATURE_NAMES),
            "energy_fitted": self.energy_fitted,
            "gamma": self.gamma_model.to_dict(),
            "phi": self.phi_model.to_dict(),
        }
        if self.energy_fitted:
            blob["energy"] = self.energy_model.to_dict()
        atomic_write_json(path, blob)

    @classmethod
    def load(cls, path: str) -> "LMForest":
        self = cls()
        if path.endswith(".npz"):
            with np.load(path) as arrays:
                header = json.loads(
                    bytes(arrays["campaign_meta"].tobytes()).decode())
                self.gamma_model = HybridRegressor.from_arrays(arrays, "gamma_")
                self.phi_model = HybridRegressor.from_arrays(arrays, "phi_")
                # Tolerant of pre-energy artifacts: the flag (and arrays)
                # only exist when the fitting ledger carried energy.
                if header.get("energy_fitted"):
                    self.energy_model = HybridRegressor.from_arrays(
                        arrays, "energy_")
                    self.energy_fitted = True
        else:
            with open(path) as f:
                blob = json.load(f)
            header = blob
            self.gamma_model = HybridRegressor.from_dict(blob["gamma"])
            self.phi_model = HybridRegressor.from_dict(blob["phi"])
            if blob.get("energy_fitted") and "energy" in blob:
                self.energy_model = HybridRegressor.from_dict(blob["energy"])
                self.energy_fitted = True
        names = header.get("feature_names", [])
        if names and list(names) != list(LM_FEATURE_NAMES):
            raise ValueError(
                f"{path} was fitted on a different feature set "
                f"({len(names)} features vs {len(LM_FEATURE_NAMES)}); refit "
                "the campaign with `python -m repro.campaign fit`")
        self.meta = header.get("meta", {})
        self.fitted = True
        return self


def _ok_records(records) -> list[dict]:
    recs = [r for r in records if r.get("status") == "ok"]
    if not recs:
        raise ValueError("no status:'ok' records in the ledger — run the "
                         "campaign first (python -m repro.campaign run)")
    return recs


def check_device_fingerprints(records, *, device=None,
                              allow_mixed: bool = False) -> dict:
    """Refuse to fit a ledger whose records were measured under different
    device constants than the spec that will featurize them (ROADMAP "per-
    record device fingerprints checked at fit time").

    Each v2+ record carries the ``DeviceSpec.fingerprint()`` it was
    measured under; if the spec resolving for that record NOW (the
    ``device`` override, else the record's own device name) hashes
    differently — a recalibration, an edited persisted spec, a
    ``--device`` re-featurization — the ledger's device-scaled features
    would silently disagree with the recorded ground truth.  Raises
    ``ValueError`` listing the mismatches unless ``allow_mixed`` (CLI
    ``--allow-mixed``) opts in; pre-fingerprint records pass (nothing to
    check).  Returns ``{checked, unstamped, mismatched}`` counts."""
    mismatched: list[str] = []
    checked = unstamped = 0
    fp_cache: dict[str, str] = {}
    for r in records:
        stamped = r.get("device_fingerprint")
        if not stamped:
            unstamped += 1
            continue
        checked += 1
        name = r.get("device", "host_cpu") if device is None else device
        key = name if isinstance(name, str) else repr(name)
        if key not in fp_cache:
            fp_cache[key] = resolve_device(name).fingerprint()
        if stamped != fp_cache[key]:
            mismatched.append(
                f"{r.get('arch')}×{r.get('shape', {}).get('name')} "
                f"[{r.get('device', 'host_cpu')}]: measured under "
                f"{stamped}, would featurize under {fp_cache[key]}")
    if mismatched and not allow_mixed:
        shown = "; ".join(mismatched[:3])
        raise ValueError(
            f"{len(mismatched)}/{checked} ledger records were measured under "
            f"different device constants than the fit would use ({shown}"
            f"{' …' if len(mismatched) > 3 else ''}); re-run the campaign or "
            "pass allow_mixed=True / --allow-mixed to fit anyway")
    return {"checked": checked, "unstamped": unstamped,
            "mismatched": len(mismatched)}


def split_records(records, *, holdout_frac: float = 0.25, seed: int = 0
                  ) -> tuple[list[dict], list[dict]]:
    """Deterministic train/holdout split of ok-records, stratified nowhere —
    cells are i.i.d. grid points; the seed makes the held-out MAPE a stable
    regression metric."""
    recs = _ok_records(records)
    n_hold = int(round(holdout_frac * len(recs)))
    if len(recs) >= 4:
        n_hold = max(n_hold, 1)
    n_hold = min(n_hold, len(recs) - 2) if len(recs) > 2 else 0
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(recs))
    hold = {int(i) for i in idx[:n_hold]}
    train = [r for i, r in enumerate(recs) if i not in hold]
    heldout = [r for i, r in enumerate(recs) if i in hold]
    return train, heldout


def fit_lm_forest(
    records: list[dict],
    *,
    device: "DeviceSpec | str | None" = None,
    holdout_frac: float = 0.25,
    seed: int = 0,
    n_estimators: int = 60,
    allow_mixed: bool = False,
) -> LMForest:
    """Grow the (Γ, Φ) forests from ledger records.

    The model is fitted on the train split only; the held-out MAPEs in
    ``meta`` are therefore honest generalization numbers (the acceptance
    gate ``benchmarks/check_thresholds.py`` compares them against the
    uncalibrated analytical path).

    ``device=None`` (the default) featurizes each record under its OWN
    recorded device — the fleet case: a multi-device campaign keeps every
    row's constants truthful, and the forest learns the device dimension.
    Pass a device only to deliberately re-featurize one campaign under
    another spec (e.g. a freshly calibrated one) — that trips the
    fingerprint guard (:func:`check_device_fingerprints`) and therefore
    needs ``allow_mixed=True``."""
    fp_check = check_device_fingerprints(_ok_records(records), device=device,
                                         allow_mixed=allow_mixed)
    train, heldout = split_records(records, holdout_frac=holdout_frac,
                                   seed=seed)
    # Query-time default coordinates: the explicit override, else the
    # (single) campaign device; a mixed-device ledger keeps per-row truth
    # in the features and the first device only as the query default.
    dev = resolve_device(device if device is not None
                         else train[0].get("device", "host_cpu"))

    def targets(recs):
        return (np.array([r["gamma_mb"] for r in recs], dtype=np.float64),
                np.array([r["phi_ms"] for r in recs], dtype=np.float64))

    X = feature_matrix(train, device=device)
    g, p = targets(train)
    forest = LMForest(n_estimators=n_estimators, seed=seed)
    forest.gamma_model.fit(X, g)
    forest.phi_model.fit(X, p)
    forest.fitted = True

    # Energy forest — only when every train row carries the v3 watts-proxy
    # column (a mixed v2/v3 ledger would teach the model that re-measured
    # cells cost 0 J).
    e = np.array([r.get("energy_j", 0.0) or 0.0 for r in train],
                 dtype=np.float64)
    if np.all(e > 0):
        forest.energy_model.fit(X, e)
        forest.energy_fitted = True

    meta = {
        "energy_fitted": forest.energy_fitted,
        "n_train": len(train), "n_heldout": len(heldout),
        "plan_hash": train[0].get("plan_hash"),
        "devices": sorted({r.get("device", "host_cpu") for r in train}),
        "device": dev.name, "device_spec": dev.to_dict(),
        "device_fingerprint": dev.fingerprint(),
        "mesh_dims": list(mesh_dims(train[0].get("mesh", "1x1"))),
        "reduced": bool(train[0].get("reduced", True)),
        "fingerprint_check": fp_check,
        "oob_gamma_mape": forest.gamma_model.oob_mape_,
        "oob_phi_mape": forest.phi_model.oob_mape_,
    }
    if heldout:
        Xh = feature_matrix(heldout, device=device)
        gh, ph = targets(heldout)
        pg, pp = forest.predict_features(Xh)
        meta["holdout_gamma_mape"] = mape(pg, gh)
        meta["holdout_phi_mape"] = mape(pp, ph)
        if forest.energy_fitted:
            eh = np.array([r.get("energy_j", 0.0) or 0.0 for r in heldout],
                          dtype=np.float64)
            if np.all(eh > 0):
                meta["holdout_energy_mape"] = mape(
                    forest.energy_model.predict(Xh), eh)
    forest.meta = meta
    return forest


def fit_hlo_constants(
    records: list[dict],
    *,
    base_device: "DeviceSpec | str | None" = None,
    name: str | None = None,
    per_class: bool = True,
    allow_mixed: bool = False,
) -> DeviceSpec:
    """NNLS-fit the ``parse_hlo_cost`` roofline constants from the ledger.

    The aggregate system — always solved, its constants landing in the
    classic DeviceSpec fields — is

        phi_s = c0 + c1·flops + c2·hbm_bytes + c3·collective_bytes

    with c ≥ 0 over the executed cells, coefficients inverted into the
    DeviceSpec denominators (``lm_roofline_terms`` divides by exactly
    these).  With ``per_class=True`` (default) and records carrying the
    v2 ``cost_classes`` breakdown, a refined system with one coefficient
    per ``decompose.LM_LATENCY_COLUMNS`` column (matmul vs elementwise vs
    collective …) is solved over the SAME cells; if its MAPE is no worse
    it lands in ``DeviceSpec.class_coeffs["lm_latency"]`` and the
    analytical backend prices ledgers class-wise.  The aggregate fit stays
    the documented fallback either way — ``meta`` records both MAPEs."""
    recs = [r for r in _ok_records(records) if r.get("phi_ms", 0) > 0]
    if len(recs) < 4:
        raise ValueError(f"need >= 4 executed cells to fit 4 constants, "
                         f"have {len(recs)}")
    check_device_fingerprints(recs, device=base_device,
                              allow_mixed=allow_mixed)
    # One NNLS system fits ONE device's constants.  A fleet ledger (the
    # forest's multi-device case) must be filtered per device first —
    # blending millisecond host rows with microsecond TPU rows would
    # 'calibrate' constants describing neither, with every per-record
    # fingerprint happily matching its own device.
    devices = {r.get("device", "host_cpu") for r in recs}
    if len(devices) > 1 and not allow_mixed:
        raise ValueError(
            f"fit_hlo_constants solves one device's constants but the "
            f"ledger spans {sorted(devices)}; filter records to a single "
            f"device or pass allow_mixed=True / --allow-mixed")
    base = resolve_device(base_device if base_device is not None
                          else recs[0].get("device", "host_cpu"))
    flops = np.array([r["flops"] for r in recs], dtype=np.float64)
    hbm = np.array([r["hbm_bytes"] for r in recs], dtype=np.float64)
    coll = np.array([r["collective_bytes"] for r in recs], dtype=np.float64)
    phi_s = np.array([r["phi_ms"] for r in recs], dtype=np.float64) / 1e3

    A = np.stack([np.ones_like(phi_s), flops, hbm, coll], axis=1)
    c = nnls(A, phi_s)
    phi_mape_agg = float(mape(A @ c, phi_s))

    # Class-wise refinement over the recorded ledger breakdowns.  Cells
    # without a breakdown (pre-v2 records) disable it — a partially
    # attributed system would bias the classes toward whichever cells
    # happened to carry one.
    class_coeffs: dict = {}
    phi_mape_cls = None
    names: list = []
    if per_class and all(r.get("cost_classes") for r in recs):
        cols = ledger_latency_columns([r["cost_classes"] for r in recs])
        names = [n for n, v in cols.items() if np.any(v)]
        if names:
            A_cls = np.stack([np.ones_like(phi_s)] + [cols[n] for n in names],
                             axis=1)
            c_cls = nnls(A_cls, phi_s)
            phi_mape_cls = float(mape(A_cls @ c_cls, phi_s))
            if phi_mape_cls <= phi_mape_agg:
                class_coeffs["lm_latency"] = {
                    "_intercept": float(c_cls[0]),
                    **{n: float(v) for n, v in zip(names, c_cls[1:])},
                }

    # Energy — fitted exactly like latency (aggregate AND class-wise NNLS
    # over the same columns, lower MAPE applied) from the schema-v3
    # watts-proxy column.  Skipped when any executed cell lacks it (a v2
    # ledger, or a zero-watt device envelope).  Whichever fit wins is
    # stored over the ledger column names ("lm_energy"): the aggregate's
    # tied coefficients map flops_*→c1, hbm_*→c2, collective→c3, so the
    # backend prices energy through one path (classwise_seconds).
    energy = np.array([r.get("energy_j", 0.0) or 0.0 for r in recs],
                      dtype=np.float64)
    energy_meta: dict = {"energy_fit": "none"}
    e_cols = e_names = A_e = ce = None
    if np.all(energy > 0):
        e_agg = nnls(A, energy)
        e_mape_agg = float(mape(A @ e_agg, energy))
        e_mape_cls = None
        use_classwise_e = False
        if per_class and all(r.get("cost_classes") for r in recs):
            e_cols = ledger_latency_columns([r["cost_classes"] for r in recs])
            e_names = [n for n, v in e_cols.items() if np.any(v)]
            if e_names:
                A_e = np.stack(
                    [np.ones_like(energy)] + [e_cols[n] for n in e_names],
                    axis=1)
                ce = nnls(A_e, energy)
                e_mape_cls = float(mape(A_e @ ce, energy))
                use_classwise_e = e_mape_cls <= e_mape_agg
        if use_classwise_e:
            class_coeffs["lm_energy"] = {
                "_intercept": float(ce[0]),
                **{n: float(v) for n, v in zip(e_names, ce[1:])},
            }
        else:
            from repro.engine.decompose import LM_LATENCY_COLUMNS

            tied = {"_intercept": float(e_agg[0])}
            for n in LM_LATENCY_COLUMNS:
                tied[n] = float(e_agg[1] if n.startswith("flops_")
                                else e_agg[3] if n == "collective"
                                else e_agg[2])
            class_coeffs["lm_energy"] = tied
        energy_meta = {
            "energy_fit": "classwise" if use_classwise_e else "aggregate",
            "energy_mape": (e_mape_cls if use_classwise_e else e_mape_agg),
            "energy_mape_aggregate": e_mape_agg,
            "energy_mape_classwise": e_mape_cls,
        }

    # Inert (never-binding) terms keep a finite, serializable denominator.
    spec = replace(
        base,
        name=name or f"{base.name}_lm_calibrated",
        peak_flops=1.0 / c[1] if c[1] > 0 else 1e18,
        hbm_bw=1.0 / c[2] if c[2] > 0 else 1e18,
        ici_bw=1.0 / c[3] if c[3] > 0 else 1e18,
        launch_overhead_s=float(c[0]),
        combine="sum",
        calibrated=True,
        class_coeffs={**{k: v for k, v in base.class_coeffs.items()
                         if k not in ("lm_latency", "lm_energy")},
                      **class_coeffs},
        meta={
            "base_device": base.name,
            "n_cells": len(recs),
            "plan_hash": recs[0].get("plan_hash"),
            "phi_mape": (phi_mape_cls if "lm_latency" in class_coeffs
                         else phi_mape_agg),
            "phi_mape_aggregate": phi_mape_agg,
            "phi_mape_classwise": phi_mape_cls,
            "latency_fit": ("classwise" if "lm_latency" in class_coeffs
                            else "aggregate"),
            "fit": "campaign_hlo_nnls",
            # Collective-calibration audit trail (the >1-device smoke
            # grid — campaign/plan.collective_smoke_plan — exists to make
            # these meaningful): how many fitted cells actually moved
            # collective bytes, whether the collective column entered the
            # class-wise system, and both fitted prices.  The planner's
            # collective_seconds() uses the class-wise coefficient when
            # present, so benchmarks gate on these fields.
            "collective_cells": int(np.sum(coll > 0)),
            "collective_column_fitted": bool("collective" in names),
            "collective_coeff_aggregate": float(c[3]),
            "collective_coeff_classwise": (
                class_coeffs.get("lm_latency", {}).get("collective")),
            "classwise_columns": list(names),
            **energy_meta,
        },
    )
    # Self-check through the shared terms: predictions must reproduce the
    # fitted systems exactly (aggregate via lm_roofline_terms, class-wise
    # via the shared classwise_seconds pricing).
    t = lm_roofline_terms(flops, hbm, coll, spec)
    assert np.allclose(spec.launch_overhead_s + sum(t), A @ c, rtol=1e-6)
    if "lm_latency" in class_coeffs:
        pred = classwise_seconds(cols, spec.class_coeffs["lm_latency"])
        assert np.allclose(pred, A_cls @ c_cls, rtol=1e-6)
    if e_cols is not None and A_e is not None \
            and spec.meta["energy_fit"] == "classwise":
        pred_e = classwise_seconds(e_cols, spec.class_coeffs["lm_energy"])
        assert np.allclose(pred_e, A_e @ ce, rtol=1e-6)
    return spec


def register_lm_forest(target, forest: LMForest):
    """Attach a fitted forest to the engine's prediction path.

    ``target`` may be a :class:`~repro.engine.engine.CostEngine`, an
    :class:`~repro.engine.backends.EnsembleBackend`, or a
    :class:`~repro.engine.backends.ForestBackend`; the first ForestBackend
    found gets ``forest`` as its LM model (its ``cache_salt`` changes with
    it, so stale on-disk estimates can't be served).  Returns the backend
    that now owns the forest."""
    from repro.engine.backends import EnsembleBackend, ForestBackend
    from repro.engine.engine import CostEngine

    if isinstance(target, CostEngine):
        return register_lm_forest(target.backend, forest)
    if isinstance(target, EnsembleBackend):
        for b in target.backends:
            if isinstance(b, ForestBackend):
                return register_lm_forest(b, forest)
        raise ValueError("no ForestBackend in the ensemble chain to attach "
                         "the LM forest to")
    if isinstance(target, ForestBackend):
        target.lm = forest
        return target
    raise TypeError(f"cannot register an LM forest on {type(target).__name__}")
