"""Campaign planning: enumerate, filter and subsample profiling grids.

A campaign cell is one ``(arch × shape × mesh × device)`` coordinate —
exactly the grid perf4sight profiles once per device before fitting
(paper §5.1.1), lifted from CNN pruning grids to the LM workloads.  The
plan is a *value*: a seeded, hashed, JSON-serializable list of cells, so
two workers given the same plan file shard identically, and a fit artifact
can name the plan (``plan_hash``) it was grown from.

``SMOKE_SHAPES`` are the host-runnable miniatures of ``configs.base.SHAPES``
(reduced configs + tiny token counts): the tier-1 campaign smoke path and
the nightly accuracy benchmark both grid over them.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import SHAPES, ShapeSpec
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.core.fileio import atomic_write_json

__all__ = [
    "SMOKE_SHAPES",
    "CampaignCell",
    "CampaignPlan",
    "mesh_dims",
    "resolve_shape",
    "plan_grid",
    "smoke_plan",
    "collective_smoke_plan",
    "load_plan",
]

# Miniature workload shapes for host-CPU campaigns over reduced() configs.
# Same three kinds as the production SHAPES; token counts small enough that
# a full grid compiles in seconds per cell on one CPU device.
SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "smoke_train_16x2": ShapeSpec("smoke_train_16x2", 16, 2, "train"),
    "smoke_train_32x2": ShapeSpec("smoke_train_32x2", 32, 2, "train"),
    "smoke_train_32x4": ShapeSpec("smoke_train_32x4", 32, 4, "train"),
    "smoke_train_64x2": ShapeSpec("smoke_train_64x2", 64, 2, "train"),
    "smoke_train_64x4": ShapeSpec("smoke_train_64x4", 64, 4, "train"),
    "smoke_prefill_32x2": ShapeSpec("smoke_prefill_32x2", 32, 2, "prefill"),
    "smoke_prefill_64x2": ShapeSpec("smoke_prefill_64x2", 64, 2, "prefill"),
    "smoke_prefill_64x4": ShapeSpec("smoke_prefill_64x4", 64, 4, "prefill"),
}


def mesh_dims(desc: str) -> tuple[int, ...]:
    """``"2x16x16"`` → ``(2, 16, 16)`` (axes: pod/data/model, model last)."""
    try:
        dims = tuple(int(x) for x in str(desc).split("x"))
    except ValueError:
        raise ValueError(f"bad mesh descriptor {desc!r}; expected e.g. '1x1'") from None
    if not dims or any(d < 1 for d in dims):
        raise ValueError(f"bad mesh descriptor {desc!r}; dims must be >= 1")
    return dims


def resolve_shape(shape: "ShapeSpec | str") -> ShapeSpec:
    if isinstance(shape, ShapeSpec):
        return shape
    try:
        return SHAPES.get(shape) or SMOKE_SHAPES[shape]
    except KeyError:
        raise KeyError(
            f"unknown shape {shape!r}; known: "
            f"{sorted(SHAPES) + sorted(SMOKE_SHAPES)}") from None


@dataclass(frozen=True)
class CampaignCell:
    """One profiling coordinate.  ``key`` is a content hash — the ledger's
    primary key, stable across processes and plan re-enumerations."""

    arch: str
    shape: ShapeSpec
    mesh: str = "1x1"
    device: str = "host_cpu"
    reduced: bool = True

    @property
    def key(self) -> str:
        blob = json.dumps(
            {"arch": self.arch, "shape": [self.shape.name, self.shape.seq_len,
                                          self.shape.global_batch, self.shape.kind],
             "mesh": self.mesh, "device": self.device, "reduced": self.reduced},
            sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()

    def to_dict(self) -> dict:
        return {"arch": self.arch, "mesh": self.mesh, "device": self.device,
                "reduced": self.reduced,
                "shape": {"name": self.shape.name, "seq_len": self.shape.seq_len,
                          "global_batch": self.shape.global_batch,
                          "kind": self.shape.kind}}

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignCell":
        s = d["shape"]
        return cls(arch=d["arch"], mesh=d.get("mesh", "1x1"),
                   device=d.get("device", "host_cpu"),
                   reduced=bool(d.get("reduced", True)),
                   shape=ShapeSpec(s["name"], int(s["seq_len"]),
                                   int(s["global_batch"]), s["kind"]))


@dataclass
class CampaignPlan:
    """A reproducible cell list: same inputs + seed → same cells, same hash."""

    cells: list[CampaignCell]
    seed: int = 0
    skipped: list[dict] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    @property
    def plan_hash(self) -> str:
        blob = json.dumps([c.key for c in self.cells] + [self.seed])
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def save(self, path: str) -> None:
        atomic_write_json(path, {
            "plan_hash": self.plan_hash, "seed": self.seed, "meta": self.meta,
            "skipped": self.skipped,
            "cells": [c.to_dict() for c in self.cells],
        })

    def __len__(self) -> int:
        return len(self.cells)


def load_plan(path: str) -> CampaignPlan:
    with open(path) as f:
        d = json.load(f)
    plan = CampaignPlan(
        cells=[CampaignCell.from_dict(c) for c in d["cells"]],
        seed=int(d.get("seed", 0)), skipped=d.get("skipped", []),
        meta=d.get("meta", {}))
    want = d.get("plan_hash")
    if want and plan.plan_hash != want:
        raise ValueError(
            f"plan file {path} is inconsistent: stored hash {want} != "
            f"recomputed {plan.plan_hash} (edited by hand?)")
    return plan


def plan_grid(
    archs: tuple[str, ...] | None = None,
    shapes: tuple | None = None,
    meshes: tuple[str, ...] = ("1x1",),
    device: str = "host_cpu",
    *,
    reduced: bool = True,
    subsample: "int | float | None" = None,
    seed: int = 0,
) -> CampaignPlan:
    """Enumerate ``archs × shapes × meshes`` on one device, drop unsupported
    cells (``cell_supported`` with the mesh dims), and optionally subsample.

    Subsampling is *stratified by arch* with a seeded rng: every arch keeps
    a proportional share of its supported cells (at least one), so a small
    campaign still spans the architecture families instead of collapsing
    onto whichever arch enumerated first.  ``subsample`` is a cell count
    (int) or a fraction (float in (0, 1]).
    """
    archs = tuple(archs) if archs else ARCH_IDS
    shape_list = [resolve_shape(s) for s in (shapes or tuple(SHAPES))]

    cells: list[CampaignCell] = []
    skipped: list[dict] = []
    for arch in archs:
        cfg = get_config(arch, reduced=reduced)
        for shape in shape_list:
            for mesh in meshes:
                dims = mesh_dims(mesh)
                ok, why = cell_supported(cfg, shape, dims)
                if not ok:
                    skipped.append({"arch": arch, "shape": shape.name,
                                    "mesh": mesh, "why": why})
                    continue
                cells.append(CampaignCell(arch=arch, shape=shape, mesh=mesh,
                                          device=device, reduced=reduced))

    if subsample is not None and cells:
        if isinstance(subsample, float) and 0 < subsample <= 1:
            target = max(1, round(subsample * len(cells)))
        else:
            target = max(1, min(int(subsample), len(cells)))
        if target < len(cells):
            frac = target / len(cells)
            rng = np.random.default_rng(seed)
            by_arch: dict[str, list[CampaignCell]] = {}
            for c in cells:
                by_arch.setdefault(c.arch, []).append(c)
            kept: list[CampaignCell] = []
            # Deterministic iteration order (insertion = arch order) keeps
            # the rng stream — and therefore the plan hash — reproducible.
            for arch, group in by_arch.items():
                n = max(1, round(frac * len(group)))
                idx = rng.choice(len(group), size=min(n, len(group)),
                                 replace=False)
                kept.extend(group[i] for i in sorted(idx))
            cells = kept

    return CampaignPlan(cells=cells, seed=seed, skipped=skipped, meta={
        "archs": list(archs), "shapes": [s.name for s in shape_list],
        "meshes": list(meshes), "device": device, "reduced": reduced,
        "subsample": subsample,
    })


def smoke_plan(
    archs: tuple[str, ...] = ("qwen3-4b", "stablelm-1.6b"),
    shapes: tuple[str, ...] = tuple(SMOKE_SHAPES),
    *,
    device: str = "host_cpu",
    subsample: "int | None" = None,
    seed: int = 0,
) -> CampaignPlan:
    """The canonical host-CPU miniature campaign: reduced configs over the
    smoke shapes on a single-device mesh.  The tier-1 smoke test trims it
    to 4 cells via ``subsample``; the nightly benchmark runs it whole."""
    return plan_grid(archs=archs, shapes=shapes, meshes=("1x1",),
                     device=device, reduced=True, subsample=subsample,
                     seed=seed)


def collective_smoke_plan(
    archs: tuple[str, ...] = ("stablelm-1.6b",),
    shapes: tuple[str, ...] = ("smoke_train_16x2", "smoke_train_32x2"),
    *,
    device: str = "host_cpu",
    seed: int = 0,
) -> CampaignPlan:
    """The >1-device calibration grid: the same cells on ``1x1`` AND on the
    two minimal multi-device meshes (``2x1`` data-parallel, ``1x2``
    tensor-parallel), so the collective column of the class-wise NNLS
    (``fit.fit_hlo_constants``) spans nonzero values and the collective
    coefficient is fit on real measurements instead of staying at the
    ici_bw guess.  Run it under a forced host device count::

        XLA_FLAGS=--xla_force_host_platform_device_count=2

    (``benchmarks/engine_bench.collective_calibration`` does exactly
    this in a subprocess; the fit then requires ``allow_mixed`` off —
    same host, same device constants, just two fake devices)."""
    return plan_grid(archs=archs, shapes=shapes,
                     meshes=("1x1", "2x1", "1x2"),
                     device=device, reduced=True, seed=seed)
