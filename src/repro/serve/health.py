"""Backend failover for the serve admission path (docs/serve.md
"Failure semantics").

The ``SLOScheduler`` prices every admission through a ``CostEngine``
whose backend is conventionally an ``EnsembleBackend`` chain
(forest → analytical).  The chain already degrades on the *semantic*
failure (:class:`~repro.engine.types.BackendUnavailable` = "I cannot
score this"), but a backend that *crashes* — a real exception from a
poisoned forest file, a compiler bug, an injected fault — used to
propagate straight out of ``ContinuousEngine.step``.

:class:`FailoverChain` wraps the engine so a crash is a handled event:

* the ensemble chain is unrolled into per-suffix sub-engines (level 0 =
  the full chain, level 1 = chain minus its head, …) sharing the
  original engine's estimate cache and device salt;
* a :class:`~repro.engine.engine.HealthState` tracks the trusted level:
  repeated exceptions step it down (forest → analytical → ``static``),
  and a periodic probe steps it back up once the better level answers
  again;
* the floor is **static degraded mode**: ``estimate_one`` returns
  ``None`` — no prediction available — and the scheduler falls back to
  a conservative static slot budget instead of cost-model admission
  (serve fewer, but keep serving);
* ``BackendUnavailable`` still propagates unchanged (it is an answer,
  not a failure), so un-scorable arches keep their legacy ungated path.

A :class:`~repro.serve.faults.FaultPlan` injects ``"backend"`` faults
here — the injected exception takes the exact path a real one would.
"""

from __future__ import annotations

from repro.engine.engine import CostEngine, HealthState
from repro.engine.types import BackendUnavailable

from repro.serve.faults import FaultInjected

__all__ = ["FailoverChain", "STATIC_LEVEL"]

STATIC_LEVEL = "static"


class FailoverChain:
    def __init__(self, engine: CostEngine, *, fail_threshold: int = 3,
                 probe_every: int = 8, faults=None,
                 health: HealthState | None = None):
        from repro.engine.backends import EnsembleBackend

        self.engine = engine
        # Duck-typed engines (test stubs, custom scorers) may not expose a
        # ``backend`` chain — they become a single-level chain whose only
        # fallback is the static floor.
        backend = getattr(engine, "backend", None)
        chain = (list(backend.backends)
                 if isinstance(backend, EnsembleBackend)
                 else [backend if backend is not None else engine])
        names = [getattr(b, "name", type(b).__name__) for b in chain]
        # Level i answers through the chain suffix chain[i:].  Level 0 is
        # the caller's engine itself (its cache hit/miss counters keep
        # meaning what they meant); deeper levels share the same cache
        # object — estimate keys are salted per backend chain, so a
        # level-1 answer never aliases a level-0 one.
        self.engines: list[CostEngine] = [engine]
        for i in range(1, len(chain)):
            sub = chain[i] if i == len(chain) - 1 else EnsembleBackend(chain[i:])
            self.engines.append(CostEngine(sub, cache=engine.cache,
                                           device=engine.device))
        self.health = health or HealthState(
            names + [STATIC_LEVEL], fail_threshold=fail_threshold,
            probe_every=probe_every)
        if len(self.health.levels) != len(self.engines) + 1:
            raise ValueError("health chain does not match backend chain")
        self.faults = faults

    @property
    def degraded(self) -> bool:
        return self.health.degraded

    def estimate_one(self, query):
        """One estimate through the healthiest level that answers.

        Returns the estimate, or ``None`` when every model-backed level
        failed (or the chain is pinned at the static floor) — the
        caller's signal to apply its static degraded policy.  Raises
        only ``BackendUnavailable`` (semantic, health-neutral); any
        other backend exception is recorded against the health state and
        absorbed by falling down the chain.
        """
        h = self.health
        probe = h.probe_level()
        start = probe if probe is not None else h.level
        poisoned = int(self.faults.fire("backend")) if self.faults else 0
        for lvl in range(start, len(self.engines)):
            try:
                if poisoned > 0:
                    poisoned -= 1
                    raise FaultInjected(
                        f"injected backend fault at {h.levels[lvl]}")
                est = self.engines[lvl].estimate_one(query)
            except BackendUnavailable:
                raise
            except Exception as e:
                # Failed probes don't count against the trusted level —
                # only failures at (or below) it advance the step-down.
                if lvl >= h.level:
                    h.record_failure(e)
                continue
            h.record_success(lvl)
            return est
        return None

    def metrics(self) -> dict:
        return self.health.metrics()
