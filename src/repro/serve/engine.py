"""Batched serving engine: prefill + KV-cache decode with slot management.

The *lockstep* engine: one fixed batch prefills together and decodes
until every member finishes (it is the baseline the continuous-batching
engine in ``continuous.py`` is gated against).  Sampling runs on device
— a jitted greedy/``jax.random.categorical`` sampler — so only sampled
token ids cross the device boundary each step.  Ragged (mixed-length)
prompts are supported via left-padding with a per-row length vector.

Placement runs through the same cost-engine admission gate as the
training launcher (paper §6.4 safety property): configure
``ServeConfig.device`` (a device-registry name or a calibrated spec) and
the engine predicts the serving footprint before allocating slots,
refusing placements that exceed the device's memory — instead of
OOM-killing a co-located process.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serve.scheduler import PlacementRefused

__all__ = ["ServeConfig", "ServeEngine", "PlacementRefused"]


@dataclass
class ServeConfig:
    max_len: int = 512
    n_slots: int = 8
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = 1
    seed: int = 0
    # placement admission (off unless a device or budget is configured)
    device: "str | object | None" = None   # registry name / DeviceSpec / path
    gamma_budget_mb: float | None = None   # None + device → device capacity
    admission_margin: float = 0.1


def pad_ragged(prompts) -> tuple[np.ndarray, np.ndarray]:
    """Left-pad a list of 1-D prompts (or a (B, S) array) to a common
    width.  Returns (tokens (B, S0), lens (B,)).  Left padding keeps the
    prefill's last column = every row's final prompt token, so one
    logits slice serves the whole ragged batch."""
    if isinstance(prompts, np.ndarray) and prompts.ndim == 2:
        B, S0 = prompts.shape
        return prompts.astype(np.int32), np.full(B, S0, np.int64)
    rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
    lens = np.array([len(r) for r in rows], np.int64)
    assert lens.min() > 0, "empty prompt"
    S0 = int(lens.max())
    tokens = np.zeros((len(rows), S0), np.int32)
    for i, r in enumerate(rows):
        tokens[i, S0 - len(r):] = r
    return tokens, lens


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig | None = None,
                 cost_engine=None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.params = params
        self.admission_info: dict | None = None
        if (cost_engine is not None or self.scfg.device is not None
                or self.scfg.gamma_budget_mb is not None):
            self._admit(cost_engine)
        B, L = self.scfg.n_slots, self.scfg.max_len

        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, b, cfg, max_len=L)
        )
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg), donate_argnums=(1,)
        )
        temp = float(self.scfg.temperature)

        def sample(logits, key):
            z = logits[:, -1].astype(jnp.float32)
            if temp <= 0:
                return jnp.argmax(z, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, z / temp, axis=-1).astype(
                jnp.int32)

        self._sampler = jax.jit(sample)
        self._key = jax.random.PRNGKey(self.scfg.seed)

    # ------------------------------------------------------------------

    def _admit(self, cost_engine) -> None:
        """Predict the serving-cell footprint (prefill at n_slots × max_len)
        and refuse placement over budget — same gate as launch/train.py."""
        from repro.engine import (
            AnalyticalBackend,
            BackendUnavailable,
            CostEngine,
            CostQuery,
            resolve_device,
        )

        device = (resolve_device(self.scfg.device)
                  if self.scfg.device is not None else None)
        # Registry convention: ArchConfig.reduced() appends "-smoke" to the
        # name.  The gate must predict the config actually being served —
        # querying the registry id of a full config with reduced=True would
        # estimate the tiny smoke variant and admit anything.
        arch, reduced = self.cfg.name, False
        if arch.endswith("-smoke"):
            arch, reduced = arch[: -len("-smoke")], True
        engine = cost_engine or CostEngine(
            AnalyticalBackend(lm_device=device, reduced=reduced),
            device=device)
        budget = self.scfg.gamma_budget_mb
        if budget is None and device is not None and cost_engine is not None:
            # An externally-supplied engine may not carry our device: the
            # configured device's capacity must still gate placement.
            budget = device.hbm_bytes / 1e6
        # reduced travels IN the query: an external engine whose backend
        # defaults to the other variant must still cost the served config.
        query = CostQuery(arch=arch, bs=self.scfg.n_slots,
                          seq=self.scfg.max_len, stage="infer",
                          reduced=reduced)
        try:
            ok, info = engine.admit(
                query,
                gamma_budget_mb=budget,
                safety_margin=self.scfg.admission_margin,
            )
        except BackendUnavailable as e:
            # Unknown arch id / uncompilable cell: placement proceeds
            # ungated rather than refusing workloads the model can't score.
            self.admission_info = {"skipped": str(e)}
            return
        if device is not None:
            info["device"] = device.name
        self.admission_info = info
        if not ok:
            raise PlacementRefused(
                f"serving cell {self.cfg.name} n_slots={self.scfg.n_slots} "
                f"max_len={self.scfg.max_len} predicted "
                f"{info['gamma_eff']:.0f}MB effective > budget "
                f"({info})", info)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """On-device sampling: the full-vocab logits never leave the
        device — only the (B,) sampled ids do.  Seeded: the engine's key
        chain is split once per sampling step, so a fixed ``ServeConfig.seed``
        reproduces the same stream across runs."""
        self._key, sub = jax.random.split(self._key)
        return np.asarray(self._sampler(logits, sub))

    def generate(self, prompts, max_new_tokens: int = 32) -> dict:
        """prompts: (B, S0) int32 array, or a list of 1-D ragged prompts
        (left-padded internally; B ≤ n_slots).

        Returns dict with ``tokens`` (B, T) raw samples, EOS-trimmed
        per-request ``outputs`` / ``token_counts``, and stats.
        """
        tokens, lens = pad_ragged(prompts)
        B, S0 = tokens.shape
        assert B <= self.scfg.n_slots
        batch = {"tokens": jnp.asarray(tokens)}
        pad = S0 - lens
        ragged = bool(pad.any())
        if ragged:
            assert not getattr(self.cfg, "n_prefix", 0), \
                "ragged prompts need a plain decoder stack"
            batch["pos_offset"] = jnp.asarray(pad, jnp.int32)
        out = self._prefill(self.params, batch)
        cache, cache_len = out["cache"], out["cache_len"]
        tok = self._sample(out["logits"])
        generated = [tok]
        finished = tok == self.scfg.eos_id
        steps = 0
        # host-side mirror of cache_len: the loop bound must not force a
        # device→host sync (int(cache_len)) on every decode step
        host_len = S0 + getattr(self.cfg, "n_prefix", 0)
        pos_offset = batch.get("pos_offset")
        for _ in range(max_new_tokens - 1):
            batch = {"tokens": jnp.asarray(tok[:, None]),
                     "cache_len": cache_len}
            if pos_offset is not None:
                batch["pos_offset"] = pos_offset
            logits, cache = self._decode(self.params, cache, batch)
            cache_len = cache_len + 1
            host_len += 1
            steps += 1
            tok = self._sample(logits)
            tok = np.where(finished, self.scfg.eos_id, tok).astype(np.int32)
            finished |= tok == self.scfg.eos_id
            generated.append(tok)
            if finished.all() or host_len >= self.scfg.max_len - 1:
                break
        stacked = np.stack(generated, axis=1)
        outputs, counts = [], np.zeros(B, np.int64)
        for i in range(B):
            row = stacked[i]
            hits = np.flatnonzero(row == self.scfg.eos_id)
            trimmed = row[: hits[0]] if len(hits) else row
            outputs.append(trimmed)
            counts[i] = len(trimmed)
        return {
            "tokens": stacked,
            "outputs": outputs,
            "token_counts": counts,
            "prompt_lens": lens,
            "decode_steps": steps + 1,
            "finished": finished,
        }
