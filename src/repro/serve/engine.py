"""Batched serving engine: prefill + KV-cache decode with slot management.

Continuous-batching-lite: a fixed pool of ``n_slots`` sequences; finished
sequences (EOS or max length) free their slot for the next queued request.
Sampling is greedy or temperature-based.  The decode step is a single jitted
function reused across the whole serving lifetime (shape-stable: the cache
is allocated once at ``max_len``).

Placement runs through the same cost-engine admission gate as the training
launcher (paper §6.4 safety property): configure ``ServeConfig.device`` (a
device-registry name or a calibrated spec) and the engine predicts the
serving footprint before allocating slots, refusing placements that exceed
the device's memory — instead of OOM-killing a co-located process.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

__all__ = ["ServeConfig", "ServeEngine", "PlacementRefused"]


class PlacementRefused(RuntimeError):
    """The admission gate predicted this serving cell exceeds the device."""


@dataclass
class ServeConfig:
    max_len: int = 512
    n_slots: int = 8
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = 1
    seed: int = 0
    # placement admission (off unless a device or budget is configured)
    device: "str | object | None" = None   # registry name / DeviceSpec / path
    gamma_budget_mb: float | None = None   # None + device → device capacity
    admission_margin: float = 0.1


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig | None = None,
                 cost_engine=None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.params = params
        self.admission_info: dict | None = None
        if (cost_engine is not None or self.scfg.device is not None
                or self.scfg.gamma_budget_mb is not None):
            self._admit(cost_engine)
        B, L = self.scfg.n_slots, self.scfg.max_len

        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, b, cfg, max_len=L)
        )
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg), donate_argnums=(1,)
        )
        self._rng = np.random.default_rng(self.scfg.seed)

    # ------------------------------------------------------------------

    def _admit(self, cost_engine) -> None:
        """Predict the serving-cell footprint (prefill at n_slots × max_len)
        and refuse placement over budget — same gate as launch/train.py."""
        from repro.engine import (
            AnalyticalBackend,
            BackendUnavailable,
            CostEngine,
            CostQuery,
            resolve_device,
        )

        device = (resolve_device(self.scfg.device)
                  if self.scfg.device is not None else None)
        # Registry convention: ArchConfig.reduced() appends "-smoke" to the
        # name.  The gate must predict the config actually being served —
        # querying the registry id of a full config with reduced=True would
        # estimate the tiny smoke variant and admit anything.
        arch, reduced = self.cfg.name, False
        if arch.endswith("-smoke"):
            arch, reduced = arch[: -len("-smoke")], True
        engine = cost_engine or CostEngine(
            AnalyticalBackend(lm_device=device, reduced=reduced),
            device=device)
        budget = self.scfg.gamma_budget_mb
        if budget is None and device is not None and cost_engine is not None:
            # An externally-supplied engine may not carry our device: the
            # configured device's capacity must still gate placement.
            budget = device.hbm_bytes / 1e6
        # reduced travels IN the query: an external engine whose backend
        # defaults to the other variant must still cost the served config.
        query = CostQuery(arch=arch, bs=self.scfg.n_slots,
                          seq=self.scfg.max_len, stage="infer",
                          reduced=reduced)
        try:
            ok, info = engine.admit(
                query,
                gamma_budget_mb=budget,
                safety_margin=self.scfg.admission_margin,
            )
        except BackendUnavailable as e:
            # Unknown arch id / uncompilable cell: placement proceeds
            # ungated rather than refusing workloads the model can't score.
            self.admission_info = {"skipped": str(e)}
            return
        if device is not None:
            info["device"] = device.name
        self.admission_info = info
        if not ok:
            raise PlacementRefused(
                f"serving cell {self.cfg.name} n_slots={self.scfg.n_slots} "
                f"max_len={self.scfg.max_len} predicted "
                f"{info['gamma_eff']:.0f}MB effective > budget "
                f"({info})")

    def _sample(self, logits: jax.Array) -> np.ndarray:
        logits = np.asarray(logits[:, -1].astype(jnp.float32))
        if self.scfg.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.scfg.temperature
        z -= z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        # vectorized inverse-CDF over the whole batch: one uniform per row,
        # first index whose running mass exceeds it (no per-row rng.choice).
        # Force the last cumsum entry to 1: f32 accumulation can leave it
        # fractionally below a u drawn near 1, and an all-False mask would
        # silently argmax to token 0.
        cdf = p.cumsum(-1)
        cdf[:, -1] = 1.0
        u = self._rng.random((p.shape[0], 1))
        return (cdf > u).argmax(-1).astype(np.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32) -> dict:
        """prompts: (B, S0) int32 (B ≤ n_slots; right-aligned, no padding).

        Returns dict with generated tokens (B, ≤max_new) and stats.
        """
        B, S0 = prompts.shape
        assert B <= self.scfg.n_slots
        out = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        cache, cache_len = out["cache"], out["cache_len"]
        tok = self._sample(out["logits"])
        generated = [tok]
        finished = np.zeros(B, bool)
        steps = 0
        # host-side mirror of cache_len: the loop bound must not force a
        # device→host sync (int(cache_len)) on every decode step
        host_len = S0 + getattr(self.cfg, "n_prefix", 0)
        for _ in range(max_new_tokens - 1):
            batch = {"tokens": jnp.asarray(tok[:, None]),
                     "cache_len": cache_len}
            logits, cache = self._decode(self.params, cache, batch)
            cache_len = cache_len + 1
            host_len += 1
            steps += 1
            tok = self._sample(logits)
            tok = np.where(finished, self.scfg.eos_id, tok).astype(np.int32)
            finished |= tok == self.scfg.eos_id
            generated.append(tok)
            if finished.all() or host_len >= self.scfg.max_len - 1:
                break
        return {
            "tokens": np.stack(generated, axis=1),
            "decode_steps": steps + 1,
            "finished": finished,
        }
