"""Batched serving engine: prefill + KV-cache decode with slot management.

Continuous-batching-lite: a fixed pool of ``n_slots`` sequences; finished
sequences (EOS or max length) free their slot for the next queued request.
Sampling is greedy or temperature-based.  The decode step is a single jitted
function reused across the whole serving lifetime (shape-stable: the cache
is allocated once at ``max_len``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

__all__ = ["ServeConfig", "ServeEngine"]


@dataclass
class ServeConfig:
    max_len: int = 512
    n_slots: int = 8
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = 1
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig | None = None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.params = params
        B, L = self.scfg.n_slots, self.scfg.max_len

        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, b, cfg, max_len=L)
        )
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg), donate_argnums=(1,)
        )
        self._rng = np.random.default_rng(self.scfg.seed)

    # ------------------------------------------------------------------

    def _sample(self, logits: jax.Array) -> np.ndarray:
        logits = np.asarray(logits[:, -1].astype(jnp.float32))
        if self.scfg.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        p = np.exp(logits / self.scfg.temperature -
                   (logits / self.scfg.temperature).max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        dtype=np.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32) -> dict:
        """prompts: (B, S0) int32 (B ≤ n_slots; right-aligned, no padding).

        Returns dict with generated tokens (B, ≤max_new) and stats.
        """
        B, S0 = prompts.shape
        assert B <= self.scfg.n_slots
        out = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        cache, cache_len = out["cache"], out["cache_len"]
        tok = self._sample(out["logits"])
        generated = [tok]
        finished = np.zeros(B, bool)
        steps = 0
        for _ in range(max_new_tokens - 1):
            batch = {"tokens": jnp.asarray(tok[:, None]),
                     "cache_len": cache_len}
            logits, cache = self._decode(self.params, cache, batch)
            cache_len = cache_len + 1
            steps += 1
            tok = self._sample(logits)
            tok = np.where(finished, self.scfg.eos_id, tok).astype(np.int32)
            finished |= tok == self.scfg.eos_id
            generated.append(tok)
            if finished.all() or int(cache_len) >= self.scfg.max_len - 1:
                break
        return {
            "tokens": np.stack(generated, axis=1),
            "decode_steps": steps + 1,
            "finished": finished,
        }
