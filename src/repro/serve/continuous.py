"""Continuous-batching serve engine over the paged KV cache.

The lockstep ``ServeEngine.generate`` admits one batch and holds every
slot hostage until the longest member finishes.  Here slots join and
leave the running batch *every step*:

* arrivals queue in ``submit`` and are priced by the
  :class:`~repro.serve.scheduler.SLOScheduler` (cost-model admission —
  REFUSE attaches a :class:`PlacementRefused` to the request);
* admitted requests prefill **individually** into a free slot (B=1 at a
  power-of-two bucketed length, left-padded) while other slots keep
  decoding — the prefill/decode split;
* prompts longer than ``prefill_chunk`` (when set) prefill in **chunks**
  interleaved with decode steps: each engine step advances every
  mid-prefill slot by one chunk through the paged S>1 decode path, so a
  running slot's inter-token gap is bounded by one chunk's cost instead
  of a whole long prompt's (greedy streams are unchanged — the first new
  token is sampled at the same logical position);
* the KV lands in the block pool (:class:`PagedKVCache`) and grows
  **incrementally**: admission allocates only the blocks the prefill
  needs, and decode allocates one more each time a request's write
  position crosses a block boundary;
* EOS / token-budget completion frees the slot and its blocks
  immediately for the next arrival.

Mispredicted load is a handled event, not a crash or a livelock
(docs/serve.md "Failure semantics"):

* **preemption** — when the pool cannot supply a growing request, the
  youngest running request is evicted (blocks freed, generated tokens
  retained) and re-queued at the head; it resumes by re-prefilling over
  prompt + generated tokens through the ordinary bucketed prefill.  The
  oldest running request is never chosen as a victim while younger ones
  exist, and resumed requests hold the queue head — the oldest admitted
  request always makes progress (anti-livelock);
* **deadlines + watchdog** — ``Request.deadline_ms`` and the engine-wide
  ``watchdog_ms`` TTL expire queued *and* running requests into the
  typed terminal ``EXPIRED`` state; a bounded wait queue (``max_queue``)
  refuses overflow at submit (backpressure); a DEFERred head retries
  with exponential backoff instead of re-pricing every step;
* **backend failover** — scheduler backend crashes step a
  :class:`~repro.engine.engine.HealthState` down the chain
  (forest → analytical → static degraded mode) via
  :class:`~repro.serve.health.FailoverChain`;
* a seeded :class:`~repro.serve.faults.FaultPlan` injects allocation
  failures, backend exceptions, and slow steps deterministically, and
  per-step robustness counters (``metrics()["preemptions"]``, …)
  let tests and the chaos bench assert on all of the above.

Shape stability: prefill retraces once per prompt-length bucket, decode
once per power-of-two block-table width, chunked prefill once per
(pow2 chunk width, pow2 table width) pair — a long-lived engine compiles
O(log² max_len) functions total, independent of traffic.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serve.health import FailoverChain
from repro.serve.kv_cache import PagedKVCache
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import (
    Decision,
    PlacementRefused,
    ServeSLO,
    SLOScheduler,
)

__all__ = ["ContinuousConfig", "ContinuousEngine"]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ContinuousConfig:
    max_len: int = 512
    n_slots: int = 8
    temperature: float = 0.0
    eos_id: int = 1
    seed: int = 0
    block_size: int | None = None     # None → serve_kv tiling via TuningCache
    pool_tokens: int | None = None    # None → n_slots·max_len / 2 budget
    prefill_chunk: int | None = None  # chunked prefill: max tokens prefilled
    #                                   per engine step (None = whole prompt)
    gamma_budget_mb: float | None = None
    energy_budget_j: float | None = None   # per-step power/thermal envelope
    safety_margin: float = 0.1
    slo: ServeSLO = field(default_factory=ServeSLO)
    # --- fault tolerance (docs/serve.md "Failure semantics") ---
    max_queue: int | None = None      # bounded wait queue; None = unbounded
    watchdog_ms: float | None = None  # engine-wide TTL; None = off
    defer_backoff_cap: int = 8        # max steps between DEFER retries
    degraded_slots: int | None = None  # static budget; None → n_slots // 2
    health_fail_threshold: int = 3    # consecutive crashes per failover step
    health_probe_every: int = 8       # estimate calls between recovery probes


class ContinuousEngine:
    def __init__(self, cfg: ArchConfig, params,
                 scfg: ContinuousConfig | None = None, *,
                 cost_engine=None, tuner=None, faults=None, clock=None):
        self.cfg = cfg
        self.scfg = scfg = scfg or ContinuousConfig()
        self.params = params
        self.faults = faults
        self._clock = clock or time.perf_counter
        self._skew_s = 0.0                 # virtual stall from "slow" faults
        self.kv = PagedKVCache(
            cfg, n_slots=scfg.n_slots, max_len=scfg.max_len,
            block_size=scfg.block_size, pool_tokens=scfg.pool_tokens,
            tuner=tuner, faults=faults)
        self.scheduler = None
        self.failover = None
        if cost_engine is not None:
            self.failover = FailoverChain(
                cost_engine, fail_threshold=scfg.health_fail_threshold,
                probe_every=scfg.health_probe_every, faults=faults)
            self.scheduler = SLOScheduler(
                cfg, cost_engine,
                max_len=scfg.max_len, n_slots=scfg.n_slots,
                gamma_budget_mb=scfg.gamma_budget_mb,
                energy_budget_j=scfg.energy_budget_j,
                safety_margin=scfg.safety_margin, slo=scfg.slo,
                failover=self.failover,
                degraded_slots=scfg.degraded_slots)

        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * scfg.n_slots
        self.finished: list[Request] = []
        self.refused: list[Request] = []
        self.expired: list[Request] = []
        self.submitted = 0
        self._admit_seq = 0
        self._cache_len = np.zeros(scfg.n_slots, np.int64)
        self._last_tok = np.zeros(scfg.n_slots, np.int32)
        self._prefilling = np.zeros(scfg.n_slots, bool)  # mid-chunked-prefill
        self._step = 0
        self.decode_steps = 0
        # Decode-path observability (metrics()): stall = a step where a
        # decodable slot existed but no decode ran (0 by construction —
        # chunked prefill interleaves, it never starves decode).
        self._stall_run = 0
        self.max_decode_stall_steps = 0
        # Widest prefill forward (padded tokens) run while decodable slots
        # were waiting — the deterministic stall bound a running slot can
        # see between two of its tokens.  Chunked prefill caps this at
        # _next_pow2(prefill_chunk); unchunked it is the whole prompt.
        self.max_prefill_stall_tokens = 0
        self.kv_gathered_bytes = 0.0   # (B · nb) blocks the gather path reads
        self.kv_touched_bytes = 0.0    # live blocks the decode kernel touches
        # Robustness counters — surfaced via metrics() so tests and the
        # chaos bench assert on events instead of log-scraping.
        self.counters = {
            "preemptions": 0,        # running requests evicted for blocks
            "resumes": 0,            # preempted requests re-admitted
            "expired_queued": 0,     # deadline/watchdog sheds from the queue
            "expired_running": 0,    # watchdog kills of running requests
            "shed_backpressure": 0,  # bounded-queue refusals at submit
            "defer_backoffs": 0,     # DEFER decisions (head now backs off)
            "alloc_denied": 0,       # pool alloc failures (real or injected)
            "failovers": 0,          # health step-downs (mirror of health)
            "degraded_steps": 0,     # steps taken in static degraded mode
            "prefill_chunks": 0,     # chunked-prefill chunks processed
        }

        self._key = jax.random.PRNGKey(scfg.seed)
        temp = float(scfg.temperature)

        def sample(logits, key):
            z = logits[:, -1].astype(jnp.float32)
            if temp <= 0:
                return jnp.argmax(z, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, z / temp, axis=-1).astype(
                jnp.int32)

        self._sample = jax.jit(sample)
        self._prefills: dict[int, object] = {}
        self._decodes: dict[int, object] = {}
        self._chunks: dict[tuple[int, int], object] = {}

    # ------------------------------------------------------------------

    @property
    def n_running(self) -> int:
        return sum(r is not None for r in self.slots)

    def _has_decodable(self) -> bool:
        return any(r is not None and not self._prefilling[i]
                   for i, r in enumerate(self.slots))

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_running == 0

    def _now(self) -> float:
        return self._clock() + self._skew_s

    @property
    def lost(self) -> int:
        """Zero-lost accounting: submitted requests not in a terminal
        state and no longer queued or running.  Must be 0 always."""
        in_flight = len(self.queue) + self.n_running
        terminal = len(self.finished) + len(self.refused) + len(self.expired)
        return self.submitted - in_flight - terminal

    def submit(self, request: Request) -> Request:
        self.submitted += 1
        request.step_submitted = self._step
        if (self.scfg.max_queue is not None
                and len(self.queue) >= self.scfg.max_queue):
            # Bounded wait queue: shed at the door with a typed refusal
            # rather than queueing work that will only expire later.
            request.state = RequestState.REFUSED
            request.refusal = PlacementRefused(
                f"request {request.rid} refused: wait queue full "
                f"({self.scfg.max_queue} deep) — backpressure",
                {"reason": "queue full", "max_queue": self.scfg.max_queue})
            self.refused.append(request)
            self.counters["shed_backpressure"] += 1
            return request
        self.queue.append(request)
        return request

    # ------------------------------------------------------------------
    # jit memos

    def _prefill_fn(self, width: int):
        fn = self._prefills.get(width)
        if fn is None:
            cache_len_dim = -(-width // self.kv.block_size) * self.kv.block_size
            fn = jax.jit(lambda p, b: T.prefill(p, b, self.cfg,
                                                max_len=cache_len_dim))
            self._prefills[width] = fn
        return fn

    def _decode_fn(self, nb: int):
        fn = self._decodes.get(nb)
        if fn is None:
            fn = jax.jit(lambda p, c, b: T.decode_step(p, c, b, self.cfg),
                         donate_argnums=(1,))
            self._decodes[nb] = fn
        return fn

    def _chunk_fn(self, width: int, nb: int):
        # One chunked-prefill trace per (pow2 chunk width, pow2 table
        # width) pair — a B=1, S=width pass through the same paged
        # decode_step path (scatter S tokens, attend causally).
        fn = self._chunks.get((width, nb))
        if fn is None:
            fn = jax.jit(lambda p, c, b: T.decode_step(p, c, b, self.cfg),
                         donate_argnums=(1,))
            self._chunks[(width, nb)] = fn
        return fn

    # ------------------------------------------------------------------
    # deadlines, TTL, shedding (requests leave without a crash)

    def _deadline_reason(self, req: Request, now: float) -> str | None:
        t_dl = req.t_deadline
        if t_dl is not None and now > t_dl:
            return f"deadline ({req.deadline_ms:.0f}ms TTL) passed"
        wd = self.scfg.watchdog_ms
        if wd is not None and now > req.t_arrival + wd / 1e3:
            return f"watchdog ({wd:.0f}ms) expired stuck request"
        return None

    def _expire_request(self, req: Request, reason: str) -> None:
        """Typed terminal EXPIRED state: blocks and slot are released, the
        partial output (req.tokens) is retained for the caller."""
        req.state = RequestState.EXPIRED
        req.expiry = reason
        req.t_finished = self._now()
        if req.blocks:
            self.kv.free(req.blocks)
            req.blocks = []
        if req.slot is not None:
            self.slots[req.slot] = None
            self._cache_len[req.slot] = 0
            self._last_tok[req.slot] = 0
            self._prefilling[req.slot] = False
            req.slot = None
        req.prefill_pos = 0
        self.expired.append(req)

    def _expire_sweep(self) -> None:
        now = self._now()
        if self.queue:
            keep: deque[Request] = deque()
            for req in self.queue:
                reason = self._deadline_reason(req, now)
                if reason is None:
                    keep.append(req)
                else:
                    self._expire_request(req, reason)
                    self.counters["expired_queued"] += 1
            self.queue = keep
        for req in list(self.slots):
            if req is None:
                continue
            reason = self._deadline_reason(req, now)
            if reason is not None:
                self._expire_request(req, reason)
                self.counters["expired_running"] += 1

    # ------------------------------------------------------------------
    # admission + prefill (slots join)

    def _refuse(self, req: Request, reason: str, info: dict | None = None,
                *, pop: bool = True) -> None:
        if pop:
            self.queue.popleft()
        req.state = RequestState.REFUSED
        req.refusal = PlacementRefused(
            f"request {req.rid} (prompt={req.prompt_len}, "
            f"max_new={req.max_new_tokens}) refused: {reason}",
            dict(info or {}, reason=reason))
        self.refused.append(req)

    def _admissions(self) -> None:
        while self.queue and None in self.slots:
            req = self.queue[0]
            if req.retry_at_step > self._step:
                break        # DEFER backoff: FIFO head holds the line
            if req.state is not RequestState.PREEMPTED:
                # Context-window check in the engine itself, not only the
                # scheduler: an ungated engine (cost_engine=None) must
                # REFUSE an oversized prompt cleanly instead of crashing
                # in ``_prefill_into`` (width - prompt_len goes negative).
                need = req.prompt_len + req.max_new_tokens
                if need > self.scfg.max_len:
                    self._refuse(req, f"needs {need} tokens > "
                                      f"max_len={self.scfg.max_len}")
                    continue
                # Pool-capacity check: a request whose lifetime footprint
                # exceeds the ENTIRE pool can never be packed — retrying
                # it every step is a livelock, so REFUSE it now.
                need_blocks = self.kv.blocks_for(min(need, self.scfg.max_len))
                if need_blocks > self.kv.usable_blocks:
                    self._refuse(
                        req, f"pool capacity: needs {need_blocks} KV blocks "
                             f"> pool of {self.kv.usable_blocks}",
                        {"need_blocks": need_blocks,
                         "pool_blocks": self.kv.usable_blocks})
                    continue
                if self.scheduler is not None:
                    decision, info = self.scheduler.admit(
                        req, n_running=self.n_running)
                    if decision is Decision.REFUSE:
                        self.queue.popleft()
                        req.state = RequestState.REFUSED
                        req.refusal = self.scheduler.refusal(req, info)
                        self.refused.append(req)
                        continue
                    if decision is Decision.DEFER:
                        # Exponential backoff: don't re-price the same
                        # head every step while occupancy drains.
                        req.defer_retries += 1
                        req.retry_at_step = self._step + min(
                            1 << (req.defer_retries - 1),
                            self.scfg.defer_backoff_cap)
                        self.counters["defer_backoffs"] += 1
                        break
            # Incremental allocation: only what the prefill itself needs
            # (+ the first decode write) — the rest is allocated as the
            # request grows, with preemption backstopping shortfalls.
            total = req.prompt_len + req.n_generated
            blocks = self.kv.alloc(self.kv.blocks_for(
                min(total + 1, self.scfg.max_len)))
            if blocks is None:
                self.counters["alloc_denied"] += 1
                break                      # pool busy: retry next step
            self.queue.popleft()
            req.blocks = blocks
            if req.state is RequestState.PREEMPTED:
                self.counters["resumes"] += 1
            req.state = RequestState.ADMITTED
            if req.admit_seq is None:      # age = FIRST admission order
                req.admit_seq = self._admit_seq
                self._admit_seq += 1
            self._prefill_into(req, self.slots.index(None))

    def _prefill_into(self, req: Request, slot: int) -> None:
        # A resumed request re-prefills over prompt + generated tokens
        # (recompute-on-resume): the logits at the last position then
        # continue the decode exactly where preemption cut it.
        seq = req.sequence()
        S = len(seq)
        chunk = self.scfg.prefill_chunk
        if chunk is not None and S > chunk:
            # Chunked prefill: occupy the slot now and feed the prompt in
            # ``chunk``-sized pieces interleaved with decode steps
            # (``_prefill_chunks``) — running slots' TPOT is bounded by
            # one chunk's cost, not this whole prompt's.  Resumed
            # requests restart from 0 (recompute-on-resume, same as the
            # solo path).
            req.state = RequestState.RUNNING
            req.slot = slot
            req.prefill_pos = 0
            self.slots[slot] = req
            self._prefilling[slot] = True
            self._cache_len[slot] = 0
            self._last_tok[slot] = 0
            return
        width = min(_next_pow2(max(S, self.kv.block_size)),
                    -(-self.scfg.max_len // self.kv.block_size)
                    * self.kv.block_size)
        if self._has_decodable():
            self.max_prefill_stall_tokens = max(
                self.max_prefill_stall_tokens, width)
        pad = width - S
        tokens = np.zeros((1, width), np.int32)
        tokens[0, pad:] = seq
        out = self._prefill_fn(width)(self.params, {
            "tokens": jnp.asarray(tokens),
            "pos_offset": jnp.asarray([pad], jnp.int32),
        })
        self._key, sub = jax.random.split(self._key)
        tok = int(np.asarray(self._sample(out["logits"], sub))[0])
        req.state = RequestState.RUNNING
        req.slot = slot
        req.tokens.append(tok)
        if req.t_first_token is None:
            req.t_first_token = self._now()
            req.step_first_token = self._step
        self.kv.pack_prefill(out["cache"], req.blocks,
                             prompt_len=S, pad=pad)
        self.slots[slot] = req
        self._cache_len[slot] = S
        self._last_tok[slot] = tok
        self._retire_if_done(req)   # max_new_tokens=1 / instant EOS

    def _prefill_chunks(self) -> None:
        """Advance every mid-prefill slot by one chunk.

        Chunks ride the paged S > 1 ``decode_step`` path: the chunk's KV
        scatters straight into the request's blocks (no ``pack_prefill``),
        right-padded to a pow2 width.  Junk positions sit beyond every
        real token, so the causal mask never attends them, and the table
        is sized to cover the padded width — writes past the row's own
        blocks route to scratch block 0.  The final chunk samples the
        first new token from the last *real* position, exactly where the
        solo prefill samples, so greedy streams are unchanged."""
        chunk = self.scfg.prefill_chunk
        bs = self.kv.block_size
        for slot in np.flatnonzero(self._prefilling):
            slot = int(slot)
            req = self.slots[slot]
            seq = req.sequence()
            s0 = req.prefill_pos
            clen = min(chunk, len(seq) - s0)
            width = _next_pow2(clen)
            if self._has_decodable():
                self.max_prefill_stall_tokens = max(
                    self.max_prefill_stall_tokens, width)
            tokens = np.zeros((1, width), np.int32)
            tokens[0, :clen] = seq[s0:s0 + clen]
            nb = _next_pow2((s0 + width - 1) // bs + 1)
            table = np.zeros((1, nb), np.int32)   # pad → scratch block 0
            table[0, :len(req.blocks[:nb])] = req.blocks[:nb]
            table = jnp.asarray(table)
            logits, self.kv.pool = self._chunk_fn(width, nb)(
                self.params, self.kv.pool, {
                    "tokens": jnp.asarray(tokens),
                    "cache_len": jnp.asarray([s0], jnp.int32),
                    "block_table": table,
                })
            self.counters["prefill_chunks"] += 1
            req.prefill_pos = s0 + clen
            self._cache_len[slot] = req.prefill_pos
            if req.prefill_pos < len(seq):
                continue
            # Final chunk: sample the first new token; the slot joins the
            # decodable set from the next _decode_once on.
            self._key, sub = jax.random.split(self._key)
            tok = int(np.asarray(self._sample(logits[:, clen - 1:clen],
                                              sub))[0])
            self._prefilling[slot] = False
            req.prefill_pos = 0
            req.tokens.append(tok)
            if req.t_first_token is None:
                req.t_first_token = self._now()
                req.step_first_token = self._step
            self._cache_len[slot] = len(seq)
            self._last_tok[slot] = tok
            self._retire_if_done(req)

    # ------------------------------------------------------------------
    # preemption under pool pressure (slots leave involuntarily)

    def _preempt(self, req: Request) -> None:
        """Evict a running request: blocks back to the pool, generated
        tokens retained, re-queued at the head (resume priority over new
        arrivals — and over younger preemptees pushed earlier)."""
        self.counters["preemptions"] += 1
        req.preemptions += 1
        if req.blocks:
            self.kv.free(req.blocks)
            req.blocks = []
        if req.slot is not None:
            self.slots[req.slot] = None
            self._cache_len[req.slot] = 0
            self._last_tok[req.slot] = 0
            self._prefilling[req.slot] = False
            req.slot = None
        req.prefill_pos = 0          # chunked prefill restarts on resume
        req.state = RequestState.PREEMPTED
        self.queue.appendleft(req)

    def _youngest_running(self) -> Request | None:
        alive = [r for r in self.slots if r is not None]
        if not alive:
            return None
        return max(alive, key=lambda r: r.admit_seq)

    def _grow_blocks(self) -> None:
        """Before decoding, make sure every occupied slot owns the block
        its next KV write lands in.  A pool shortfall preempts the
        youngest running request (possibly the grower itself) — never the
        oldest while younger victims exist, so the oldest always
        progresses."""
        order = sorted(
            (i for i, r in enumerate(self.slots) if r is not None),
            key=lambda i: self.slots[i].admit_seq)
        for i in order:
            req = self.slots[i]
            if req is None:
                continue               # already taken as a victim
            need_idx = int(self._cache_len[i]) // self.kv.block_size
            while req.slot is not None and len(req.blocks) <= need_idx:
                got = self.kv.alloc(1)
                if got is not None:
                    req.blocks.extend(got)
                    continue
                self.counters["alloc_denied"] += 1
                victim = self._youngest_running()
                if victim is None or victim is req:
                    self._preempt(req)     # nobody younger: yield itself
                    break
                self._preempt(victim)      # then retry the alloc

    # ------------------------------------------------------------------
    # decode (all occupied slots advance one token)

    def _decode_once(self) -> None:
        self._grow_blocks()
        # Mid-prefill slots are occupied but not decodable: their table
        # rows stay empty (scratch) and cache_len is masked to 0, so the
        # batched step writes their junk token to scratch block 0.
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and not self._prefilling[i]]
        if not active:
            return
        nb_need = max(int(self._cache_len[i]) // self.kv.block_size + 1
                      for i in active)
        nb = min(_next_pow2(nb_need), self.kv.blocks_per_seq)
        decodable = np.array([r is not None and not self._prefilling[i]
                              for i, r in enumerate(self.slots)])
        table = self.kv.table_array(
            [r.blocks[:nb] if decodable[i] else []
             for i, r in enumerate(self.slots)], nb)
        batch = {
            "tokens": jnp.asarray(self._last_tok[:, None]),
            "cache_len": jnp.asarray(
                np.where(decodable, self._cache_len, 0).astype(np.int32)),
            "block_table": table,
        }
        per_block = self.kv.bytes / self.kv.n_blocks
        self.kv_gathered_bytes += len(self.slots) * nb * per_block
        self.kv_touched_bytes += per_block * sum(
            int(self._cache_len[i]) // self.kv.block_size + 1 for i in active)
        logits, self.kv.pool = self._decode_fn(nb)(
            self.params, self.kv.pool, batch)
        self._key, sub = jax.random.split(self._key)
        toks = np.asarray(self._sample(logits, sub))
        self.decode_steps += 1
        now = self._now()
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.tokens.append(tok)
            self._cache_len[i] += 1
            self._last_tok[i] = tok
            self._retire_if_done(req, now)

    def _retire_if_done(self, req: Request, now: float | None = None) -> None:
        done = (req.tokens[-1] == self.scfg.eos_id
                or req.n_generated >= req.max_new_tokens
                or req.prompt_len + req.n_generated >= self.scfg.max_len)
        if not done:
            return
        req.state = RequestState.FINISHED
        req.t_finished = now if now is not None else self._now()
        self.kv.free(req.blocks)
        req.blocks = []
        if req.slot is not None:
            self.slots[req.slot] = None
            self._cache_len[req.slot] = 0
            self._last_tok[req.slot] = 0
        self.finished.append(req)

    # ------------------------------------------------------------------

    def step(self) -> None:
        """One engine iteration: expire stale work, admit+prefill into
        free slots, then one ragged decode step for every occupied slot.

        Every failure the fault plan can inject here — pool-allocation
        denial, backend exceptions, slow steps — is handled inside the
        call: nothing escapes ``step`` short of a real model bug."""
        self._step += 1
        if self.faults is not None:
            self.faults.begin_step(self._step)
            self._skew_s += float(self.faults.fire("slow"))
        self._expire_sweep()
        self._admissions()
        self._prefill_chunks()
        decodable_before = self._has_decodable()
        before = self.decode_steps
        self._decode_once()
        if (decodable_before and self.decode_steps == before
                and self._has_decodable()):
            # A decodable slot existed, survived the step, and still no
            # decode ran — a genuine stall (0 by construction: chunked
            # prefill interleaves with decode instead of displacing it).
            self._stall_run += 1
            self.max_decode_stall_steps = max(self.max_decode_stall_steps,
                                              self._stall_run)
        else:
            self._stall_run = 0
        if self.failover is not None:
            self.counters["failovers"] = self.failover.health.failovers
            if self.failover.degraded:
                self.counters["degraded_steps"] += 1

    def run(self, requests: list[Request] | None = None, *,
            max_steps: int = 100_000) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until idle."""
        for r in requests or ():
            self.submit(r)
        for _ in range(max_steps):
            if self.idle:
                break
            self.step()
        return self.finished

    # ------------------------------------------------------------------

    def metrics(self) -> dict:
        ttfts = [r.ttft_s for r in self.finished if r.ttft_s is not None]
        tpots = [r.tpot_s for r in self.finished if r.tpot_s is not None]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else float("nan")

        out = {
            "finished": len(self.finished),
            "refused": len(self.refused),
            "expired": len(self.expired),
            "submitted": self.submitted,
            "lost": self.lost,
            "decode_steps": self.decode_steps,
            "tokens_out": sum(r.n_generated for r in self.finished),
            "ttft_p50_ms": pct(ttfts, 50) * 1e3,
            "ttft_p99_ms": pct(ttfts, 99) * 1e3,
            "tpot_p50_ms": pct(tpots, 50) * 1e3,
            "tpot_p99_ms": pct(tpots, 99) * 1e3,
            "kv_bytes": self.kv.bytes,
            "kv_dense_bytes": self.kv.dense_bytes,
            "block_size": self.kv.block_size,
            "max_decode_stall_steps": self.max_decode_stall_steps,
            "max_prefill_stall_tokens": self.max_prefill_stall_tokens,
            "kv_gathered_bytes": self.kv_gathered_bytes,
            "kv_touched_bytes": self.kv_touched_bytes,
            **self.counters,
        }
        if self.failover is not None:
            out["health"] = self.failover.metrics()
        if self.faults is not None:
            out["faults"] = self.faults.summary()
        return out
