"""Continuous-batching serve engine over the paged KV cache.

The lockstep ``ServeEngine.generate`` admits one batch and holds every
slot hostage until the longest member finishes.  Here slots join and
leave the running batch *every step*:

* arrivals queue in ``submit`` and are priced by the
  :class:`~repro.serve.scheduler.SLOScheduler` (cost-model admission —
  REFUSE attaches a :class:`PlacementRefused` to the request);
* admitted requests prefill **individually** into a free slot (B=1 at a
  power-of-two bucketed length, left-padded) while other slots keep
  decoding — the prefill/decode split;
* the KV lands in the block pool (:class:`PagedKVCache`), and one jitted
  ragged decode advances *all* occupied slots with per-row
  ``cache_len`` + block tables;
* EOS / token-budget completion frees the slot and its blocks
  immediately for the next arrival.

Shape stability: prefill retraces once per prompt-length bucket, decode
once per power-of-two block-table width — a long-lived engine compiles
O(log max_len) functions total, independent of traffic.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serve.kv_cache import PagedKVCache
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import (
    Decision,
    PlacementRefused,
    ServeSLO,
    SLOScheduler,
)

__all__ = ["ContinuousConfig", "ContinuousEngine"]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ContinuousConfig:
    max_len: int = 512
    n_slots: int = 8
    temperature: float = 0.0
    eos_id: int = 1
    seed: int = 0
    block_size: int | None = None     # None → serve_kv tiling via TuningCache
    pool_tokens: int | None = None    # None → n_slots·max_len / 2 budget
    gamma_budget_mb: float | None = None
    energy_budget_j: float | None = None   # per-step power/thermal envelope
    safety_margin: float = 0.1
    slo: ServeSLO = field(default_factory=ServeSLO)


class ContinuousEngine:
    def __init__(self, cfg: ArchConfig, params,
                 scfg: ContinuousConfig | None = None, *,
                 cost_engine=None, tuner=None):
        self.cfg = cfg
        self.scfg = scfg = scfg or ContinuousConfig()
        self.params = params
        self.kv = PagedKVCache(
            cfg, n_slots=scfg.n_slots, max_len=scfg.max_len,
            block_size=scfg.block_size, pool_tokens=scfg.pool_tokens,
            tuner=tuner)
        self.scheduler = None
        if cost_engine is not None:
            self.scheduler = SLOScheduler(
                cfg, cost_engine,
                max_len=scfg.max_len, n_slots=scfg.n_slots,
                gamma_budget_mb=scfg.gamma_budget_mb,
                energy_budget_j=scfg.energy_budget_j,
                safety_margin=scfg.safety_margin, slo=scfg.slo)

        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * scfg.n_slots
        self.finished: list[Request] = []
        self.refused: list[Request] = []
        self._cache_len = np.zeros(scfg.n_slots, np.int64)
        self._last_tok = np.zeros(scfg.n_slots, np.int32)
        self._step = 0
        self.decode_steps = 0

        self._key = jax.random.PRNGKey(scfg.seed)
        temp = float(scfg.temperature)

        def sample(logits, key):
            z = logits[:, -1].astype(jnp.float32)
            if temp <= 0:
                return jnp.argmax(z, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, z / temp, axis=-1).astype(
                jnp.int32)

        self._sample = jax.jit(sample)
        self._prefills: dict[int, object] = {}
        self._decodes: dict[int, object] = {}

    # ------------------------------------------------------------------

    @property
    def n_running(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_running == 0

    def submit(self, request: Request) -> Request:
        self.queue.append(request)
        return request

    # ------------------------------------------------------------------
    # jit memos

    def _prefill_fn(self, width: int):
        fn = self._prefills.get(width)
        if fn is None:
            cache_len_dim = -(-width // self.kv.block_size) * self.kv.block_size
            fn = jax.jit(lambda p, b: T.prefill(p, b, self.cfg,
                                                max_len=cache_len_dim))
            self._prefills[width] = fn
        return fn

    def _decode_fn(self, nb: int):
        fn = self._decodes.get(nb)
        if fn is None:
            fn = jax.jit(lambda p, c, b: T.decode_step(p, c, b, self.cfg),
                         donate_argnums=(1,))
            self._decodes[nb] = fn
        return fn

    # ------------------------------------------------------------------
    # admission + prefill (slots join)

    def _admissions(self) -> None:
        while self.queue and None in self.slots:
            req = self.queue[0]
            # Context-window check in the engine itself, not only the
            # scheduler: an ungated engine (cost_engine=None) must REFUSE
            # an oversized prompt cleanly instead of crashing in
            # ``_prefill_into`` (width - prompt_len goes negative).
            need = req.prompt_len + req.max_new_tokens
            if need > self.scfg.max_len:
                self.queue.popleft()
                req.state = RequestState.REFUSED
                req.refusal = PlacementRefused(
                    f"request {req.rid} (prompt={req.prompt_len}, "
                    f"max_new={req.max_new_tokens}) refused: needs {need} "
                    f"tokens > max_len={self.scfg.max_len}",
                    {"reason": f"needs {need} tokens > "
                               f"max_len={self.scfg.max_len}"})
                self.refused.append(req)
                continue
            if self.scheduler is not None:
                decision, info = self.scheduler.admit(
                    req, n_running=self.n_running)
                if decision is Decision.REFUSE:
                    self.queue.popleft()
                    req.state = RequestState.REFUSED
                    req.refusal = self.scheduler.refusal(req, info)
                    self.refused.append(req)
                    continue
                if decision is Decision.DEFER:
                    break
            blocks = self.kv.alloc(self.kv.blocks_for(
                min(req.prompt_len + req.max_new_tokens, self.scfg.max_len)))
            if blocks is None:
                break                      # pool full: retry next step
            self.queue.popleft()
            req.blocks = blocks
            req.state = RequestState.ADMITTED
            self._prefill_into(req, self.slots.index(None))

    def _prefill_into(self, req: Request, slot: int) -> None:
        S = req.prompt_len
        width = min(_next_pow2(max(S, self.kv.block_size)),
                    -(-self.scfg.max_len // self.kv.block_size)
                    * self.kv.block_size)
        pad = width - S
        tokens = np.zeros((1, width), np.int32)
        tokens[0, pad:] = req.prompt
        out = self._prefill_fn(width)(self.params, {
            "tokens": jnp.asarray(tokens),
            "pos_offset": jnp.asarray([pad], jnp.int32),
        })
        self._key, sub = jax.random.split(self._key)
        tok = int(np.asarray(self._sample(out["logits"], sub))[0])
        req.state = RequestState.RUNNING
        req.slot = slot
        req.tokens.append(tok)
        req.t_first_token = time.perf_counter()
        self.kv.pack_prefill(out["cache"], req.blocks,
                             prompt_len=S, pad=pad)
        self.slots[slot] = req
        self._cache_len[slot] = S
        self._last_tok[slot] = tok
        self._retire_if_done(req)   # max_new_tokens=1 / instant EOS

    # ------------------------------------------------------------------
    # decode (all occupied slots advance one token)

    def _decode_once(self) -> None:
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        nb_need = max(int(self._cache_len[i]) // self.kv.block_size + 1
                      for i in active)
        nb = min(_next_pow2(nb_need), self.kv.blocks_per_seq)
        table = self.kv.table_array(
            [r.blocks[:nb] if r is not None else [] for r in self.slots], nb)
        batch = {
            "tokens": jnp.asarray(self._last_tok[:, None]),
            "cache_len": jnp.asarray(
                np.where([r is not None for r in self.slots],
                         self._cache_len, 0).astype(np.int32)),
            "block_table": table,
        }
        logits, self.kv.pool = self._decode_fn(nb)(
            self.params, self.kv.pool, batch)
        self._key, sub = jax.random.split(self._key)
        toks = np.asarray(self._sample(logits, sub))
        self.decode_steps += 1
        now = time.perf_counter()
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.tokens.append(tok)
            self._cache_len[i] += 1
            self._last_tok[i] = tok
            self._retire_if_done(req, now)

    def _retire_if_done(self, req: Request, now: float | None = None) -> None:
        done = (req.tokens[-1] == self.scfg.eos_id
                or req.n_generated >= req.max_new_tokens
                or req.prompt_len + req.n_generated >= self.scfg.max_len)
        if not done:
            return
        req.state = RequestState.FINISHED
        req.t_finished = now if now is not None else time.perf_counter()
        self.kv.free(req.blocks)
        req.blocks = []
        if req.slot is not None:
            self.slots[req.slot] = None
            self._cache_len[req.slot] = 0
            self._last_tok[req.slot] = 0
        self.finished.append(req)

    # ------------------------------------------------------------------

    def step(self) -> None:
        """One engine iteration: admit+prefill into free slots, then one
        ragged decode step for every occupied slot."""
        self._step += 1
        self._admissions()
        self._decode_once()

    def run(self, requests: list[Request] | None = None, *,
            max_steps: int = 100_000) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until idle."""
        for r in requests or ():
            self.submit(r)
        for _ in range(max_steps):
            if self.idle:
                break
            self.step()
        return self.finished

    # ------------------------------------------------------------------

    def metrics(self) -> dict:
        ttfts = [r.ttft_s for r in self.finished if r.ttft_s is not None]
        tpots = [r.tpot_s for r in self.finished if r.tpot_s is not None]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else float("nan")

        return {
            "finished": len(self.finished),
            "refused": len(self.refused),
            "decode_steps": self.decode_steps,
            "tokens_out": sum(r.n_generated for r in self.finished),
            "ttft_p50_ms": pct(ttfts, 50) * 1e3,
            "ttft_p99_ms": pct(ttfts, 99) * 1e3,
            "tpot_p50_ms": pct(tpots, 50) * 1e3,
            "tpot_p99_ms": pct(tpots, 99) * 1e3,
            "kv_bytes": self.kv.bytes,
            "kv_dense_bytes": self.kv.dense_bytes,
            "block_size": self.kv.block_size,
        }
