"""Paged KV cache: a block pool + host-side allocator for the serve path.

Dense serving caches are sized ``n_slots × max_len`` and mostly hold
zeros — a 512-long window with 8 slots allocates 4096 token slots even
when typical occupancy is a few hundred.  The pool instead holds a
*budget* of fixed-size KV blocks (``paged_cache_shapes``); each running
request owns a list of physical blocks, and the decode step routes reads
and writes through a per-slot block table (``decode_step``'s
``block_table``).  Physical block 0 is reserved as scratch: idle slots
point every table entry (and their single-token write) at it.

The block size is not hard-coded — it is resolved through the kernel
autotuner's ``serve_kv`` tiling model, so it is roofline-ranked for the
configured device and memoised in the device-fingerprint-keyed
``TuningCache`` like any kernel block size.  That model prices each
candidate through the ``paged_decode`` kernel's own cost model (joint
resolution), and the kernel's ``block_kv`` candidates divide the pool
block size by construction — the two tuners cannot disagree on
blocking.

Prefill packing: prompts prefill through the ordinary dense path (at a
bucketed length, left-padded), then ``pack_prefill`` rolls the padding
off, chops the sequence into blocks, and scatters them into the pool in
one jitted donate-in-place call.  Traces are memoised per bucketed
length, so a long-lived engine compiles a handful of pack functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.autotune import tuned_config
from repro.kernels.serve_kv.tiling import default as _default_config
from repro.kernels.serve_kv.tiling import shape_key
from repro.models import transformer as T

__all__ = ["PagedKVCache", "resolve_block_size"]


def resolve_block_size(cfg: ArchConfig, *, n_slots: int, max_len: int,
                       tuner=None) -> int:
    """KV block size for this serving cell, via the ``serve_kv`` tiling
    model.  With an explicit ``tuner`` the lookup is authoritative (tests
    assert cache hits); otherwise it goes through the best-effort
    process-default path and falls back to the model's default config."""
    shape = shape_key(n_slots, max_len, cfg.n_kv_heads, cfg.head_dim_,
                      T.DTYPE, n_heads=cfg.n_heads)
    if tuner is not None:
        config = tuner.tune("serve_kv", shape)
    else:
        config = tuned_config("serve_kv", shape, _default_config(shape))
    return int(config["block_size"])


class PagedKVCache:
    def __init__(self, cfg: ArchConfig, *, n_slots: int, max_len: int,
                 block_size: int | None = None, pool_tokens: int | None = None,
                 tuner=None, faults=None):
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        if block_size is None:
            block_size = resolve_block_size(cfg, n_slots=n_slots,
                                            max_len=max_len, tuner=tuner)
        self.block_size = bs = max(1, int(block_size))
        if pool_tokens is None:
            # expected steady-state occupancy (the serve_kv cost model's
            # operating point) — half the dense footprint
            pool_tokens = max((self.n_slots * self.max_len) // 2,
                              self.max_len)
        # An explicit pool_tokens is honoured as given (no silent
        # inflation to max_len): requests whose lifetime footprint cannot
        # fit the pool are the *engine's* job to REFUSE with a
        # pool-capacity reason, not the pool's to paper over.
        pool_tokens = max(int(pool_tokens), bs)
        self.n_blocks = 1 + -(-pool_tokens // bs)      # +1: scratch block 0
        self.blocks_per_seq = -(-self.max_len // bs)   # table width ceiling
        self.pool = T.init_paged_cache(cfg, self.n_blocks, bs)
        self._free = list(range(self.n_blocks - 1, 0, -1))
        self._allocated: set[int] = set()
        self._pack_fns: dict[int, object] = {}
        self.faults = faults               # FaultPlan: injected alloc failures

    # ------------------------------------------------------------------
    # host-side block accounting

    @property
    def n_free_blocks(self) -> int:
        return len(self._free)

    @property
    def usable_blocks(self) -> int:
        """Total allocatable blocks (pool minus the reserved scratch) —
        the hard ceiling on any single request's lifetime footprint."""
        return self.n_blocks - 1

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(1, int(n_tokens)) // self.block_size)

    def alloc(self, n: int) -> list[int] | None:
        """n physical blocks, or None if the pool can't cover them now
        (caller defers, preempts, or refuses; nothing is allocated
        partially).  An injected ``"alloc"`` fault denies the request
        exactly as a genuinely empty free list would."""
        if self.faults is not None and self.faults.fire("alloc"):
            return None
        if n > len(self._free):
            return None
        taken = self._free[-n:]
        del self._free[-n:]
        self._allocated.update(taken)
        return taken

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the pool.  Conservation is load-bearing under
        preemption/expiry (the same block list can reach multiple exit
        paths), so a double-free or foreign block is an error, not a
        silent free-list corruption."""
        assert 0 not in blocks, "physical block 0 is reserved scratch"
        bad = [b for b in blocks if b not in self._allocated]
        if bad:
            raise ValueError(f"free of unallocated block(s) {bad} "
                             f"(double free or foreign block)")
        self._allocated.difference_update(blocks)
        self._free.extend(blocks)

    @property
    def bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.pool))

    @property
    def dense_bytes(self) -> int:
        """What the dense ``(n_slots, max_len)`` layout would have cost —
        the savings the paged layout exists to bank."""
        per_token = self.bytes / (self.n_blocks * self.block_size)
        return int(per_token * self.n_slots * self.max_len)

    def table_array(self, block_lists: list[list[int]], width: int) -> jnp.ndarray:
        """(n_slots, width) int32 block table; short rows and idle slots
        pad with scratch block 0."""
        table = np.zeros((self.n_slots, width), np.int32)
        for row, blocks in enumerate(block_lists):
            if blocks:
                table[row, : len(blocks)] = blocks[:width]
        return jnp.asarray(table)

    # ------------------------------------------------------------------
    # prefill → pool packing

    def _pack_fn(self, cache_len_dim: int):
        bs, fn = self.block_size, self._pack_fns.get(cache_len_dim)
        if fn is not None:
            return fn
        assert cache_len_dim % bs == 0
        nb = cache_len_dim // bs

        def pack(pool, dense, phys, pad):
            def one(pool_leaf, dense_leaf):
                # dense_leaf: (n_scan, 1, L, Hkv, Dh) — drop the B=1 axis,
                # roll the left-padding off so real token i lands at slot i
                d = jnp.roll(dense_leaf[:, 0], -pad, axis=1)
                blocks = d.reshape(d.shape[0], nb, bs, *d.shape[2:])
                return pool_leaf.at[:, phys].set(blocks.astype(pool_leaf.dtype))

            return {
                sub: {"k_pool": one(leaves["k_pool"], dense[sub]["k"]),
                      "v_pool": one(leaves["v_pool"], dense[sub]["v"])}
                for sub, leaves in pool.items()
            }

        fn = jax.jit(pack, donate_argnums=(0,))
        self._pack_fns[cache_len_dim] = fn
        return fn

    def pack_prefill(self, dense_cache, blocks: list[int], *,
                     prompt_len: int, pad: int) -> None:
        """Scatter a B=1 dense prefill cache into the pool at ``blocks``.

        ``dense_cache`` comes from ``T.prefill(..., max_len=L)`` with L a
        multiple of the block size; the prompt sits left-padded by
        ``pad``.  Only the first ``ceil(prompt_len/block_size)`` blocks
        carry prompt KV; trailing dense blocks (stale pad KV after the
        roll) are routed to scratch block 0, and the request's remaining
        blocks fill incrementally during decode.
        """
        leaf = next(iter(dense_cache.values()))["k"]
        cache_len_dim = leaf.shape[2]
        nb_dense = cache_len_dim // self.block_size
        used = min(self.blocks_for(prompt_len), len(blocks), nb_dense)
        phys = np.zeros(nb_dense, np.int32)
        phys[:used] = blocks[:used]
        self.pool = self._pack_fn(cache_len_dim)(
            self.pool, dense_cache, jnp.asarray(phys), jnp.int32(pad))
