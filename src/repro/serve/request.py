"""Request lifecycle for the continuous-batching engine.

A :class:`Request` is the unit the scheduler prices and the batcher
places: it arrives (``QUEUED``), is admitted against the cost model
(``ADMITTED``), prefills into a free decode slot (``RUNNING``), and
leaves the batch on EOS / token budget (``FINISHED``) or is bounced by
the scheduler (``REFUSED``).  Timing fields are wall-clock marks the
bench turns into TTFT / per-token latency percentiles.

Fault tolerance (docs/serve.md "Failure semantics") adds two states:

* ``PREEMPTED`` — evicted from its slot under KV-pool pressure with
  generated tokens retained; it re-queues at the head and resumes by
  re-prefilling over prompt + generated tokens.  Not terminal.
* ``EXPIRED`` — terminal: the deadline/watchdog shed it (``expiry``
  says why).  Every admitted request ends FINISHED, REFUSED, or
  EXPIRED — the engine's zero-lost accounting contract.
"""

from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Request", "RequestState", "TERMINAL_STATES"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    ADMITTED = "admitted"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"
    REFUSED = "refused"
    EXPIRED = "expired"


#: States a request never leaves (the zero-lost accounting set).
TERMINAL_STATES = frozenset(
    {RequestState.FINISHED, RequestState.REFUSED, RequestState.EXPIRED})


_ids = itertools.count()


@dataclass(eq=False)      # identity equality: prompt arrays don't compare
class Request:
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new_tokens: int = 32
    slo_ms: float | None = None         # per-token latency SLO (None = none)
    deadline_ms: float | None = None    # end-to-end TTL from arrival (None = none)
    rid: int = field(default_factory=lambda: next(_ids))
    state: RequestState = RequestState.QUEUED

    # filled in by the engine
    slot: int | None = None
    blocks: list[int] = field(default_factory=list)   # physical KV blocks
    tokens: list[int] = field(default_factory=list)   # generated ids
    estimate: "object | None" = None                  # CostEstimate at admit
    refusal: "object | None" = None                   # PlacementRefused
    expiry: str | None = None                         # why EXPIRED, if it did
    admit_seq: int | None = None        # first-admission order (preempt age)
    prefill_pos: int = 0                # tokens prefilled so far (chunked)
    preemptions: int = 0                # times evicted under pool pressure
    defer_retries: int = 0              # DEFER backoff attempts so far
    retry_at_step: int = 0              # engine step before which not re-priced

    # wall-clock marks (seconds, time.perf_counter domain)
    t_arrival: float = field(default_factory=time.perf_counter)
    t_first_token: float | None = None
    t_finished: float | None = None
    # engine-step marks — the deterministic (noise-free) TTFT the serve
    # bench gates on: step_first_token - step_submitted
    step_submitted: int | None = None
    step_first_token: int | None = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if not len(self.prompt):
            raise ValueError("empty prompt")

    # ------------------------------------------------------------------

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def t_deadline(self) -> float | None:
        """Absolute deadline (arrival clock domain), or None."""
        if self.deadline_ms is None:
            return None
        return self.t_arrival + self.deadline_ms / 1e3

    def sequence(self) -> np.ndarray:
        """Prompt plus every generated token — what a preempted request
        re-prefills over on resume (recompute-on-resume)."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)])

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def ttft_s(self) -> float | None:
        """Time to first token (prefill wait + queueing)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def tpot_s(self) -> float | None:
        """Mean per-output-token latency after the first token."""
        if self.t_finished is None or self.n_generated < 2:
            return None
        return (self.t_finished - self.t_first_token) / (self.n_generated - 1)

    def output(self, eos_id: int) -> np.ndarray:
        """Generated ids trimmed at (and excluding) the first EOS."""
        out = np.asarray(self.tokens, np.int32)
        hits = np.flatnonzero(out == eos_id)
        return out[: hits[0]] if len(hits) else out
