"""Deterministic fault injection for the serve stack (see docs/serve.md
"Failure semantics").

Robustness claims are only testable if the failures are reproducible: a
:class:`FaultPlan` is a *schedule* of faults pinned to engine step
indices, built either explicitly (unit tests plant one fault at one
step) or from a seeded RNG (:meth:`FaultPlan.seeded` — the chaos bench
replays the identical fault sequence on every run).  The engine drives
the plan's step cursor (``begin_step``); the injection sites *consult*
it (``fire``), so production code paths and fault paths are the same
code — a fired fault is indistinguishable from the real failure it
models:

* ``"alloc"``  — :meth:`PagedKVCache.alloc` returns ``None`` as if the
  pool had no free blocks (→ admission retry / decode-time preemption);
* ``"backend"`` — the admission failover chain raises
  :class:`FaultInjected` in place of the backend call (→ health
  step-down forest → analytical → static degraded mode);
* ``"slow"``   — the engine's virtual clock skews forward by the
  fault's ``delay_s`` as if the step had stalled (→ deadline expiry and
  watchdog paths, without real sleeps in tests).

A plan is single-use state: it counts what actually fired
(:attr:`fired`) so tests and the chaos bench can assert the faults they
planned really happened instead of silently missing the window.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Fault", "FaultInjected", "FaultPlan", "FAULT_KINDS"]

FAULT_KINDS = ("alloc", "backend", "slow")


class FaultInjected(RuntimeError):
    """The synthetic backend exception a ``"backend"`` fault raises —
    typed so tests can tell an injected failure from a real bug."""


@dataclass(frozen=True)
class Fault:
    """One planned fault: ``kind`` fires at engine step ``step``.

    ``count`` is how many injection-site consultations it poisons within
    that step (an ``"alloc"`` fault with count=2 fails two consecutive
    allocation attempts); ``delay_s`` is the virtual stall a ``"slow"``
    fault adds to the engine clock."""

    step: int
    kind: str
    count: int = 1
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.step < 0 or self.count < 1 or self.delay_s < 0:
            raise ValueError(f"invalid fault {self!r}")


class FaultPlan:
    def __init__(self, faults: "list[Fault] | tuple[Fault, ...]" = ()):
        self.faults = sorted(faults, key=lambda f: (f.step, f.kind))
        self._by_step: dict[int, list[Fault]] = {}
        for f in self.faults:
            self._by_step.setdefault(f.step, []).append(f)
        self.fired = {k: 0 for k in FAULT_KINDS}
        self._step: int | None = None
        self._budget: dict[str, int] = {}
        self._slow_pending = 0.0

    @classmethod
    def seeded(cls, seed: int, *, n_steps: int, p_alloc: float = 0.0,
               p_backend: float = 0.0, p_slow: float = 0.0,
               slow_s: float = 0.05) -> "FaultPlan":
        """Bernoulli-per-step plan from one RNG seed: the same seed
        always builds the same schedule (the chaos bench's contract)."""
        rng = np.random.default_rng(seed)
        faults = []
        for step in range(n_steps):
            if p_alloc and rng.random() < p_alloc:
                faults.append(Fault(step, "alloc"))
            if p_backend and rng.random() < p_backend:
                faults.append(Fault(step, "backend"))
            if p_slow and rng.random() < p_slow:
                faults.append(Fault(step, "slow", delay_s=slow_s))
        return cls(faults)

    # ------------------------------------------------------------------

    def begin_step(self, step: int) -> None:
        """Advance the cursor: subsequent ``fire`` calls consult the
        faults planned for ``step``.  Un-fired budget from the previous
        step is dropped (a fault that found no injection site in its
        step never fired — ``summary`` shows the shortfall)."""
        self._step = int(step)
        self._budget = {}
        self._slow_pending = 0.0
        for f in self._by_step.get(self._step, ()):
            if f.kind == "slow":
                self._slow_pending += f.delay_s
            else:
                self._budget[f.kind] = self._budget.get(f.kind, 0) + f.count

    def fire(self, kind: str) -> float:
        """Consume one planned fault of ``kind`` at the current step.

        Returns a truthy payload when a fault fires — ``1`` for
        alloc/backend, the stall seconds for ``"slow"`` — and ``0``
        otherwise (including before any ``begin_step``)."""
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        if self._step is None:
            return 0
        if kind == "slow":
            delay, self._slow_pending = self._slow_pending, 0.0
            if delay > 0:
                self.fired["slow"] += 1
            return delay
        if self._budget.get(kind, 0) > 0:
            self._budget[kind] -= 1
            self.fired[kind] += 1
            return 1
        return 0

    # ------------------------------------------------------------------

    @property
    def planned(self) -> dict:
        out = {k: 0 for k in FAULT_KINDS}
        for f in self.faults:
            out[f.kind] += 1 if f.kind == "slow" else f.count
        return out

    def summary(self) -> dict:
        """Planned vs actually-fired counts, per kind."""
        return {"planned": self.planned, "fired": dict(self.fired)}
