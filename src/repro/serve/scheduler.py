"""SLO-aware admission scheduler: price the batch before joining it.

perf4sight's predict-then-place property, applied per request: before a
prompt may occupy a decode slot, the scheduler prices the batch as it
would look *after* admission (one ``CostQuery`` at ``bs = running + 1``
over the full context window) through the same forest→analytical
``CostEngine`` chain as the training launcher, and compares:

* predicted memory footprint (× safety margin) against the
  ``DeviceSpec`` HBM envelope / explicit ``gamma_budget_mb``;
* predicted step energy (× safety margin) against an explicit
  ``energy_budget_j`` power/thermal envelope;
* a per-token latency proxy (``phi_ms / max_len`` of the composed
  batch) against the request's latency SLO;
* a time-to-first-token proxy (the request's own prefill priced at
  ``bs=1`` over its prompt) against ``ServeSLO.ttft_ms``;
* the request's own token need against the context window.

Decisions are ``ADMIT`` (join now), ``DEFER``, or ``REFUSE``, split by
*whose fault the failure is*: a batch-dependent miss (memory/energy/
latency at ``bs = running + 1``) that clears when the request is
re-priced alone at ``bs=1`` is occupancy-transient — the engine keeps
it queued and retries next step (``DEFER``); a miss that persists even
alone (or a TTFT/context-window miss, which no amount of waiting
fixes) is ``REFUSE`` — a :class:`PlacementRefused` carrying the
estimate's ledger-class breakdown (``detail["cost_classes"]``, and
``detail["energy_classes"]`` when energy was priced) so operators see
*which* cost class blew the budget, not just that one did.

The decision path is pure prediction: with a fitted ``LMForest`` behind
the engine it triggers zero JAX compilations (asserted by
``tests/test_serve.py`` with a booby-trapped ``jax.jit``).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass

from repro.configs.base import ArchConfig

__all__ = ["Decision", "PlacementRefused", "SLOScheduler", "ServeSLO"]


class PlacementRefused(RuntimeError):
    """The admission gate predicted this placement exceeds the device.

    ``info`` carries the gate's evidence: predicted vs effective footprint,
    budget, backend source, and (when the analytical backend answered) the
    per-ledger-class cost breakdown.
    """

    def __init__(self, message: str, info: dict | None = None):
        super().__init__(message)
        self.info = info or {}


class Decision(enum.Enum):
    ADMIT = "admit"
    DEFER = "defer"
    REFUSE = "refuse"


@dataclass
class ServeSLO:
    """Serving-cell service-level objectives (engine-wide defaults;
    ``Request.slo_ms`` overrides per request)."""
    ttft_ms: float | None = None   # first-token target, prefill proxy
    tpot_ms: float | None = None   # per-output-token target, decode proxy


class SLOScheduler:
    def __init__(self, cfg: ArchConfig, cost_engine, *,
                 max_len: int, n_slots: int,
                 gamma_budget_mb: float | None = None,
                 energy_budget_j: float | None = None,
                 safety_margin: float = 0.1,
                 slo: ServeSLO | None = None,
                 seq_bucket: int = 64,
                 failover=None,
                 degraded_slots: int | None = None):
        self.cfg = cfg
        self.engine = cost_engine
        self.max_len = int(max_len)
        self.n_slots = int(n_slots)
        self.safety_margin = float(safety_margin)
        self.slo = slo or ServeSLO()
        self.seq_bucket = max(1, int(seq_bucket))
        # Failover chain (serve/health.py): backend *crashes* are health
        # events, not admission answers.  When every model-backed level
        # is down the scheduler falls back to a conservative static slot
        # budget — serve fewer, but keep serving.
        self.failover = failover
        self.degraded_slots = (max(1, int(degraded_slots))
                               if degraded_slots is not None
                               else max(1, self.n_slots // 2))
        # Registry convention: ArchConfig.reduced() appends "-smoke"; the
        # gate must predict the config actually being served.
        arch, reduced = cfg.name, False
        if arch.endswith("-smoke"):
            arch, reduced = arch[: -len("-smoke")], True
        self.arch, self.reduced = arch, reduced
        budget = gamma_budget_mb
        device = getattr(cost_engine, "device", None)
        if budget is None and device is not None:
            budget = device.hbm_bytes / 1e6
        self.gamma_budget_mb = budget
        self.energy_budget_j = energy_budget_j
        self.device = device
        self.unavailable: str | None = None   # backend couldn't score us
        self._last_miss: str | None = None    # why the last estimate was None

    # ------------------------------------------------------------------

    def _estimate(self, bs: int, seq: int):
        from repro.engine import BackendUnavailable, CostQuery

        seq = min(self.max_len,
                  max(self.seq_bucket,
                      -(-seq // self.seq_bucket) * self.seq_bucket))
        query = CostQuery(arch=self.arch, bs=max(1, bs), seq=seq,
                          stage="infer", reduced=self.reduced)
        self._last_miss = None
        try:
            if self.failover is not None:
                # None here means "every model-backed level failed" —
                # the degraded-mode signal, distinct from the semantic
                # BackendUnavailable (which still raises through).
                est = self.failover.estimate_one(query)
                if est is None:
                    self._last_miss = "degraded"
                return est
            return self.engine.estimate_one(query)
        except BackendUnavailable as e:
            self.unavailable = str(e)
            self._last_miss = "unavailable"
            return None

    def price(self, request) -> "object | None":
        """Per-request cost (bs=1 over its own token need) — attached to
        the request for the bench's goodput accounting."""
        est = self._estimate(1, request.prompt_len + request.max_new_tokens)
        request.estimate = est
        return est

    # ------------------------------------------------------------------

    def _gate_info(self, est, bs: int) -> dict:
        """The gate's evidence for one priced batch composition."""
        margin = 1 + self.safety_margin
        info = {
            "bs": bs, "seq": self.max_len,
            "gamma_mb": est.gamma_mb, "gamma_eff": est.gamma_mb * margin,
            "phi_ms": est.phi_ms, "source": est.source,
            "budget_mb": self.gamma_budget_mb,
        }
        if self.energy_budget_j is not None or est.energy_j:
            info["energy_j"] = est.energy_j
            info["energy_eff"] = est.energy_j * margin
            info["energy_budget_j"] = self.energy_budget_j
        if self.device is not None:
            info["device"] = self.device.name
        detail = est.detail or {}
        if detail.get("cost_classes") is not None:
            info["cost_classes"] = detail["cost_classes"]
        if detail.get("energy_classes") is not None:
            info["energy_classes"] = detail["energy_classes"]
        return info

    def _batch_reason(self, est, request, bs: int, info: dict) -> str | None:
        """First batch-dependent gate the composed batch fails (None = all
        pass).  These are the checks that can clear at lower occupancy —
        the DEFER candidates; occupancy-independent gates (context window,
        TTFT) live in :meth:`admit` directly."""
        margin = 1 + self.safety_margin
        if (self.gamma_budget_mb is not None
                and est.gamma_mb * margin > self.gamma_budget_mb):
            return (f"predicted {est.gamma_mb * margin:.0f}MB effective "
                    f"footprint at bs={bs} > budget "
                    f"{self.gamma_budget_mb:.0f}MB")
        if (self.energy_budget_j is not None
                and est.energy_j * margin > self.energy_budget_j):
            return (f"predicted {est.energy_j * margin:.3g}J effective step "
                    f"energy at bs={bs} > budget {self.energy_budget_j:.3g}J")
        slo_ms = request.slo_ms
        if slo_ms is None:
            slo_ms = self.slo.tpot_ms
        if slo_ms is not None:
            tpot = est.phi_ms / self.max_len * margin
            info["tpot_proxy_ms"] = tpot
            if tpot > slo_ms:
                return (f"per-token proxy {tpot:.3f}ms at bs={bs} "
                        f"> SLO {slo_ms:.3f}ms")
        return None

    def admit(self, request, *, n_running: int) -> tuple[Decision, dict]:
        """Price the composed batch and decide.  Never raises: a REFUSE
        returns the decision with the refusal info; the engine turns it
        into a ``PlacementRefused`` on the request."""
        need = request.prompt_len + request.max_new_tokens
        if need > self.max_len:
            return Decision.REFUSE, {
                "reason": f"needs {need} tokens > max_len={self.max_len}"}

        est = self._estimate(n_running + 1, self.max_len)
        if est is None:
            if self._last_miss == "degraded":
                # Static-budget degraded mode: no model-backed level can
                # price the batch, so admission falls back to a
                # conservative fixed concurrency cap.  Over the cap is a
                # DEFER (occupancy drains; the health probe may recover
                # a real backend), never a REFUSE — degraded mode sheds
                # throughput, not requests.
                info = {"degraded": True,
                        "health": self.failover.health.current,
                        "static_slots": self.degraded_slots}
                if n_running < self.degraded_slots:
                    return Decision.ADMIT, info
                info["reason"] = (
                    f"degraded static budget: {n_running} running >= "
                    f"{self.degraded_slots} static slots")
                return Decision.DEFER, info
            # unknown arch / unscorable cell: serve ungated rather than
            # refusing workloads the model can't price (legacy behaviour)
            return Decision.ADMIT, {"skipped": self.unavailable}

        info = self._gate_info(est, n_running + 1)

        # TTFT gate — occupancy-independent: the continuous engine
        # prefills at B=1 over the prompt no matter who else is decoding,
        # so a predicted miss can never clear by waiting → straight
        # REFUSE, never DEFER.
        if self.slo.ttft_ms is not None:
            pest = self._estimate(1, request.prompt_len)
            if pest is not None:
                ttft = pest.phi_ms * (1 + self.safety_margin)
                info["ttft_proxy_ms"] = ttft
                if ttft > self.slo.ttft_ms:
                    info["reason"] = (
                        f"prefill proxy {ttft:.3f}ms for prompt="
                        f"{request.prompt_len} > TTFT SLO "
                        f"{self.slo.ttft_ms:.3f}ms")
                    return Decision.REFUSE, info

        reason = self._batch_reason(est, request, n_running + 1, info)
        if reason is None:
            return Decision.ADMIT, info
        info["reason"] = reason

        # Batch-dependent miss: decide whose fault it is.  Re-priced
        # alone (bs=1) and passing every gate → the current occupancy is
        # the problem, not the request: DEFER, the engine retries next
        # step as slots drain.  Failing even alone → it can never fit:
        # REFUSE for good.
        if n_running > 0:
            alone = self._estimate(1, self.max_len)
            if alone is not None and self._batch_reason(
                    alone, request, 1, dict(info)) is None:
                info["defer"] = "passes every gate alone at bs=1"
                return Decision.DEFER, info
        return Decision.REFUSE, info

    def refusal(self, request, info: dict) -> PlacementRefused:
        breakdown = ""
        if "cost_classes" in info:
            def mag(v):
                # Buckets come in two shapes: a scalar per class (forest
                # detail) or a class_sums dict (analytical detail).
                if isinstance(v, dict):
                    return sum(float(x) for k, x in v.items()
                               if k != "count")
                return float(v)
            top = sorted(info["cost_classes"].items(),
                         key=lambda kv: -mag(kv[1]))[:3]
            breakdown = " [" + ", ".join(
                f"{k}={mag(v):.3g}" for k, v in top) + "]"
        return PlacementRefused(
            f"request {request.rid} (prompt={request.prompt_len}, "
            f"max_new={request.max_new_tokens}) refused: "
            f"{info.get('reason', 'over budget')}{breakdown}", info)
