"""Serving engines over the cost-prediction stack (see docs/serve.md).

Two engines share the model stack and the admission gate:

* :class:`ServeEngine` — the lockstep baseline: one batch prefills
  together and decodes until every member finishes.
* :class:`ContinuousEngine` — continuous batching: requests queue,
  are priced per admission by the :class:`SLOScheduler` through the
  ``CostEngine`` forest→analytical chain, prefill individually into free
  slots, and decode raggedly out of a :class:`PagedKVCache` block pool
  whose block size comes from the kernel autotuner's ``serve_kv`` tiling
  model.
"""

from repro.serve.continuous import ContinuousConfig, ContinuousEngine
from repro.serve.engine import ServeConfig, ServeEngine, pad_ragged
from repro.serve.kv_cache import PagedKVCache, resolve_block_size
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import (
    Decision,
    PlacementRefused,
    ServeSLO,
    SLOScheduler,
)

__all__ = [
    "ContinuousConfig",
    "ContinuousEngine",
    "Decision",
    "PagedKVCache",
    "PlacementRefused",
    "Request",
    "RequestState",
    "SLOScheduler",
    "ServeConfig",
    "ServeEngine",
    "ServeSLO",
    "pad_ragged",
    "resolve_block_size",
]
