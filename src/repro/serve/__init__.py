"""Serving engines over the cost-prediction stack (see docs/serve.md).

Two engines share the model stack and the admission gate:

* :class:`ServeEngine` — the lockstep baseline: one batch prefills
  together and decodes until every member finishes.
* :class:`ContinuousEngine` — continuous batching: requests queue,
  are priced per admission by the :class:`SLOScheduler` through the
  ``CostEngine`` forest→analytical chain, prefill individually into free
  slots, and decode raggedly out of a :class:`PagedKVCache` block pool
  whose block size comes from the kernel autotuner's ``serve_kv`` tiling
  model.

The fault-tolerance layer (docs/serve.md "Failure semantics") rides on
the continuous engine: preemption under pool pressure, per-request
deadlines + a watchdog, backend failover into static degraded mode
(:class:`FailoverChain`), and a seeded deterministic fault-injection
harness (:class:`FaultPlan`).
"""

from repro.serve.continuous import ContinuousConfig, ContinuousEngine
from repro.serve.engine import ServeConfig, ServeEngine, pad_ragged
from repro.serve.faults import FAULT_KINDS, Fault, FaultInjected, FaultPlan
from repro.serve.health import STATIC_LEVEL, FailoverChain
from repro.serve.kv_cache import PagedKVCache, resolve_block_size
from repro.serve.request import TERMINAL_STATES, Request, RequestState
from repro.serve.scheduler import (
    Decision,
    PlacementRefused,
    ServeSLO,
    SLOScheduler,
)

__all__ = [
    "ContinuousConfig",
    "ContinuousEngine",
    "Decision",
    "FAULT_KINDS",
    "Fault",
    "FaultInjected",
    "FaultPlan",
    "FailoverChain",
    "PagedKVCache",
    "PlacementRefused",
    "Request",
    "RequestState",
    "SLOScheduler",
    "STATIC_LEVEL",
    "ServeConfig",
    "ServeEngine",
    "ServeSLO",
    "TERMINAL_STATES",
    "pad_ragged",
    "resolve_block_size",
]
