"""perf4sight predictor (paper Fig. 2): analytical features + profiled
datapoints → one random forest per attribute (Γ, Φ) → fast prediction and
admission control.

The fitted predictor is the framework's *admission controller*: the launcher
asks it whether a (model, batch size) training job fits the device's memory
and latency budget before any device allocation happens — the paper's
safety-critical motivation (§1, §6.4), promoted to a first-class feature.
"""

from __future__ import annotations

import json
from dataclasses import dataclass

import numpy as np

from repro.core.dataset import Datapoint, features_targets
from repro.core.features import NetworkSpec, feature_matrix, network_features
from repro.core.fileio import atomic_write_bytes, atomic_write_json
from repro.core.forest import RandomForestRegressor

__all__ = ["Perf4Sight", "EvalReport", "mape"]


def mape(pred: np.ndarray, true: np.ndarray) -> float:
    """Mean absolute percentage error (the paper's attribute-error metric)."""
    true = np.asarray(true, dtype=np.float64)
    denom = np.where(np.abs(true) > 1e-12, np.abs(true), 1.0)
    return float(np.mean(np.abs(np.asarray(pred) - true) / denom))


@dataclass
class EvalReport:
    gamma_mape: float
    phi_mape: float
    n: int

    def __str__(self) -> str:
        return (
            f"Γ error {self.gamma_mape * 100:.2f}% | Φ error {self.phi_mape * 100:.2f}% "
            f"({self.n} test points)"
        )


class HybridRegressor:
    """Ridge over the analytical features + random forest on the residual.

    The paper observes both attributes are linear in batch size with a
    topology-dependent fit (App. B); the ridge captures that global linear
    structure (which a 20-point forest cannot extrapolate), the forest
    captures the framework/device-specific nonlinearity — the same
    analytical+learned split as the paper's Fig. 2, one level deeper.
    Beyond-paper addition, decisive in the small-profiling-grid regime
    (EXPERIMENTS.md §Reproduction)."""

    def __init__(self, alpha: float = 1e-2, seed: int = 0, **forest_kw):
        self.alpha = alpha
        self.forest = RandomForestRegressor(seed=seed, **forest_kw)
        self._lin: tuple | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "HybridRegressor":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        mu, sd = X.mean(0), X.std(0) + 1e-12
        Xn = (X - mu) / sd
        A = Xn.T @ Xn + self.alpha * len(y) * np.eye(X.shape[1])
        w = np.linalg.solve(A, Xn.T @ (y - y.mean()))
        self._lin = (mu, sd, w, float(y.mean()))
        self.forest.fit(X, y - self._linear(X))
        self.oob_mape_ = self.forest.oob_mape_
        return self

    def _linear(self, X: np.ndarray) -> np.ndarray:
        mu, sd, w, b = self._lin
        return ((np.asarray(X, np.float64) - mu) / sd) @ w + b

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, np.float64))
        return self._linear(X) + self.forest.predict(X)

    def to_dict(self) -> dict:
        mu, sd, w, b = self._lin
        return {"hybrid": True, "alpha": self.alpha,
                "lin": {"mu": mu.tolist(), "sd": sd.tolist(),
                        "w": w.tolist(), "b": b},
                "forest": self.forest.to_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "HybridRegressor":
        self = cls(alpha=d.get("alpha", 1e-2))
        lin = d["lin"]
        self._lin = (np.array(lin["mu"]), np.array(lin["sd"]),
                     np.array(lin["w"]), float(lin["b"]))
        self.forest = RandomForestRegressor.from_dict(d["forest"])
        return self

    def content_hash(self) -> str:
        import hashlib

        mu, sd, w, b = self._lin
        h = hashlib.sha1()
        for a in (mu, sd, w):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(np.float64(b).tobytes())
        h.update(self.forest.content_hash().encode())
        return h.hexdigest()

    def to_arrays(self, prefix: str = "") -> dict:
        mu, sd, w, b = self._lin
        out = {
            prefix + "lin_mu": mu,
            prefix + "lin_sd": sd,
            prefix + "lin_w": w,
            prefix + "lin_b": np.array([b, self.alpha]),
        }
        out.update(self.forest.to_arrays(prefix + "forest_"))
        return out

    @classmethod
    def from_arrays(cls, arrays, prefix: str = "") -> "HybridRegressor":
        b_alpha = np.asarray(arrays[prefix + "lin_b"], dtype=np.float64)
        self = cls(alpha=float(b_alpha[1]))
        self._lin = (
            np.asarray(arrays[prefix + "lin_mu"], dtype=np.float64),
            np.asarray(arrays[prefix + "lin_sd"], dtype=np.float64),
            np.asarray(arrays[prefix + "lin_w"], dtype=np.float64),
            float(b_alpha[0]),
        )
        self.forest = RandomForestRegressor.from_arrays(arrays, prefix + "forest_")
        return self


class Perf4Sight:
    """Two regressors (Γ memory MB, Φ latency ms) over the 42 features —
    hybrid ridge+forest by default, pure forest with ``hybrid=False``
    (the paper-faithful baseline)."""

    def __init__(
        self,
        n_estimators: int = 100,
        min_samples_leaf: int = 1,
        max_features: int | float | str | None = "third",
        seed: int = 0,
        hybrid: bool = True,
    ):
        kw = dict(
            n_estimators=n_estimators,
            min_samples_leaf=min_samples_leaf,
            max_features=max_features,
        )
        if hybrid:
            self.gamma_model = HybridRegressor(seed=seed, **kw)
            self.phi_model = HybridRegressor(seed=seed + 1, **kw)
        else:
            self.gamma_model = RandomForestRegressor(seed=seed, **kw)
            self.phi_model = RandomForestRegressor(seed=seed + 1, **kw)
        self.fitted = False

    # -- training ------------------------------------------------------------

    def fit(self, datapoints: list[Datapoint]) -> "Perf4Sight":
        X, g, p = features_targets(datapoints)
        self.gamma_model.fit(X, g)
        self.phi_model.fit(X, p)
        self.fitted = True
        return self

    def fit_arrays(self, X: np.ndarray, gamma: np.ndarray, phi: np.ndarray) -> "Perf4Sight":
        self.gamma_model.fit(X, gamma)
        self.phi_model.fit(X, phi)
        self.fitted = True
        return self

    # -- prediction ----------------------------------------------------------

    def predict_features(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        return self.gamma_model.predict(X), self.phi_model.predict(X)

    def predict(self, spec: NetworkSpec, bs: int) -> tuple[float, float]:
        """(Γ MB, Φ ms) for a network spec at batch size ``bs`` — pure
        Python + numpy, ~0.1 ms (paper §6.4 requires no-GPU, sub-second)."""
        x = network_features(spec, bs)[None, :]
        g, p = self.predict_features(x)
        return float(g[0]), float(p[0])

    def content_hash(self) -> str:
        """Hash of both fitted models — salts engine cache keys so estimates
        from differently-fitted predictors never alias on disk."""
        import hashlib

        h = hashlib.sha1()
        h.update(self.gamma_model.content_hash().encode())
        h.update(self.phi_model.content_hash().encode())
        return h.hexdigest()

    def predict_batch(
        self, specs_and_bs: list[tuple[NetworkSpec, int]]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched (Γ, Φ) for N (spec, batch size) candidates: one vectorized
        feature-matrix build + one forest traversal per attribute, instead of
        N scalar round-trips (the engine/search fast path)."""
        if not specs_and_bs:
            return np.zeros(0), np.zeros(0)
        X = feature_matrix(specs_and_bs)
        return self.predict_features(X)

    def evaluate(self, datapoints: list[Datapoint]) -> EvalReport:
        X, g, p = features_targets(datapoints)
        pg, pp = self.predict_features(X)
        return EvalReport(gamma_mape=mape(pg, g), phi_mape=mape(pp, p), n=len(datapoints))

    # -- admission control (launcher integration) -----------------------------

    def admit(
        self,
        spec: NetworkSpec,
        bs: int,
        *,
        gamma_budget_mb: float | None = None,
        phi_budget_ms: float | None = None,
        safety_margin: float = 0.1,
    ) -> tuple[bool, dict]:
        """Gate a training job: refuse if the predicted footprint/latency
        (inflated by ``safety_margin``) exceeds the budget."""
        g, p = self.predict(spec, bs)
        g_eff, p_eff = g * (1 + safety_margin), p * (1 + safety_margin)
        ok = True
        if gamma_budget_mb is not None and g_eff > gamma_budget_mb:
            ok = False
        if phi_budget_ms is not None and p_eff > phi_budget_ms:
            ok = False
        return ok, {"gamma_mb": g, "phi_ms": p, "gamma_eff": g_eff, "phi_eff": p_eff}

    # -- persistence -----------------------------------------------------------
    #
    # Two formats, chosen by extension, so fitted forests round-trip between
    # processes (search jobs load once instead of refitting):
    #   *.json — nested tree dicts (human-inspectable, the original format)
    #   *.npz  — packed flat arrays (compact; production-size forests)
    # Both writes are atomic (tempfile in the target dir + os.replace).

    def save(self, path: str) -> None:
        if path.endswith(".npz"):
            arrays: dict[str, np.ndarray] = {}
            for prefix, model in (("gamma_", self.gamma_model),
                                  ("phi_", self.phi_model)):
                arrays[prefix + "hybrid"] = np.array(
                    [1.0 if isinstance(model, HybridRegressor) else 0.0])
                arrays.update(model.to_arrays(prefix))
            atomic_write_bytes(path, lambda f: np.savez_compressed(f, **arrays),
                               suffix=".npz")
            return
        atomic_write_json(path, {"gamma": self.gamma_model.to_dict(),
                                 "phi": self.phi_model.to_dict()})

    @classmethod
    def load(cls, path: str) -> "Perf4Sight":
        self = cls()
        if path.endswith(".npz"):
            with np.load(path) as arrays:
                models = {}
                for prefix in ("gamma_", "phi_"):
                    if float(arrays[prefix + "hybrid"][0]):
                        models[prefix] = HybridRegressor.from_arrays(arrays, prefix)
                    else:
                        models[prefix] = RandomForestRegressor.from_arrays(
                            arrays, prefix)
            self.gamma_model = models["gamma_"]
            self.phi_model = models["phi_"]
            self.fitted = True
            return self
        with open(path) as f:
            blob = json.load(f)
        loader = (
            lambda d: HybridRegressor.from_dict(d) if d.get("hybrid")
            else RandomForestRegressor.from_dict(d)
        )
        self.gamma_model = loader(blob["gamma"])
        self.phi_model = loader(blob["phi"])
        self.fitted = True
        return self
