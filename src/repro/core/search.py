"""Evolutionary architecture search under hard resource constraints —
the paper's on-device OFA case study (§6.4), generalised.

The paper runs [3]'s evolutionary search: population 100, 500 iterations,
every sampled sub-network needs (Γ, γ, φ) estimates.  Profiling costs ~20 s
per sample on-device (11 days for 50 000 samples) and risks OOM-killing
co-located safety-critical processes; the perf4sight predictor costs ~0.1 s
on CPU (1.4 h) — a ~200× search-time gain.

Since the engine refactor the search talks to the unified
:class:`~repro.engine.CostBackend` API and evaluates WHOLE POPULATIONS in
one batched ``estimate`` call per stage: one vectorized feature-matrix
build + one packed forest traversal for all N candidates, instead of N
scalar predictor round-trips per generation (≥5× on a 100-candidate
population; see benchmarks/engine_bench.py).

Here the search space is the pruned-topology space of a base CNN (the
reproduction analogue of OFA sub-network sampling: per-group keep ratios
define a sub-network of the unpruned super-network).  Fitness is total kept
filters (a monotone accuracy proxy — more capacity, better accuracy, as in
the paper's MIN < A/B < MAX ordering), maximised subject to hard constraints
on predicted training memory Γ, inference memory γ and inference latency φ.

The same driver powers the LM-framework admission search (mesh/microbatch
configs) via a different genome — see launch/train.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.models.cnn import CNN_BUILDERS

# NOTE: repro.engine is imported lazily inside the functions that need it —
# the core layer must stay importable without dragging in the engine stack
# (same discipline as roofline.py's duck-typed DeviceSpec handling), and the
# engine package itself imports core modules.

__all__ = ["Constraints", "SearchResult", "evolutionary_search",
           "sample_subnetwork", "fold_population"]


@dataclass
class Constraints:
    gamma_mb: float | None = None        # training memory budget (Γ)
    gamma_inf_mb: float | None = None    # inference memory budget (γ)
    phi_inf_ms: float | None = None      # inference latency budget (φ)
    train_bs: int = 32
    infer_bs: int = 1


@dataclass
class SearchResult:
    widths: dict[str, int]
    fitness: float
    gamma_mb: float
    gamma_inf_mb: float
    phi_inf_ms: float
    evaluations: int
    search_time_s: float
    history: list[float] = field(default_factory=list)


def sample_subnetwork(
    canonical: dict[str, int], rng: np.random.Generator, min_ch: int = 2
) -> dict[str, int]:
    """Sample per-group keep ratios uniformly in [0.1, 1.0] (OFA-style)."""
    return {
        g: max(min_ch, int(round(n * rng.uniform(0.1, 1.0))))
        for g, n in canonical.items()
    }


def _mutate(
    widths: dict[str, int],
    canonical: dict[str, int],
    rng: np.random.Generator,
    rate: float = 0.2,
    min_ch: int = 2,
) -> dict[str, int]:
    out = dict(widths)
    for g in canonical:
        if rng.random() < rate:
            out[g] = max(min_ch, int(round(canonical[g] * rng.uniform(0.1, 1.0))))
    return out


def _crossover(a: dict[str, int], b: dict[str, int], rng: np.random.Generator) -> dict[str, int]:
    return {g: (a[g] if rng.random() < 0.5 else b[g]) for g in a}


def fold_population(
    widths_list: list[dict[str, int]],
) -> tuple[list[dict[str, int]], list[int]]:
    """Fold identical width dicts to unique entries + a fan-in index.

    Converged populations produce many identical candidates (crossover of
    identical parents, low-rate mutation); each duplicate would otherwise
    pay a full model build + feature build + engine query.  Returns
    ``(unique, fan_in)`` with ``unique[fan_in[i]]`` the representative of
    candidate ``i``.
    """
    uniq_index: dict[tuple, int] = {}
    unique: list[dict[str, int]] = []
    fan_in: list[int] = []
    for w in widths_list:
        key = tuple(sorted(w.items()))
        if key not in uniq_index:
            uniq_index[key] = len(unique)
            unique.append(w)
        fan_in.append(uniq_index[key])
    return unique, fan_in


def _as_engine(backend) -> "CostEngine":
    """Accept a CostEngine, any CostBackend, or (train, infer) Perf4Sight
    predictors (the pre-engine calling convention)."""
    from repro.engine.engine import CostEngine

    if isinstance(backend, CostEngine):
        return backend
    if isinstance(backend, tuple):
        from repro.engine.backends import ForestBackend

        train, infer = backend
        return CostEngine(ForestBackend(train=train, infer=infer))
    from repro.core.predictor import Perf4Sight

    if isinstance(backend, Perf4Sight):
        from repro.engine.backends import ForestBackend

        return CostEngine(ForestBackend(train=backend, infer=backend))
    return CostEngine(backend)


def evolutionary_search(
    family: str,
    backend,
    constraints: Constraints,
    *,
    population: int = 100,
    iterations: int = 500,
    parent_frac: float = 0.25,
    mutate_prob: float = 0.5,
    width_mult: float = 0.25,
    input_hw: int = 16,
    seed: int = 0,
) -> SearchResult:
    """Paper §6.4 ES: population of sub-networks, constraint-checked via the
    cost engine, evolved toward maximum capacity within budget.

    ``backend`` is a :class:`~repro.engine.CostEngine`, any
    :class:`~repro.engine.CostBackend`, or a ``(predictor_train,
    predictor_infer)`` tuple of fitted :class:`Perf4Sight` models.  Every
    generation is scored with ONE batched ``estimate`` call per stage.
    """
    from repro.engine.types import STAGE_INFER, STAGE_TRAIN, CostQuery

    engine = _as_engine(backend)
    rng = np.random.default_rng(seed)
    build = CNN_BUILDERS[family]
    canonical = build(width_mult=width_mult, input_hw=input_hw).widths
    t0 = time.perf_counter()
    evaluations = 0

    def evaluate_population(
        widths_list: list[dict[str, int]],
    ) -> list[tuple[float, float, float, float]]:
        """Batched: (fitness (-inf if constraints violated), Γ, γ, φ) per
        candidate, from two engine calls covering the whole population.

        Identical width dicts within a generation (converged populations
        produce many, via crossover of identical parents) are folded to ONE
        model build + feature build + query; results fan back out per
        candidate.
        """
        nonlocal evaluations
        evaluations += len(widths_list)
        uniq_widths, fan_in = fold_population(widths_list)
        specs = [
            build(widths=w, input_hw=input_hw).conv_specs() for w in uniq_widths
        ]
        uniq_t = engine.estimate(
            [CostQuery(spec=s, bs=constraints.train_bs, stage=STAGE_TRAIN)
             for s in specs])
        uniq_i = engine.estimate(
            [CostQuery(spec=s, bs=constraints.infer_bs, stage=STAGE_INFER)
             for s in specs])
        est_t = [uniq_t[j] for j in fan_in]
        est_i = [uniq_i[j] for j in fan_in]
        out = []
        for w, et, ei in zip(widths_list, est_t, est_i):
            g_train, g_inf, p_inf = et.gamma_mb, ei.gamma_mb, ei.phi_ms
            ok = (
                (constraints.gamma_mb is None or g_train <= constraints.gamma_mb)
                and (constraints.gamma_inf_mb is None or g_inf <= constraints.gamma_inf_mb)
                and (constraints.phi_inf_ms is None or p_inf <= constraints.phi_inf_ms)
            )
            fitness = float(sum(w.values())) if ok else -np.inf
            out.append((fitness, g_train, g_inf, p_inf))
        return out

    pop = [sample_subnetwork(canonical, rng) for _ in range(population)]
    scored = list(zip(evaluate_population(pop), pop))
    history = []
    n_parents = max(2, int(parent_frac * population))
    for _ in range(iterations):
        scored.sort(key=lambda sw: sw[0][0], reverse=True)
        history.append(scored[0][0][0])
        parents = [w for (_, w) in scored[:n_parents]]
        children = []
        for _ in range(population - n_parents):
            if rng.random() < mutate_prob:
                child = _mutate(parents[rng.integers(len(parents))], canonical, rng)
            else:
                a, b = rng.choice(len(parents), 2, replace=False)
                child = _crossover(parents[a], parents[b], rng)
            children.append(child)
        scored = scored[:n_parents] + list(zip(evaluate_population(children), children))

    scored.sort(key=lambda sw: sw[0][0], reverse=True)
    (fitness, g_t, g_i, p_i), best = scored[0]
    return SearchResult(
        widths=best,
        fitness=fitness,
        gamma_mb=g_t,
        gamma_inf_mb=g_i,
        phi_inf_ms=p_i,
        evaluations=evaluations,
        search_time_s=time.perf_counter() - t0,
        history=history,
    )
