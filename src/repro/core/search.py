"""Evolutionary architecture search under hard resource constraints —
the paper's on-device OFA case study (§6.4), generalised.

The paper runs [3]'s evolutionary search: population 100, 500 iterations,
every sampled sub-network needs (Γ, γ, φ) estimates.  Profiling costs ~20 s
per sample on-device (11 days for 50 000 samples) and risks OOM-killing
co-located safety-critical processes; the perf4sight predictor costs ~0.1 s
on CPU (1.4 h) — a ~200× search-time gain.

Here the search space is the pruned-topology space of a base CNN (the
reproduction analogue of OFA sub-network sampling: per-group keep ratios
define a sub-network of the unpruned super-network).  Fitness is total kept
filters (a monotone accuracy proxy — more capacity, better accuracy, as in
the paper's MIN < A/B < MAX ordering), maximised subject to hard constraints
on predicted training memory Γ, inference memory γ and inference latency φ.

The same driver powers the LM-framework admission search (mesh/microbatch
configs) via a different genome — see launch/train.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.predictor import Perf4Sight
from repro.models.cnn import CNN_BUILDERS

__all__ = ["Constraints", "SearchResult", "evolutionary_search", "sample_subnetwork"]


@dataclass
class Constraints:
    gamma_mb: float | None = None        # training memory budget (Γ)
    gamma_inf_mb: float | None = None    # inference memory budget (γ)
    phi_inf_ms: float | None = None      # inference latency budget (φ)
    train_bs: int = 32
    infer_bs: int = 1


@dataclass
class SearchResult:
    widths: dict[str, int]
    fitness: float
    gamma_mb: float
    gamma_inf_mb: float
    phi_inf_ms: float
    evaluations: int
    search_time_s: float
    history: list[float] = field(default_factory=list)


def sample_subnetwork(
    canonical: dict[str, int], rng: np.random.Generator, min_ch: int = 2
) -> dict[str, int]:
    """Sample per-group keep ratios uniformly in [0.1, 1.0] (OFA-style)."""
    return {
        g: max(min_ch, int(round(n * rng.uniform(0.1, 1.0))))
        for g, n in canonical.items()
    }


def _mutate(
    widths: dict[str, int],
    canonical: dict[str, int],
    rng: np.random.Generator,
    rate: float = 0.2,
    min_ch: int = 2,
) -> dict[str, int]:
    out = dict(widths)
    for g in canonical:
        if rng.random() < rate:
            out[g] = max(min_ch, int(round(canonical[g] * rng.uniform(0.1, 1.0))))
    return out


def _crossover(a: dict[str, int], b: dict[str, int], rng: np.random.Generator) -> dict[str, int]:
    return {g: (a[g] if rng.random() < 0.5 else b[g]) for g in a}


def evolutionary_search(
    family: str,
    predictor_train: Perf4Sight,
    predictor_infer: Perf4Sight,
    constraints: Constraints,
    *,
    population: int = 100,
    iterations: int = 500,
    parent_frac: float = 0.25,
    mutate_prob: float = 0.5,
    width_mult: float = 0.25,
    input_hw: int = 16,
    seed: int = 0,
) -> SearchResult:
    """Paper §6.4 ES: population of sub-networks, constraint-checked via the
    predictors, evolved toward maximum capacity within budget."""
    rng = np.random.default_rng(seed)
    build = CNN_BUILDERS[family]
    canonical = build(width_mult=width_mult, input_hw=input_hw).widths
    t0 = time.perf_counter()
    evaluations = 0

    def evaluate(widths: dict[str, int]) -> tuple[float, float, float, float]:
        """fitness (-inf if constraints violated), Γ, γ, φ."""
        nonlocal evaluations
        evaluations += 1
        model = build(widths=widths, input_hw=input_hw)
        spec = model.conv_specs()
        g_train, _ = predictor_train.predict(spec, constraints.train_bs)
        g_inf, p_inf = predictor_infer.predict(spec, constraints.infer_bs)
        ok = (
            (constraints.gamma_mb is None or g_train <= constraints.gamma_mb)
            and (constraints.gamma_inf_mb is None or g_inf <= constraints.gamma_inf_mb)
            and (constraints.phi_inf_ms is None or p_inf <= constraints.phi_inf_ms)
        )
        fitness = float(sum(widths.values())) if ok else -np.inf
        return fitness, g_train, g_inf, p_inf

    pop = [sample_subnetwork(canonical, rng) for _ in range(population)]
    scored = [(evaluate(w), w) for w in pop]
    history = []
    n_parents = max(2, int(parent_frac * population))
    for _ in range(iterations):
        scored.sort(key=lambda sw: sw[0][0], reverse=True)
        history.append(scored[0][0][0])
        parents = [w for (_, w) in scored[:n_parents]]
        children = []
        for _ in range(population - n_parents):
            if rng.random() < mutate_prob:
                child = _mutate(parents[rng.integers(len(parents))], canonical, rng)
            else:
                a, b = rng.choice(len(parents), 2, replace=False)
                child = _crossover(parents[a], parents[b], rng)
            children.append(child)
        scored = scored[:n_parents] + [(evaluate(w), w) for w in children]

    scored.sort(key=lambda sw: sw[0][0], reverse=True)
    (fitness, g_t, g_i, p_i), best = scored[0]
    return SearchResult(
        widths=best,
        fitness=fitness,
        gamma_mb=g_t,
        gamma_inf_mb=g_i,
        phi_inf_ms=p_i,
        evaluations=evaluations,
        search_time_s=time.perf_counter() - t0,
        history=history,
    )
