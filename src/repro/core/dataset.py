"""Profiling-grid construction (paper §5.1.1 / §6.1) with an on-disk cache.

The degrees of freedom are pruning level, pruning strategy and batch size.
Paper values: 25 batch sizes in [2, 256], levels {5x | x ∈ [0, 18]}, training
set T = {0, 30, 50, 70, 90} (tuned on AlexNet, §6.1), random strategy for the
training set, random + L1 for the test sets.

The reproduction keeps the protocol but scales the grid to the 1-core CPU
host (see DESIGN.md §5): profile-scale networks (width_mult, input_hw are
hyperparameters of the grid) and a reduced default batch/level grid.  The
``full`` preset restores the paper grid.

Every profiled datapoint is cached as JSON keyed by its full configuration,
so benchmarks re-run instantly and long collections can resume after
interruption (the same property the real toolflow needs on a flaky edge
fleet).
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core import pruning as pr
from repro.core.fileio import atomic_write_json, load_json_tolerant
from repro.core.features import network_features
from repro.core.profiler import profile_training
from repro.models.cnn import CNN_BUILDERS

__all__ = [
    "Datapoint",
    "GridSpec",
    "PAPER_TRAIN_LEVELS",
    "paper_test_levels",
    "default_grid",
    "collect_grid",
    "DatasetCache",
]

# Paper §6.1: T tuned on AlexNet; test = {5x | x in [0,18]} \ T.
PAPER_TRAIN_LEVELS = (0.0, 0.30, 0.50, 0.70, 0.90)
PAPER_ALL_LEVELS = tuple(0.05 * x for x in range(19))

# Reduced CPU-host defaults (protocol unchanged, grid subsampled).
DEFAULT_TRAIN_LEVELS = PAPER_TRAIN_LEVELS
DEFAULT_TEST_LEVELS = (0.10, 0.40, 0.60, 0.80)
DEFAULT_BATCH_SIZES = (2, 8, 16, 32)
PAPER_BATCH_SIZES = (2, 4, 8, 16, 32, 64, 70, 80, 90, 100, 110, 120, 128, 140,
                     150, 160, 170, 180, 190, 200, 210, 220, 230, 240, 256)


def paper_test_levels(train=PAPER_TRAIN_LEVELS) -> tuple[float, ...]:
    return tuple(l for l in PAPER_ALL_LEVELS if round(l * 100) not in
                 {round(t * 100) for t in train})


@dataclass(frozen=True)
class GridSpec:
    family: str
    levels: tuple[float, ...]
    strategy: str = "random"
    batch_sizes: tuple[int, ...] = DEFAULT_BATCH_SIZES
    width_mult: float = 0.25
    input_hw: int = 16
    seed: int = 0


@dataclass
class Datapoint:
    family: str
    level: float
    strategy: str
    bs: int
    width_mult: float
    input_hw: int
    seed: int
    gamma_mb: float
    phi_ms: float
    # Measured step energy in joules; 0.0 = no power rail sampled (the
    # calibration energy fit then targets the envelope watts-proxy).
    energy_j: float = 0.0
    features: list[float] = field(default_factory=list)

    @property
    def key(self) -> str:
        return (
            f"{self.family}|l={self.level:.2f}|s={self.strategy}|bs={self.bs}"
            f"|wm={self.width_mult}|hw={self.input_hw}|seed={self.seed}"
        )


def default_grid(family: str, *, full: bool = False) -> list[GridSpec]:
    """Train + test grids for one network family (fig3 protocol)."""
    if full:
        train_l, test_l, bss = PAPER_TRAIN_LEVELS, paper_test_levels(), PAPER_BATCH_SIZES
    else:
        train_l, test_l, bss = DEFAULT_TRAIN_LEVELS, DEFAULT_TEST_LEVELS, DEFAULT_BATCH_SIZES
    return [
        GridSpec(family, train_l, "random", bss),
        GridSpec(family, test_l, "random", bss),
        GridSpec(family, test_l, "l1", bss),
    ]


class DatasetCache:
    """JSON-file cache of profiled datapoints, write-atomic and append-only.

    Writes go to a tempfile in the target directory, are fsync'd, then
    ``os.replace``d over the cache — an interrupted collection run can never
    leave a truncated cache behind.  A corrupt cache file (e.g. written by a
    pre-atomic version, or a torn disk) is quarantined to ``<path>.corrupt``
    and collection restarts from empty instead of crashing the run.
    """

    def __init__(self, path: str):
        self.path = path
        self._data: dict[str, dict] = load_json_tolerant(path)

    def get(self, key: str) -> Datapoint | None:
        d = self._data.get(key)
        return Datapoint(**d) if d else None

    def put(self, dp: Datapoint) -> None:
        self._data[dp.key] = asdict(dp)

    def flush(self) -> None:
        atomic_write_json(self.path, self._data)

    def __len__(self) -> int:
        return len(self._data)


def _build_pruned(spec: GridSpec, level: float) -> "object":
    build = CNN_BUILDERS[spec.family]
    base = build(width_mult=spec.width_mult, input_hw=spec.input_hw)
    rng = np.random.default_rng(spec.seed + int(level * 100))
    scores = pr.l1_scores(base, spec.seed) if spec.strategy == "l1" else None
    widths = pr.prune_widths(base.widths, level, spec.strategy, rng, scores=scores)
    m = build(widths=widths, input_hw=spec.input_hw)
    m.name = f"{spec.family}-p{int(level * 100)}-{spec.strategy}"
    return m


def collect_grid(
    spec: GridSpec,
    cache: DatasetCache | None = None,
    *,
    repeats: int = 2,
    warmup: int = 1,
    verbose: bool = False,
) -> list[Datapoint]:
    """Profile every (level × batch size) cell of ``spec`` (cache-aware).

    One topology is built per level, then profiled across all batch sizes —
    mirroring Fig. 1's pruning process → data collection process split.
    """
    out: list[Datapoint] = []
    for level in spec.levels:
        model = _build_pruned(spec, level)
        net_spec = model.conv_specs()
        for bs in spec.batch_sizes:
            dp = Datapoint(
                family=spec.family, level=level, strategy=spec.strategy, bs=bs,
                width_mult=spec.width_mult, input_hw=spec.input_hw, seed=spec.seed,
                gamma_mb=0.0, phi_ms=0.0,
            )
            cached = cache.get(dp.key) if cache is not None else None
            if cached is not None:
                out.append(cached)
                continue
            res = profile_training(model, bs, repeats=repeats, warmup=warmup, seed=spec.seed)
            dp.gamma_mb = res.gamma_mb
            dp.phi_ms = res.phi_ms
            dp.features = [float(v) for v in network_features(net_spec, bs)]
            out.append(dp)
            if cache is not None:
                cache.put(dp)
                cache.flush()
            if verbose:
                print(
                    f"  {dp.key}: gamma={dp.gamma_mb:.1f}MB phi={dp.phi_ms:.1f}ms "
                    f"(compile {res.compile_s:.1f}s)",
                    flush=True,
                )
    return out


def features_targets(dps: list[Datapoint]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(X, gamma, phi) arrays from datapoints (features must be populated)."""
    X = np.array([dp.features for dp in dps], dtype=np.float64)
    g = np.array([dp.gamma_mb for dp in dps])
    p = np.array([dp.phi_ms for dp in dps])
    return X, g, p
