"""Analytical feature extraction — paper §5.2.1 / Appendix B, exact formulas.

For every convolution layer the paper models the memory consumption and
operation counts of the three cuDNN convolution algorithms (matrix
multiplication / im2col, FFT, Winograd) for each of the three training
computations:

    Eq.1 (fwd):    y = x * w
    Eq.2 (bwd_x):  dL/dx = dL/dy * rot180(w)
    Eq.3 (bwd_w):  dL/dw = x * dL/dy

plus algorithm-independent tensor allocations.  Features are computed
per-layer and summed across all layers of the network (paper §5.3), giving a
single 42-dimensional vector per (network topology, batch size) datapoint.

Notation (paper §5.2.1):
    n_l  : number of filters (output channels)
    m_l  : input channels
    k_l  : kernel spatial size (k x k)
    s_l  : stride,  p_l : padding,  g_l : groups
    ip_l : input spatial size (ip x ip)
    op_l : output spatial size, op = 1 + floor((ip + 2p - k) / s)
    bs   : training batch size

The Winograd features (App. B items 29-42) are "applied twice for (q x r) of
(4 x 3) and (3 x 2)".  To preserve the paper's stated 42-feature count, the
default mode sums the two (q, r) instantiations per feature; ``qr_mode=
"concat"`` exposes the 56-dim variant instead (14 extra winograd features).
Forests are insensitive to this monotone choice; both are tested.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

__all__ = [
    "ConvLayerSpec",
    "NetworkSpec",
    "FEATURE_NAMES",
    "layer_features",
    "network_features",
    "feature_matrix",
    "batch_network_features",
]

# Winograd (q, r) output-tile / filter-tap sizes most used by cuDNN (paper
# App. B.2.4, citing Jorda et al.).
WINOGRAD_QR = ((4, 3), (3, 2))


@dataclass(frozen=True)
class ConvLayerSpec:
    """Geometry of a single convolution layer (paper §5.2.1 notation)."""

    n: int          # filters / output channels (n_l)
    m: int          # input channels (m_l)
    k: int          # kernel size (k_l)
    stride: int = 1
    padding: int = 0
    groups: int = 1
    ip: int = 1     # input spatial size (ip_l)

    def __post_init__(self):
        if self.n <= 0 or self.m <= 0 or self.k <= 0:
            raise ValueError(f"degenerate conv layer: {self}")
        if self.m % self.groups != 0 or self.n % self.groups != 0:
            raise ValueError(f"channels not divisible by groups: {self}")

    @property
    def op(self) -> int:
        """Output spatial size: op = 1 + floor((ip + 2p - k) / s)."""
        o = 1 + (self.ip + 2 * self.padding - self.k) // self.stride
        if o <= 0:
            raise ValueError(f"non-positive OFM size for {self}")
        return o

    @property
    def m_per_group(self) -> float:
        return self.m / self.groups


@dataclass(frozen=True)
class NetworkSpec:
    """A network as the ordered list of its conv layers (the paper models
    only convolution layers; FC layers may be encoded as 1x1 convs on a 1x1
    feature map, which makes their allocation terms exact and their op terms
    the matmul op count)."""

    name: str
    layers: tuple[ConvLayerSpec, ...] = field(default_factory=tuple)

    def scaled(self, name: str, keep: "np.ndarray | list") -> "NetworkSpec":
        """Return a copy with per-layer filter counts replaced (used by the
        pruning process to derive topologies)."""
        keep = list(keep)
        if len(keep) != len(self.layers):
            raise ValueError("keep vector length mismatch")
        new_layers = []
        prev_out = None
        for layer, n_new in zip(self.layers, keep):
            new_layers.append(replace(layer, n=int(n_new)))
        return NetworkSpec(name=name, layers=tuple(new_layers))


# ---------------------------------------------------------------------------
# Per-layer feature terms.  Names follow Appendix B numbering.
# ---------------------------------------------------------------------------


def _tensor_allocations(l: ConvLayerSpec, bs: int) -> dict[str, float]:
    """App. B.2.1 items 1-5: algorithm-independent tensor allocations."""
    mem_w = l.n * l.m_per_group * l.k**2                       # (1)
    mem_w_grad = bs * l.n * l.m_per_group * l.k**2             # (2)
    mem_ifm_grad = bs * l.m * l.ip**2                          # (3)
    mem_ofm_grad = bs * l.n * l.op**2                          # (4)
    return {
        "mem_w": mem_w,
        "mem_w_grad": mem_w_grad,
        "mem_ifm_grad": mem_ifm_grad,
        "mem_ofm_grad": mem_ofm_grad,
        "mem_alloc_total": mem_w + mem_w_grad + mem_ifm_grad + mem_ofm_grad,  # (5)
    }


def _matmul_features(l: ConvLayerSpec, bs: int) -> dict[str, float]:
    """App. B.2.2 items 6-15: im2col / matrix-multiplication algorithm."""
    op2, ip2, k2 = l.op**2, l.ip**2, l.k**2
    i2c_fwd_total = bs * op2 * k2 * l.m                        # (6)
    i2c_bwdw_total = bs * op2 * k2 * l.m_per_group             # (7)
    i2c_fwd_index = bs * op2                                   # (8) fwd == bwd_w
    i2c_bwdx_total = bs * ip2 * k2 * l.m                       # (9)
    i2c_bwdx_index = bs * ip2                                  # (10)
    ops_fwd = bs * l.n * op2 * k2 * l.m_per_group              # (13) fwd == bwd_w
    ops_bwdx = bs * l.m * ip2 * k2 * l.n                       # (14)
    return {
        "mm_i2c_fwd_total": i2c_fwd_total,
        "mm_i2c_bwdw_total": i2c_bwdw_total,
        "mm_i2c_fwd_index": i2c_fwd_index,
        "mm_i2c_bwdx_total": i2c_bwdx_total,
        "mm_i2c_bwdx_index": i2c_bwdx_index,
        "mm_i2c_total_sum": i2c_fwd_total + i2c_bwdx_total + i2c_bwdw_total,   # (11)
        "mm_i2c_index_sum": 2 * i2c_fwd_index + i2c_bwdx_index,               # (12)
        "mm_ops_fwd": ops_fwd,
        "mm_ops_bwdx": ops_bwdx,
        "mm_ops_sum": 2 * ops_fwd + ops_bwdx,                                  # (15)
    }


def _log(v: float) -> float:
    # Natural log; paper writes log() unqualified.  log(1) = 0 handles ip=1.
    return math.log(v) if v > 1 else 0.0


def _fft_features(l: ConvLayerSpec, bs: int) -> dict[str, float]:
    """App. B.2.3 items 16-28: FFT algorithm (after Mathieu et al.)."""
    n, m, g, ip, op = l.n, l.m, l.groups, l.ip, l.op
    mpg = l.m_per_group
    w_fwd = n * mpg * ip * (1 + ip)                            # (16)
    ifm_fwd = bs * m * ip * (1 + ip)                           # (17) fwd == bwd_w ifm
    ofm_bwdw = bs * n * ip * (1 + ip)                          # (18)
    w_bwdx = n * mpg * op * (1 + op)                           # (19)
    ofm_bwdx = bs * n * op * (1 + op)                          # (20)
    s21 = w_fwd + ifm_fwd                                      # (21)
    s22 = ofm_bwdx + w_bwdx                                    # (22)  (bwd_x terms)
    s23 = ofm_bwdw + ifm_fwd                                   # (23)
    common = bs * (m + n) + n * mpg
    ops_fwd = ip**2 * _log(ip) * common + bs * n * m * ip**2   # (25)
    ops_bwdx = op**2 * _log(op) * common + bs * n * m * op**2  # (26)
    ops_bwdw = ip * _log(ip**2) * common + bs * n * m * ip**2  # (27)
    return {
        "fft_w_fwd": w_fwd,
        "fft_ifm_fwd": ifm_fwd,
        "fft_ofm_bwdw": ofm_bwdw,
        "fft_w_bwdx": w_bwdx,
        "fft_ofm_bwdx": ofm_bwdx,
        "fft_mem_fwd_sum": s21,
        "fft_mem_bwdx_sum": s22,
        "fft_mem_bwdw_sum": s23,
        "fft_mem_total": s21 + s22 + s23,                      # (24)
        "fft_ops_fwd": ops_fwd,
        "fft_ops_bwdx": ops_bwdx,
        "fft_ops_bwdw": ops_bwdw,
        "fft_ops_sum": ops_fwd + ops_bwdx + ops_bwdw,          # (28)
    }


def _winograd_features_qr(l: ConvLayerSpec, bs: int, q: int, r: int) -> dict[str, float]:
    """App. B.2.4 items 29-42 for a single (q, r) instantiation."""
    n, m, g, ip, op, k = l.n, l.m, l.groups, l.ip, l.op, l.k
    mpg = l.m_per_group
    tiles_ip = math.ceil(ip / q) ** 2
    tiles_op = math.ceil(op / q) ** 2
    tiles_k = math.ceil(k / r) ** 2
    tiles_op_r = math.ceil(op / r) ** 2
    had = (q + r - 1) ** 2                       # Hadamard product size
    mem_fwd = bs * n * tiles_ip * 3 * had                      # (29)
    mem_bwdx = bs * m * tiles_op * 3 * had                     # (30)
    mem_bwdw = bs * n * mpg * tiles_ip * 3 * had               # (31)
    ops_fwd = bs * n * mpg * tiles_ip * tiles_k * had          # (36)
    ops_bwdx = bs * m * n * tiles_op * tiles_k * had           # (37)
    ops_bwdw = bs * n * mpg * mpg * tiles_ip * tiles_op_r * had  # (38)
    s32 = mem_fwd + mem_bwdx                                   # (32)
    s33 = mem_fwd + mem_bwdw                                   # (33)
    s34 = mem_bwdw + mem_bwdx                                  # (34)
    s39 = ops_fwd + ops_bwdx                                   # (39)
    s40 = ops_fwd + ops_bwdw                                   # (40)
    s41 = ops_bwdx + ops_bwdw                                  # (41)
    return {
        "wino_mem_fwd": mem_fwd,
        "wino_mem_bwdx": mem_bwdx,
        "wino_mem_bwdw": mem_bwdw,
        "wino_mem_fwd_bwdx": s32,
        "wino_mem_fwd_bwdw": s33,
        "wino_mem_bwdw_bwdx": s34,
        "wino_mem_total": s32 + s33 + s34,                     # (35)
        "wino_ops_fwd": ops_fwd,
        "wino_ops_bwdx": ops_bwdx,
        "wino_ops_bwdw": ops_bwdw,
        "wino_ops_fwd_bwdx": s39,
        "wino_ops_fwd_bwdw": s40,
        "wino_ops_bwdx_bwdw": s41,
        "wino_ops_total": s39 + s40 + s41,                     # (42)
    }


def _winograd_features(l: ConvLayerSpec, bs: int, qr_mode: str) -> dict[str, float]:
    per_qr = [_winograd_features_qr(l, bs, q, r) for q, r in WINOGRAD_QR]
    if qr_mode == "sum":
        return {k: sum(d[k] for d in per_qr) for k in per_qr[0]}
    if qr_mode == "concat":
        out: dict[str, float] = {}
        for (q, r), d in zip(WINOGRAD_QR, per_qr):
            out.update({f"{k}_q{q}r{r}": v for k, v in d.items()})
        return out
    raise ValueError(f"unknown qr_mode {qr_mode!r}")


def layer_features(l: ConvLayerSpec, bs: int, qr_mode: str = "sum") -> dict[str, float]:
    """All Appendix-B features for one layer at batch size ``bs``."""
    out: dict[str, float] = {}
    out.update(_tensor_allocations(l, bs))
    out.update(_matmul_features(l, bs))
    out.update(_fft_features(l, bs))
    out.update(_winograd_features(l, bs, qr_mode))
    return out


def _names(qr_mode: str) -> list[str]:
    probe = ConvLayerSpec(n=1, m=1, k=1, ip=1)
    return list(layer_features(probe, 1, qr_mode).keys())


FEATURE_NAMES: list[str] = _names("sum")           # 42 features (paper count)
FEATURE_NAMES_CONCAT: list[str] = _names("concat")  # 56-dim variant


def network_features(net: NetworkSpec, bs: int, qr_mode: str = "sum") -> np.ndarray:
    """Sum the per-layer features across all layers (paper §5.3)."""
    names = FEATURE_NAMES if qr_mode == "sum" else FEATURE_NAMES_CONCAT
    acc = np.zeros(len(names), dtype=np.float64)
    for l in net.layers:
        f = layer_features(l, bs, qr_mode)
        acc += np.array([f[k] for k in names], dtype=np.float64)
    return acc


# ---------------------------------------------------------------------------
# Vectorized batch path.  The scalar functions above are the reference
# implementation (hand-checked against Appendix B in tests); the batch path
# computes the same formulas over flat numpy arrays covering every layer of
# every datapoint at once, then segment-sums per datapoint.  This is what
# makes population-scale prediction (engine.ForestBackend, core/search.py)
# fast: one array program instead of N_python round-trips.
# ---------------------------------------------------------------------------


def _vlog(v: np.ndarray) -> np.ndarray:
    # vectorized twin of _log: natural log, 0 for v <= 1
    return np.where(v > 1, np.log(np.maximum(v, 1.0)), 0.0)


def _batch_layer_features(cols: dict[str, np.ndarray], qr_mode: str) -> dict[str, np.ndarray]:
    """All Appendix-B features for a flat array of layers (one row each)."""
    n, m, g, ip, op, k, bs = (cols[c] for c in ("n", "m", "g", "ip", "op", "k", "bs"))
    mpg = m / g
    k2, ip2, op2 = k * k, ip * ip, op * op
    f: dict[str, np.ndarray] = {}

    # App. B.2.1 tensor allocations
    f["mem_w"] = n * mpg * k2
    f["mem_w_grad"] = bs * n * mpg * k2
    f["mem_ifm_grad"] = bs * m * ip2
    f["mem_ofm_grad"] = bs * n * op2
    f["mem_alloc_total"] = f["mem_w"] + f["mem_w_grad"] + f["mem_ifm_grad"] + f["mem_ofm_grad"]

    # App. B.2.2 im2col / matmul
    i2c_fwd_total = bs * op2 * k2 * m
    i2c_bwdw_total = bs * op2 * k2 * mpg
    i2c_fwd_index = bs * op2
    i2c_bwdx_total = bs * ip2 * k2 * m
    i2c_bwdx_index = bs * ip2
    ops_fwd = bs * n * op2 * k2 * mpg
    ops_bwdx = bs * m * ip2 * k2 * n
    f["mm_i2c_fwd_total"] = i2c_fwd_total
    f["mm_i2c_bwdw_total"] = i2c_bwdw_total
    f["mm_i2c_fwd_index"] = i2c_fwd_index
    f["mm_i2c_bwdx_total"] = i2c_bwdx_total
    f["mm_i2c_bwdx_index"] = i2c_bwdx_index
    f["mm_i2c_total_sum"] = i2c_fwd_total + i2c_bwdx_total + i2c_bwdw_total
    f["mm_i2c_index_sum"] = 2 * i2c_fwd_index + i2c_bwdx_index
    f["mm_ops_fwd"] = ops_fwd
    f["mm_ops_bwdx"] = ops_bwdx
    f["mm_ops_sum"] = 2 * ops_fwd + ops_bwdx

    # App. B.2.3 FFT
    w_fwd = n * mpg * ip * (1 + ip)
    ifm_fwd = bs * m * ip * (1 + ip)
    ofm_bwdw = bs * n * ip * (1 + ip)
    w_bwdx = n * mpg * op * (1 + op)
    ofm_bwdx = bs * n * op * (1 + op)
    s21 = w_fwd + ifm_fwd
    s22 = ofm_bwdx + w_bwdx
    s23 = ofm_bwdw + ifm_fwd
    common = bs * (m + n) + n * mpg
    fft_ops_fwd = ip2 * _vlog(ip) * common + bs * n * m * ip2
    fft_ops_bwdx = op2 * _vlog(op) * common + bs * n * m * op2
    fft_ops_bwdw = ip * _vlog(ip2) * common + bs * n * m * ip2
    f["fft_w_fwd"] = w_fwd
    f["fft_ifm_fwd"] = ifm_fwd
    f["fft_ofm_bwdw"] = ofm_bwdw
    f["fft_w_bwdx"] = w_bwdx
    f["fft_ofm_bwdx"] = ofm_bwdx
    f["fft_mem_fwd_sum"] = s21
    f["fft_mem_bwdx_sum"] = s22
    f["fft_mem_bwdw_sum"] = s23
    f["fft_mem_total"] = s21 + s22 + s23
    f["fft_ops_fwd"] = fft_ops_fwd
    f["fft_ops_bwdx"] = fft_ops_bwdx
    f["fft_ops_bwdw"] = fft_ops_bwdw
    f["fft_ops_sum"] = fft_ops_fwd + fft_ops_bwdx + fft_ops_bwdw

    # App. B.2.4 Winograd, per (q, r) instantiation
    per_qr: list[dict[str, np.ndarray]] = []
    for q, r in WINOGRAD_QR:
        tiles_ip = np.ceil(ip / q) ** 2
        tiles_op = np.ceil(op / q) ** 2
        tiles_k = np.ceil(k / r) ** 2
        tiles_op_r = np.ceil(op / r) ** 2
        had = (q + r - 1) ** 2
        mem_fwd = bs * n * tiles_ip * 3 * had
        mem_bwdx = bs * m * tiles_op * 3 * had
        mem_bwdw = bs * n * mpg * tiles_ip * 3 * had
        wops_fwd = bs * n * mpg * tiles_ip * tiles_k * had
        wops_bwdx = bs * m * n * tiles_op * tiles_k * had
        wops_bwdw = bs * n * mpg * mpg * tiles_ip * tiles_op_r * had
        s32 = mem_fwd + mem_bwdx
        s33 = mem_fwd + mem_bwdw
        s34 = mem_bwdw + mem_bwdx
        s39 = wops_fwd + wops_bwdx
        s40 = wops_fwd + wops_bwdw
        s41 = wops_bwdx + wops_bwdw
        per_qr.append({
            "wino_mem_fwd": mem_fwd,
            "wino_mem_bwdx": mem_bwdx,
            "wino_mem_bwdw": mem_bwdw,
            "wino_mem_fwd_bwdx": s32,
            "wino_mem_fwd_bwdw": s33,
            "wino_mem_bwdw_bwdx": s34,
            "wino_mem_total": s32 + s33 + s34,
            "wino_ops_fwd": wops_fwd,
            "wino_ops_bwdx": wops_bwdx,
            "wino_ops_bwdw": wops_bwdw,
            "wino_ops_fwd_bwdx": s39,
            "wino_ops_fwd_bwdw": s40,
            "wino_ops_bwdx_bwdw": s41,
            "wino_ops_total": s39 + s40 + s41,
        })
    if qr_mode == "sum":
        for key in per_qr[0]:
            f[key] = sum(d[key] for d in per_qr)
    elif qr_mode == "concat":
        for (q, r), d in zip(WINOGRAD_QR, per_qr):
            for key, v in d.items():
                f[f"{key}_q{q}r{r}"] = v
    else:
        raise ValueError(f"unknown qr_mode {qr_mode!r}")
    return f


def batch_network_features(
    nets_and_bs: list[tuple[NetworkSpec, int]], qr_mode: str = "sum"
) -> np.ndarray:
    """Feature matrix (N, F) for N (network, batch size) datapoints in one
    vectorized pass: flatten every layer of every network into flat arrays,
    evaluate all Appendix-B formulas once, segment-sum per network."""
    names = FEATURE_NAMES if qr_mode == "sum" else FEATURE_NAMES_CONCAT
    out = np.zeros((len(nets_and_bs), len(names)), dtype=np.float64)
    if not nets_and_bs:
        return out
    seg, rows = [], {c: [] for c in ("n", "m", "g", "ip", "op", "k", "bs")}
    for i, (net, bs) in enumerate(nets_and_bs):
        for l in net.layers:
            seg.append(i)
            rows["n"].append(l.n)
            rows["m"].append(l.m)
            rows["g"].append(l.groups)
            rows["ip"].append(l.ip)
            rows["op"].append(l.op)
            rows["k"].append(l.k)
            rows["bs"].append(bs)
    cols = {c: np.asarray(v, dtype=np.float64) for c, v in rows.items()}
    f = _batch_layer_features(cols, qr_mode)
    per_layer = np.stack([f[k] for k in names], axis=1)      # (L_total, F)
    # explicit int dtype: an all-empty batch gives an empty seg list, which
    # np.asarray would default to float64 — an invalid index array
    np.add.at(out, np.asarray(seg, dtype=np.int64), per_layer)
    return out


def feature_matrix(nets_and_bs: list[tuple[NetworkSpec, int]], qr_mode: str = "sum") -> np.ndarray:
    """Stack feature vectors for a list of (network, batch size) datapoints
    (vectorized — see batch_network_features)."""
    return batch_network_features(nets_and_bs, qr_mode)
