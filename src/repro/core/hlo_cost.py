"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, ignoring the
trip count — for scan-over-layers models that under-reports FLOPs/bytes by a
factor of n_layers (verified experimentally; see EXPERIMENTS.md §Dry-run).
This module parses the optimized HLO, builds the computation call graph
(entry → while bodies × trip count → fusions/calls), and accumulates:

  * flops             — 2·M·N·K per dot (batch dims included), anywhere in
                        the graph, times the context multiplier
  * hbm bytes         — Σ over *scheduled* instructions (outside fusion
                        bodies) of operand+output buffer sizes × multiplier;
                        fusion internals are on-chip and excluded, matching
                        XLA's own bytes-accessed convention
  * collective bytes  — ring-model bytes per all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        times the multiplier (collectives inside scanned
                        layers count n_layers times)

Trip counts come from the loop-condition pattern emitted by ``lax.scan``
(compare(get-tuple-element(param), constant(N)) direction=LT).

Since the per-op cost ledger refactor, the parse's primary output is a
:class:`repro.costmodel.CostLedger` — one :class:`~repro.costmodel.OpCost`
record per scheduled instruction, classified through the shared op-class
taxonomy — and the three :class:`HloCost` scalars are *derived* from it by
plain left-to-right summation.  There is exactly one accumulation path, so
``sum(ledger) == aggregates`` holds bit-identically by construction (the
parity contract ``tests/test_costmodel.py`` asserts on the golden
fixtures).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.costmodel import CostLedger, OpCost, classify_op

__all__ = ["HloCost", "parse_hlo_cost"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OPCODE_RE = re.compile(r"^\s*(?:\(|)([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class _Instr:
    name: str
    opcode: str
    dtypes_dims: list[tuple[str, str]]  # output component shapes
    operands: list[str]
    raw: str

    @property
    def out_bytes(self) -> float:
        return sum(_shape_bytes(dt, dims) for dt, dims in self.dtypes_dims)


@dataclass
class _Comp:
    name: str
    instrs: dict[str, _Instr] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


@dataclass
class HloCost:
    """Aggregate view over a parsed module's :class:`CostLedger`.

    ``flops``/``hbm_bytes``/``collective_bytes`` are left-to-right sums of
    ``ledger`` — byte-identical to the pre-ledger accumulation on the
    golden fixtures (the parity contract)."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    trip_counts: dict = field(default_factory=dict)
    ledger: CostLedger = field(default_factory=CostLedger)

    def by_class(self) -> dict:
        """Per-op-class sums (``repro.costmodel`` taxonomy)."""
        return self.ledger.class_sums()


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _shape_numel(dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _parse_module(text: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    entry: str | None = None
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s and ("=" not in s.split("(")[0]):
                m = _COMP_HDR_RE.match(s)
                if m:
                    cur = _Comp(m.group(1))
                    if s.startswith("ENTRY"):
                        entry = cur.name
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # output shapes: everything before the opcode token
        op_m = re.search(r"\s([a-z][a-z0-9\-]*)\(", rhs)
        opcode = op_m.group(1) if op_m else ""
        shape_part = rhs[: op_m.start()] if op_m else rhs
        shapes = _SHAPE_RE.findall(shape_part)
        operand_part = rhs[op_m.start():] if op_m else ""
        operands = _OPERAND_RE.findall(operand_part)
        cur.instrs[name] = _Instr(name, opcode, shapes, operands, rhs)
        cur.order.append(name)
    return comps, entry


def _dot_flops(instr: _Instr, comp: _Comp, comps: dict[str, _Comp]) -> float:
    """2 × output numel × K (product of contracting dims of the lhs)."""
    out_numel = sum(_shape_numel(d) for _, d in instr.dtypes_dims)
    # lhs shape: prefer inline typed operand, else symbol lookup
    inline = _SHAPE_RE.findall(instr.raw[instr.raw.index("("):])
    lhs_dims: str | None = inline[0][1] if inline else None
    if lhs_dims is None and instr.operands:
        src = comp.instrs.get(instr.operands[0])
        if src and src.dtypes_dims:
            lhs_dims = src.dtypes_dims[0][1]
    k = 1.0
    cm = _CONTRACT_RE.search(instr.raw)
    if lhs_dims is not None and cm and cm.group(1):
        dims = [int(x) for x in lhs_dims.split(",") if x]
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_numel * k


def _conv_flops(instr: _Instr) -> float:
    # flops ≈ 2 × out numel × (kernel numel × Cin / (groups·Cout-slice))
    # parse window + operand kernel shape from inline types
    inline = _SHAPE_RE.findall(instr.raw[instr.raw.index("("):])
    out_numel = sum(_shape_numel(d) for _, d in instr.dtypes_dims)
    if len(inline) >= 2:
        kdims = [int(x) for x in inline[1][1].split(",") if x]
        if kdims:
            # HWIO kernel: all dims except the last (O) multiply
            k = 1
            for d in kdims[:-1]:
                k *= d
            return 2.0 * out_numel * k
    return 2.0 * out_numel


def _group_size(raw: str) -> int:
    m = _GROUPS_IOTA_RE.search(raw)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(raw)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _collective_bytes(instr: _Instr) -> float:
    g = _group_size(instr.raw)
    frac = (g - 1) / g
    out = instr.out_bytes
    kind = instr.opcode.replace("-start", "")
    if kind == "all-gather":
        return out * frac
    if kind == "all-reduce":
        return 2.0 * out * frac
    if kind == "reduce-scatter":
        return out * g * frac
    if kind == "all-to-all":
        return out * frac
    return out  # collective-permute


def _trip_count(cond: _Comp) -> int:
    """Loop bound from a lax.scan condition: the comparison constant.  The
    compare itself may be wrapped in a fusion, so take the largest integer
    constant defined in the condition computation (counter inits are 0/1)."""
    best = 1
    for name in cond.order:
        ins = cond.instrs[name]
        if ins.opcode == "constant":
            m = _TRIP_RE.search(ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def parse_hlo_cost(text: str) -> HloCost:
    comps, entry = _parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost

    _PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")

    def fusion_bytes(instr: _Instr, comp: _Comp) -> float:
        """Slice-aware traffic for a fusion: a fused dynamic-slice reads only
        the slice; a fused dynamic-update-slice writes only the update region
        (the rest of the buffer is aliased in place).  Without this, scanned
        per-layer slicing of stacked (L, …) params/grads over-counts by L×."""
        cm = _CALLS_RE.search(instr.raw)
        called = comps.get(cm.group(1)) if cm else None
        if called is None:
            total = instr.out_bytes
            for op in instr.operands:
                src = comp.instrs.get(op)
                if src is not None:
                    total += src.out_bytes
            return total
        # map operand index -> internal parameter name
        param_names: dict[int, str] = {}
        for n in called.order:
            ins2 = called.instrs[n]
            if ins2.opcode == "parameter":
                m = _PARAM_IDX_RE.search(ins2.raw)
                if m:
                    param_names[int(m.group(1))] = n
        total = 0.0
        dus_root = False
        for idx, op in enumerate(instr.operands):
            src = comp.instrs.get(op)
            if src is None:
                continue
            pname = param_names.get(idx)
            eff = src.out_bytes
            if pname is not None:
                consumers = [
                    called.instrs[n] for n in called.order
                    if pname in called.instrs[n].operands
                ]
                if consumers:
                    if all(c.opcode == "dynamic-slice" for c in consumers):
                        eff = sum(c.out_bytes for c in consumers)
                    elif any(
                        c.opcode == "dynamic-update-slice"
                        and c.operands and c.operands[0] == pname
                        for c in consumers
                    ):
                        # in-place target: traffic = update region only
                        upd = 0.0
                        for c in consumers:
                            if c.opcode == "dynamic-update-slice" and len(c.operands) > 1:
                                u = called.instrs.get(c.operands[1])
                                upd += u.out_bytes if u is not None else 0.0
                        eff = upd
                        dus_root = True
            total += eff
        # output: if the root is a DUS the full buffer aliases in place
        if dus_root:
            for n in called.order:
                c = called.instrs[n]
                if c.opcode == "dynamic-update-slice" and len(c.operands) > 1:
                    u = called.instrs.get(c.operands[1])
                    total += u.out_bytes if u is not None else 0.0
        else:
            total += instr.out_bytes
        return total

    def op_bytes(instr: _Instr, comp: _Comp) -> float:
        oc = instr.opcode
        if oc == "fusion":
            return fusion_bytes(instr, comp)
        if oc == "dynamic-slice" or oc == "gather":
            return 2.0 * instr.out_bytes
        if oc == "dynamic-update-slice":
            upd = comp.instrs.get(instr.operands[1]) if len(instr.operands) > 1 else None
            return 2.0 * (upd.out_bytes if upd is not None else instr.out_bytes)
        total = instr.out_bytes
        for op in instr.operands:
            src = comp.instrs.get(op)
            if src is not None:
                total += src.out_bytes
        return total

    _SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id"}

    def _dtype(ins: _Instr) -> str:
        return ins.dtypes_dims[0][0] if ins.dtypes_dims else ""

    def record(ins: _Instr, comp_name: str, mult: float, *,
               flops: float = 0.0, hbm: float = 0.0, coll: float = 0.0,
               dot_flops: float = 0.0, conv_flops: float = 0.0) -> None:
        cost.ledger.append(OpCost(
            op=ins.opcode,
            op_class=classify_op(ins.opcode, dot_flops=dot_flops,
                                 conv_flops=conv_flops),
            dtype=_dtype(ins),
            flops=flops, hbm_bytes=hbm, collective_bytes=coll,
            trip_multiplier=mult, origin=comp_name,
        ))

    def walk(comp_name: str, mult: float, seen: tuple = ()):  # noqa: C901
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        for name in comp.order:
            ins = comp.instrs[name]
            oc = ins.opcode
            if oc == "while":
                wm = _WHILE_RE.search(ins.raw)
                trip = 1
                body = None
                if wm:
                    cond_name, body = wm.group(1), wm.group(2)
                    if cond_name in comps:
                        trip = _trip_count(comps[cond_name])
                cost.trip_counts[name] = trip
                if body:
                    walk(body, mult * trip, seen + (comp_name,))
                continue
            if oc in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter",
                      "conditional"):
                # count dots inside the called computation(s) for flops —
                # the wrapper record carries them, classified as the work
                # it feeds (a fused matmul's bytes are matmul-class bytes)
                dot_f = conv_f = 0.0
                cm = _CALLS_RE.search(ins.raw)
                if cm and cm.group(1) in comps:
                    dot_f, conv_f = _flops_only(
                        comps[cm.group(1)], mult, seen + (comp_name,))
                hbm = op_bytes(ins, comp) * mult if oc != "conditional" else 0.0
                record(ins, comp_name, mult, flops=dot_f + conv_f, hbm=hbm,
                       dot_flops=dot_f, conv_flops=conv_f)
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                b = _collective_bytes(ins) * mult
                cost.bytes_by_kind[base] = cost.bytes_by_kind.get(base, 0.0) + b
                cost.count_by_kind[base] = cost.count_by_kind.get(base, 0) + 1
                record(ins, comp_name, mult, coll=b,
                       hbm=op_bytes(ins, comp) * mult)
                continue
            if oc == "dot":
                record(ins, comp_name, mult,
                       flops=_dot_flops(ins, comp, comps) * mult,
                       hbm=op_bytes(ins, comp) * mult)
                continue
            if oc == "convolution":
                record(ins, comp_name, mult, flops=_conv_flops(ins) * mult,
                       hbm=op_bytes(ins, comp) * mult)
                continue
            if oc in _SKIP_BYTES or not oc:
                continue
            record(ins, comp_name, mult, hbm=op_bytes(ins, comp) * mult)

    def _flops_only(comp: _Comp, mult: float, seen: tuple
                    ) -> tuple[float, float]:
        """(dot_flops, conv_flops) of every contraction reachable from
        ``comp``, each already × ``mult`` — accumulated in schedule order."""
        if comp.name in seen:
            return 0.0, 0.0
        dot_f = conv_f = 0.0
        for name in comp.order:
            ins = comp.instrs[name]
            if ins.opcode == "dot":
                dot_f += _dot_flops(ins, comp, comps) * mult
            elif ins.opcode == "convolution":
                conv_f += _conv_flops(ins) * mult
            elif ins.opcode in ("fusion", "call"):
                cm = _CALLS_RE.search(ins.raw)
                if cm and cm.group(1) in comps:
                    d, c = _flops_only(comps[cm.group(1)], mult,
                                       seen + (comp.name,))
                    dot_f += d
                    conv_f += c
        return dot_f, conv_f

    walk(entry, 1.0)
    # The scalars ARE the ledger sums — one accumulation path, so the
    # parity contract cannot drift.
    cost.flops = cost.ledger.flops
    cost.hbm_bytes = cost.ledger.hbm_bytes
    cost.collective_bytes = cost.ledger.collective_bytes
    return cost
