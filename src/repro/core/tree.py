"""CART regression tree (paper §5.2: decision trees partition the feature
space into low-entropy regions; regression predicts the region mean).

Pure-numpy implementation with exact variance-reduction splits computed via
prefix sums over sorted feature columns — O(d · n log n) per node.  Supports
per-node feature subsampling (for random forests) and min-samples / max-depth
regularisation.  Trees are stored as flat arrays so prediction is a vectorised
loop over depth, not Python recursion per sample.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["RegressionTree"]

_LEAF = -1


@dataclass
class _Node:
    feature: int = _LEAF
    threshold: float = 0.0
    left: int = _LEAF
    right: int = _LEAF
    value: float = 0.0
    n_samples: int = 0
    impurity_decrease: float = 0.0


class RegressionTree:
    """Greedy CART regressor.

    Parameters
    ----------
    max_depth : depth cap (None = unbounded).
    min_samples_leaf : minimum samples in each child of a split.
    min_samples_split : minimum samples required to consider splitting.
    max_features : None (all), int, float fraction, "sqrt", or "third" —
        number of candidate features sampled per node.
    rng : numpy Generator for feature subsampling / tie-breaks.
    """

    def __init__(
        self,
        max_depth: int | None = None,
        min_samples_leaf: int = 1,
        min_samples_split: int = 2,
        max_features: int | float | str | None = None,
        rng: np.random.Generator | None = None,
    ):
        self.max_depth = max_depth
        self.min_samples_leaf = int(min_samples_leaf)
        self.min_samples_split = int(min_samples_split)
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self._nodes: list[_Node] = []
        self.n_features_: int | None = None
        self.feature_importances_: np.ndarray | None = None

    # -- fitting ----------------------------------------------------------

    def _n_candidate_features(self, d: int) -> int:
        mf = self.max_features
        if mf is None:
            return d
        if isinstance(mf, str):
            if mf == "sqrt":
                return max(1, int(np.sqrt(d)))
            if mf == "third":
                return max(1, d // 3)
            raise ValueError(f"unknown max_features {mf!r}")
        if isinstance(mf, float):
            return max(1, int(mf * d))
        return max(1, min(int(mf), d))

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if X.ndim != 2 or y.ndim != 1 or len(X) != len(y):
            raise ValueError(f"bad shapes X{X.shape} y{y.shape}")
        if len(y) == 0:
            raise ValueError("empty training set")
        self.n_features_ = X.shape[1]
        self._nodes = []
        importances = np.zeros(self.n_features_)
        # Iterative construction with an explicit stack (no recursion limit).
        root_idx = self._new_node()
        stack = [(root_idx, np.arange(len(y)), 0)]
        while stack:
            node_idx, idx, depth = stack.pop()
            node = self._nodes[node_idx]
            ysub = y[idx]
            node.value = float(ysub.mean())
            node.n_samples = len(idx)
            if (
                len(idx) < self.min_samples_split
                or (self.max_depth is not None and depth >= self.max_depth)
                or np.all(ysub == ysub[0])
            ):
                continue
            split = self._best_split(X, y, idx)
            if split is None:
                continue
            feat, thr, gain = split
            mask = X[idx, feat] <= thr
            left_idx, right_idx = idx[mask], idx[~mask]
            if len(left_idx) < self.min_samples_leaf or len(right_idx) < self.min_samples_leaf:
                continue
            node.feature = feat
            node.threshold = thr
            node.impurity_decrease = gain
            importances[feat] += gain * len(idx)
            node.left = self._new_node()
            node.right = self._new_node()
            stack.append((node.left, left_idx, depth + 1))
            stack.append((node.right, right_idx, depth + 1))
        total = importances.sum()
        self.feature_importances_ = importances / total if total > 0 else importances
        self._pack()
        return self

    def _new_node(self) -> int:
        self._nodes.append(_Node())
        return len(self._nodes) - 1

    def _best_split(
        self, X: np.ndarray, y: np.ndarray, idx: np.ndarray
    ) -> tuple[int, float, float] | None:
        """Exact best (feature, threshold) by weighted-variance reduction."""
        n = len(idx)
        ysub = y[idx]
        parent_sse = float(((ysub - ysub.mean()) ** 2).sum())
        d = X.shape[1]
        n_cand = self._n_candidate_features(d)
        feats = (
            self.rng.choice(d, size=n_cand, replace=False) if n_cand < d else np.arange(d)
        )
        best: tuple[int, float, float] | None = None
        best_gain = 1e-12  # require strictly positive gain
        msl = self.min_samples_leaf
        for f in feats:
            col = X[idx, f]
            order = np.argsort(col, kind="stable")
            cs, ys = col[order], ysub[order]
            # candidate split positions: between distinct consecutive values
            diff = cs[1:] != cs[:-1]
            if not diff.any():
                continue
            csum = np.cumsum(ys)
            csum2 = np.cumsum(ys * ys)
            total, total2 = csum[-1], csum2[-1]
            k = np.arange(1, n)  # left sizes
            valid = diff & (k >= msl) & ((n - k) >= msl)
            if not valid.any():
                continue
            lsum, lsum2 = csum[:-1], csum2[:-1]
            rsum, rsum2 = total - lsum, total2 - lsum2
            sse = (lsum2 - lsum**2 / k) + (rsum2 - rsum**2 / (n - k))
            sse = np.where(valid, sse, np.inf)
            j = int(np.argmin(sse))
            gain = parent_sse - float(sse[j])
            if gain > best_gain:
                best_gain = gain
                thr = 0.5 * (cs[j] + cs[j + 1])
                best = (int(f), float(thr), gain)
        return best

    # -- prediction --------------------------------------------------------

    def _pack(self) -> None:
        """Flatten node list to arrays for vectorised prediction."""
        n = len(self._nodes)
        self._feat = np.array([nd.feature for nd in self._nodes], dtype=np.int64)
        self._thr = np.array([nd.threshold for nd in self._nodes], dtype=np.float64)
        self._left = np.array([nd.left for nd in self._nodes], dtype=np.int64)
        self._right = np.array([nd.right for nd in self._nodes], dtype=np.int64)
        self._val = np.array([nd.value for nd in self._nodes], dtype=np.float64)

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        if self.n_features_ is None:
            raise RuntimeError("tree not fitted")
        pos = np.zeros(len(X), dtype=np.int64)
        active = self._feat[pos] != _LEAF
        while active.any():
            p = pos[active]
            f = self._feat[p]
            go_left = X[active, f] <= self._thr[p]
            pos[active] = np.where(go_left, self._left[p], self._right[p])
            active = self._feat[pos] != _LEAF
        return self._val[pos]

    # -- introspection ------------------------------------------------------

    @property
    def node_count(self) -> int:
        return len(self._nodes)

    @property
    def depth(self) -> int:
        depths = {0: 0}
        best = 0
        for i, nd in enumerate(self._nodes):
            d = depths.get(i, 0)
            best = max(best, d)
            if nd.feature != _LEAF:
                depths[nd.left] = d + 1
                depths[nd.right] = d + 1
        return best
