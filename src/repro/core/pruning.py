"""Structured filter pruning — the paper's topology generator (§5.1, §6.2).

The profiling process derives training datapoints by structurally pruning a
base network: removing entire convolution filters.  Strategies:

  * ``random``  — paper §6.2 "randomly pruning filters with equal probability
    across all layers": a global pool of all filters, each equally likely to
    be pruned ⇒ per-group counts follow a multivariate hypergeometric.
  * ``l1``      — paper Fig.3 test strategy: globally prune the filters with
    the smallest L1 norm first (scores from an initialised model).
  * ``uniform`` — keep round(n·(1−level)) per group (paper §6.2's "uniform"
    variant among the 100 strategies).
  * ``early`` / ``middle`` / ``late`` — position-biased profiles (paper §6.2:
    "increased pruning at early, late or middle layers").

All strategies return a new ``widths`` dict; the CNN builders rebuild the
pruned topology from it.  A floor of ``min_ch`` filters per group keeps every
topology valid.
"""

from __future__ import annotations

import numpy as np

from repro.models.cnn import CNN_BUILDERS, CNNModel, iter_tagged

__all__ = ["prune_widths", "l1_scores", "random_profile_widths", "PRUNE_STRATEGIES"]

PRUNE_STRATEGIES = ("random", "l1", "uniform", "early", "middle", "late")


def _position_weights(n_groups: int, profile: str) -> np.ndarray:
    """Relative pruning propensity per group position (order of widths dict)."""
    x = np.linspace(0.0, 1.0, n_groups)
    if profile == "early":
        w = 1.0 - x
    elif profile == "late":
        w = x
    elif profile == "middle":
        w = 1.0 - np.abs(x - 0.5) * 2.0
    else:
        raise ValueError(profile)
    return w + 0.15  # keep strictly positive so every group can lose filters


def prune_widths(
    canonical: dict[str, int],
    level: float,
    strategy: str = "random",
    rng: np.random.Generator | None = None,
    min_ch: int = 2,
    scores: dict[str, np.ndarray] | None = None,
) -> dict[str, int]:
    """Derive a pruned ``widths`` dict from ``canonical`` at ``level``∈[0,1)."""
    if not 0.0 <= level < 1.0:
        raise ValueError(f"pruning level must be in [0,1): {level}")
    if level == 0.0:
        return dict(canonical)
    rng = rng or np.random.default_rng(0)
    groups = list(canonical.keys())
    sizes = np.array([canonical[g] for g in groups], dtype=np.int64)
    total = int(sizes.sum())
    n_prune = int(round(level * total))

    if strategy == "uniform":
        kept = np.maximum(min_ch, np.round(sizes * (1.0 - level)).astype(np.int64))
    elif strategy == "random":
        pruned = rng.multivariate_hypergeometric(sizes, n_prune)
        kept = np.maximum(min_ch, sizes - pruned)
    elif strategy == "l1":
        if scores is None:
            raise ValueError("l1 strategy requires per-group filter scores")
        flat_scores, owner = [], []
        for gi, g in enumerate(groups):
            s = np.asarray(scores[g], dtype=np.float64)
            if len(s) != canonical[g]:
                raise ValueError(f"score length mismatch for group {g}")
            flat_scores.append(s)
            owner.append(np.full(len(s), gi))
        flat_scores = np.concatenate(flat_scores)
        owner = np.concatenate(owner)
        order = np.argsort(flat_scores, kind="stable")[:n_prune]
        pruned = np.bincount(owner[order], minlength=len(groups))
        kept = np.maximum(min_ch, sizes - pruned)
    elif strategy in ("early", "middle", "late"):
        w = _position_weights(len(groups), strategy)
        # Per-group prune counts proportional to weight · size, iteratively
        # clipped so no group drops below min_ch while the total stays ~level.
        budget = n_prune
        kept = sizes.copy()
        for _ in range(8):
            room = kept - min_ch
            active = room > 0
            if budget <= 0 or not active.any():
                break
            alloc = w * sizes
            alloc = np.where(active, alloc, 0.0)
            if alloc.sum() == 0:
                break
            take = np.minimum(room, np.round(alloc / alloc.sum() * budget).astype(np.int64))
            kept = kept - take
            budget -= int(take.sum())
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return {g: int(k) for g, k in zip(groups, kept)}


def random_profile_widths(
    canonical: dict[str, int],
    level: float,
    rng: np.random.Generator,
    min_ch: int = 2,
) -> dict[str, int]:
    """Paper §6.2: one of "100 random pruning strategies" — per-group pruning
    ratios drawn from a Dirichlet around the target level (includes heavily
    non-uniform allocations)."""
    groups = list(canonical.keys())
    sizes = np.array([canonical[g] for g in groups], dtype=np.float64)
    total = sizes.sum()
    n_prune = level * total
    alloc = rng.dirichlet(np.full(len(groups), 1.5)) * n_prune
    kept = np.maximum(min_ch, np.round(sizes - np.minimum(alloc, sizes - min_ch)))
    return {g: int(k) for g, k in zip(groups, kept)}


def l1_scores(model: CNNModel, seed: int = 0) -> dict[str, np.ndarray]:
    """Per-group per-filter L1 norms from an initialised model (the paper
    scores a trained model; at reproduction scale the init-weight L1 plays the
    same role: a deterministic, non-uniform global ranking)."""
    params = model.init(seed)
    out: dict[str, np.ndarray] = {}
    for group, node, p in iter_tagged(model.graph, params):
        if group in out:
            continue  # first occurrence is the primary producer
        w = np.asarray(p["w"])
        if w.ndim == 4:  # HWIO conv: per-filter sum over (k,k,cin)
            out[group] = np.abs(w).sum(axis=(0, 1, 2))
        else:  # dense (cin, cout)
            out[group] = np.abs(w).sum(axis=0)
    return out


def pruned_model(
    family: str,
    level: float,
    strategy: str = "random",
    seed: int = 0,
    width_mult: float = 1.0,
    input_hw: int = 32,
) -> CNNModel:
    """Convenience: canonical model → pruned widths → rebuilt model."""
    build = CNN_BUILDERS[family]
    base = build(width_mult=width_mult, input_hw=input_hw)
    rng = np.random.default_rng(seed)
    scores = l1_scores(base, seed) if strategy == "l1" else None
    widths = prune_widths(base.widths, level, strategy, rng, scores=scores)
    m = build(widths=widths, input_hw=input_hw)
    m.name = f"{family}-p{int(level * 100)}-{strategy}"
    return m
