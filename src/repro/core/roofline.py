"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell, derive the three roofline terms from the
AOT-compiled executable:

    compute term    = HLO_FLOPs(per device)      / peak_FLOP/s
    memory term     = HLO_bytes(per device)      / HBM_bw
    collective term = collective_bytes(per dev)  / link_bw

The post-SPMD compiled module is already per-device, so ``cost_analysis()``
FLOPs/bytes are per-device quantities.  collective_bytes comes from
``analyze_hlo_collectives`` over the optimized HLO text.

We also report MODEL_FLOPS = 6·N·D (training; N = params, D = tokens) or
2·N·D (inference fwd) per device and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs — low values flag remat/dispatch overcompute.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass
from dataclasses import field as dataclasses_field

from repro.core.hlo_cost import parse_hlo_cost
from repro.launch.mesh import TPU_V5E

__all__ = ["RooflineReport", "roofline_from_compiled"]


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # raw per-device quantities
    flops: float
    hbm_bytes: float
    collective_bytes: float
    bytes_by_kind: dict
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    step_s: float               # max of the three (no-overlap bound)
    # usefulness
    model_flops: float          # 6·N·D (train) / 2·N·D (fwd) per device
    useful_ratio: float
    # memory plan
    per_device_hbm_gb: float
    fits_hbm: bool
    compile_s: float = 0.0
    # per-op-class attribution (repro.costmodel taxonomy): {cls: {flops,
    # hbm_bytes, collective_bytes, count}} + the top ledger records
    class_breakdown: dict = dataclasses_field(default_factory=dict)
    top_ops: list = dataclasses_field(default_factory=list)

    def to_dict(self) -> dict:
        return asdict(self)

    def summary(self) -> str:
        lines = [
            f"{self.arch:>24s} {self.shape:<12s} {self.mesh:<9s} "
            f"C={self.compute_s * 1e3:9.2f}ms M={self.memory_s * 1e3:9.2f}ms "
            f"X={self.collective_s * 1e3:9.2f}ms dom={self.dominant:<10s} "
            f"useful={self.useful_ratio:5.2f} hbm={self.per_device_hbm_gb:6.2f}GB"
            f"{'' if self.fits_hbm else ' OVER'} [compile {self.compile_s:.0f}s]"
        ]
        if self.class_breakdown:
            parts = []
            for cls, s in self.class_breakdown.items():
                share = s["hbm_bytes"] / self.hbm_bytes if self.hbm_bytes else 0.0
                parts.append(f"{cls}={share:.0%}")
            lines.append(" " * 25 + "bytes by class: " + " ".join(parts))
        for op in self.top_ops:
            share = op["hbm_bytes"] / self.hbm_bytes if self.hbm_bytes else 0.0
            lines.append(
                " " * 25 + f"top op {op['op']:<20s} [{op['op_class']}] "
                f"{op['hbm_bytes'] / 1e6:10.1f}MB ({share:.0%}) "
                f"x{op['trip_multiplier']:.0f} @{op['origin']}")
        return "\n".join(lines)


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def memory_bytes(compiled) -> float:
    ma = compiled.memory_analysis()
    return float(
        getattr(ma, "argument_size_in_bytes", 0)
        + getattr(ma, "output_size_in_bytes", 0)
        + getattr(ma, "temp_size_in_bytes", 0)
        - getattr(ma, "alias_size_in_bytes", 0)
    )


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    n_devices: int,
    model_flops_total: float,
    hw: dict = TPU_V5E,
    compile_s: float = 0.0,
) -> RooflineReport:
    # ``hw`` is a constants dict or an engine DeviceSpec (duck-typed so the
    # core layer needs no engine import).
    if hasattr(hw, "hw_table"):
        hw = hw.hw_table()
    # Trip-count-aware parse of the optimized HLO (XLA's cost_analysis counts
    # while bodies once — see hlo_cost module docstring).
    cost = parse_hlo_cost(compiled.as_text())
    flops = cost.flops
    hbm = cost.hbm_bytes
    stats = cost

    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = hbm / hw["hbm_bw"]
    coll_s = stats.collective_bytes / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)

    model_flops_dev = model_flops_total / n_devices
    hbm_plan = memory_bytes(compiled)
    top_ops = [
        {"op": r.op, "op_class": r.op_class, "flops": r.flops,
         "hbm_bytes": r.hbm_bytes, "collective_bytes": r.collective_bytes,
         "trip_multiplier": r.trip_multiplier, "origin": r.origin}
        for r in cost.ledger.top_k(3, by="hbm_bytes")
    ]
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        n_devices=n_devices,
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=stats.collective_bytes,
        bytes_by_kind=dict(stats.bytes_by_kind),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        step_s=max(terms.values()),
        model_flops=model_flops_dev,
        useful_ratio=(model_flops_dev / flops) if flops else 0.0,
        per_device_hbm_gb=hbm_plan / 1e9,
        fits_hbm=hbm_plan <= hw["hbm_bytes"],
        compile_s=compile_s,
        class_breakdown=cost.ledger.class_sums(),
        top_ops=top_ops,
    )


def model_flops_for_cell(cfg, shape) -> float:
    """Total MODEL_FLOPS across devices for one step of this cell."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
