"""Random forest regressor (paper §5.2, citing Breiman 2001).

Bootstrap-aggregated :class:`~repro.core.tree.RegressionTree`s with per-node
feature subsampling, out-of-bag (OOB) error estimation and aggregated feature
importances.  One forest is trained per modelled attribute (Γ memory,
Φ latency) — paper §5.3.
"""

from __future__ import annotations

import numpy as np

from .tree import RegressionTree

__all__ = ["RandomForestRegressor"]


class RandomForestRegressor:
    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int | None = None,
        min_samples_leaf: int = 1,
        min_samples_split: int = 2,
        max_features: int | float | str | None = "third",
        bootstrap: bool = True,
        seed: int = 0,
    ):
        self.n_estimators = int(n_estimators)
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.min_samples_split = min_samples_split
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.seed = seed
        self.trees_: list[RegressionTree] = []
        self.oob_prediction_: np.ndarray | None = None
        self.oob_mape_: float | None = None
        self.feature_importances_: np.ndarray | None = None
        self._y_min: float | None = None
        self._y_max: float | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(y)
        if n == 0:
            raise ValueError("empty training set")
        root = np.random.default_rng(self.seed)
        self.trees_ = []
        oob_sum = np.zeros(n)
        oob_cnt = np.zeros(n)
        importances = np.zeros(X.shape[1])
        for t in range(self.n_estimators):
            rng = np.random.default_rng(root.integers(2**63))
            if self.bootstrap:
                idx = rng.integers(0, n, size=n)
            else:
                idx = np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                min_samples_split=self.min_samples_split,
                max_features=self.max_features,
                rng=rng,
            ).fit(X[idx], y[idx])
            self.trees_.append(tree)
            importances += tree.feature_importances_
            if self.bootstrap:
                oob_mask = np.ones(n, dtype=bool)
                oob_mask[np.unique(idx)] = False
                if oob_mask.any():
                    oob_sum[oob_mask] += tree.predict(X[oob_mask])
                    oob_cnt[oob_mask] += 1
        total = importances.sum()
        self.feature_importances_ = importances / total if total > 0 else importances
        if self.bootstrap and (oob_cnt > 0).any():
            covered = oob_cnt > 0
            oob_pred = np.full(n, np.nan)
            oob_pred[covered] = oob_sum[covered] / oob_cnt[covered]
            self.oob_prediction_ = oob_pred
            denom = np.where(np.abs(y[covered]) > 1e-12, np.abs(y[covered]), 1.0)
            self.oob_mape_ = float(
                np.mean(np.abs(oob_pred[covered] - y[covered]) / denom)
            )
        self._y_min, self._y_max = float(y.min()), float(y.max())
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees_:
            raise RuntimeError("forest not fitted")
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        acc = np.zeros(len(X))
        for tree in self.trees_:
            acc += tree.predict(X)
        return acc / len(self.trees_)

    # -- persistence (used by the launcher's admission controller) ----------

    def to_dict(self) -> dict:
        trees = []
        for t in self.trees_:
            trees.append(
                {
                    "feat": t._feat.tolist(),
                    "thr": t._thr.tolist(),
                    "left": t._left.tolist(),
                    "right": t._right.tolist(),
                    "val": t._val.tolist(),
                    "n_features": t.n_features_,
                }
            )
        return {
            "trees": trees,
            "y_min": self._y_min,
            "y_max": self._y_max,
            "params": {
                "n_estimators": self.n_estimators,
                "max_depth": self.max_depth,
                "min_samples_leaf": self.min_samples_leaf,
                "max_features": self.max_features,
            },
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RandomForestRegressor":
        self = cls(n_estimators=len(d["trees"]))
        self._y_min = d.get("y_min")
        self._y_max = d.get("y_max")
        self.trees_ = []
        for td in d["trees"]:
            t = RegressionTree()
            t.n_features_ = td["n_features"]
            t._feat = np.array(td["feat"], dtype=np.int64)
            t._thr = np.array(td["thr"], dtype=np.float64)
            t._left = np.array(td["left"], dtype=np.int64)
            t._right = np.array(td["right"], dtype=np.int64)
            t._val = np.array(td["val"], dtype=np.float64)
            self.trees_.append(t)
        return self
