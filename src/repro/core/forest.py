"""Random forest regressor (paper §5.2, citing Breiman 2001).

Bootstrap-aggregated :class:`~repro.core.tree.RegressionTree`s with per-node
feature subsampling, out-of-bag (OOB) error estimation and aggregated feature
importances.  One forest is trained per modelled attribute (Γ memory,
Φ latency) — paper §5.3.
"""

from __future__ import annotations

import numpy as np

from .tree import RegressionTree

__all__ = ["RandomForestRegressor"]


class RandomForestRegressor:
    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int | None = None,
        min_samples_leaf: int = 1,
        min_samples_split: int = 2,
        max_features: int | float | str | None = "third",
        bootstrap: bool = True,
        seed: int = 0,
    ):
        self.n_estimators = int(n_estimators)
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.min_samples_split = min_samples_split
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.seed = seed
        self.trees_: list[RegressionTree] = []
        self.oob_prediction_: np.ndarray | None = None
        self.oob_mape_: float | None = None
        self.feature_importances_: np.ndarray | None = None
        self._y_min: float | None = None
        self._y_max: float | None = None
        self._packed: tuple | None = None  # lazily-built flat forest arrays

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(y)
        if n == 0:
            raise ValueError("empty training set")
        root = np.random.default_rng(self.seed)
        self.trees_ = []
        oob_sum = np.zeros(n)
        oob_cnt = np.zeros(n)
        importances = np.zeros(X.shape[1])
        for t in range(self.n_estimators):
            rng = np.random.default_rng(root.integers(2**63))
            if self.bootstrap:
                idx = rng.integers(0, n, size=n)
            else:
                idx = np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                min_samples_split=self.min_samples_split,
                max_features=self.max_features,
                rng=rng,
            ).fit(X[idx], y[idx])
            self.trees_.append(tree)
            importances += tree.feature_importances_
            if self.bootstrap:
                oob_mask = np.ones(n, dtype=bool)
                oob_mask[np.unique(idx)] = False
                if oob_mask.any():
                    oob_sum[oob_mask] += tree.predict(X[oob_mask])
                    oob_cnt[oob_mask] += 1
        total = importances.sum()
        self.feature_importances_ = importances / total if total > 0 else importances
        if self.bootstrap and (oob_cnt > 0).any():
            covered = oob_cnt > 0
            oob_pred = np.full(n, np.nan)
            oob_pred[covered] = oob_sum[covered] / oob_cnt[covered]
            self.oob_prediction_ = oob_pred
            denom = np.where(np.abs(y[covered]) > 1e-12, np.abs(y[covered]), 1.0)
            self.oob_mape_ = float(
                np.mean(np.abs(oob_pred[covered] - y[covered]) / denom)
            )
        self._y_min, self._y_max = float(y.min()), float(y.max())
        self._packed = None
        return self

    # -- vectorized prediction ----------------------------------------------
    #
    # All trees are concatenated into one flat node-array set (child indices
    # rebased to global node ids).  Prediction then walks every (tree, sample)
    # pair simultaneously: a (T, S) position matrix descends one level per
    # numpy iteration, so the cost is max-depth gathers instead of a Python
    # loop over T trees.

    def _pack(self) -> tuple:
        if self._packed is None:
            offsets = np.zeros(len(self.trees_) + 1, dtype=np.int64)
            for i, t in enumerate(self.trees_):
                offsets[i + 1] = offsets[i] + len(t._feat)
            feat = np.concatenate([t._feat for t in self.trees_])
            thr = np.concatenate([t._thr for t in self.trees_])
            val = np.concatenate([t._val for t in self.trees_])
            left = np.concatenate([
                np.where(t._left >= 0, t._left + off, -1)
                for t, off in zip(self.trees_, offsets[:-1])
            ])
            right = np.concatenate([
                np.where(t._right >= 0, t._right + off, -1)
                for t, off in zip(self.trees_, offsets[:-1])
            ])
            self._packed = (offsets, feat, thr, left, right, val)
        return self._packed

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees_:
            raise RuntimeError("forest not fitted")
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        offsets, feat, thr, left, right, val = self._pack()
        n_samples = len(X)
        pos = np.broadcast_to(
            offsets[:-1][:, None], (len(self.trees_), n_samples)
        ).copy()
        cols = np.arange(n_samples)[None, :]
        while True:
            f = feat[pos]
            internal = f >= 0
            if not internal.any():
                break
            xv = X[cols, np.where(internal, f, 0)]
            go_left = xv <= thr[pos]
            nxt = np.where(go_left, left[pos], right[pos])
            pos = np.where(internal, nxt, pos)
        return val[pos].mean(axis=0)

    def _predict_per_tree(self, X: np.ndarray) -> np.ndarray:
        """Reference path: average of per-tree predictions (kept for parity
        tests against the packed vectorized traversal)."""
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        acc = np.zeros(len(X))
        for tree in self.trees_:
            acc += tree.predict(X)
        return acc / len(self.trees_)

    def content_hash(self) -> str:
        """Hash of the fitted forest structure (cache-key salt: estimates
        produced by different fitted models must never alias).  Memoized per
        packing — a refit invalidates the packed arrays and thus the hash."""
        import hashlib

        packed = self._pack()
        cached = getattr(self, "_content_hash", None)
        if cached is not None and cached[0] is packed:
            return cached[1]
        h = hashlib.sha1()
        for a in packed:  # offsets, feat, thr, left, right, val — all of them
            h.update(np.ascontiguousarray(a).tobytes())
        digest = h.hexdigest()
        self._content_hash = (packed, digest)
        return digest

    # -- persistence (used by the launcher's admission controller) ----------

    def to_arrays(self, prefix: str = "") -> dict[str, np.ndarray]:
        """Flat-array form of the fitted forest (NPZ-serializable): the packed
        node arrays plus per-tree offsets — far more compact than the nested
        JSON tree dicts for production-size forests."""
        if not self.trees_:
            raise RuntimeError("forest not fitted")
        offsets, feat, thr, left, right, val = self._pack()
        y_min = np.nan if self._y_min is None else self._y_min
        y_max = np.nan if self._y_max is None else self._y_max
        return {
            prefix + "offsets": offsets,
            prefix + "feat": feat,
            prefix + "thr": thr,
            prefix + "left": left,
            prefix + "right": right,
            prefix + "val": val,
            prefix + "meta": np.array(
                [float(self.trees_[0].n_features_), y_min, y_max]
            ),
        }

    @classmethod
    def from_arrays(cls, arrays, prefix: str = "") -> "RandomForestRegressor":
        offsets = np.asarray(arrays[prefix + "offsets"], dtype=np.int64)
        feat = np.asarray(arrays[prefix + "feat"], dtype=np.int64)
        thr = np.asarray(arrays[prefix + "thr"], dtype=np.float64)
        left = np.asarray(arrays[prefix + "left"], dtype=np.int64)
        right = np.asarray(arrays[prefix + "right"], dtype=np.int64)
        val = np.asarray(arrays[prefix + "val"], dtype=np.float64)
        meta = np.asarray(arrays[prefix + "meta"], dtype=np.float64)
        n_features = int(meta[0])
        self = cls(n_estimators=len(offsets) - 1)
        self._y_min = None if np.isnan(meta[1]) else float(meta[1])
        self._y_max = None if np.isnan(meta[2]) else float(meta[2])
        self.trees_ = []
        for i in range(len(offsets) - 1):
            lo, hi = offsets[i], offsets[i + 1]
            t = RegressionTree()
            t.n_features_ = n_features
            t._feat = feat[lo:hi].copy()
            t._thr = thr[lo:hi].copy()
            t._left = np.where(feat[lo:hi] >= 0, left[lo:hi] - lo, -1)
            t._right = np.where(feat[lo:hi] >= 0, right[lo:hi] - lo, -1)
            t._val = val[lo:hi].copy()
            self.trees_.append(t)
        return self

    def to_dict(self) -> dict:
        trees = []
        for t in self.trees_:
            trees.append(
                {
                    "feat": t._feat.tolist(),
                    "thr": t._thr.tolist(),
                    "left": t._left.tolist(),
                    "right": t._right.tolist(),
                    "val": t._val.tolist(),
                    "n_features": t.n_features_,
                }
            )
        return {
            "trees": trees,
            "y_min": self._y_min,
            "y_max": self._y_max,
            "params": {
                "n_estimators": self.n_estimators,
                "max_depth": self.max_depth,
                "min_samples_leaf": self.min_samples_leaf,
                "max_features": self.max_features,
            },
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RandomForestRegressor":
        self = cls(n_estimators=len(d["trees"]))
        self._y_min = d.get("y_min")
        self._y_max = d.get("y_max")
        self.trees_ = []
        for td in d["trees"]:
            t = RegressionTree()
            t.n_features_ = td["n_features"]
            t._feat = np.array(td["feat"], dtype=np.int64)
            t._thr = np.array(td["thr"], dtype=np.float64)
            t._left = np.array(td["left"], dtype=np.int64)
            t._right = np.array(td["right"], dtype=np.int64)
            t._val = np.array(td["val"], dtype=np.float64)
            self.trees_.append(t)
        return self
