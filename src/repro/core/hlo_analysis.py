"""HLO-text analysis: per-collective byte counts for the roofline model.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the optimized (post-SPMD) HLO text and sum, per
collective kind, the bytes each device moves over ICI/DCI.

Byte model per op kind (ring algorithms, g = replica-group size, S = result
buffer bytes on one device):

  all-gather        : device receives S·(g−1)/g  ≈ S bytes
  reduce-scatter    : operand is g·S; device sends/receives (g−1)·S ≈ input bytes
  all-reduce        : ring RS+AG ⇒ 2·S·(g−1)/g   ≈ 2·S bytes
  all-to-all        : device exchanges S·(g−1)/g ≈ S bytes
  collective-permute: S bytes

These are the standard ring-collective costs; exact (g−1)/g factors are
applied when the replica-group size is parseable from the op attributes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "analyze_hlo_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

# e.g.  %all-gather.3 = bf16[16,128]{1,0} all-gather(%param.1), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TUPLE_OP_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: float) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[ngroups,gsize]<=...
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown: assume ≥2 so the (g-1)/g factor ≈ 0.5..1


def analyze_hlo_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective bytes from optimized HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "fusion" in line and "calls=" in line:
            pass  # collectives never hide inside fusions
        m = _OP_RE.search(line)
        shapes: list[tuple[str, str]] = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_OP_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind:
            continue
        out_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        frac = (g - 1) / g
        if kind == "all-gather":
            moved = out_bytes * frac
        elif kind == "all-reduce":
            moved = 2.0 * out_bytes * frac
        elif kind == "reduce-scatter":
            moved = out_bytes * g * frac  # operand = g × result
        elif kind == "all-to-all":
            moved = out_bytes * frac
        else:  # collective-permute
            moved = out_bytes
        stats.add(kind, moved)
    return stats
