"""Shared atomic-file idioms for the on-disk caches and model files.

Every persistent artifact in this repo (profiling cache, estimate cache,
fitted predictors) follows the same contract: writes go to a tempfile in
the target directory, are fsync'd, then ``os.replace``d over the target —
an interrupted run can never leave a truncated file; and a corrupt file
(pre-atomic writer, torn disk) is quarantined to ``<path>.corrupt`` so the
caller restarts from empty instead of crashing.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Callable

__all__ = [
    "load_json_tolerant",
    "atomic_write_json",
    "atomic_write_bytes",
    "append_jsonl",
    "load_jsonl_tolerant",
]


def load_json_tolerant(path: str) -> dict:
    """Load a JSON dict; quarantine an unreadable/corrupt/non-dict file and
    return {} (valid JSON that is not an object would crash callers just as
    surely as a parse error)."""
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict):
            return data
    except (json.JSONDecodeError, UnicodeDecodeError):
        pass
    except OSError:
        # Transient read failure (permissions, I/O hiccup) is NOT evidence
        # of corruption — never rename a possibly-valid cache away.
        return {}
    try:
        os.replace(path, path + ".corrupt")
    except OSError:
        pass
    return {}


def _atomic_write(path: str, mode: str, write_fn: Callable, suffix: str = "") -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=suffix)
    try:
        with os.fdopen(fd, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def atomic_write_json(path: str, obj) -> None:
    _atomic_write(path, "w", lambda f: json.dump(obj, f))


def atomic_write_bytes(path: str, write_fn: Callable, suffix: str = "") -> None:
    """Atomic binary write; ``write_fn(file)`` produces the content (e.g.
    ``lambda f: np.savez_compressed(f, **arrays)``)."""
    _atomic_write(path, "wb", write_fn, suffix=suffix)


# ---------------------------------------------------------------------------
# Append-only JSONL ledgers (profiling campaigns, dry-run reports).
#
# The whole-file atomic rewrite above is wrong for a ledger shared by many
# workers: two concurrent rewrites lose each other's records.  An O_APPEND
# write of complete ``record\n`` lines in a single ``os.write`` call never
# interleaves with another appender's lines on POSIX, and the fsync makes a
# recorded cell durable before the runner moves to the next one.  A crash
# can at worst leave one torn *final* line, which the tolerant loader drops
# — so restart logic re-runs only the cell whose record was torn.
# ---------------------------------------------------------------------------


def append_jsonl(path: str, records: list | dict) -> int:
    """Durably append record dict(s) as JSONL; returns the number written."""
    if isinstance(records, dict):
        records = [records]
    if not records:
        return 0
    payload = "".join(
        json.dumps(r, sort_keys=True, default=str) + "\n" for r in records
    )
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # O_RDWR (not O_WRONLY) so the pread below can heal a torn tail: if a
    # crashed writer left the file without a trailing newline, start this
    # append on a fresh line — otherwise the first new record glues onto
    # the torn fragment and BOTH lines are lost to the tolerant loader.
    fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        size = os.fstat(fd).st_size
        if size > 0 and os.pread(fd, 1, size - 1) != b"\n":
            payload = "\n" + payload
        os.write(fd, payload.encode())
        os.fsync(fd)
    finally:
        os.close(fd)
    return len(records)


def load_jsonl_tolerant(path: str) -> list[dict]:
    """Load JSONL records, skipping anything unparsable.

    Blank lines and non-dict rows are ignored; a torn final line (a crash
    mid-append) parses as garbage and is silently dropped — the caller's
    resume logic treats that cell as never recorded.  Unlike
    :func:`load_json_tolerant` the file is NOT quarantined: every intact
    line is an independent record and stays usable."""
    if not os.path.exists(path):
        return []
    out: list[dict] = []
    try:
        with open(path, errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(rec, dict):
                    out.append(rec)
    except OSError:
        return out
    return out
