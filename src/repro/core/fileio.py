"""Shared atomic-file idioms for the on-disk caches and model files.

Every persistent artifact in this repo (profiling cache, estimate cache,
fitted predictors) follows the same contract: writes go to a tempfile in
the target directory, are fsync'd, then ``os.replace``d over the target —
an interrupted run can never leave a truncated file; and a corrupt file
(pre-atomic writer, torn disk) is quarantined to ``<path>.corrupt`` so the
caller restarts from empty instead of crashing.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Callable

__all__ = ["load_json_tolerant", "atomic_write_json", "atomic_write_bytes"]


def load_json_tolerant(path: str) -> dict:
    """Load a JSON dict; quarantine an unreadable/corrupt/non-dict file and
    return {} (valid JSON that is not an object would crash callers just as
    surely as a parse error)."""
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict):
            return data
    except (json.JSONDecodeError, UnicodeDecodeError):
        pass
    except OSError:
        # Transient read failure (permissions, I/O hiccup) is NOT evidence
        # of corruption — never rename a possibly-valid cache away.
        return {}
    try:
        os.replace(path, path + ".corrupt")
    except OSError:
        pass
    return {}


def _atomic_write(path: str, mode: str, write_fn: Callable, suffix: str = "") -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=suffix)
    try:
        with os.fdopen(fd, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def atomic_write_json(path: str, obj) -> None:
    _atomic_write(path, "w", lambda f: json.dump(obj, f))


def atomic_write_bytes(path: str, write_fn: Callable, suffix: str = "") -> None:
    """Atomic binary write; ``write_fn(file)`` produces the content (e.g.
    ``lambda f: np.savez_compressed(f, **arrays)``)."""
    _atomic_write(path, "wb", write_fn, suffix=suffix)
