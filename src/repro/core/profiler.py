"""Network-wise profiling strategy (paper §5.1, Appendix A).

Each datapoint profiles an *entire* training step — forward pass, backward
pass and the SGD(+momentum) update — never an isolated layer, because
frameworks allocate for whole-network execution (paper §3.1).

Attribute definitions (paper §4), adapted to this device (1-core CPU host
standing in for the edge device; XLA is the framework):

  Γ (gamma_mb)  — total training-step memory: the XLA executable's
      argument + output + temporary + generated-code bytes from
      ``compiled.memory_analysis()``.  On TPU this is exactly the per-device
      HBM plan that decides "fits / doesn't fit" — the deterministic
      analogue of the paper's /proc/meminfo sampling on unified memory.
  Φ (phi_ms)    — wall-clock latency of one jitted training step (data
      preparation excluded, update step included — paper §4), median over
      ``repeats`` runs after ``warmup`` warmup runs, timed around
      ``block_until_ready`` (the torch.cuda.Events analogue).

Inference-stage attributes γ/φ (paper §6.4) are profiled the same way over
a forward-only executable.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import CNNModel

__all__ = [
    "ProfileResult",
    "make_train_step",
    "make_infer_fn",
    "profile_training",
    "profile_inference",
    "memory_analysis_bytes",
]


@dataclass
class ProfileResult:
    gamma_mb: float          # Γ — total memory (MB)
    phi_ms: float            # Φ — per-step latency (ms)
    compile_s: float         # one-off compile time (not part of Φ)
    flops: float | None      # XLA cost-analysis FLOPs, when available
    temp_mb: float = 0.0
    arg_mb: float = 0.0
    out_mb: float = 0.0
    code_mb: float = 0.0


def make_train_step(model: CNNModel, lr: float = 0.01, momentum: float = 0.9):
    """fwd + bwd + SGD-momentum update, as the paper profiles (§4)."""

    def loss_fn(params, x, y):
        logits = model.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    def step(params, mom, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        mom = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        return params, mom, loss

    return step


def make_infer_fn(model: CNNModel):
    def infer(params, x):
        return model.apply(params, x)

    return infer


def memory_analysis_bytes(compiled) -> dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "arg": float(getattr(ma, "argument_size_in_bytes", 0.0)),
        "out": float(getattr(ma, "output_size_in_bytes", 0.0)),
        "temp": float(getattr(ma, "temp_size_in_bytes", 0.0)),
        "code": float(getattr(ma, "generated_code_size_in_bytes", 0.0)),
        "alias": float(getattr(ma, "alias_size_in_bytes", 0.0)),
    }


def _flops(compiled) -> float | None:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca.get("flops", 0.0)) or None
    except Exception:
        return None


def _time_calls(fn, args, repeats: int, warmup: int) -> float:
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e3


def profile_training(
    model: CNNModel,
    bs: int,
    *,
    repeats: int = 2,
    warmup: int = 1,
    seed: int = 0,
    run: bool = True,
) -> ProfileResult:
    """Profile Γ and Φ of one training mini-batch for ``model`` at ``bs``."""
    params = model.init(seed)
    mom = jax.tree.map(lambda a: np.zeros_like(a), params)
    np_rng = np.random.default_rng(seed)
    x = np_rng.normal(size=(bs, model.input_hw, model.input_hw, 3)).astype(np.float32)
    y = np_rng.integers(0, model.num_classes, size=(bs,)).astype(np.int32)

    step = jax.jit(make_train_step(model))
    t0 = time.perf_counter()
    compiled = step.lower(params, mom, x, y).compile()
    compile_s = time.perf_counter() - t0

    mb = memory_analysis_bytes(compiled)
    gamma_mb = (mb["arg"] + mb["out"] + mb["temp"] + mb["code"]) / 1e6
    phi_ms = _time_calls(compiled, (params, mom, x, y), repeats, warmup) if run else 0.0
    return ProfileResult(
        gamma_mb=gamma_mb,
        phi_ms=phi_ms,
        compile_s=compile_s,
        flops=_flops(compiled),
        temp_mb=mb["temp"] / 1e6,
        arg_mb=mb["arg"] / 1e6,
        out_mb=mb["out"] / 1e6,
        code_mb=mb["code"] / 1e6,
    )


def profile_inference(
    model: CNNModel,
    bs: int,
    *,
    repeats: int = 3,
    warmup: int = 1,
    seed: int = 0,
    run: bool = True,
) -> ProfileResult:
    """Profile γ and φ (inference memory / latency) — paper §6.4."""
    params = model.init(seed)
    np_rng = np.random.default_rng(seed)
    x = np_rng.normal(size=(bs, model.input_hw, model.input_hw, 3)).astype(np.float32)

    fn = jax.jit(make_infer_fn(model))
    t0 = time.perf_counter()
    compiled = fn.lower(params, x).compile()
    compile_s = time.perf_counter() - t0

    mb = memory_analysis_bytes(compiled)
    gamma_mb = (mb["arg"] + mb["out"] + mb["temp"] + mb["code"]) / 1e6
    phi_ms = _time_calls(compiled, (params, x), repeats, warmup) if run else 0.0
    return ProfileResult(
        gamma_mb=gamma_mb,
        phi_ms=phi_ms,
        compile_s=compile_s,
        flops=_flops(compiled),
        temp_mb=mb["temp"] / 1e6,
        arg_mb=mb["arg"] / 1e6,
        out_mb=mb["out"] / 1e6,
        code_mb=mb["code"] / 1e6,
    )
