"""Cost-model-driven auto-sharding: rank mesh layouts without compiling.

``LayoutPlanner.plan`` answers "how should I split N devices between
data, tensor and pipeline parallelism for this (arch × shape)?" the same
way the admission gate answers "does it fit?": by asking the cost model,
never the compiler.  One base :class:`~repro.engine.types.CostQuery`
(the single-device step) goes through the :class:`~repro.engine.engine.
CostEngine` front door — forest-backed, cached, or analytical — and
every candidate layout is then priced *analytically* from that anchor:

* **compute** — the base step time divided by the useful parallelism.
  The model axis only speeds up what actually sharded:
  ``layout_collectives`` reports the replicated parameter fraction ``r``
  (fallback replication priced, per the sharding-rules contract), and
  Amdahl gives the model-axis efficiency ``1 / ((1-r)/M + r)``.
* **pipeline bubble** — ``bubble_fraction(P, n_micro)`` stretches the
  ideal stage time by ``1/(1-bubble)`` (GPipe fill/drain).
* **collectives** — the per-class byte counts derived from the actual
  PartitionSpecs, priced by ``engine.decompose.collective_seconds``
  (campaign-fitted collective coefficient when the device carries one,
  ici_bw roofline otherwise).
* **memory** — the base footprint scaled by the layout's per-device
  memory split (params/grads/opt/activations under TP+ZeRO+pipe) over
  the single-device split.
* **energy** — power-conserving: per-device step energy scales with the
  per-device step time; the fleet total multiplies by N.

Layouts that cannot run are *refused with a reason* (batch not divisible
by the data axis, layer stack not divisible by the pipe factor, memory
over capacity) and kept in the plan — a pruned layout is a documented
decision, not a silent hole.  Indivisible heads/dims are NOT a refusal:
the sharding rules fall back to replication and the planner prices that
fallback (extra model-axis all-reduce + unsplit memory), so a 40-head
arch on a 16-way model axis ranks badly instead of vanishing.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ArchConfig, ShapeSpec
from repro.configs.registry import get_config
from repro.distributed.collectives import abstract_mesh, layout_collectives
from repro.engine.decompose import collective_seconds
from repro.engine.devices import resolve_device
from repro.engine.types import CostQuery
from repro.launch.mesh import validate_mesh_spec
from repro.planner.layouts import MeshLayout, enumerate_layouts

__all__ = ["LayoutDecision", "LayoutRefusal", "LayoutPlan", "LayoutPlanner"]


@dataclass
class LayoutDecision:
    """One priced layout: predicted per-device (phi, gamma, energy) plus
    the additive breakdown the ranking came from."""

    layout: MeshLayout
    phi_ms: float
    gamma_mb: float
    energy_j: float          # per device, one step
    energy_total_j: float    # fleet (n_devices × per-device)
    breakdown: dict = field(default_factory=dict)
    collectives: dict = field(default_factory=dict)

    @property
    def sort_key(self) -> tuple:
        # Deterministic total order: latency, then fleet energy, then the
        # descriptor so exact ties break identically across processes.
        return (self.phi_ms, self.energy_total_j, self.layout.descriptor)

    def to_dict(self) -> dict:
        return {"layout": self.layout.to_dict(),
                "phi_ms": float(self.phi_ms),
                "gamma_mb": float(self.gamma_mb),
                "energy_j": float(self.energy_j),
                "energy_total_j": float(self.energy_total_j),
                "breakdown": dict(self.breakdown),
                "collectives": dict(self.collectives)}


@dataclass
class LayoutRefusal:
    """A layout the planner declined to rank, and exactly why."""

    layout: MeshLayout
    reason: str
    detail: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"layout": self.layout.to_dict(), "reason": self.reason,
                "detail": dict(self.detail)}


@dataclass
class LayoutPlan:
    """The ranked answer: ``ranked[0]`` (= :attr:`chosen`) is the predicted
    cheapest runnable layout; ``refused`` documents every pruned one."""

    arch: str
    shape: ShapeSpec
    n_devices: int
    device: str
    base: dict
    ranked: list = field(default_factory=list)
    refused: list = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    @property
    def chosen(self) -> LayoutDecision | None:
        return self.ranked[0] if self.ranked else None

    def decision_for(self, layout: "MeshLayout | str") -> LayoutDecision | None:
        desc = layout if isinstance(layout, str) else layout.descriptor
        for d in self.ranked:
            if d.layout.descriptor == desc:
                return d
        return None

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": {"name": self.shape.name, "seq_len": self.shape.seq_len,
                      "global_batch": self.shape.global_batch,
                      "kind": self.shape.kind},
            "n_devices": self.n_devices,
            "device": self.device,
            "base": dict(self.base),
            "chosen": self.chosen.to_dict() if self.chosen else None,
            "ranked": [d.to_dict() for d in self.ranked],
            "refused": [r.to_dict() for r in self.refused],
            "meta": dict(self.meta),
        }

    def table(self, top: int | None = 10) -> str:
        """Ranked text table with the per-class collective breakdown —
        what ``python -m repro.planner plan`` prints."""
        rows = self.ranked if top is None else self.ranked[:top]
        head = (f"{'#':>3} {'layout':>10} {'phi_ms':>12} {'gamma_mb':>11} "
                f"{'energy_j':>10} {'compute':>10} {'bubble%':>8} "
                f"{'coll_ms':>10} {'repl%':>6}")
        lines = [f"plan {self.arch} × {self.shape.name} on "
                 f"{self.n_devices}× {self.device} "
                 f"(base phi {self.base.get('phi_ms', 0.0):.3f} ms, "
                 f"source {self.base.get('source', '?')})", head]
        for i, d in enumerate(rows):
            b = d.breakdown
            lines.append(
                f"{i:>3} {d.layout.descriptor:>10} {d.phi_ms:>12.4f} "
                f"{d.gamma_mb:>11.1f} {d.energy_j:>10.3f} "
                f"{b.get('compute_ms', 0.0):>10.4f} "
                f"{100 * b.get('bubble', 0.0):>7.1f}% "
                f"{b.get('collective_ms', 0.0):>10.4f} "
                f"{100 * b.get('replicated_fraction', 0.0):>5.1f}%")
        for d in rows:
            per = d.breakdown.get("per_class_ms", {})
            busy = {k: v for k, v in per.items() if v}
            if busy:
                lines.append(
                    f"    {d.layout.descriptor}: " + "  ".join(
                        f"{k}={v:.4f}ms" for k, v in sorted(busy.items())))
        if self.refused:
            lines.append(f"refused {len(self.refused)}:")
            for r in self.refused:
                lines.append(f"    {r.layout.descriptor}: {r.reason}")
        return "\n".join(lines)


class LayoutPlanner:
    """Zero-compile layout search over a :class:`CostEngine`.

    ``engine`` answers the single base query (and is ``None``-able when
    ``base`` pins the anchor costs directly — offline planning from a
    known measurement).  ``device`` defaults to the engine's device and
    supplies the collective coefficient / ici_bw, the HBM capacity used
    for memory refusals, and the fleet-energy multiplier.
    """

    def __init__(self, engine=None, *, device=None, reduced: bool | None = None,
                 base: dict | None = None):
        if engine is None and base is None:
            raise ValueError("LayoutPlanner needs an engine or base costs")
        self.engine = engine
        dev = device
        if dev is None and engine is not None:
            dev = engine.device
        self.device = resolve_device(dev)
        self.reduced = reduced
        self.base = dict(base) if base else None

    # -- the anchor --------------------------------------------------------

    def base_estimate(self, arch: str, shape: ShapeSpec) -> dict:
        """The single-device step cost everything is scaled from: one
        (cacheable) engine query, or the pinned ``base`` dict."""
        if self.base is not None:
            return {"phi_ms": float(self.base.get("phi_ms", 0.0)),
                    "gamma_mb": float(self.base.get("gamma_mb", 0.0)),
                    "energy_j": float(self.base.get("energy_j", 0.0)),
                    "source": self.base.get("source", "pinned")}
        est = self.engine.estimate_one(CostQuery(
            arch=arch, bs=shape.global_batch, seq=shape.seq_len,
            stage="train" if shape.kind == "train" else "infer",
            reduced=self.reduced))
        return {"phi_ms": est.phi_ms, "gamma_mb": est.gamma_mb,
                "energy_j": est.energy_j, "source": est.source}

    # -- the search --------------------------------------------------------

    def plan(
        self,
        arch: str,
        shape: "ShapeSpec | str",
        n_devices: int,
        *,
        cfg: ArchConfig | None = None,
        max_pipe: int | None = None,
        n_micro: int = 8,
        check_memory: bool = True,
    ) -> LayoutPlan:
        """Enumerate, price and rank every (pipe × data × model) layout of
        ``n_devices`` for ``arch × shape``; see the module docstring for
        the pricing model.  ``max_pipe=1`` (what the training launcher
        passes — it has no pipeline schedule) removes the pipe dimension
        at enumeration time; ``check_memory=False`` keeps over-capacity
        layouts ranked instead of refused (capacity planning view)."""
        from repro.campaign.plan import resolve_shape

        shape = resolve_shape(shape)
        if cfg is None:
            cfg = get_config(arch, reduced=bool(self.reduced))
        base = self.base_estimate(arch, shape)
        phi_base = float(base["phi_ms"])
        gamma_base = float(base["gamma_mb"])
        energy_base = float(base["energy_j"])
        dev = self.device
        cap_mb = dev.hbm_bytes / 1e6

        # The 1-device memory split anchors the gamma ratio: the engine's
        # base gamma already includes runtime overheads the analytic split
        # doesn't model, so layouts scale the *measured-or-predicted* base
        # by the *modelled* per-device ratio instead of trusting raw bytes.
        lc1 = layout_collectives(cfg, shape, abstract_mesh((1, 1)), pipe=1)
        mem1 = max(lc1.memory["total_bytes_dev"], 1.0)

        layouts = enumerate_layouts(n_devices, max_pipe=max_pipe)
        ranked: list[LayoutDecision] = []
        refused: list[LayoutRefusal] = []
        for lay in layouts:
            # The shared mesh validator (launch.mesh) vets the spec the
            # layout would build — same error surface as make_mesh.
            validate_mesh_spec(lay.mesh_shape, lay.mesh_axes)
            if shape.global_batch % lay.data:
                refused.append(LayoutRefusal(lay, (
                    f"global batch {shape.global_batch} not divisible by "
                    f"{lay.data}-way data parallelism"),
                    {"global_batch": shape.global_batch, "data": lay.data}))
                continue
            if lay.pipe > 1 and cfg.n_layers % lay.pipe:
                refused.append(LayoutRefusal(lay, (
                    f"layer stack {cfg.n_layers} not divisible into "
                    f"{lay.pipe} pipeline stages"),
                    {"n_layers": cfg.n_layers, "pipe": lay.pipe}))
                continue
            if lay.pipe > 1 and shape.global_batch % (lay.data * lay.pipe):
                refused.append(LayoutRefusal(lay, (
                    f"global batch {shape.global_batch} cannot form "
                    f"microbatches over {lay.data}-way data × "
                    f"{lay.pipe}-stage pipeline"),
                    {"global_batch": shape.global_batch,
                     "data": lay.data, "pipe": lay.pipe}))
                continue

            mesh = abstract_mesh(lay.mesh_shape, lay.mesh_axes)
            lc = layout_collectives(cfg, shape, mesh,
                                    pipe=lay.pipe, n_micro=n_micro)
            r = lc.replicated_fraction
            m = lay.model
            # Amdahl over the model axis: only the (1-r) sharded fraction
            # of the work speeds up M-fold; replicated leaves run whole on
            # every model-axis device.
            model_eff = 1.0 / ((1.0 - r) / m + r) if m > 1 else 1.0
            ideal_ms = phi_base / (lay.data * lay.pipe * model_eff)
            pipe_ms = ideal_ms / max(1.0 - lc.bubble, 1e-9)
            per_class_ms = {
                cls: float(collective_seconds(b, dev)) * 1e3
                for cls, b in lc.per_class.items()
            }
            coll_ms = sum(per_class_ms.values())
            phi_ms = pipe_ms + coll_ms

            mem_ratio = lc.memory["total_bytes_dev"] / mem1
            gamma_mb = gamma_base * mem_ratio
            if check_memory and gamma_mb > cap_mb:
                refused.append(LayoutRefusal(lay, (
                    f"predicted {gamma_mb:.0f} MB/device exceeds "
                    f"{dev.name} capacity {cap_mb:.0f} MB"),
                    {"gamma_mb": gamma_mb, "capacity_mb": cap_mb}))
                continue

            energy_j = (energy_base * phi_ms / phi_base
                        if phi_base > 0 else 0.0)
            ranked.append(LayoutDecision(
                layout=lay, phi_ms=phi_ms, gamma_mb=gamma_mb,
                energy_j=energy_j,
                energy_total_j=energy_j * lay.n_devices,
                breakdown={
                    "compute_ms": ideal_ms,
                    "bubble": lc.bubble,
                    "bubble_ms": pipe_ms - ideal_ms,
                    "collective_ms": coll_ms,
                    "per_class_ms": per_class_ms,
                    "model_efficiency": model_eff,
                    "replicated_fraction": r,
                    "mem_ratio": mem_ratio,
                },
                collectives=lc.to_dict(),
            ))

        ranked.sort(key=lambda d: d.sort_key)
        return LayoutPlan(
            arch=arch, shape=shape, n_devices=int(n_devices),
            device=dev.name, base=base, ranked=ranked, refused=refused,
            meta={
                "n_layouts": len(layouts),
                "n_ranked": len(ranked),
                "n_refused": len(refused),
                "max_pipe": max_pipe,
                "n_micro": int(n_micro),
                "reduced": self.reduced,
                "collective_coeff_fitted": bool(float(
                    (dev.class_coeffs.get("lm_latency") or {})
                    .get("collective", 0.0)) > 0.0),
            })
