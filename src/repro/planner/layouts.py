"""Mesh-layout space: the (pipe × data × model) factorizations of a device
count.

A :class:`MeshLayout` is one candidate placement — ``pipe`` pipeline
stages (outside the GSPMD mesh, splitting the layer stack), ``data``-way
data parallelism and ``model``-way tensor parallelism (the two mesh
axes, model last per the repo-wide ``configs.base.mesh_split``
convention).  :func:`enumerate_layouts` lists every ordered
factorization deterministically; the planner prices them all — pruning
happens by *refusal with a reason* (``planner.LayoutPlanner``), never by
silent omission here.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["MeshLayout", "enumerate_layouts"]


@dataclass(frozen=True, order=True)
class MeshLayout:
    """One (pipe, data, model) parallelism split.  Frozen + ordered so a
    layout list sorts deterministically and works as a dict key."""

    pipe: int
    data: int
    model: int

    def __post_init__(self):
        for f in ("pipe", "data", "model"):
            v = getattr(self, f)
            if not (isinstance(v, int) and v >= 1):
                raise ValueError(f"layout {f} must be an int >= 1, got {v!r}")

    @property
    def n_devices(self) -> int:
        return self.pipe * self.data * self.model

    @property
    def descriptor(self) -> str:
        """``"PxDxM"`` — e.g. the 256-chip production default is
        ``1x16x16`` (no pipeline, 16-way data, 16-way model)."""
        return f"{self.pipe}x{self.data}x{self.model}"

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """The GSPMD mesh dims (model axis last); pipeline stages live
        outside the mesh, so they don't appear here."""
        return (self.data, self.model)

    @property
    def mesh_axes(self) -> tuple[str, str]:
        return ("data", "model")

    @classmethod
    def parse(cls, desc: str) -> "MeshLayout":
        """``"2x4x8"`` → MeshLayout(2, 4, 8); ``"4x8"`` → pipe=1."""
        try:
            dims = tuple(int(x) for x in str(desc).split("x"))
        except ValueError:
            raise ValueError(
                f"bad layout descriptor {desc!r}; expected e.g. '1x16x16'"
            ) from None
        if len(dims) == 2:
            dims = (1,) + dims
        if len(dims) != 3:
            raise ValueError(
                f"bad layout descriptor {desc!r}; expected PxDxM or DxM")
        return cls(*dims)

    def to_dict(self) -> dict:
        return {"pipe": self.pipe, "data": self.data, "model": self.model,
                "descriptor": self.descriptor,
                "mesh_shape": list(self.mesh_shape),
                "mesh_axes": list(self.mesh_axes)}


def _divisors(n: int) -> list[int]:
    out = [d for d in range(1, int(n ** 0.5) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if d * d != n]
    return out


def enumerate_layouts(n_devices: int, *, max_pipe: int | None = None
                      ) -> list[MeshLayout]:
    """Every ordered (pipe, data, model) triple with product ``n_devices``,
    sorted ascending by (pipe, data, model) — byte-identical across
    processes, so two planners over the same inputs rank the same list.

    ``max_pipe`` caps the pipeline factor at *enumeration* time (a caller
    with no pipeline schedule passes 1 and the pipe>1 column never
    exists); divisibility against the workload is NOT checked here — the
    planner prices or refuses each layout with a recorded reason.
    """
    if not (isinstance(n_devices, int) and n_devices >= 1):
        raise ValueError(f"n_devices must be an int >= 1, got {n_devices!r}")
    out = []
    for p in _divisors(n_devices):
        if max_pipe is not None and p > max_pipe:
            continue
        rest = n_devices // p
        for d in _divisors(rest):
            out.append(MeshLayout(pipe=p, data=d, model=rest // d))
    out.sort()
    return out
