"""Planner CLI: rank mesh layouts for an (arch × shape × device count).

    # rank every layout of 256 devices for the production train cell
    PYTHONPATH=src python -m repro.planner plan --arch qwen3-4b \
        --shape train_4k --devices 256 --device tpu_v5e

    # offline: anchor on known base costs instead of running the engine
    PYTHONPATH=src python -m repro.planner plan --arch qwen3-4b \
        --shape train_4k --devices 16 --device tpu_v5e \
        --base-phi-ms 120 --base-gamma-mb 9000 --base-energy-j 18

    # why was a specific layout ranked/refused where it was?
    PYTHONPATH=src python -m repro.planner explain --arch qwen3-4b \
        --shape train_4k --devices 256 --device tpu_v5e --layout 1x16x16
"""

from __future__ import annotations

import argparse
import json

from repro.configs.base import SHAPES, ShapeSpec
from repro.configs.registry import ARCH_IDS
from repro.planner.layouts import MeshLayout
from repro.planner.planner import LayoutPlanner


def _build_planner(args) -> LayoutPlanner:
    if args.base_phi_ms is not None:
        base = {"phi_ms": args.base_phi_ms,
                "gamma_mb": args.base_gamma_mb or 0.0,
                "energy_j": args.base_energy_j or 0.0,
                "source": "cli"}
        return LayoutPlanner(device=args.device, reduced=args.reduced,
                             base=base)
    from repro.engine import (
        AnalyticalBackend,
        CostEngine,
        EnsembleBackend,
        ForestBackend,
        resolve_device,
    )

    device = resolve_device(args.device)
    chain = []
    if args.lm_forest:
        from repro.campaign import LMForest

        chain.append(ForestBackend(lm=LMForest.load(args.lm_forest)))
    chain.append(AnalyticalBackend(reduced=args.reduced, lm_device=device))
    engine = CostEngine(EnsembleBackend(chain), cache=args.estimate_cache,
                        device=device)
    return LayoutPlanner(engine, reduced=args.reduced)


def _shape(args) -> "ShapeSpec | str":
    if args.shape:
        return args.shape
    return ShapeSpec("cli", args.seq, args.batch, args.kind)


def _cmd_plan(args) -> int:
    planner = _build_planner(args)
    plan = planner.plan(args.arch, _shape(args), args.devices,
                        max_pipe=args.max_pipe, n_micro=args.n_micro,
                        check_memory=not args.no_memory_check)
    if args.out:
        from repro.core.fileio import atomic_write_json

        atomic_write_json(args.out, plan.to_dict())
    if args.json:
        print(json.dumps(plan.to_dict(), indent=2))
    else:
        print(plan.table(top=args.top))
    return 0 if plan.chosen is not None else 4  # 4 = nothing runnable


def _cmd_explain(args) -> int:
    planner = _build_planner(args)
    plan = planner.plan(args.arch, _shape(args), args.devices,
                        max_pipe=args.max_pipe, n_micro=args.n_micro,
                        check_memory=not args.no_memory_check)
    lay = MeshLayout.parse(args.layout)
    if lay.n_devices != args.devices:
        print(f"layout {lay.descriptor} uses {lay.n_devices} devices, "
              f"not --devices {args.devices}")
        return 2
    dec = plan.decision_for(lay)
    if dec is not None:
        rank = next(i for i, d in enumerate(plan.ranked)
                    if d.layout == dec.layout)
        print(json.dumps({"rank": rank, "of": len(plan.ranked),
                          "chosen": rank == 0, **dec.to_dict()}, indent=2))
        return 0
    for r in plan.refused:
        if r.layout == lay:
            print(json.dumps({"refused": True, **r.to_dict()}, indent=2))
            return 0
    print(f"layout {lay.descriptor} was not enumerated "
          f"(max_pipe={args.max_pipe})")
    return 2


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.planner")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--arch", required=True, choices=ARCH_IDS)
        p.add_argument("--devices", type=int, required=True,
                       help="device count to factorize into pipe×data×model")
        p.add_argument("--shape", default=None,
                       help=f"named shape ({sorted(SHAPES)} or a smoke "
                            "shape); overrides --seq/--batch/--kind")
        p.add_argument("--seq", type=int, default=4096)
        p.add_argument("--batch", type=int, default=256)
        p.add_argument("--kind", default="train",
                       choices=("train", "prefill", "decode"))
        p.add_argument("--device", default="tpu_v5e",
                       help="device registry name or DeviceSpec path — "
                            "supplies the collective coefficient / ici_bw "
                            "and the HBM capacity for memory refusals")
        p.add_argument("--reduced", action="store_true",
                       help="smoke-scale config (CPU-runnable base query)")
        p.add_argument("--max-pipe", type=int, default=None,
                       help="cap the pipeline factor (1 = no pipelining)")
        p.add_argument("--n-micro", type=int, default=8,
                       help="microbatches per step for the bubble model")
        p.add_argument("--no-memory-check", action="store_true",
                       help="rank over-capacity layouts instead of "
                            "refusing them")
        p.add_argument("--lm-forest", default=None,
                       help="campaign-fitted LM forest: the base query is "
                            "answered with zero compiles")
        p.add_argument("--estimate-cache", default=None)
        p.add_argument("--base-phi-ms", type=float, default=None,
                       help="pin the single-device step latency (engine-"
                            "free offline planning)")
        p.add_argument("--base-gamma-mb", type=float, default=None)
        p.add_argument("--base-energy-j", type=float, default=None)

    p = sub.add_parser("plan", help="rank every layout, print the table")
    common(p)
    p.add_argument("--top", type=int, default=10,
                   help="rows to print (refusals always listed)")
    p.add_argument("--json", action="store_true")
    p.add_argument("--out", default=None,
                   help="also write the full plan as JSON")
    p.set_defaults(fn=_cmd_plan)

    p = sub.add_parser("explain",
                       help="where did one layout rank, and why?")
    common(p)
    p.add_argument("--layout", required=True,
                   help="PxDxM descriptor, e.g. 1x16x16")
    p.set_defaults(fn=_cmd_explain)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
