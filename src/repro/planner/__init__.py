"""Auto-sharding planner: cost-model-driven mesh layout search.

Public surface: enumerate the (pipe × data × model) layout space
(:func:`enumerate_layouts`), price + rank it with zero compiles
(:class:`LayoutPlanner` → :class:`LayoutPlan`), and build the winning
mesh through the shared :func:`repro.launch.mesh.make_mesh` validator.
CLI: ``python -m repro.planner plan|explain``.
"""

from repro.planner.layouts import MeshLayout, enumerate_layouts
from repro.planner.planner import (
    LayoutDecision,
    LayoutPlan,
    LayoutPlanner,
    LayoutRefusal,
)

__all__ = [
    "MeshLayout",
    "enumerate_layouts",
    "LayoutDecision",
    "LayoutRefusal",
    "LayoutPlan",
    "LayoutPlanner",
]
