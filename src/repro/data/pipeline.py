"""Sharding-aware synthetic-token data pipeline.

Deterministic per (seed, step): any host can regenerate any batch, which is
what makes checkpoint-resume and elastic re-sharding exact — the pipeline has
no state beyond the step counter (the same property a production loader gets
from a deterministic index shuffle).

A background prefetch thread keeps ``prefetch`` batches ready so host-side
generation overlaps device compute (the paper's Φ explicitly excludes data
preparation for the same reason — PyTorch overlaps it; §4).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["PipelineConfig", "TokenPipeline", "make_batch"]


@dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # mixture of synthetic "domains" with different token distributions —
    # exercises the data-distribution-shift scenario from the paper's §6.4
    mixture_weights: tuple[float, ...] = (1.0,)


def make_batch(cfg: ArchConfig, shape: ShapeSpec, step: int, seed: int = 0) -> dict:
    """One deterministic batch for (arch, shape, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)}
    if cfg.n_prefix:
        batch["patches"] = rng.standard_normal(
            (B, cfg.n_prefix, cfg.d_model)).astype(np.float32)
    if cfg.n_encoder_layers:
        batch["frames"] = rng.standard_normal(
            (B, cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
    return batch


class TokenPipeline:
    """Iterator of training batches with background prefetch and exact resume.

    ``start_step`` resumes mid-stream; ``set_shardings`` makes ``__next__``
    return committed global jax.Arrays on the mesh.
    """

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, *, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2, shardings=None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = start_step
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, step, self.seed)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> tuple[int, dict]:
        while True:
            step, batch = self._q.get()
            if step < self.step:
                continue  # stale after a resume seek
            self.step = step + 1
            if self.shardings is not None:
                batch = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), batch, self.shardings
                )
            return step, batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
