"""The cost engine: one batched, cacheable prediction front door.

``CostEngine`` wraps any :class:`CostBackend` (usually an
:class:`~repro.engine.backends.EnsembleBackend` chain) with a content-keyed
on-disk estimate cache and an admission helper.  Consumers — the
evolutionary search, the training launcher, benchmarks — talk only to this
class; which backend answered, and whether it came from cache, is carried
in each estimate's ``source`` / ``detail``.
"""

from __future__ import annotations

import hashlib

from repro.engine.cache import EstimateCache
from repro.engine.devices import resolve_device
from repro.engine.types import CostBackend, CostEstimate, CostQuery

__all__ = ["CostEngine", "HealthState"]


class HealthState:
    """Consecutive-failure state machine over a named failover chain.

    The cost engine's backends are *predictors* — when one starts
    throwing real exceptions (not the semantic
    :class:`~repro.engine.types.BackendUnavailable`), the consumer
    should stop asking it, not crash.  ``HealthState`` tracks which link
    of a chain (e.g. ``["forest", "analytical", "static"]``) is
    currently trusted:

    * :meth:`record_failure` at the trusted level steps down one level
      after ``fail_threshold`` *consecutive* failures (the last level —
      conventionally a model-free ``"static"`` degraded mode — is the
      floor: it cannot fail, so the chain never runs out of answers);
    * :meth:`record_success` resets the consecutive counter, and — when
      the success came from a *better* level than the trusted one (a
      probe) — recovers the trusted level upward;
    * :meth:`probe_level` schedules recovery: every ``probe_every``
      calls while degraded, one call is routed through the next-better
      level to test whether it healed.

    The serve failover chain (``repro.serve.health.FailoverChain``)
    drives this; :meth:`metrics` is what the engine surfaces per step so
    benches and tests assert on failovers/recoveries instead of
    log-scraping.
    """

    def __init__(self, levels: "list[str] | tuple[str, ...]", *,
                 fail_threshold: int = 3, probe_every: int = 8):
        if not levels:
            raise ValueError("empty health chain")
        self.levels = [str(x) for x in levels]
        self.fail_threshold = max(1, int(fail_threshold))
        self.probe_every = max(1, int(probe_every))
        self.level = 0
        self.consecutive = 0
        self.calls = 0
        self.failovers = 0
        self.recoveries = 0
        self.probes = 0
        self.last_error: str | None = None

    @property
    def current(self) -> str:
        return self.levels[self.level]

    @property
    def degraded(self) -> bool:
        """At the chain floor (no model-backed level left)."""
        return len(self.levels) > 1 and self.level == len(self.levels) - 1

    def record_success(self, level: int | None = None) -> None:
        if level is not None and level < self.level:
            self.level = level           # successful probe: recover
            self.recoveries += 1
        if level is None or level <= self.level:
            # A success at a *worse* level than the trusted one doesn't
            # reset the count — the trusted level is still failing, and
            # absolving it here would keep every call paying its crash.
            self.consecutive = 0

    def record_failure(self, err: "BaseException | str | None" = None) -> bool:
        """Count one failure at the trusted level; returns True when it
        tripped a step-down."""
        if err is not None:
            self.last_error = (f"{type(err).__name__}: {err}"
                               if isinstance(err, BaseException) else str(err))
        self.consecutive += 1
        if (self.consecutive >= self.fail_threshold
                and self.level < len(self.levels) - 1):
            self.level += 1
            self.consecutive = 0
            self.failovers += 1
            return True
        return False

    def probe_level(self) -> int | None:
        """Level to try this call instead of the trusted one, or None.
        Advances the call counter; while degraded below level 0, every
        ``probe_every``-th call probes one level up."""
        self.calls += 1
        if self.level > 0 and self.calls % self.probe_every == 0:
            self.probes += 1
            return self.level - 1
        return None

    def metrics(self) -> dict:
        return {
            "level": self.level,
            "current": self.current,
            "degraded": self.degraded,
            "failovers": self.failovers,
            "recoveries": self.recoveries,
            "probes": self.probes,
            "consecutive_failures": self.consecutive,
            "last_error": self.last_error,
        }


class CostEngine:
    """Cache-first front door over a backend.

    Cache keys are the query's content hash salted with the backend's
    ``cache_salt()`` (fitted-model content hash, hardware table, reduced
    flag, …), so estimates from a refit predictor or a different backend
    configuration never alias on disk.

    ``flush_every`` amortizes disk writes: the JSON cache is rewritten
    atomically once at least that many new estimates have accumulated
    (and always at the end of the ``estimate`` call that crossed the
    threshold).  The default of 1 flushes after every miss batch —
    maximum durability; raise it for cheap-to-recompute backends in hot
    search loops and call :meth:`flush` at the end.
    """

    def __init__(self, backend: CostBackend, cache: EstimateCache | str | None = None,
                 *, flush_every: int = 1, device=None):
        self.backend = backend
        self.cache = EstimateCache(cache) if isinstance(cache, str) else cache
        self.flush_every = max(1, int(flush_every))
        # Optional engine-level device: an extra salt over the backend's own
        # (so two engines serving different devices through one device-less
        # backend never alias), and the default admission capacity.
        self.device = resolve_device(device) if device is not None else None
        self.hits = 0
        self.misses = 0
        self._pending = 0

    def _salt(self) -> str:
        # Recomputed per batch, NOT memoized: a refit predictor must change
        # the salt (the expensive part — the forest content hash — is
        # memoized per packing on the forest itself).
        salt_fn = getattr(self.backend, "cache_salt", None)
        salt = salt_fn() if callable(salt_fn) else self.backend.name
        if self.device is not None:
            salt = f"{salt}@{self.device.fingerprint()}"
        return salt

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]:
        """Answer a batch of queries: cache first, then ONE batched backend
        call for all misses, then (at most) a single atomic cache flush."""
        results: list[CostEstimate | None] = [None] * len(queries)
        miss_idx: list[int] = []
        if self.cache is not None:
            salt = self._salt()
            keys = [
                hashlib.sha1(f"{q.key}|{salt}".encode()).hexdigest()
                for q in queries
            ]
        else:
            keys = None
        for i, q in enumerate(queries):
            cached = self.cache.get(keys[i]) if keys is not None else None
            if cached is not None:
                cached.detail = dict(cached.detail, cached=True)
                results[i] = cached
                self.hits += 1
            else:
                miss_idx.append(i)
        if miss_idx:
            self.misses += len(miss_idx)
            fresh = self.backend.estimate([queries[i] for i in miss_idx])
            for i, est in zip(miss_idx, fresh):
                results[i] = est
                if keys is not None:
                    self.cache.put(keys[i], est)
            if self.cache is not None:
                self._pending += len(miss_idx)
                if self._pending >= self.flush_every:
                    self.flush()
        return results

    def flush(self) -> None:
        if self.cache is not None and self._pending:
            self.cache.flush()
            self._pending = 0

    def estimate_one(self, query: CostQuery) -> CostEstimate:
        return self.estimate([query])[0]

    def estimate_requests(
        self,
        arch: str,
        lens: list[int],
        *,
        stage: str = "infer",
        reduced: bool = False,
        bs: int = 1,
        bucket: int = 64,
    ) -> list[CostEstimate]:
        """One estimate per serving request, bucketed to stay cacheable.

        Ragged request lengths would make every admission a distinct
        query; rounding each length up to a ``bucket`` multiple collapses
        them onto a handful of (bs, seq) cells, so a serving scheduler
        pricing thousands of arrivals issues (and caches) only as many
        backend calls as there are occupied buckets.  Estimates fan back
        out in request order.
        """
        bucket = max(1, int(bucket))
        seqs = [max(bucket, -(-int(L) // bucket) * bucket) for L in lens]
        uniq = sorted(set(seqs))
        ests = self.estimate([
            CostQuery(arch=arch, bs=bs, seq=s, stage=stage, reduced=reduced)
            for s in uniq
        ])
        by_seq = dict(zip(uniq, ests))
        return [by_seq[s] for s in seqs]

    def admit(
        self,
        query: CostQuery,
        *,
        gamma_budget_mb: float | None = None,
        phi_budget_ms: float | None = None,
        energy_budget_j: float | None = None,
        safety_margin: float = 0.1,
    ) -> tuple[bool, dict]:
        """Admission gate (paper §6.4 safety property), backend-agnostic:
        refuse when the predicted footprint/latency/step-energy, inflated
        by ``safety_margin``, exceeds the budget.  With an engine-level
        device and no explicit memory budget, the device's capacity is the
        budget.
        """
        if gamma_budget_mb is None and self.device is not None:
            gamma_budget_mb = self.device.hbm_bytes / 1e6
        est = self.estimate_one(query)
        g_eff = est.gamma_mb * (1 + safety_margin)
        p_eff = est.phi_ms * (1 + safety_margin)
        e_eff = est.energy_j * (1 + safety_margin)
        ok = not (
            (gamma_budget_mb is not None and g_eff > gamma_budget_mb)
            or (phi_budget_ms is not None and p_eff > phi_budget_ms)
            or (energy_budget_j is not None and e_eff > energy_budget_j)
        )
        return ok, {"gamma_mb": est.gamma_mb, "phi_ms": est.phi_ms,
                    "energy_j": est.energy_j,
                    "gamma_eff": g_eff, "phi_eff": p_eff,
                    "energy_eff": e_eff, "source": est.source}
