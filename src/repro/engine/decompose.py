"""The train-step compute/byte decomposition — single source of truth.

The calibration fit (``engine/calibrate.py``) solves for device constants
over exactly these regressors, and the analytical prediction path
(``engine/backends.AnalyticalBackend``) multiplies the same regressors by
the fitted constants.  They MUST stay byte-identical: a drift between the
two (e.g. one side changing what counts as "bytes moved") silently skews
every calibrated prediction with nothing failing loudly.  Hence one
module, consumed by both.

All functions take a ``(N, F)`` Appendix-B feature matrix (rows =
workloads, columns = ``core.features.FEATURE_NAMES`` order, train stage)
and return per-row arrays.
"""

from __future__ import annotations

import numpy as np

from repro.core.features import FEATURE_NAMES

__all__ = ["latency_terms", "memory_terms", "lm_roofline_terms"]

_I_W = FEATURE_NAMES.index("mem_w")
_I_IFM = FEATURE_NAMES.index("mem_ifm_grad")
_I_OFM = FEATURE_NAMES.index("mem_ofm_grad")
_I_ALLOC = FEATURE_NAMES.index("mem_alloc_total")
_I_OPS = FEATURE_NAMES.index("mm_ops_sum")
_I_I2C = FEATURE_NAMES.index("mm_i2c_total_sum")


def latency_terms(feats: np.ndarray, bytes_per_el: int) -> tuple[np.ndarray, np.ndarray]:
    """(flops, bytes_moved) per training-step workload: FLOPs are 2× the
    fwd+bwd MAC count; traffic is the allocation total plus the im2col
    lowering volume."""
    F = np.atleast_2d(np.asarray(feats, dtype=np.float64))
    flops = 2.0 * F[:, _I_OPS]
    bytes_moved = bytes_per_el * (F[:, _I_ALLOC] + F[:, _I_I2C])
    return flops, bytes_moved


def lm_roofline_terms(
    flops, hbm_bytes, collective_bytes, device
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """LM-cell analogue of :func:`latency_terms`: the three roofline seconds
    (compute, memory, collective) a device spec turns HLO-parse counts into.

    The SAME single-source-of-truth contract as the CNN terms above: the
    analytical prediction path (``backends.AnalyticalBackend``), the
    campaign featurizer (``campaign/lm_features.py``) and the parse_hlo_cost
    constant fit (``campaign/fit.py``) all divide by the same denominators,
    so fitted device constants transfer between all three.  Inputs may be
    scalars or arrays; outputs follow numpy broadcasting.
    """
    flops = np.asarray(flops, dtype=np.float64)
    hbm_bytes = np.asarray(hbm_bytes, dtype=np.float64)
    collective_bytes = np.asarray(collective_bytes, dtype=np.float64)
    return (flops / device.peak_flops, hbm_bytes / device.hbm_bw,
            collective_bytes / device.ici_bw)


def memory_terms(feats: np.ndarray, bytes_per_el: int) -> tuple[np.ndarray, np.ndarray]:
    """(weight_bytes, activation_bytes) per training-step workload — the
    two allocation families whose per-device scales the memory fit solves
    for (weights scale with optimizer/grad copies, activations with batch)."""
    F = np.atleast_2d(np.asarray(feats, dtype=np.float64))
    weight_bytes = bytes_per_el * F[:, _I_W]
    act_bytes = bytes_per_el * (F[:, _I_IFM] + F[:, _I_OFM])
    return weight_bytes, act_bytes
