"""The train-step compute/byte decomposition — single source of truth.

The calibration fit (``engine/calibrate.py``) solves for device constants
over exactly these regressors, and the analytical prediction path
(``engine/backends.AnalyticalBackend``) multiplies the same regressors by
the fitted constants.  They MUST stay byte-identical: a drift between the
two (e.g. one side changing what counts as "bytes moved") silently skews
every calibrated prediction with nothing failing loudly.  Hence one
module, consumed by both.

All functions take a ``(N, F)`` Appendix-B feature matrix (rows =
workloads, columns = ``core.features.FEATURE_NAMES`` order, train stage)
and return per-row arrays.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core.features import FEATURE_NAMES
from repro.costmodel import OP_CLASSES, CostLedger

__all__ = [
    "latency_terms",
    "memory_terms",
    "lm_roofline_terms",
    "energy_terms",
    "watts_proxy",
    "price_ledger_energy",
    "cnn_energy_class_joules",
    "CNN_LATENCY_COLUMNS",
    "latency_class_columns",
    "LM_LATENCY_COLUMNS",
    "ledger_latency_columns",
    "classwise_seconds",
    "collective_seconds",
]

_I_W = FEATURE_NAMES.index("mem_w")
_I_IFM = FEATURE_NAMES.index("mem_ifm_grad")
_I_OFM = FEATURE_NAMES.index("mem_ofm_grad")
_I_ALLOC = FEATURE_NAMES.index("mem_alloc_total")
_I_OPS = FEATURE_NAMES.index("mm_ops_sum")
_I_I2C = FEATURE_NAMES.index("mm_i2c_total_sum")


def latency_terms(feats: np.ndarray, bytes_per_el: int) -> tuple[np.ndarray, np.ndarray]:
    """(flops, bytes_moved) per training-step workload: FLOPs are 2× the
    fwd+bwd MAC count; traffic is the allocation total plus the im2col
    lowering volume."""
    F = np.atleast_2d(np.asarray(feats, dtype=np.float64))
    flops = 2.0 * F[:, _I_OPS]
    bytes_moved = bytes_per_el * (F[:, _I_ALLOC] + F[:, _I_I2C])
    return flops, bytes_moved


def lm_roofline_terms(
    flops, hbm_bytes, collective_bytes, device
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """LM-cell analogue of :func:`latency_terms`: the three roofline seconds
    (compute, memory, collective) a device spec turns HLO-parse counts into.

    The SAME single-source-of-truth contract as the CNN terms above: the
    analytical prediction path (``backends.AnalyticalBackend``), the
    campaign featurizer (``campaign/lm_features.py``) and the parse_hlo_cost
    constant fit (``campaign/fit.py``) all divide by the same denominators,
    so fitted device constants transfer between all three.  Inputs may be
    scalars or arrays; outputs follow numpy broadcasting.
    """
    flops = np.asarray(flops, dtype=np.float64)
    hbm_bytes = np.asarray(hbm_bytes, dtype=np.float64)
    collective_bytes = np.asarray(collective_bytes, dtype=np.float64)
    return (flops / device.peak_flops, hbm_bytes / device.hbm_bw,
            collective_bytes / device.ici_bw)


# ---------------------------------------------------------------------------
# Energy (PowerTrain-style: board power = idle + dynamic × utilisation).
#
# The dynamic energy of a roofline phase is its busy time × the device's
# dynamic power range, so every term below is an existing latency term
# multiplied by ``dynamic_w`` — the energy decomposition inherits the
# latency decomposition's single-source-of-truth contract for free, and
# per-class energy re-sums to the aggregate exactly like the latency
# columns do.  The static term (``idle_w × phi``) is per-step, kept
# separate from the per-op dynamic terms.
# ---------------------------------------------------------------------------


def energy_terms(flops, hbm_bytes, phi_s, device, collective_bytes=0.0
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(static_j, compute_j, memory_j, collective_j) per workload row.

    ``static_j = idle_w × phi_s`` (whatever the step's wall time is —
    measured or predicted); the dynamic terms are the roofline busy
    seconds × ``dynamic_w``.  A zero-watt envelope returns all zeros."""
    dyn = device.dynamic_w
    static_j = device.idle_w * np.asarray(phi_s, dtype=np.float64)
    compute_s, memory_s, coll_s = lm_roofline_terms(
        flops, hbm_bytes, collective_bytes, device)
    return static_j, dyn * compute_s, dyn * memory_s, dyn * coll_s


def watts_proxy(flops, phi_s, device) -> np.ndarray:
    """Modelled average board draw of a measured step: idle plus the
    dynamic range scaled by compute-roofline utilisation (busy seconds /
    measured wall seconds, clamped to 1).  The campaign runner records
    this per cell and calibration uses it as the energy ground-truth
    proxy when no power rail was sampled."""
    compute_s = np.asarray(flops, dtype=np.float64) / device.peak_flops
    phi = np.asarray(phi_s, dtype=np.float64)
    util = np.where(phi > 0.0,
                    np.minimum(1.0, compute_s / np.maximum(phi, 1e-300)),
                    0.0)
    return device.idle_w + device.dynamic_w * util


def price_ledger_energy(ledger: CostLedger, device) -> CostLedger:
    """A copy of ``ledger`` with every record's dynamic energy stamped:
    ``energy_j = flops·(dyn/peak) + hbm·(dyn/bw) + coll·(dyn/ici)``.

    Parity contract (same as flops/bytes): the per-class energy sums of
    the returned ledger re-sum to its aggregate ``energy_j`` —
    bit-identically when the envelope constants are powers of two
    (tested), within 1e-9 relative otherwise (bench-gated)."""
    dyn = device.dynamic_w
    kf = dyn / device.peak_flops
    kb = dyn / device.hbm_bw
    kc = dyn / device.ici_bw
    return CostLedger([
        replace(r, energy_j=(r.flops * kf + r.hbm_bytes * kb
                             + r.collective_bytes * kc))
        for r in ledger
    ])


def cnn_energy_class_joules(feats: np.ndarray, bytes_per_el: int, device
                            ) -> dict[str, np.ndarray]:
    """Per-class dynamic energy of a CNN training step, keyed by op class
    (``matmul``/``elementwise``/``data_movement``).  The values sum to the
    aggregate dynamic energy of :func:`energy_terms` because the underlying
    latency class columns sum to the aggregate terms."""
    cols = latency_class_columns(feats, bytes_per_el)
    kf = device.dynamic_w / device.peak_flops
    kb = device.dynamic_w / device.hbm_bw
    return {
        "matmul": cols["flops_matmul"] * kf,
        "elementwise": cols["hbm_elementwise"] * kb,
        "data_movement": cols["hbm_data_movement"] * kb,
    }


# ---------------------------------------------------------------------------
# Class-wise columns (the per-op cost ledger refactor).
#
# The class-wise NNLS fits (engine/calibrate.calibrate, campaign/fit.
# fit_hlo_constants) solve for one coefficient per column below, and the
# class-wise prediction paths multiply the SAME columns by the fitted
# ``DeviceSpec.class_coeffs`` — the identical single-source-of-truth
# contract the aggregate terms above carry.  Two invariants, both tested:
#
#   sum over flops columns  == the aggregate flops term
#   sum over byte columns   == the aggregate bytes_moved / hbm_bytes term
#
# so the aggregate fit is exactly the class-wise fit with tied
# coefficients, and a class-wise solution can never *lose* information.
# ---------------------------------------------------------------------------

# CNN (Appendix-B feature) decomposition: all MACs are conv-lowered matmul
# work; traffic splits into the allocation families (elementwise streaming)
# and the im2col lowering volume (pure data movement).
CNN_LATENCY_COLUMNS: tuple[str, ...] = (
    "flops_matmul", "hbm_elementwise", "hbm_data_movement",
)


def latency_class_columns(feats: np.ndarray, bytes_per_el: int
                          ) -> dict[str, np.ndarray]:
    """Per-class latency regressor columns (``CNN_LATENCY_COLUMNS`` order)
    for training-step workloads.  ``flops_matmul`` equals the aggregate
    flops term; the two byte columns sum to the aggregate bytes_moved."""
    F = np.atleast_2d(np.asarray(feats, dtype=np.float64))
    return {
        "flops_matmul": 2.0 * F[:, _I_OPS],
        "hbm_elementwise": float(bytes_per_el) * F[:, _I_ALLOC],
        "hbm_data_movement": float(bytes_per_el) * F[:, _I_I2C],
    }


# LM (HLO ledger) decomposition: one flops + one bytes column per op class,
# plus the total collective traffic.
LM_LATENCY_COLUMNS: tuple[str, ...] = tuple(
    [f"flops_{cls}" for cls in OP_CLASSES]
    + [f"hbm_{cls}" for cls in OP_CLASSES]
    + ["collective"]
)


def ledger_latency_columns(class_sums) -> dict[str, np.ndarray]:
    """(``LM_LATENCY_COLUMNS`` name → per-row array) from per-ledger class
    sums.

    ``class_sums`` is a list whose entries are either
    :class:`~repro.costmodel.CostLedger` instances or the
    ``CostLedger.class_sums()`` dicts campaign records persist
    (``cost_classes``) — one entry per workload row."""
    sums = [cs.class_sums() if isinstance(cs, CostLedger) else (cs or {})
            for cs in class_sums]
    cols: dict[str, np.ndarray] = {}
    for cls in OP_CLASSES:
        cols[f"flops_{cls}"] = np.array(
            [s.get(cls, {}).get("flops", 0.0) for s in sums], dtype=np.float64)
        cols[f"hbm_{cls}"] = np.array(
            [s.get(cls, {}).get("hbm_bytes", 0.0) for s in sums],
            dtype=np.float64)
    cols["collective"] = np.array(
        [sum(s.get(cls, {}).get("collective_bytes", 0.0) for cls in s)
         for s in sums], dtype=np.float64)
    return cols


def classwise_seconds(columns: dict, coeffs: dict) -> np.ndarray:
    """Seconds under class-wise fitted constants: the coefficients'
    ``_intercept`` plus Σ coeff × column over the shared column names.
    Columns absent from ``coeffs`` (classes the fit zeroed or never saw)
    contribute nothing — exactly how the NNLS treated them."""
    total = np.asarray(float(coeffs.get("_intercept", 0.0)), dtype=np.float64)
    for name, col in columns.items():
        c = coeffs.get(name, 0.0)
        if c:
            total = total + c * np.asarray(col, dtype=np.float64)
    return total


def collective_seconds(collective_bytes, device) -> np.ndarray:
    """Seconds a device spends moving ``collective_bytes`` of collective
    traffic — the planner's layout-pricing bridge.

    When the device carries campaign-fitted class-wise constants
    (``class_coeffs["lm_latency"]["collective"]``, grown by
    ``campaign.fit.fit_hlo_constants`` from >1-device measurements), the
    fitted coefficient prices the bytes — the SAME column the NNLS solved
    over, so a layout's collective term and a measured cell's agree by
    construction.  Without a fitted coefficient the roofline denominator
    (``ici_bw``, the third :func:`lm_roofline_terms` term) is the
    documented fallback."""
    b = np.asarray(collective_bytes, dtype=np.float64)
    coeffs = device.class_coeffs.get("lm_latency") or {}
    c = float(coeffs.get("collective", 0.0))
    if c > 0.0:
        return c * b
    return b / device.ici_bw


def memory_terms(feats: np.ndarray, bytes_per_el: int) -> tuple[np.ndarray, np.ndarray]:
    """(weight_bytes, activation_bytes) per training-step workload — the
    two allocation families whose per-device scales the memory fit solves
    for (weights scale with optimizer/grad copies, activations with batch)."""
    F = np.atleast_2d(np.asarray(feats, dtype=np.float64))
    weight_bytes = bytes_per_el * F[:, _I_W]
    act_bytes = bytes_per_el * (F[:, _I_IFM] + F[:, _I_OFM])
    return weight_bytes, act_bytes
