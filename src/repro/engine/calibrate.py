"""Fit a :class:`DeviceSpec` from profiler ground truth (paper §5/§6).

perf4sight's accuracy claims rest on per-device fitting: the toolflow
profiles a small (network × batch) grid on the target device, then fits
the model to that ground truth.  This module is the analytical-model
analogue — instead of training a forest, it solves for the handful of
hardware constants the closed forms need:

    phi_s    = launch_overhead_s + flops / peak_flops + bytes / hbm_bw
    gamma_mb = mem_base_mb + mem_weight_scale * weight_mb
                           + mem_act_scale   * activation_mb

Both are linear in the unknowns (1/peak_flops, 1/hbm_bw, the scales), with
all coefficients physically nonnegative, so the fit is a nonnegative least
squares over the per-workload compute/byte decomposition that
``core/features`` already produces (the same decomposition
``core/roofline.py`` and ``core/hlo_cost.py`` feed the LM path).  The
additive latency form is the standard relaxation of the roofline ``max``;
the fitted spec records it via ``combine="sum"``.

Ground truth comes from :class:`~repro.engine.backends.ProfilerBackend`,
consulted through a :class:`~repro.core.dataset.DatasetCache` so repeated
calibrations (and the golden accuracy tests) reuse profiled datapoints
instead of re-running compile-heavy steps.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.dataset import DatasetCache, Datapoint
from repro.core.features import network_features
from repro.engine.devices import DeviceSpec, resolve_device
from repro.engine.types import STAGE_TRAIN, CostQuery

__all__ = [
    "CalibrationWorkload",
    "default_workloads",
    "measure_ground_truth",
    "nnls",
    "timed_tuning_rows",
    "calibrate",
    "evaluate_accuracy",
]

@dataclass(frozen=True)
class CalibrationWorkload:
    """One cell of the calibration grid — the same coordinates as a
    :class:`~repro.core.dataset.Datapoint`, so profiled ground truth is
    shared with the data-collection caches (``benchmarks/cache/*.json``)."""

    family: str
    level: float
    bs: int
    strategy: str = "random"
    width_mult: float = 0.25
    input_hw: int = 16
    seed: int = 0

    @property
    def key(self) -> str:
        return (
            f"{self.family}|l={self.level:.2f}|s={self.strategy}|bs={self.bs}"
            f"|wm={self.width_mult}|hw={self.input_hw}|seed={self.seed}"
        )

    def build_model(self):
        from repro.core.dataset import GridSpec, _build_pruned

        grid = GridSpec(self.family, (self.level,), self.strategy, (self.bs,),
                        self.width_mult, self.input_hw, self.seed)
        return _build_pruned(grid, self.level)


def default_workloads(
    families: tuple[str, ...] = ("squeezenet",),
    levels: tuple[float, ...] = (0.0, 0.30, 0.50),
    batch_sizes: tuple[int, ...] = (2, 8, 16, 32),
    **kw,
) -> list[CalibrationWorkload]:
    """Small (network × pruning level × batch) grid: a few topologies spanning
    the footprint range, each profiled across batch sizes, so both fits see
    variation in the batch-dependent and batch-independent terms."""
    return [
        CalibrationWorkload(family=f, level=l, bs=b, **kw)
        for f in families for l in levels for b in batch_sizes
    ]


def nnls(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Nonnegative least squares, numpy-only (tier-1 runs without scipy).

    Lawson–Hanson active-set method: variables enter the passive (free) set
    by largest positive gradient and can LEAVE it again on a blocking step,
    so the returned point satisfies the NNLS KKT conditions — a
    remove-only clamp can permanently drop a variable (e.g. zero out the
    launch-overhead intercept) and silently return a worse fit.  The
    calibration systems are tiny (≤4 columns); this converges in a handful
    of iterations.
    """
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = A.shape[1]
    # Column scaling: the columns span ~15 orders of magnitude (counts of
    # FLOPs vs a constant 1), so solve in normalized coordinates.
    scale = np.linalg.norm(A, axis=0)
    scale[scale == 0] = 1.0
    An = A / scale
    x = np.zeros(n)
    passive = np.zeros(n, dtype=bool)
    w = An.T @ b
    tol = 1e-12 * max(float(np.abs(w).max()), 1.0)
    for _ in range(3 * n + 10):
        if passive.all() or (w[~passive] <= tol).all():
            break
        free = np.flatnonzero(~passive)
        passive[free[np.argmax(w[free])]] = True
        while True:
            s = np.zeros(n)
            s[passive], *_ = np.linalg.lstsq(An[:, passive], b, rcond=None)
            if (s[passive] > 0).all():
                x = s
                break
            # blocking step: walk toward s until the first passive variable
            # hits zero, then release it back to the active set
            blocking = passive & (s <= 0)
            alpha = np.min(x[blocking] / (x[blocking] - s[blocking]))
            x = x + alpha * (s - x)
            passive &= x > tol
            x[~passive] = 0.0
            if not passive.any():
                break
        w = An.T @ (b - An @ x)
    return x / scale


def measure_ground_truth(profiler, workloads, cache: DatasetCache | None = None,
                         stage: str = STAGE_TRAIN) -> tuple[list[Datapoint], int]:
    """Ground truth per workload: cached datapoint when available, otherwise
    one ProfilerBackend run (written back to the cache).  Returns
    ``(datapoints, n_profiled_live)``.  Callers that also want to score a
    backend against the same grid (``evaluate_accuracy``) should measure
    once here and pass ``datapoints=`` to :func:`calibrate` rather than
    letting it re-measure."""
    dps: list[Datapoint] = []
    profiled = 0
    for w in workloads:
        dp = cache.get(w.key) if cache is not None else None
        if dp is None:
            model = w.build_model()
            est = profiler.estimate(
                [CostQuery(spec=model.conv_specs(), bs=w.bs, stage=stage,
                           model=model)])[0]
            dp = Datapoint(
                family=w.family, level=w.level, strategy=w.strategy, bs=w.bs,
                width_mult=w.width_mult, input_hw=w.input_hw, seed=w.seed,
                gamma_mb=est.gamma_mb, phi_ms=est.phi_ms,
                features=[float(v) for v in
                          network_features(model.conv_specs(), w.bs)],
            )
            profiled += 1
            if cache is not None:
                cache.put(dp)
                cache.flush()
        if not dp.features:
            dp.features = [float(v) for v in network_features(
                w.build_model().conv_specs(), w.bs)]
        dps.append(dp)
    return dps, profiled


def _decompose(dps: list[Datapoint], bytes_per_el: int):
    """Per-workload (flops, bytes_moved, weight_mb, act_mb) + measured
    targets — the regressors of the two NNLS systems, produced by the SAME
    ``engine/decompose.py`` terms the analytical prediction path multiplies
    the fitted constants against — plus the per-op-class latency columns
    (``decompose.latency_class_columns``) the class-wise fit refines the
    aggregate terms into."""
    from repro.engine.decompose import (
        latency_class_columns,
        latency_terms,
        memory_terms,
    )

    F = np.array([dp.features for dp in dps], dtype=np.float64)
    flops, bytes_moved = latency_terms(F, bytes_per_el)
    weight_bytes, act_bytes = memory_terms(F, bytes_per_el)
    cols = latency_class_columns(F, bytes_per_el)
    phi_s = np.array([dp.phi_ms for dp in dps]) / 1e3
    gamma_mb = np.array([dp.gamma_mb for dp in dps])
    return (flops, bytes_moved, weight_bytes / 1e6, act_bytes / 1e6, phi_s,
            gamma_mb, cols)


def _mape(pred: np.ndarray, true: np.ndarray) -> float:
    return float(np.mean(np.abs(pred - true) / np.maximum(np.abs(true), 1e-12)))


def timed_tuning_rows(tuning_cache) -> tuple[np.ndarray, np.ndarray]:
    """Extra latency-fit rows from wall-clock-timed autotuner winners.

    Every ``source:"timed"`` :class:`~repro.kernels.autotune.TuningCache`
    entry is a measured (kernel launch → seconds) datapoint the device paid
    for anyway during tuning; its tiling model rebuilds the (flops, bytes)
    decomposition from the stored launch shape, giving the NNLS system
    single-kernel rows alongside the whole-training-step workloads.  Those
    rows pin down the roofline denominators at a granularity the step-level
    grid can't (one kernel = one dominant term), which is why the tuner
    feeds its measurements back here instead of discarding them.

    Returns ``(A_rows, phi_s)`` with columns matching the latency system
    ``[1, flops, bytes_moved]``; empty arrays when the cache has no timed
    entries (the model-ranked path stores ``source:"model"``).
    """
    from repro.kernels.autotune import get_tiling

    rows, phi = [], []
    for entry in tuning_cache.entries():
        if entry.get("source") != "timed" or not entry.get("measured_us"):
            continue
        shape = entry.get("shape")
        if not shape:
            continue  # pre-shape-stamping cache entry: nothing to rebuild
        try:
            cost = get_tiling(entry["kernel"]).cost(shape, entry["config"])
        except KeyError:
            continue  # tiling module no longer registered
        rows.append([1.0, cost.flops, cost.hbm_bytes])
        phi.append(entry["measured_us"] * 1e-6)
    if not rows:
        return np.zeros((0, 3)), np.zeros(0)
    return np.asarray(rows, dtype=np.float64), np.asarray(phi, dtype=np.float64)


def calibrate(
    backend,
    profiler,
    workloads: list[CalibrationWorkload],
    *,
    cache: DatasetCache | str | None = None,
    datapoints: list[Datapoint] | None = None,
    tuning_cache=None,
    name: str | None = None,
    apply: bool = True,
) -> DeviceSpec:
    """Fit the backend's device constants against profiler ground truth.

    Runs ``workloads`` through ``profiler`` (cache-first), solves the two
    NNLS systems over the per-workload compute/byte decomposition, and
    returns a ``calibrated=True`` :class:`DeviceSpec` seeded from the
    backend's current device (capacity/interconnect/granularity carry
    over).  Callers that already measured the grid (via
    :func:`measure_ground_truth`) pass it as ``datapoints`` and no
    re-measurement happens.  A ``tuning_cache``
    (:class:`~repro.kernels.autotune.TuningCache`) contributes its
    wall-clock-timed autotuner winners as extra latency rows
    (:func:`timed_tuning_rows`).  With ``apply=True`` (default) the backend
    is switched to the fitted spec in place — its ``cache_salt()`` changes
    with it, so engine caches never serve pre-calibration estimates
    afterwards.
    """
    if len(datapoints if datapoints is not None else workloads) < 3:
        raise ValueError("calibration needs >= 3 workloads to fit 3 constants")
    if isinstance(cache, str):
        cache = DatasetCache(cache)
    base = resolve_device(getattr(backend, "device", None))
    bytes_per_el = getattr(backend, "bytes_per_el", 4)

    if datapoints is not None:
        dps, profiled = datapoints, 0
    else:
        dps, profiled = measure_ground_truth(profiler, workloads, cache,
                                             STAGE_TRAIN)
    flops, bytes_moved, weight_mb, act_mb, phi_s, gamma_mb, cols = _decompose(
        dps, bytes_per_el)

    from repro.engine.decompose import CNN_LATENCY_COLUMNS

    # Latency, aggregate: phi = c0 + c1·flops + c2·bytes, c ≥ 0 — and the
    # class-wise refinement over the same workloads: one coefficient per
    # decompose.CNN_LATENCY_COLUMNS column.  The aggregate system is the
    # class-wise one with tied byte coefficients, so the class-wise fit can
    # only match or improve the training error; whichever achieves the
    # lower MAPE is applied (the aggregate fallback keeps old behaviour
    # when the split carries no signal).
    ones = np.ones_like(phi_s)
    A_lat = np.stack([ones, flops, bytes_moved], axis=1)
    A_cls = np.stack([ones] + [cols[n] for n in CNN_LATENCY_COLUMNS], axis=1)
    b_lat = phi_s
    n_timed = 0
    if tuning_cache is not None:
        A_timed, phi_timed = timed_tuning_rows(tuning_cache)
        n_timed = len(phi_timed)
        if n_timed:
            A_lat = np.concatenate([A_lat, A_timed])
            # Kernel launches are matmul-class compute streaming its
            # operands: flops → flops_matmul, bytes → hbm_elementwise.
            A_timed_cls = np.zeros((n_timed, A_cls.shape[1]))
            A_timed_cls[:, 0] = A_timed[:, 0]
            A_timed_cls[:, 1 + CNN_LATENCY_COLUMNS.index("flops_matmul")] = \
                A_timed[:, 1]
            A_timed_cls[:, 1 + CNN_LATENCY_COLUMNS.index("hbm_elementwise")] = \
                A_timed[:, 2]
            A_cls = np.concatenate([A_cls, A_timed_cls])
            b_lat = np.concatenate([b_lat, phi_timed])
    c = nnls(A_lat, b_lat)
    c_cls = nnls(A_cls, b_lat)
    n_work = len(phi_s)
    phi_mape_agg = _mape(A_lat[:n_work] @ c, phi_s)
    phi_mape_cls = _mape(A_cls[:n_work] @ c_cls, phi_s)
    use_classwise = phi_mape_cls <= phi_mape_agg
    class_coeffs = dict(base.class_coeffs)
    class_coeffs.pop("cnn_latency", None)
    if use_classwise:
        class_coeffs["cnn_latency"] = {
            "_intercept": float(c_cls[0]),
            **{n: float(v) for n, v in zip(CNN_LATENCY_COLUMNS, c_cls[1:])},
        }
    # A zero coefficient means that term never binds on this grid; keep the
    # term inert with an effectively-infinite (but finite, serializable)
    # denominator instead of dividing by zero.  The classic fields always
    # carry the aggregate fit — anything reading peak_flops/hbm_bw sees a
    # self-consistent 3-term model; the class-wise refinement rides in
    # ``class_coeffs`` and is consumed only by the class-aware paths.
    peak_flops = 1.0 / c[1] if c[1] > 0 else 1e18
    hbm_bw = 1.0 / c[2] if c[2] > 0 else 1e18

    # Memory: gamma = m0 + m1·weight_mb + m2·act_mb, m ≥ 0.
    m = nnls(np.stack([ones, weight_mb, act_mb], axis=1), gamma_mb)

    # Energy: fitted exactly like latency — aggregate AND class-wise NNLS
    # over the same decompose columns, lower MAPE applied.  Ground truth
    # per workload is the datapoint's measured joules when a power rail
    # was sampled, else the base envelope's watts-proxy at the MEASURED
    # phi (decompose.watts_proxy).  A zero-watt base envelope yields
    # all-zero targets and the energy fit is skipped (energy_fit="none").
    # Either winning fit is stored over the class-column names (the
    # aggregate's tied byte coefficient mapped onto both byte columns) so
    # pricing stays one code path: classwise_seconds(·, "cnn_energy").
    from repro.engine.decompose import watts_proxy

    energy_true = np.array([getattr(dp, "energy_j", 0.0) or 0.0 for dp in dps],
                           dtype=np.float64)
    proxied = energy_true <= 0
    if proxied.any():
        energy_true = np.where(
            proxied, watts_proxy(flops, phi_s, base) * phi_s, energy_true)
    class_coeffs.pop("cnn_energy", None)
    energy_meta: dict = {"energy_fit": "none"}
    if np.any(energy_true > 0):
        # Timed tuning rows carry no energy measurement: fit on the
        # workload rows only.
        e = nnls(A_lat[:n_work], energy_true)
        e_cls = nnls(A_cls[:n_work], energy_true)
        e_mape_agg = _mape(A_lat[:n_work] @ e, energy_true)
        e_mape_cls = _mape(A_cls[:n_work] @ e_cls, energy_true)
        use_classwise_e = e_mape_cls <= e_mape_agg
        if use_classwise_e:
            class_coeffs["cnn_energy"] = {
                "_intercept": float(e_cls[0]),
                **{n: float(v) for n, v in zip(CNN_LATENCY_COLUMNS,
                                               e_cls[1:])},
            }
        else:
            class_coeffs["cnn_energy"] = {
                "_intercept": float(e[0]),
                "flops_matmul": float(e[1]),
                "hbm_elementwise": float(e[2]),
                "hbm_data_movement": float(e[2]),
            }
        energy_meta = {
            "energy_fit": "classwise" if use_classwise_e else "aggregate",
            "energy_mape": min(e_mape_cls, e_mape_agg),
            "energy_mape_aggregate": e_mape_agg,
            "energy_mape_classwise": e_mape_cls,
            "energy_proxied": int(proxied.sum()),
        }

    spec = replace(
        base,
        name=name or f"{base.name}_calibrated",
        peak_flops=peak_flops,
        hbm_bw=hbm_bw,
        launch_overhead_s=float(c[0]),
        mem_base_mb=float(m[0]),
        mem_weight_scale=float(m[1]),
        mem_act_scale=float(m[2]),
        combine="sum",
        calibrated=True,
        class_coeffs=class_coeffs,
        meta={
            "base_device": base.name,
            "n_workloads": len(dps),
            "n_profiled": profiled,
            "n_timed_kernel_rows": n_timed,
            "latency_fit": "classwise" if use_classwise else "aggregate",
            "phi_mape": min(phi_mape_cls, phi_mape_agg),
            "phi_mape_aggregate": phi_mape_agg,
            "phi_mape_classwise": phi_mape_cls,
            "gamma_mape": _mape(m[0] + m[1] * weight_mb + m[2] * act_mb,
                                gamma_mb),
            **energy_meta,
        },
    )
    if apply:
        backend.device = spec
    return spec


def evaluate_accuracy(backend, dps: list[Datapoint]) -> dict:
    """Prediction error of ``backend`` against measured datapoints: MAPE of
    Φ (latency) and Γ (memory) — the paper's Table-4 framing."""
    ests = backend.estimate([
        CostQuery(spec=_spec_of(dp), bs=dp.bs, stage=STAGE_TRAIN)
        for dp in dps
    ])
    phi_pred = np.array([e.phi_ms for e in ests])
    gamma_pred = np.array([e.gamma_mb for e in ests])
    phi_true = np.array([dp.phi_ms for dp in dps])
    gamma_true = np.array([dp.gamma_mb for dp in dps])
    return {
        "phi_mape": _mape(phi_pred, phi_true),
        "gamma_mape": _mape(gamma_pred, gamma_true),
        "n": len(dps),
    }


def _spec_of(dp: Datapoint):
    """Rebuild the NetworkSpec for a datapoint's grid coordinates."""
    w = CalibrationWorkload(family=dp.family, level=dp.level, bs=dp.bs,
                            strategy=dp.strategy, width_mult=dp.width_mult,
                            input_hw=dp.input_hw, seed=dp.seed)
    return w.build_model().conv_specs()
