"""Unified cost-prediction engine (see docs/engine.md).

One batched, cacheable API — ``CostBackend.estimate(queries) ->
CostEstimate[]`` — over the three cost paths this repo grew separately:
the fitted perf4sight forest, the HLO/roofline analytical model, and the
ground-truth profiler.  Hardware constants live in the device registry
(``repro.engine.devices``) and are fitted per device by
``repro.engine.calibrate``.
"""

from repro.engine.backends import (
    AnalyticalBackend,
    EnsembleBackend,
    ForestBackend,
    ProfilerBackend,
)
from repro.engine.cache import EstimateCache
from repro.engine.calibrate import (
    CalibrationWorkload,
    calibrate,
    default_workloads,
    evaluate_accuracy,
    measure_ground_truth,
    timed_tuning_rows,
)
from repro.engine.devices import (
    DEVICE_REGISTRY,
    DeviceSpec,
    from_jax_device,
    get_device,
    list_devices,
    load_device_spec,
    register_device,
    resolve_device,
    save_device_spec,
)
from repro.engine.engine import CostEngine, HealthState
from repro.engine.types import (
    STAGE_INFER,
    STAGE_TRAIN,
    BackendUnavailable,
    CostBackend,
    CostEstimate,
    CostQuery,
)

__all__ = [
    "AnalyticalBackend",
    "BackendUnavailable",
    "CalibrationWorkload",
    "CostBackend",
    "CostEngine",
    "CostEstimate",
    "CostQuery",
    "DEVICE_REGISTRY",
    "DeviceSpec",
    "EnsembleBackend",
    "EstimateCache",
    "ForestBackend",
    "HealthState",
    "ProfilerBackend",
    "STAGE_INFER",
    "STAGE_TRAIN",
    "calibrate",
    "default_workloads",
    "evaluate_accuracy",
    "from_jax_device",
    "get_device",
    "list_devices",
    "load_device_spec",
    "measure_ground_truth",
    "register_device",
    "resolve_device",
    "save_device_spec",
    "timed_tuning_rows",
]
