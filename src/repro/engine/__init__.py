"""Unified cost-prediction engine (see docs/engine.md).

One batched, cacheable API — ``CostBackend.estimate(queries) ->
CostEstimate[]`` — over the three cost paths this repo grew separately:
the fitted perf4sight forest, the HLO/roofline analytical model, and the
ground-truth profiler.
"""

from repro.engine.backends import (
    HOST_CPU,
    AnalyticalBackend,
    EnsembleBackend,
    ForestBackend,
    ProfilerBackend,
)
from repro.engine.cache import EstimateCache
from repro.engine.engine import CostEngine
from repro.engine.types import (
    STAGE_INFER,
    STAGE_TRAIN,
    BackendUnavailable,
    CostBackend,
    CostEstimate,
    CostQuery,
)

__all__ = [
    "AnalyticalBackend",
    "BackendUnavailable",
    "CostBackend",
    "CostEngine",
    "CostEstimate",
    "CostQuery",
    "EnsembleBackend",
    "EstimateCache",
    "ForestBackend",
    "HOST_CPU",
    "ProfilerBackend",
    "STAGE_INFER",
    "STAGE_TRAIN",
]
