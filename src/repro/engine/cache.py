"""Content-keyed on-disk cache of cost estimates.

Same idiom as ``core/dataset.py``'s profiling cache: one JSON file, loaded
eagerly, written atomically (tempfile in the target directory + fsync +
``os.replace``), tolerant of a corrupt file left by earlier non-atomic
writers.  Keys are :meth:`CostQuery.key` content hashes, so estimates are
shared across processes, runs, and differently-named specs with identical
geometry.
"""

from __future__ import annotations

import copy

from repro.core.fileio import atomic_write_json, load_json_tolerant
from repro.engine.types import CostEstimate

__all__ = ["EstimateCache"]


class EstimateCache:
    def __init__(self, path: str):
        self.path = path
        self._data: dict[str, dict] = load_json_tolerant(path)

    def get(self, key: str) -> CostEstimate | None:
        d = self._data.get(key)
        if not d:
            return None
        est = CostEstimate.from_dict(d)
        est.detail = dict(est.detail)   # callers may annotate their copy
        return est

    def put(self, key: str, est: CostEstimate) -> None:
        # Deep-copy the detail dict: the estimate object stays live with the
        # caller, and post-call annotations (possibly non-JSON values) must
        # not leak into — or break the flush of — the on-disk cache.
        d = est.to_dict()
        d["detail"] = copy.deepcopy(d["detail"])
        self._data[key] = d

    def flush(self) -> None:
        atomic_write_json(self.path, self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        return key in self._data
