"""Core types of the unified cost engine.

Every consumer that needs to know "what does this configuration cost?"
(admission control, architecture search, benchmarks, serving placement)
expresses the question as a :class:`CostQuery` and receives a
:class:`CostEstimate` — regardless of whether the answer comes from the
fitted perf4sight forest, the roofline/HLO analytical model, or the
ground-truth profiler.  Backends implement :class:`CostBackend`; the
batched ``estimate`` signature is the whole point: N candidate queries are
answered with one feature-matrix build + one forest traversal instead of
N scalar round-trips (paper §6.4's 200× search-speed argument, kept honest
at population scale).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Protocol, runtime_checkable

from repro.core.features import NetworkSpec

__all__ = [
    "CostQuery",
    "CostEstimate",
    "CostBackend",
    "BackendUnavailable",
    "STAGE_TRAIN",
    "STAGE_INFER",
]

STAGE_TRAIN = "train"
STAGE_INFER = "infer"
_STAGES = (STAGE_TRAIN, STAGE_INFER)


class BackendUnavailable(RuntimeError):
    """Raised by a backend that cannot answer the queries handed to it; the
    ensemble treats it as "fall through to the next backend in the chain"."""


@dataclass(frozen=True)
class CostQuery:
    """One "what does this cost?" question.

    Exactly one of ``spec`` (a CNN conv-layer topology — the perf4sight
    feature path) or ``arch`` (an LM architecture id from
    ``configs.registry`` — the HLO/roofline path) identifies the workload.
    ``model`` optionally carries a concrete built model for the profiler
    backend; it never participates in equality or cache keys.
    """

    bs: int
    stage: str = STAGE_TRAIN
    spec: NetworkSpec | None = None
    arch: str | None = None
    seq: int = 64                      # LM-only: sequence length
    reduced: bool | None = None        # LM-only: smoke-scale config variant;
    #                                    None defers to the backend's default
    model: Any = field(default=None, compare=False, hash=False, repr=False)

    def __post_init__(self):
        if self.stage not in _STAGES:
            raise ValueError(f"stage must be one of {_STAGES}, got {self.stage!r}")
        if self.spec is None and self.arch is None and self.model is None:
            raise ValueError("CostQuery needs a spec, an arch id, or a model")

    @property
    def key(self) -> str:
        """Content key: stable across processes, independent of spec naming
        (two specs with identical layer geometry share estimates)."""
        if self.spec is not None:
            ident = [
                (l.n, l.m, l.k, l.stride, l.padding, l.groups, l.ip)
                for l in self.spec.layers
            ]
        elif self.arch is not None:
            ident = self.arch
        else:
            # model-only query: name alone collides across pruned variants
            # of one family — key on the conv geometry when available.
            conv_specs = getattr(self.model, "conv_specs", None)
            if callable(conv_specs):
                ident = [
                    (l.n, l.m, l.k, l.stride, l.padding, l.groups, l.ip)
                    for l in conv_specs().layers
                ]
            else:
                ident = [getattr(self.model, "name", repr(type(self.model))),
                         sorted(getattr(self.model, "widths", {}).items())]
        blob = json.dumps(
            {"id": ident, "bs": self.bs, "stage": self.stage,
             "seq": self.seq if self.arch is not None else None,
             "reduced": self.reduced if self.arch is not None else None},
            sort_keys=True,
        )
        return hashlib.sha1(blob.encode()).hexdigest()


@dataclass
class CostEstimate:
    """Predicted (Γ memory, Φ latency, E energy) for one query, tagged with
    the backend that produced it.

    ``energy_j`` is the predicted per-step energy in joules — 0.0 when the
    answering backend has no power model (zero-watt device envelope, forest
    without an energy fit).  Per-class attribution rides in
    ``detail["energy_classes"]`` when the analytical path answered."""

    gamma_mb: float
    phi_ms: float
    energy_j: float = 0.0
    source: str = ""
    detail: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"gamma_mb": self.gamma_mb, "phi_ms": self.phi_ms,
                "energy_j": self.energy_j,
                "source": self.source, "detail": self.detail}

    @classmethod
    def from_dict(cls, d: dict) -> "CostEstimate":
        # .get: estimate caches written before the energy attribute load
        # with energy defaulted, not invalidated.
        return cls(gamma_mb=float(d["gamma_mb"]), phi_ms=float(d["phi_ms"]),
                   energy_j=float(d.get("energy_j", 0.0)),
                   source=d.get("source", ""), detail=d.get("detail", {}))


@runtime_checkable
class CostBackend(Protocol):
    """The uniform prediction interface.

    ``supports`` is a cheap per-query capability check (no computation);
    ``estimate`` answers a *batch* of supported queries in one call and
    must return one estimate per query, in order.  A backend that cannot
    answer (not fitted, missing dependency, compile failure) raises
    :class:`BackendUnavailable` for the whole batch.
    """

    name: str

    def supports(self, query: CostQuery) -> bool: ...

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]: ...
