"""CostBackend implementations.

Three ways to answer the same question, in decreasing accuracy and
increasing speed of setup:

* :class:`ProfilerBackend`   — ground truth: compile + run the real step.
* :class:`AnalyticalBackend` — no fitting, no execution: roofline over the
  trip-count-aware HLO cost for LM cells, Appendix-B closed forms for CNNs.
* :class:`ForestBackend`     — the fitted perf4sight predictor; microseconds
  per query once fitted, fully batched.

:class:`EnsembleBackend` chains them (forest → analytical → profiler by
convention): each query is answered by the first backend in the chain that
supports it and succeeds, so a search job transparently degrades from
"fitted forest" to "analytical" to "measure it" instead of crashing.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.features import FEATURE_NAMES, feature_matrix
from repro.core.predictor import Perf4Sight
from repro.engine.decompose import (
    classwise_seconds,
    cnn_energy_class_joules,
    energy_terms,
    latency_class_columns,
    latency_terms,
    ledger_latency_columns,
    lm_roofline_terms,
    memory_terms,
    price_ledger_energy,
)
from repro.engine.devices import DeviceSpec, resolve_device
from repro.engine.types import (
    STAGE_INFER,
    STAGE_TRAIN,
    BackendUnavailable,
    CostEstimate,
    CostQuery,
)

__all__ = [
    "ForestBackend",
    "AnalyticalBackend",
    "ProfilerBackend",
    "EnsembleBackend",
]

# CNN energy-fit column → ledger op class, for the per-class breakdown the
# fitted path reports (the columns are already class-labelled).
_CNN_COL2CLS = {"flops_matmul": "matmul", "hbm_elementwise": "elementwise",
                "hbm_data_movement": "data_movement"}


class ForestBackend:
    """Batched prediction through fitted forests: :class:`Perf4Sight` models
    (one per stage) for CNN conv-spec queries, and — once a profiling
    campaign has been fitted (``repro.campaign.fit``) — an
    :class:`~repro.campaign.fit.LMForest` for LM arch queries.  N queries
    cost one feature-matrix build + one packed forest traversal per
    attribute, with **zero jax compiles** on either path — the engine's
    hot path."""

    name = "forest"

    def __init__(self, train: Perf4Sight | None = None,
                 infer: Perf4Sight | None = None, lm=None):
        self.predictors = {STAGE_TRAIN: train, STAGE_INFER: infer}
        self.lm = lm

    def _predictor(self, stage: str) -> Perf4Sight | None:
        p = self.predictors.get(stage)
        return p if (p is not None and p.fitted) else None

    def _lm_forest(self):
        lm = self.lm
        return lm if (lm is not None and getattr(lm, "fitted", False)) else None

    def cache_salt(self) -> str:
        """Content hash of the fitted models: a refit predictor invalidates
        on-disk estimates instead of silently serving stale ones."""
        parts = []
        for stage in (STAGE_TRAIN, STAGE_INFER):
            p = self._predictor(stage)
            parts.append(p.content_hash() if p is not None else "-")
        lm = self._lm_forest()
        parts.append(lm.content_hash() if lm is not None else "-")
        return f"{self.name}:" + ":".join(parts)

    def supports(self, query: CostQuery) -> bool:
        if query.spec is not None:
            return self._predictor(query.stage) is not None
        return query.arch is not None and self._lm_forest() is not None

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]:
        results: list[CostEstimate | None] = [None] * len(queries)
        by_stage: dict[str, list[int]] = {}
        lm_idx: list[int] = []
        for i, q in enumerate(queries):
            if not self.supports(q):
                raise BackendUnavailable(f"forest backend cannot answer {q}")
            if q.spec is not None:
                by_stage.setdefault(q.stage, []).append(i)
            else:
                lm_idx.append(i)
        for stage, idx in by_stage.items():
            predictor = self._predictor(stage)
            g, p = predictor.predict_batch(
                [(queries[i].spec, queries[i].bs) for i in idx])
            for j, i in enumerate(idx):
                results[i] = CostEstimate(
                    gamma_mb=float(g[j]), phi_ms=float(p[j]), source=self.name)
        if lm_idx:
            lm = self._lm_forest()
            lm_queries = [queries[i] for i in lm_idx]
            g, p = lm.predict_queries(lm_queries)
            # Energy is an optional forest attribute (campaigns recorded
            # before the watts-proxy column fit no energy model) —
            # getattr so pre-energy forests and test fakes keep working.
            e = None
            predict_energy = getattr(lm, "predict_energy", None)
            if callable(predict_energy) and getattr(lm, "energy_fitted", False):
                e = predict_energy(lm_queries)
            detail = {"lm": True, "device": lm.default_device.name,
                      "plan_hash": lm.meta.get("plan_hash")}
            for j, i in enumerate(lm_idx):
                results[i] = CostEstimate(
                    gamma_mb=float(g[j]), phi_ms=float(p[j]),
                    energy_j=float(e[j]) if e is not None else 0.0,
                    source=self.name, detail=dict(detail))
        return results


class AnalyticalBackend:
    """No-fit estimates.

    CNN conv-spec queries use the Appendix-B closed forms directly: Γ from
    the algorithm-independent tensor allocations, Φ from a roofline over the
    im2col op count and allocation traffic.  LM arch queries AOT-compile the
    real step (no execution) and run the trip-count-aware HLO cost parse
    through the roofline terms — the same machinery as core/roofline.py.

    Hardware constants come from :class:`~repro.engine.devices.DeviceSpec`
    (``device`` for the CNN path, ``lm_device`` for the LM path) — registry
    guesses by default, per-device fitted constants after
    :func:`repro.engine.calibrate.calibrate`.
    """

    name = "analytical"

    def __init__(self, device: "DeviceSpec | str | dict | None" = None,
                 lm_device: "DeviceSpec | str | dict | None" = None,
                 reduced: bool = True, bytes_per_el: int = 4,
                 hw: dict | None = None, lm_hw: dict | None = None):
        # ``hw`` / ``lm_hw`` are the pre-registry dict spellings, still
        # accepted; ``device`` / ``lm_device`` take registry names, persisted
        # spec paths, or DeviceSpec instances (see engine/devices.py).
        self.device = resolve_device(device if device is not None else hw)
        self.lm_device = resolve_device(
            lm_device if lm_device is not None else lm_hw, default="tpu_v5e")
        self.reduced = reduced
        self.bytes_per_el = bytes_per_el
        self._compiled_cache: dict[tuple, CostEstimate] = {}
        # infer-stage heuristic indices; the train stage goes through the
        # shared engine/decompose.py terms instead
        self._i_alloc = FEATURE_NAMES.index("mem_alloc_total")
        self._i_ops_fwd = FEATURE_NAMES.index("mm_ops_fwd")
        self._i_i2c = FEATURE_NAMES.index("mm_i2c_total_sum")

    def cache_salt(self) -> str:
        # Salted by BOTH device fingerprints: calibrated and uncalibrated
        # estimates (or two differently-fitted specs) never alias on disk.
        return (f"{self.name}:{self.reduced}:{self.bytes_per_el}:"
                f"{self.device.fingerprint()}:{self.lm_device.fingerprint()}")

    def supports(self, query: CostQuery) -> bool:
        return query.spec is not None or query.arch is not None

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]:
        results: list[CostEstimate | None] = [None] * len(queries)
        spec_idx = [i for i, q in enumerate(queries) if q.spec is not None]
        arch_idx = [i for i, q in enumerate(queries)
                    if q.spec is None and q.arch is not None]
        if len(spec_idx) + len(arch_idx) != len(queries):
            raise BackendUnavailable("analytical backend cannot answer model-only queries")
        if spec_idx:
            X = feature_matrix([(queries[i].spec, queries[i].bs) for i in spec_idx])
            for j, i in enumerate(spec_idx):
                results[i] = self._estimate_spec(queries[i], X[j])
        for i in arch_idx:
            results[i] = self._estimate_arch(queries[i])
        return results

    # -- CNN closed-form path -------------------------------------------------

    def _estimate_spec(self, q: CostQuery, feats: np.ndarray) -> CostEstimate:
        # Γ: element count of weights/grads/activation-grads (App. B.2.1).
        # Inference allocates no gradient buffers: approximate with the
        # weight + activation terms only (~alloc_total minus the grad terms
        # isn't directly a feature, so scale by the fwd/total op ratio).
        dev = self.device
        if q.stage == STAGE_INFER:
            # Inference heuristic: drop bwd_w / bwd_x buffers and ops.
            alloc = feats[self._i_alloc] / 3.0
            ops = feats[self._i_ops_fwd]
            i2c = feats[self._i_i2c] / 3.0
            flops = 2.0 * ops
            bytes_moved = self.bytes_per_el * (alloc + i2c)
            gamma_mb = dev.round_alloc(self.bytes_per_el * alloc) / 1e6
        else:
            # Train stage: the SAME decomposition the calibration fit uses
            # (engine/decompose.py) — fitted constants multiply these terms.
            flops, bytes_moved = (v[0] for v in
                                  latency_terms(feats, self.bytes_per_el))
            if dev.calibrated:
                w_b, a_b = (v[0] for v in
                            memory_terms(feats, self.bytes_per_el))
                gamma_mb = (dev.mem_base_mb
                            + dev.mem_weight_scale * dev.round_alloc(w_b) / 1e6
                            + dev.mem_act_scale * dev.round_alloc(a_b) / 1e6)
            else:
                gamma_mb = dev.round_alloc(
                    self.bytes_per_el * feats[self._i_alloc]) / 1e6
        compute_s = flops / dev.peak_flops
        memory_s = bytes_moved / dev.hbm_bw
        coeffs = dev.class_coeffs.get("cnn_latency")
        if dev.calibrated and q.stage != STAGE_TRAIN:
            # The additive combine and launch overhead were fitted on FULL
            # training steps (backward-pass dispatch included); applying
            # them to inference would let the train-fitted intercept
            # dominate small sub-millisecond candidates.  Inference reuses
            # only the fitted denominators under the plain roofline max.
            phi_ms = max(compute_s, memory_s) * 1e3
        elif dev.calibrated and coeffs:
            # Class-wise fitted constants: price the SAME decompose columns
            # the calibration solved over (single-source-of-truth contract).
            phi_ms = float(np.atleast_1d(classwise_seconds(
                latency_class_columns(feats, self.bytes_per_el),
                coeffs))[0]) * 1e3
        else:
            phi_ms = dev.combine_terms(compute_s, memory_s) * 1e3

        # Energy: fitted class-wise constants when calibration found them
        # (train stage — where they were fitted), the device power envelope
        # otherwise.  Either way the per-class breakdown re-sums to the
        # dynamic aggregate (the columns sum to the aggregate terms).
        cols = (latency_class_columns(feats, self.bytes_per_el)
                if q.stage == STAGE_TRAIN else None)
        e_coeffs = dev.class_coeffs.get("cnn_energy")
        energy_classes = None
        if dev.calibrated and e_coeffs and cols is not None:
            energy_j = float(np.atleast_1d(
                classwise_seconds(cols, e_coeffs))[0])
            energy_fit = "fitted"
            energy_classes = {
                _CNN_COL2CLS[name]: float(e_coeffs.get(name, 0.0)
                                          * np.atleast_1d(col)[0])
                for name, col in cols.items()}
        else:
            static_j, comp_j, mem_j, _ = energy_terms(
                flops, bytes_moved, phi_ms / 1e3, dev)
            energy_j = float(np.atleast_1d(static_j + comp_j + mem_j)[0])
            energy_fit = "envelope"
            if cols is not None:
                energy_classes = {
                    k: float(np.atleast_1d(v)[0]) for k, v in
                    cnn_energy_class_joules(feats, self.bytes_per_el,
                                            dev).items()}

        detail = {"compute_s": float(compute_s), "memory_s": float(memory_s),
                  "device": dev.name, "calibrated": dev.calibrated,
                  "latency_fit": "classwise" if (dev.calibrated and coeffs
                                                 and q.stage == STAGE_TRAIN)
                  else "aggregate",
                  "energy_fit": energy_fit,
                  "dominant": "compute" if compute_s >= memory_s else "memory"}
        if energy_classes is not None:
            detail["energy_classes"] = energy_classes
        return CostEstimate(
            gamma_mb=float(gamma_mb), phi_ms=float(phi_ms),
            energy_j=energy_j, source=self.name, detail=detail)

    # -- LM HLO/roofline path -------------------------------------------------

    def _reduced(self, q: CostQuery) -> bool:
        """Per-query smoke/full choice; the backend flag is only a default."""
        return self.reduced if q.reduced is None else q.reduced

    def _estimate_arch(self, q: CostQuery) -> CostEstimate:
        key = (q.arch, q.stage, q.bs, q.seq, self._reduced(q),
               self.lm_device.fingerprint())
        if key in self._compiled_cache:
            return self._compiled_cache[key]
        try:
            est = self._compile_arch(q)
        except BackendUnavailable:
            raise
        except Exception as e:  # compile/lowering failure → fall through chain
            raise BackendUnavailable(
                f"analytical compile failed for {q.arch}: {e}") from e
        self._compiled_cache[key] = est
        return est

    def _compile_arch(self, q: CostQuery) -> CostEstimate:
        import jax
        import jax.numpy as jnp

        from repro.configs.base import ShapeSpec
        from repro.configs.registry import get_config
        from repro.core.hlo_cost import parse_hlo_cost
        from repro.core.profiler import memory_analysis_bytes
        from repro.models import transformer as T
        from repro.optim.optimizer import OptimizerConfig, apply_updates

        dev = self.lm_device
        reduced = self._reduced(q)
        cfg = get_config(q.arch, reduced=reduced)
        kind = "train" if q.stage == STAGE_TRAIN else "prefill"
        shape = ShapeSpec("engine", q.seq, q.bs, kind)
        t0 = time.perf_counter()
        specs = T.input_specs(cfg, shape)
        if kind == "train":
            opt_cfg = OptimizerConfig()
            f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
            pspecs = specs["params"]
            opt_specs = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                         "m": jax.tree.map(f32, pspecs),
                         "v": jax.tree.map(f32, pspecs)}

            def step(state, batch):
                (l, _), g = jax.value_and_grad(T.loss_fn, has_aux=True)(
                    state["params"], batch, cfg)
                p2, o2, _ = apply_updates(state["params"], g, state["opt"], opt_cfg)
                return {"params": p2, "opt": o2}, l

            compiled = jax.jit(step).lower(
                {"params": pspecs, "opt": opt_specs}, specs["batch"]).compile()
        else:
            max_len = q.seq + cfg.n_prefix

            def fwd(params, batch):
                return T.prefill(params, batch, cfg, max_len=max_len)

            compiled = jax.jit(fwd).lower(specs["params"], specs["batch"]).compile()
        compile_s = time.perf_counter() - t0

        mb = memory_analysis_bytes(compiled)
        gamma_mb = dev.round_alloc(
            mb["arg"] + mb["out"] + mb["temp"] + mb["code"]) / 1e6
        cost = parse_hlo_cost(compiled.as_text())
        # Price per-op dynamic energy into the ledger before taking class
        # sums: the breakdown every consumer sees (cost_classes) then
        # carries an energy bucket whose class sums re-sum to the ledger
        # aggregate — the same parity contract as flops/bytes.
        eledger = price_ledger_energy(cost.ledger, dev)
        class_sums = eledger.class_sums()
        compute_s, memory_s, coll_s = (
            float(v) for v in lm_roofline_terms(
                cost.flops, cost.hbm_bytes, cost.collective_bytes, dev))
        coeffs = dev.class_coeffs.get("lm_latency")
        if coeffs:
            # Class-wise fitted constants price the ledger's per-class
            # columns — the same decompose.ledger_latency_columns the
            # campaign constant fit solved over.
            phi_ms = float(np.atleast_1d(classwise_seconds(
                ledger_latency_columns([class_sums]), coeffs))[0]) * 1e3
        else:
            phi_ms = dev.combine_terms(compute_s, memory_s, coll_s) * 1e3
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}

        # Energy: campaign-fitted constants when present, envelope pricing
        # otherwise (static idle term + the per-op dynamic joules above).
        e_coeffs = dev.class_coeffs.get("lm_energy")
        static_j = dev.idle_w * (phi_ms / 1e3)
        if e_coeffs:
            energy_j = float(np.atleast_1d(classwise_seconds(
                ledger_latency_columns([class_sums]), e_coeffs))[0])
            energy_fit = "fitted"
        else:
            energy_j = float(static_j + eledger.energy_j)
            energy_fit = "envelope"
        return CostEstimate(
            gamma_mb=float(gamma_mb), phi_ms=float(phi_ms),
            energy_j=energy_j, source=self.name,
            detail={"flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
                    "collective_bytes": cost.collective_bytes,
                    "cost_classes": class_sums,
                    "latency_fit": "classwise" if coeffs else "aggregate",
                    "energy_fit": energy_fit,
                    "energy_static_j": float(static_j),
                    "energy_classes": {cls: s["energy_j"]
                                       for cls, s in class_sums.items()},
                    "dominant": max(terms, key=terms.get),
                    "device": dev.name,
                    "compile_s": compile_s, "reduced": reduced})


class ProfilerBackend:
    """Ground truth: compile and run the real training/inference step for a
    concrete built model.  Inherently per-query (each candidate is its own
    executable); used for calibration and as the last link of the ensemble
    chain."""

    name = "profiler"

    def __init__(self, repeats: int = 2, warmup: int = 1, run: bool = True):
        self.repeats = repeats
        self.warmup = warmup
        self.run = run

    def cache_salt(self) -> str:
        return f"{self.name}:{self.repeats}:{self.warmup}:{self.run}"

    def supports(self, query: CostQuery) -> bool:
        return query.model is not None

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]:
        from repro.core.profiler import profile_inference, profile_training

        out = []
        for q in queries:
            if q.model is None:
                raise BackendUnavailable("profiler backend needs a built model")
            prof = profile_training if q.stage == STAGE_TRAIN else profile_inference
            res = prof(q.model, q.bs, repeats=self.repeats, warmup=self.warmup,
                       run=self.run)
            out.append(CostEstimate(
                gamma_mb=res.gamma_mb, phi_ms=res.phi_ms, source=self.name,
                detail={"compile_s": res.compile_s, "flops": res.flops,
                        "temp_mb": res.temp_mb}))
        return out


class EnsembleBackend:
    """Fallback chain: each query is answered by the first backend that
    supports it and succeeds.  A backend failing with
    :class:`BackendUnavailable` drops out for that batch only; remaining
    queries flow to the next link."""

    name = "ensemble"

    def __init__(self, backends: list):
        if not backends:
            raise ValueError("empty backend chain")
        self.backends = list(backends)

    def cache_salt(self) -> str:
        salts = [getattr(b, "cache_salt", lambda: b.name)() for b in self.backends]
        return f"{self.name}:[" + "|".join(salts) + "]"

    def supports(self, query: CostQuery) -> bool:
        return any(b.supports(query) for b in self.backends)

    def estimate(self, queries: list[CostQuery]) -> list[CostEstimate]:
        results: list[CostEstimate | None] = [None] * len(queries)
        remaining = list(range(len(queries)))
        failures: list[str] = []
        last_exc: BackendUnavailable | None = None
        for backend in self.backends:
            if not remaining:
                break
            idx = [i for i in remaining if backend.supports(queries[i])]
            if not idx:
                continue
            try:
                ests = backend.estimate([queries[i] for i in idx])
            except BackendUnavailable as e:
                # One poisoned query (e.g. an arch that fails to compile)
                # must not discard the whole batch's answerable queries:
                # retry per query so only the failing ones fall through.
                if len(idx) > 1:
                    salvaged = 0
                    for i in idx:
                        try:
                            results[i] = backend.estimate([queries[i]])[0]
                            salvaged += 1
                        except BackendUnavailable as e2:
                            last_exc = e2
                    if salvaged:
                        failures.append(
                            f"{backend.name}: answered {salvaged}/{len(idx)}"
                            f" after batch failure ({e})")
                    else:
                        failures.append(f"{backend.name}: {e}")
                else:
                    failures.append(f"{backend.name}: {e}")
                    last_exc = e
                remaining = [i for i in remaining if results[i] is None]
                continue
            for i, est in zip(idx, ests):
                results[i] = est
            remaining = [i for i in remaining if results[i] is None]
        if remaining:
            why = ("; ".join(failures)) if failures else "no backend supports them"
            raise BackendUnavailable(
                f"no backend in {[b.name for b in self.backends]} could answer "
                f"{len(remaining)}/{len(queries)} queries ({why})") from last_exc
        return results
