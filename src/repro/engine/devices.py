"""Device registry: named hardware constants behind every cost estimate.

perf4sight's models are *per-device* (paper §5): the same topology costs
differently on a TX2 than on a workstation, so the constants that turn
compute/byte decompositions into seconds and megabytes must be first-class,
named, and swappable — not literals buried in a backend.  A
:class:`DeviceSpec` carries the roofline denominators (peak FLOP/s, memory
bandwidth, interconnect bandwidth), the fitted latency constants (kernel
launch overhead, term-combination mode) and the fitted memory constants
(allocator granularity, weight/activation scale, base footprint).

Specs come from three places:

* the built-in registry (``host_cpu``, ``tx2_like``, ``tpu_v5e``) — coarse
  datasheet guesses, ``calibrated=False``;
* :func:`repro.engine.calibrate.calibrate` — constants fitted against
  :class:`~repro.engine.backends.ProfilerBackend` ground truth,
  ``calibrated=True``;
* :func:`from_jax_device` — auto-derived from a live ``jax.devices()``
  entry (platform heuristics, still uncalibrated).

``fingerprint()`` hashes every constant that affects a prediction; the
engine salts estimate-cache keys with it so calibrated and uncalibrated
estimates can never collide on disk.  Fitted specs persist through the
atomic ``core/fileio`` helpers as JSON (inspectable) or NPZ (compact),
chosen by extension.
"""

from __future__ import annotations

import hashlib
import json
import math
import os
from dataclasses import asdict, dataclass, field, replace

__all__ = [
    "DeviceSpec",
    "DEVICE_REGISTRY",
    "POWER_MODE_FIELDS",
    "get_device",
    "register_device",
    "list_devices",
    "resolve_device",
    "from_jax_device",
    "save_device_spec",
    "load_device_spec",
]

# Constants that change predictions — exactly the fields the fingerprint
# (and therefore every estimate-cache key) must be sensitive to.
# ``calibrated`` is included because the analytical backend branches on it
# (fitted memory model, infer-stage combine), not just on the constants.
FITTED_FIELDS = (
    "peak_flops",
    "hbm_bw",
    "ici_bw",
    "hbm_bytes",
    "launch_overhead_s",
    "alloc_granularity",
    "mem_weight_scale",
    "mem_act_scale",
    "mem_base_mb",
    "idle_w",
    "peak_w",
    "power_modes",
    "combine",
    "calibrated",
    "class_coeffs",
)

# DeviceSpec fields a named power-mode entry may override (a nvpmodel-style
# mode caps the power budget *and* the clocks, so the roofline denominators
# are legitimately part of a mode).
POWER_MODE_FIELDS = ("idle_w", "peak_w", "peak_flops", "hbm_bw")


@dataclass(frozen=True)
class DeviceSpec:
    """Hardware constants of one device, guessed or fitted.

    Latency model (``AnalyticalBackend``):

        phi_s = launch_overhead_s + combine(flops / peak_flops,
                                            bytes_moved / hbm_bw)

    where ``combine`` is ``max`` (classic roofline, the uncalibrated
    default) or ``sum`` (the additive relaxation the NNLS calibration
    fits — overlap folded into the fitted denominators).

    Memory model:

        gamma_mb = mem_base_mb + mem_weight_scale * weight_mb
                              + mem_act_scale   * activation_mb

    with byte totals rounded up to ``alloc_granularity``.  The uncalibrated
    defaults (scale 1, base 0, granularity 1) leave the raw Appendix-B
    allocation totals untouched.

    Power envelope (PowerTrain / the Jetson characterization papers):
    ``idle_w`` is the board's static draw, ``peak_w`` its full-utilisation
    draw; the dynamic range ``max(peak_w - idle_w, 0)`` scales with
    roofline utilisation to give analytical energy (see
    ``engine/decompose.energy_terms``).  ``power_modes`` optionally names
    nvpmodel-style operating points (``{"MAXQ": {"peak_w": 7.5, ...}}``,
    each entry overriding :data:`POWER_MODE_FIELDS`); apply one with
    :meth:`with_power_mode`.  The zero-watt default keeps envelope energy
    inert (0 J) on specs that never declared one.
    """

    name: str
    peak_flops: float                  # FLOP/s
    hbm_bw: float                      # B/s
    ici_bw: float = 1e9                # B/s (interconnect / collective)
    hbm_bytes: float = 4e9             # memory capacity
    launch_overhead_s: float = 0.0     # fixed per-step dispatch cost
    alloc_granularity: int = 1         # allocator rounding (bytes)
    mem_weight_scale: float = 1.0      # measured MB per modeled weight MB
    mem_act_scale: float = 1.0         # measured MB per modeled activation MB
    mem_base_mb: float = 0.0           # fixed runtime footprint
    idle_w: float = 0.0                # static board draw (W)
    peak_w: float = 0.0                # full-utilisation draw (W)
    combine: str = "max"               # "max" roofline | "sum" calibrated
    calibrated: bool = False
    # Named operating points (nvpmodel-style): {mode: {field: value}} with
    # fields restricted to POWER_MODE_FIELDS.  hash=False for the same
    # reason as class_coeffs below.
    power_modes: dict = field(default_factory=dict, hash=False)
    # Class-wise fitted constants (the per-op cost ledger refactor): maps a
    # fit family ("cnn_latency", "lm_latency") to {column: seconds-per-unit}
    # coefficients over the engine/decompose class columns, with the fit's
    # intercept under "_intercept".  Empty dict = aggregate constants only.
    # hash=False: a dict would make the frozen spec unhashable; identity
    # for hashing purposes is the fingerprint (which covers this field).
    class_coeffs: dict = field(default_factory=dict, hash=False)
    meta: dict = field(default_factory=dict, compare=False)

    def __post_init__(self):
        if self.peak_flops <= 0 or self.hbm_bw <= 0:
            raise ValueError(f"non-positive roofline denominator: {self}")
        if self.combine not in ("max", "sum"):
            raise ValueError(f"combine must be 'max' or 'sum', got {self.combine!r}")
        if self.alloc_granularity < 1:
            raise ValueError(f"alloc_granularity must be >= 1: {self}")
        if self.idle_w < 0 or self.peak_w < 0:
            raise ValueError(f"negative power envelope: {self}")
        for mode, entry in self.power_modes.items():
            bad = set(entry) - set(POWER_MODE_FIELDS)
            if bad:
                raise ValueError(
                    f"power mode {mode!r} overrides non-mode fields {sorted(bad)}"
                    f" (allowed: {POWER_MODE_FIELDS})")

    # -- prediction helpers --------------------------------------------------

    @property
    def dynamic_w(self) -> float:
        """Utilisation-scaled power range.  Clamped at 0 so a partially
        declared envelope (idle only) stays inert rather than negative."""
        return max(self.peak_w - self.idle_w, 0.0)

    def with_power_mode(self, mode: str) -> "DeviceSpec":
        """The spec at a named operating point: ``power_modes[mode]``
        overrides applied, name suffixed ``@mode``, fingerprint distinct."""
        try:
            entry = self.power_modes[mode]
        except KeyError:
            raise KeyError(
                f"device {self.name!r} has no power mode {mode!r}; "
                f"available: {sorted(self.power_modes)}") from None
        return replace(self, name=f"{self.name}@{mode}", **entry)

    def combine_terms(self, *terms_s: float) -> float:
        """Fold roofline terms into seconds, plus the launch overhead."""
        folded = max(terms_s) if self.combine == "max" else sum(terms_s)
        return self.launch_overhead_s + folded

    def round_alloc(self, nbytes: float) -> float:
        """Round a byte total up to the allocator granularity."""
        g = self.alloc_granularity
        return nbytes if g <= 1 else math.ceil(nbytes / g) * g

    # -- identity ------------------------------------------------------------

    def fingerprint(self) -> str:
        """Hash of every fitted constant (not the name or meta).

        Deliberately conservative: ``hbm_bytes`` only affects admission
        budgets, not estimates, but is still in the key — editing a spec's
        capacity invalidates its cached estimates (a harmless recompute)
        rather than risking any constant change silently aliasing."""
        blob = json.dumps([getattr(self, f) for f in FITTED_FIELDS],
                          sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def hw_table(self) -> dict:
        """Legacy roofline dict (``core/roofline.py`` key names)."""
        return {
            "peak_flops_bf16": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "ici_bw": self.ici_bw,
            "hbm_bytes": self.hbm_bytes,
        }

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DeviceSpec":
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def from_hw_table(cls, hw: dict, name: str = "custom") -> "DeviceSpec":
        """Adopt a legacy ``{"peak_flops_bf16": ..., "hbm_bw": ...}`` dict."""
        return cls(
            name=name,
            peak_flops=float(hw["peak_flops_bf16"]),
            hbm_bw=float(hw["hbm_bw"]),
            ici_bw=float(hw.get("ici_bw", 1e9)),
            hbm_bytes=float(hw.get("hbm_bytes", 4e9)),
        )


# ---------------------------------------------------------------------------
# Registry.  host_cpu carries the constants that used to live as the
# HOST_CPU literal in engine/backends.py; tx2_like approximates the paper's
# Jetson TX2 (§6: 256-core Pascal, 8 GB unified LPDDR4); tpu_v5e mirrors
# launch/mesh.TPU_V5E for the LM/HLO path.
# ---------------------------------------------------------------------------

DEVICE_REGISTRY: dict[str, DeviceSpec] = {}


def register_device(spec: DeviceSpec, *, overwrite: bool = False) -> DeviceSpec:
    if spec.name in DEVICE_REGISTRY and not overwrite:
        raise ValueError(f"device {spec.name!r} already registered")
    DEVICE_REGISTRY[spec.name] = spec
    return spec


def get_device(name: str) -> DeviceSpec:
    try:
        return DEVICE_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown device {name!r}; registered: {sorted(DEVICE_REGISTRY)}"
        ) from None


def list_devices() -> list[str]:
    return sorted(DEVICE_REGISTRY)


register_device(DeviceSpec(
    name="host_cpu",
    peak_flops=5e10,        # 1-core CPU stand-in for the edge device
    hbm_bw=2e10,
    ici_bw=1e9,             # loopback; collectives are degenerate
    hbm_bytes=4e9,
    idle_w=10.0,            # desktop-class package idle
    peak_w=65.0,            # typical TDP
))

register_device(DeviceSpec(
    name="tx2_like",
    peak_flops=1.33e12,     # TX2 256-core Pascal, fp16
    hbm_bw=59.7e9,          # LPDDR4 128-bit
    ici_bw=1e9,
    hbm_bytes=8e9,          # unified memory
    launch_overhead_s=2e-4, # CUDA kernel dispatch per step (order-of-magnitude)
    alloc_granularity=512,  # CUDA caching-allocator block rounding
    idle_w=1.4,             # module idle, board rails excluded
    peak_w=15.0,            # MAXN budget
    # nvpmodel-style operating points (Jetson characterization paper):
    # MAXQ caps the budget at 7.5 W by halving clocks — the roofline
    # denominators move with the envelope, not just the watts.
    power_modes={
        "MAXN": {"idle_w": 1.4, "peak_w": 15.0},
        "MAXQ": {"idle_w": 1.4, "peak_w": 7.5,
                 "peak_flops": 0.67e12, "hbm_bw": 40.6e9},
        "MAXP_CORE_ALL": {"idle_w": 1.4, "peak_w": 11.0,
                          "peak_flops": 1.12e12},
    },
))

register_device(DeviceSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    idle_w=55.0,            # order-of-magnitude chip+HBM idle
    peak_w=170.0,
))


# ---------------------------------------------------------------------------
# Resolution and auto-derivation.
# ---------------------------------------------------------------------------


def resolve_device(device, default: str = "host_cpu") -> DeviceSpec:
    """Turn any accepted device description into a :class:`DeviceSpec`.

    Accepts a spec (returned as-is), a registry name, a path to a persisted
    spec (``.json`` / ``.npz``), a legacy hardware-constant dict, or ``None``
    (the registry ``default``).
    """
    if device is None:
        return get_device(default)
    if isinstance(device, DeviceSpec):
        return device
    if isinstance(device, dict):
        return DeviceSpec.from_hw_table(device)
    if isinstance(device, str):
        if device in DEVICE_REGISTRY:
            return get_device(device)
        if device.endswith((".json", ".npz")) or os.sep in device:
            return load_device_spec(device)
        return get_device(device)  # raises with the registered names
    raise TypeError(f"cannot resolve a DeviceSpec from {device!r}")


def from_jax_device(dev=None) -> DeviceSpec:
    """Derive an (uncalibrated) spec from a live jax device: the registry
    template for its platform, named after the device kind, with the memory
    capacity read from ``memory_stats()`` when the runtime exposes it."""
    if dev is None:
        import jax

        dev = jax.devices()[0]
    platform = getattr(dev, "platform", "cpu")
    base = get_device({"tpu": "tpu_v5e", "gpu": "tx2_like"}.get(platform, "host_cpu"))
    kind = getattr(dev, "device_kind", platform) or platform
    name = "jax_" + "".join(c if c.isalnum() else "_" for c in str(kind).lower())
    hbm = base.hbm_bytes
    try:
        stats = dev.memory_stats() or {}
        hbm = float(stats.get("bytes_limit", hbm)) or hbm
    except Exception:
        pass
    spec = replace(base, name=name, hbm_bytes=hbm,
                   meta={"platform": platform, "device_kind": str(kind)})
    # Overwrite any previous derivation: the registry entry and the returned
    # spec must agree (memory_stats can change between calls, e.g. with XLA
    # preallocation settings — a stale entry would give resolve_device(name)
    # a different capacity than the spec the caller just received).
    return register_device(spec, overwrite=True)


# ---------------------------------------------------------------------------
# Persistence (atomic, JSON or NPZ by extension — the fileio contract every
# on-disk artifact in this repo follows).
# ---------------------------------------------------------------------------


def save_device_spec(path: str, spec: DeviceSpec) -> None:
    from repro.core.fileio import atomic_write_bytes, atomic_write_json

    if path.endswith(".npz"):
        import numpy as np

        arrays = {
            f: np.asarray(getattr(spec, f))
            for f in FITTED_FIELDS
            if f not in ("combine", "class_coeffs", "power_modes")
        }
        header = json.dumps({"name": spec.name, "combine": spec.combine,
                             "class_coeffs": spec.class_coeffs,
                             "power_modes": spec.power_modes,
                             "meta": spec.meta})
        arrays["header"] = np.frombuffer(header.encode(), dtype=np.uint8)
        atomic_write_bytes(path, lambda f: np.savez_compressed(f, **arrays),
                           suffix=".npz")
    else:
        atomic_write_json(path, spec.to_dict())


def load_device_spec(path: str) -> DeviceSpec:
    if path.endswith(".npz"):
        import numpy as np

        with np.load(path) as z:
            header = json.loads(bytes(z["header"].tobytes()).decode())
            d = {f: z[f].item() for f in FITTED_FIELDS
                 if f not in ("combine", "class_coeffs", "power_modes")
                 and f in z}
            d["alloc_granularity"] = int(d["alloc_granularity"])
            d["calibrated"] = bool(d["calibrated"])
            d.update(name=header["name"], combine=header["combine"],
                     class_coeffs=header.get("class_coeffs", {}),
                     power_modes=header.get("power_modes", {}),
                     meta=header.get("meta", {}))
            return DeviceSpec(**d)
    with open(path) as f:
        return DeviceSpec.from_dict(json.load(f))
