"""Pure-jnp/XLA oracle for the MM-convolution kernel — this is also the
paper's *materialising* im2col variant: the explicit im2col matrix
(``mem_i2c_total`` feature) is built in memory, then one matmul runs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conv_ref", "conv_im2col_ref"]


def conv_ref(x, w, *, stride=1, padding=0):
    """XLA convolution (NHWC / HWIO)."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_im2col_ref(x, w, *, stride=1, padding=0):
    """Materialised im2col + single matmul (paper's mem_i2c_total variant)."""
    N, H, W, C = x.shape
    KH, KW, _, O = w.shape
    OH = 1 + (H + 2 * padding - KH) // stride
    OW = 1 + (W + 2 * padding - KW) // stride
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    cols = []
    for i in range(KH):
        for j in range(KW):
            patch = jax.lax.slice(
                x, (0, i, j, 0),
                (N, i + (OH - 1) * stride + 1, j + (OW - 1) * stride + 1, C),
                (1, stride, stride, 1),
            )
            cols.append(patch.reshape(N, OH * OW, C))
    im2col = jnp.concatenate(cols, axis=-1)          # (N, OH·OW, KH·KW·C)
    wmat = w.transpose(0, 1, 2, 3).reshape(KH * KW * C, O)
    y = jnp.einsum("npk,ko->npo", im2col.astype(jnp.float32),
                   wmat.astype(jnp.float32))
    return y.reshape(N, OH, OW, O).astype(x.dtype)
