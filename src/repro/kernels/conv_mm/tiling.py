"""Tiling search space + static cost model for the MM-convolution kernel.

Executable form of the VMEM arithmetic in ``kernel.py``'s docstring.
Grid = (N, O/block_o) with the o-axis innermost, so Pallas's revisit
elision fetches each padded image once per n while every weight tile is
fetched per program — total HBM traffic is block-independent and
``block_o`` trades grid-step count and MXU lane fill against the
(weights + accumulator) VMEM working set.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    largest_dividing_block,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default"]

# Lane-aligned seeds plus small fallbacks for narrow test layers; each is
# snapped to the largest divisor of O it covers, then deduped.
_BLOCK_SEEDS = (8, 16, 32, 64, 128, 256, 512)


def shape_key(x_shape, w_shape, *, stride: int, padding: int, dtype) -> dict:
    N, H, W, C = (int(d) for d in x_shape)
    KH, KW, _, O = (int(d) for d in w_shape)
    return {"N": N, "H": H, "W": W, "C": C, "KH": KH, "KW": KW, "O": O,
            "stride": int(stride), "padding": int(padding),
            "dtype": str(dtype)}


def _geom(shape: dict):
    s, p = shape["stride"], shape["padding"]
    OH = 1 + (shape["H"] + 2 * p - shape["KH"]) // s
    OW = 1 + (shape["W"] + 2 * p - shape["KW"]) // s
    Hp, Wp = shape["H"] + 2 * p, shape["W"] + 2 * p
    return OH, OW, Hp, Wp


def candidates(shape: dict) -> list[dict]:
    O = shape["O"]
    blocks = {largest_dividing_block(O, b) for b in _BLOCK_SEEDS}
    blocks.add(O)
    return [{"block_o": b} for b in sorted(blocks)]


def default(shape: dict) -> dict:
    return {"block_o": min(shape["O"], 256)}


def cost(shape: dict, config: dict) -> KernelCost:
    N, C, O = shape["N"], shape["C"], shape["O"]
    KH, KW = shape["KH"], shape["KW"]
    bo = largest_dividing_block(O, config.get("block_o"))
    OH, OW, Hp, Wp = _geom(shape)
    bpe = bytes_per_element(shape["dtype"])
    n_bo = O // bo

    flops = 2.0 * N * OH * OW * KH * KW * C * O
    # x once per image (o innermost ⇒ revisit-elided), w per program, y once
    hbm = bpe * (N * Hp * Wp * C + N * KH * KW * C * O + N * OH * OW * O)
    vmem = (bpe * (Hp * Wp * C + KH * KW * C * bo + OH * OW * bo)
            + 4.0 * OH * OW * bo)  # f32 accumulator
    return KernelCost(
        op="conv_mm", op_class="conv", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=N * n_bo,
        mxu_min_dim=min(bo, C, OH * OW),
    )


def _runner(shape: dict, config: dict):
    import jax.numpy as jnp
    import numpy as np

    from .ops import conv_mm

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((shape["N"], shape["H"], shape["W"],
                                         shape["C"])), shape["dtype"])
    w = jnp.asarray(rng.standard_normal((shape["KH"], shape["KW"], shape["C"],
                                         shape["O"])), shape["dtype"])
    bo = config["block_o"]
    return lambda: conv_mm(x, w, stride=shape["stride"],
                           padding=shape["padding"], block_o=bo)


register_tiling(TilingModel(
    name="conv_mm", candidates=candidates, cost=cost, default=default,
    runner=_runner,
), overwrite=True)
