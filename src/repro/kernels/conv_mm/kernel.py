"""Matrix-multiplication convolution Pallas TPU kernel.

This is the paper's *Matrix Multiplication* conv algorithm (§2, App. B.2.2)
adapted to the TPU: cuDNN's choice between storing the full im2col matrix
(``mem_i2c_total``) and an index-only variant (``mem_i2c_index``) maps onto
the MXU as a **fused im2col+matmul** — patches are formed on the fly from
the VMEM-resident input tile and fed straight to the MXU, so the im2col
matrix never exists in HBM.  The kernel therefore realises the paper's
index variant natively; ``ref.py``'s XLA convolution stands in for the
materialising variant.

Mapping: grid = (N, O/block_o).  Each program holds one padded input image
(H+2p, W+2p, C) and a (KH·KW·C, block_o) weight tile in VMEM and accumulates
y(n) = Σ_{kh,kw} patch(kh,kw) @ w[kh,kw] in f32 — KH·KW MXU matmuls of
(OH·OW, C) × (C, block_o).

VMEM (32×32×256 input, 3×3 kernel, block_o=256, f32):
  x 1.1 MiB + w 2.25 MiB + acc 1 MiB ≈ 4.4 MiB « 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.autotune import largest_dividing_block

__all__ = ["conv_mm_kernel"]


def _conv_body(x_ref, w_ref, o_ref, *, kh, kw, stride, oh, ow):
    """x_ref: (1, Hp, Wp, C) padded; w_ref: (kh, kw, C, bo); o: (1, oh, ow, bo)."""
    C = x_ref.shape[-1]
    bo = w_ref.shape[-1]
    acc = jnp.zeros((oh * ow, bo), jnp.float32)
    x = x_ref[0]
    for i in range(kh):
        for j in range(kw):
            # strided window: rows i..i+oh·s, cols j..j+ow·s (static slices)
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, C),
                (stride, stride, 1),
            )  # (oh, ow, C)
            w_ij = w_ref[i, j]  # (C, bo)
            acc += jax.lax.dot(
                patch.reshape(oh * ow, C), w_ij,
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.reshape(oh, ow, bo).astype(o_ref.dtype)


def conv_mm_kernel(
    x, w, *, stride: int = 1, padding: int = 0,
    block_o: int | None = None, interpret: bool = False,
):
    """x: (N, H, W, C) NHWC;  w: (KH, KW, C, O) HWIO  →  (N, OH, OW, O)."""
    N, H, W, C = x.shape
    KH, KW, _, O = w.shape
    OH = 1 + (H + 2 * padding - KH) // stride
    OW = 1 + (W + 2 * padding - KW) // stride
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    Hp, Wp = x.shape[1], x.shape[2]
    # A requested block that doesn't tile O falls back to the largest
    # dividing block ≤ requested (e.g. O=96, block_o=256 → 96) so arbitrary
    # channel counts run instead of crashing on a divisibility assert.
    block_o = largest_dividing_block(O, block_o or min(O, 256))

    kernel = functools.partial(
        _conv_body, kh=KH, kw=KW, stride=stride, oh=OH, ow=OW
    )
    return pl.pallas_call(
        kernel,
        grid=(N, O // block_o),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda n, o: (n, 0, 0, 0)),
            pl.BlockSpec((KH, KW, C, block_o), lambda n, o: (0, 0, 0, o)),
        ],
        out_specs=pl.BlockSpec((1, OH, OW, block_o), lambda n, o: (n, 0, 0, o)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, O), x.dtype),
        interpret=interpret,
    )(x, w)
