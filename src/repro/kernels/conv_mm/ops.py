"""Jitted public wrapper for the MM-convolution kernel.

``block_o=None`` consults the process autotuner (roofline-ranked,
device-keyed cache — see ``repro.kernels.autotune``) for this launch
shape; an explicit ``block_o`` always wins.  Resolution happens outside
the jit so the tuned value participates in the static-arg cache key.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.autotune import tuned_config

from . import tiling
from .kernel import conv_mm_kernel
from .ref import conv_ref

__all__ = ["conv_mm"]


@partial(jax.jit, static_argnames=("stride", "padding", "block_o", "interpret"))
def _conv_mm_jit(x, w, *, stride, padding, block_o, interpret):
    if jax.default_backend() == "tpu" or interpret:
        return conv_mm_kernel(
            x, w, stride=stride, padding=padding, block_o=block_o,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    return conv_ref(x, w, stride=stride, padding=padding)


def conv_mm(x, w, *, stride=1, padding=0, block_o=None, interpret=False):
    if block_o is None:
        shape = tiling.shape_key(x.shape, w.shape, stride=stride,
                                 padding=padding, dtype=x.dtype)
        block_o = tuned_config("conv_mm", shape,
                               tiling.default(shape)).get("block_o")
    return _conv_mm_jit(x, w, stride=stride, padding=padding,
                        block_o=block_o, interpret=interpret)
