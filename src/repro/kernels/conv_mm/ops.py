"""Jitted public wrapper for the MM-convolution kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import conv_mm_kernel
from .ref import conv_ref

__all__ = ["conv_mm"]


@partial(jax.jit, static_argnames=("stride", "padding", "block_o", "interpret"))
def conv_mm(x, w, *, stride=1, padding=0, block_o=None, interpret=False):
    if jax.default_backend() == "tpu" or interpret:
        return conv_mm_kernel(
            x, w, stride=stride, padding=padding, block_o=block_o,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    return conv_ref(x, w, stride=stride, padding=padding)
