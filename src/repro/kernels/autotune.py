"""Kernel autotuner: roofline-pruned block-size search, device-keyed cache.

perf4sight's core move — *predict cheaply, measure only what matters*
(paper §5–6) — applied to our own Pallas hot paths.  Brute-force timing
every (block_q, block_k, block_o, chunk) point on-device is exactly the
cost the paper's toolflow exists to avoid, so the tuner works in three
stages:

1. **Enumerate** — each kernel package exports a :class:`TilingModel`
   whose ``candidates(shape)`` generates the legal block configurations
   for a concrete launch shape (always including the kernel's static
   default, so tuning can never regress the modelled time).
2. **Prune + rank** — the model's ``cost(shape, config)`` returns a
   static :class:`KernelCost` (FLOPs, HBM bytes, VMEM working set, grid
   steps — the same formulas as the kernel docstrings and
   ``benchmarks/kernel_bench.py``, now executable).  Candidates whose
   working set exceeds the VMEM budget are rejected outright; the rest
   are ranked by roofline time under the calibrated
   :class:`~repro.engine.devices.DeviceSpec`.
3. **Measure (TPU only)** — the top-K survivors are wall-clock timed
   through the tiling model's ``runner``.  Off-TPU (interpret mode)
   wall-clock is meaningless, so the model ranking alone decides.

Winners persist in a :class:`TuningCache` — the same atomic, corrupt-
tolerant JSON contract as ``engine/cache.py`` (via ``core/fileio``),
with every key salted by the device fingerprint so two specs can never
alias an entry.  A second ``tune()`` for the same (kernel, shape,
device) is a pure cache hit: no re-ranking, no re-timing.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.core.fileio import atomic_write_json, load_json_tolerant
from repro.costmodel import OpCost

__all__ = [
    "KernelCost",
    "TilingModel",
    "TuningCache",
    "KernelTuner",
    "register_tiling",
    "get_tiling",
    "list_tilings",
    "roofline_seconds",
    "vmem_ok",
    "largest_dividing_block",
    "autotune_enabled",
    "get_tuner",
    "set_tuner",
    "tuned_config",
]

# TPU v5e-class VMEM per core; the budget leaves headroom for compiler
# scratch, register spills and double-buffered pipeline copies that the
# static working-set formulas don't see.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET_FRACTION = 0.9

# MXU systolic-array edge: matmul operand dims below this underfill the
# unit, scaling effective peak FLOP/s by ~dim/128 (see docs/kernels.md).
MXU_DIM = 128

# Per sequenced step (grid program or inner loop trip): block-index
# bookkeeping + pipeline bubble.  Order-of-magnitude constant — it only
# needs to break ties between configs with identical roofline terms
# (favouring fewer, larger blocks), not predict absolute latency.
STEP_OVERHEAD_S = 2e-7

BYTES_PER_ELEMENT = {
    "float32": 4, "bfloat16": 2, "float16": 2, "float64": 8, "int8": 1,
}


def bytes_per_element(dtype: str) -> int:
    return BYTES_PER_ELEMENT.get(str(dtype), 4)


def largest_dividing_block(n: int, requested: int | None) -> int:
    """Largest block size that divides ``n`` and is ≤ ``requested``.

    The documented fallback for every block-size argument: a requested
    block that doesn't tile the dimension evenly degrades to the nearest
    legal (dividing) size instead of crashing the launch.  ``None`` or a
    request ≥ n yields n itself (single block)."""
    n = int(n)
    if n <= 0:
        raise ValueError(f"cannot block a non-positive dim: {n}")
    b = max(1, min(int(requested) if requested else n, n))
    while n % b:
        b -= 1
    return b


@dataclass(frozen=True, kw_only=True)
class KernelCost(OpCost):
    """Static cost of one kernel launch under one block configuration — a
    thin view over the shared :class:`~repro.costmodel.OpCost` record, so
    tuner rows and calibration rows carry one schema (a timed winner feeds
    ``engine/calibrate.timed_tuning_rows`` as an op-class-attributed
    latency row, exactly like a parsed HLO instruction).

    On top of the OpCost fields (``flops``, ``hbm_bytes``, ``vmem_bytes``,
    ``op_class``, …): ``n_steps`` counts sequenced steps — grid programs
    plus inner-loop trips — each paying ``STEP_OVERHEAD_S``, and
    ``mxu_min_dim`` is the smallest matmul operand dim the tiling
    produces; it scales effective MXU peak by ``min(1, dim/128)``."""

    n_steps: int = 1
    mxu_min_dim: int = MXU_DIM

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


def vmem_ok(cost: KernelCost, *, budget_bytes: float | None = None) -> bool:
    limit = (VMEM_BYTES * VMEM_BUDGET_FRACTION
             if budget_bytes is None else budget_bytes)
    return cost.vmem_bytes <= limit


def roofline_seconds(cost: KernelCost, device) -> float:
    """Modelled launch time on ``device`` (a DeviceSpec).

    Classic roofline over the device's calibrated denominators — via
    ``DeviceSpec.combine_terms``, so a calibrated spec's additive
    relaxation and launch overhead apply here exactly as they do in the
    cost engine — plus the per-step sequencing overhead."""
    util = min(1.0, max(int(cost.mxu_min_dim), 1) / MXU_DIM)
    t = device.combine_terms(
        cost.flops / (device.peak_flops * util),
        cost.hbm_bytes / device.hbm_bw,
    )
    return t + cost.n_steps * STEP_OVERHEAD_S


@dataclass(frozen=True)
class TilingModel:
    """One kernel's tiling search space and static cost model.

    ``candidates(shape) -> list[dict]`` — legal block configs (must
    include ``default(shape)``).
    ``cost(shape, config) -> KernelCost`` — static launch cost.
    ``default(shape) -> dict`` — the hand-picked constants the kernel
    used before autotuning (the tuner's baseline).
    ``runner(shape, config) -> Callable[[], None]`` — optional: builds a
    zero-arg closure running the real kernel (for on-TPU timing).
    """

    name: str
    candidates: Callable
    cost: Callable
    default: Callable
    runner: Callable | None = None


_TILINGS: dict[str, TilingModel] = {}
_BUILTIN_MODULES = (
    "repro.kernels.conv_mm.tiling",
    "repro.kernels.flash_attention.tiling",
    "repro.kernels.ssm_scan.tiling",
    "repro.kernels.moe_dispatch.tiling",
    "repro.kernels.serve_kv.tiling",
    "repro.kernels.paged_decode.tiling",
)


def register_tiling(model: TilingModel, *, overwrite: bool = False) -> TilingModel:
    if model.name in _TILINGS and not overwrite:
        raise ValueError(f"tiling {model.name!r} already registered")
    _TILINGS[model.name] = model
    return model


def _ensure_builtin() -> None:
    import importlib

    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def get_tiling(name: str) -> TilingModel:
    if name not in _TILINGS:
        _ensure_builtin()
    try:
        return _TILINGS[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel tiling {name!r}; registered: {sorted(_TILINGS)}"
        ) from None


def list_tilings() -> list[str]:
    _ensure_builtin()
    return sorted(_TILINGS)


# ---------------------------------------------------------------------------
# Persistence: the tuning cache (engine/cache.py idiom on core/fileio).
# ---------------------------------------------------------------------------


class TuningCache:
    """Content-keyed on-disk winners: {key: {"config": ..., meta...}}.

    Keys are sha1(kernel | canonical shape json | device fingerprint) —
    built by :meth:`KernelTuner.key` — so entries tuned for one device
    spec can never be served to another.  Atomic writes, corrupt files
    quarantined and restarted from empty (``core/fileio`` contract)."""

    def __init__(self, path: str):
        self.path = path
        self._data: dict[str, dict] = load_json_tolerant(path)

    def get(self, key: str) -> dict | None:
        entry = self._data.get(key)
        return dict(entry) if entry else None

    def put(self, key: str, entry: dict) -> None:
        self._data[key] = dict(entry)

    def entries(self) -> list[dict]:
        """All cached winners (copies) — the calibration residual feed
        (``engine/calibrate.timed_tuning_rows``) iterates these."""
        return [dict(e) for e in self._data.values()]

    def flush(self) -> None:
        # Merge-on-flush: re-read the file and lay our entries over it, so
        # concurrent tuners sharing one path (multi-process launch, or two
        # devices salting into the same file) append rather than clobber.
        # Keys are content hashes — a colliding key carries the same shape
        # and device, so last-writer-wins on an entry is benign.
        on_disk = load_json_tolerant(self.path)
        if on_disk:
            self._data = {**on_disk, **self._data}
        atomic_write_json(self.path, self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        return key in self._data


# ---------------------------------------------------------------------------
# The tuner.
# ---------------------------------------------------------------------------


class KernelTuner:
    """Roofline-pruned block-size search with per-device memoization.

    ``tune(kernel, shape)`` resolution order: in-process memo → on-disk
    :class:`TuningCache` → model-pruned search (→ top-K wall-clock only
    when ``measure`` and a runner are available).  ``hits``/``misses``/
    ``timed`` count those paths for benchmarks and tests.
    """

    def __init__(self, device=None, cache: TuningCache | str | None = None,
                 *, top_k: int = 3, measure: bool | None = None,
                 vmem_budget_bytes: float | None = None):
        self._device = device
        self.cache = TuningCache(cache) if isinstance(cache, str) else cache
        self.top_k = max(1, int(top_k))
        self.measure = measure
        self.vmem_budget_bytes = vmem_budget_bytes
        self._memo: dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        self.timed = 0

    # -- device ------------------------------------------------------------

    @property
    def device(self):
        """The DeviceSpec keys are salted with (lazily derived from the
        live jax backend when not configured)."""
        if self._device is None:
            from repro.engine.devices import from_jax_device

            self._device = from_jax_device()
        elif isinstance(self._device, (str, dict)):
            from repro.engine.devices import resolve_device

            self._device = resolve_device(self._device)
        return self._device

    def _should_measure(self) -> bool:
        if self.measure is not None:
            return self.measure
        try:
            import jax

            return jax.default_backend() == "tpu"
        except Exception:
            return False

    # -- keys --------------------------------------------------------------

    def key(self, kernel: str, shape: dict) -> str:
        blob = f"{kernel}|{json.dumps(shape, sort_keys=True)}|{self.device.fingerprint()}"
        return hashlib.sha1(blob.encode()).hexdigest()

    # -- search ------------------------------------------------------------

    def tune(self, kernel: str, shape: dict) -> dict:
        """Best block config for one concrete launch shape (a plain dict
        of static kwargs for the kernel, e.g. ``{"block_o": 128}``)."""
        key = self.key(kernel, shape)
        entry = self._memo.get(key)
        if entry is None and self.cache is not None:
            entry = self.cache.get(key)
            if entry is not None:
                self._memo[key] = entry
        if entry is not None:
            self.hits += 1
            return dict(entry["config"])
        self.misses += 1
        entry = self._search(get_tiling(kernel), shape)
        self._memo[key] = entry
        if self.cache is not None:
            self.cache.put(key, entry)
            self.cache.flush()
        return dict(entry["config"])

    def explain(self, kernel: str, shape: dict) -> dict:
        """The full cached entry (config + modelled times + provenance)."""
        self.tune(kernel, shape)
        return dict(self._memo[self.key(kernel, shape)])

    def _search(self, tiling: TilingModel, shape: dict) -> dict:
        device = self.device
        default = tiling.default(shape)
        cands = list(tiling.candidates(shape))
        if default not in cands:
            cands.append(default)

        scored = []
        rejected_vmem = 0
        for cfg in cands:
            cost = tiling.cost(shape, cfg)
            if not vmem_ok(cost, budget_bytes=self.vmem_budget_bytes):
                rejected_vmem += 1
                continue
            scored.append((roofline_seconds(cost, device), cost, cfg))
        if not scored:
            # Nothing fits the budget (huge shape): least-infeasible
            # candidate, flagged — the kernel may still spill but runs.
            cost_cfgs = [(tiling.cost(shape, c), c) for c in cands]
            cost, cfg = min(cost_cfgs, key=lambda t: t[0].vmem_bytes)
            scored = [(roofline_seconds(cost, device), cost, cfg)]
        scored.sort(key=lambda t: (t[0], json.dumps(t[2], sort_keys=True)))

        best_t, best_cost, best_cfg = scored[0]
        source = "model"
        if self._should_measure() and tiling.runner is not None:
            best_t, best_cfg = self._time_top_k(tiling, shape, scored)
            best_cost = tiling.cost(shape, best_cfg)
            source = "timed"

        default_cost = tiling.cost(shape, default)
        return {
            "kernel": tiling.name,
            "config": dict(best_cfg),
            "shape": dict(shape),  # lets calibration rebuild the cost terms
            "source": source,
            "device": device.name,
            "model_us": best_t * 1e6 if source == "model" else
            roofline_seconds(best_cost, device) * 1e6,
            "measured_us": best_t * 1e6 if source == "timed" else None,
            "default_config": dict(default),
            "default_model_us": roofline_seconds(default_cost, device) * 1e6,
            "vmem_kb": best_cost.vmem_bytes / 1024,
            "candidates": len(cands),
            "rejected_vmem": rejected_vmem,
        }

    def _time_top_k(self, tiling: TilingModel, shape: dict, scored) -> tuple[float, dict]:
        import jax

        best = (float("inf"), scored[0][2])
        for _, _, cfg in scored[: self.top_k]:
            fn = tiling.runner(shape, cfg)
            jax.block_until_ready(fn())  # compile
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts.append(time.perf_counter() - t0)
            self.timed += 1
            t = min(ts)
            if t < best[0]:
                best = (t, cfg)
        return best


# ---------------------------------------------------------------------------
# Process-default tuner: what the ops wrappers and model code consult when
# no explicit block sizes are passed.
# ---------------------------------------------------------------------------

_DEFAULT_TUNER: KernelTuner | None = None


def autotune_enabled() -> bool:
    """Gate for implicit tuning in ops/model call sites (REPRO_AUTOTUNE=0
    restores the hand-picked constants everywhere)."""
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def _default_cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNING_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "kernel_tuning.json"),
    )


def get_tuner() -> KernelTuner:
    global _DEFAULT_TUNER
    if _DEFAULT_TUNER is None:
        _DEFAULT_TUNER = KernelTuner(cache=_default_cache_path())
    return _DEFAULT_TUNER


def set_tuner(tuner: KernelTuner | None) -> None:
    """Install (or with None, reset) the process-default tuner — tests and
    benchmarks point it at a scratch cache/device."""
    global _DEFAULT_TUNER
    _DEFAULT_TUNER = tuner


def tuned_config(kernel: str, shape: dict, default: dict | None = None) -> dict:
    """Best-effort tuned config for implicit call sites: returns ``default``
    (or {}) when autotuning is disabled or the lookup fails — a model
    forward must never die because a cache directory is read-only."""
    if not autotune_enabled():
        return dict(default or {})
    try:
        return get_tuner().tune(kernel, shape)
    except Exception:
        return dict(default or {})
