"""Pallas compute kernels (conv_mm, flash_attention, ssm_scan) and the
block-size autotuner that picks their launch configurations.

Each kernel package ships ``kernel.py`` (the Pallas body), ``ops.py``
(the jitted public wrapper; block sizes default to autotuned values),
``ref.py`` (pure-jnp oracle) and ``tiling.py`` (candidate generator +
static cost model registered with :mod:`repro.kernels.autotune`).
"""

from repro.kernels.autotune import (
    KernelCost,
    KernelTuner,
    TilingModel,
    TuningCache,
    autotune_enabled,
    get_tiling,
    get_tuner,
    largest_dividing_block,
    list_tilings,
    register_tiling,
    roofline_seconds,
    set_tuner,
    tuned_config,
    vmem_ok,
)

__all__ = [
    "KernelCost",
    "KernelTuner",
    "TilingModel",
    "TuningCache",
    "autotune_enabled",
    "get_tiling",
    "get_tuner",
    "largest_dividing_block",
    "list_tilings",
    "register_tiling",
    "roofline_seconds",
    "set_tuner",
    "tuned_config",
    "vmem_ok",
]
