"""SSD intra-chunk Pallas TPU kernel (Mamba-2 state-space duality).

The chunked SSD algorithm splits into a *parallel* part (quadratic
attention-like compute inside each chunk + per-chunk state summaries) and a
tiny *sequential* part (the inter-chunk state recurrence).  The parallel
part is ~99.9% of FLOPs and is what this kernel implements; the recurrence
stays in JAX (``ops.py``) — matching how the hardware wants it: big MXU
matmuls per chunk, a short scan over (H, P, N) states between chunks.

Grid = (B, n_chunks, H).  Per program, VMEM holds one chunk of one head:
x (l, P), a (l,), B/C (l, N) — for l=128, P=64, N=128 that is ≈ 0.2 MiB.
Outputs: y_diag (l, P), chunk state (P, N), chunk decay (scalar), and the
within-chunk cumulative decay (l,) needed for the y_off correction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_kernel"]


def _ssd_body(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, dec_ref, cum_ref):
    l, P = x_ref.shape[1], x_ref.shape[3]
    N = b_ref.shape[-1]
    x = x_ref[0, :, 0, :].astype(jnp.float32)     # (l, P)
    a = a_ref[0, :, 0].astype(jnp.float32)        # (l,)
    Bm = b_ref[0].astype(jnp.float32)             # (l, N)
    Cm = c_ref[0].astype(jnp.float32)             # (l, N)

    cum = jnp.cumsum(a)                            # (l,)
    last = cum[l - 1]
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i else 0
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.iota(jnp.int32, l)
    tril = ii[:, None] >= ii[None, :]
    L = jnp.where(tril, jnp.exp(seg), 0.0)         # (l, l)
    s = jax.lax.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (l, l)
    y = jax.lax.dot(s * L, x, preferred_element_type=jnp.float32)  # (l, P)

    # chunk state: Σ_i exp(cum_last - cum_i) B_i ⊗ x_i  → (N, P)
    decay_states = jnp.exp(last - cum)             # (l,)
    st = jax.lax.dot(
        (Bm * decay_states[:, None]).T, x, preferred_element_type=jnp.float32
    )                                               # (N, P)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)
    dec_ref[0, 0, 0] = jnp.exp(last).astype(dec_ref.dtype)
    cum_ref[0, 0, 0] = cum.astype(cum_ref.dtype)


def ssd_chunk_kernel(xh, a, Bm, Cm, *, chunk: int, interpret: bool = False):
    """Intra-chunk SSD.

    xh: (B, S, H, P) dt-scaled inputs;  a: (B, S, H) log-decays;
    Bm, Cm: (B, S, N) (single B/C group, broadcast over heads).
    Returns (y_diag (B,S,H,P), states (B,nc,H,N,P), chunk_decay (B,nc,H),
             cum_a (B,nc,H,l)).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    # (B, nc, l, H, P) views via index maps (no copies)
    grid = (B, nc, H)
    f32 = jnp.float32
    outs = [
        jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),      # y_diag
        jax.ShapeDtypeStruct((B, nc, H, N, P), f32),       # states
        jax.ShapeDtypeStruct((B, nc, H), f32),             # chunk decay
        jax.ShapeDtypeStruct((B, nc, H, chunk), f32),      # cum within chunk
    ]
    kernel = _ssd_body
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c, h: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, c, h: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c, h: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c, h: (b, c, h)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, c, h: (b, c, h, 0)),
        ],
        out_shape=outs,
        interpret=interpret,
    )(xh, a, Bm, Cm)
