"""Tiling search space + static cost model for the SSD (Mamba-2) kernel.

The chunk length is a genuine optimum, not a monotone knob: intra-chunk
compute is quadratic in ``chunk`` (the (l, l) decay/score tiles), while
the inter-chunk state traffic and sequential recurrence shrink as 1/chunk
— state write-back is 4·B·H·N·P·(S/l) bytes and the ``lax.scan`` adds
S/l dependent steps.  The roofline model balances the two per device.

Grid = (B, n_chunks, H), h innermost: B/C blocks (index independent of h)
are fetched once per (b, chunk); x/a per program.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    largest_dividing_block,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default"]

_CHUNK_SEEDS = (16, 32, 64, 128, 256, 512)


def shape_key(xh_shape, n_state: int, *, dtype) -> dict:
    B, S, H, P = (int(d) for d in xh_shape)
    return {"B": B, "S": S, "H": H, "P": P, "N": int(n_state),
            "dtype": str(dtype)}


def candidates(shape: dict) -> list[dict]:
    S = shape["S"]
    chunks = {largest_dividing_block(S, c) for c in _CHUNK_SEEDS} | {S}
    return [{"chunk": c} for c in sorted(chunks)]


def default(shape: dict) -> dict:
    return {"chunk": largest_dividing_block(shape["S"], 128)}


def cost(shape: dict, config: dict) -> KernelCost:
    B, S, H, P, N = (shape[k] for k in ("B", "S", "H", "P", "N"))
    l = largest_dividing_block(S, config.get("chunk"))
    nc = S // l
    bpe = bytes_per_element(shape["dtype"])

    # intra-chunk matmuls (C·Bᵀ and (s∘L)·x are l×l) + state build/apply
    flops = 2.0 * B * S * H * (l * (N + P) + 2.0 * N * P)
    hbm = (bpe * (2.0 * B * S * H * P)        # x in, y_diag out
           + 4.0 * 2 * B * S * H              # a in (f32 view), cum out
           + bpe * 2.0 * B * S * N            # B/C once per (b, chunk)
           + 4.0 * B * nc * H * (N * P + 1))  # states + chunk decay out
    vmem = (bpe * (l * P + 2 * l * N)         # x, B/C blocks
            + 4.0 * (2 * l * l               # L decay + score tiles (f32)
                     + l * P                  # y accumulator
                     + N * P + 2 * l))        # state tile, cum/decay vectors
    return KernelCost(
        op="ssm_scan", op_class="matmul", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=B * nc * H + nc,              # grid programs + scan steps
        mxu_min_dim=min(l, N, P),
    )


def _runner(shape: dict, config: dict):
    import jax.numpy as jnp
    import numpy as np

    from .ops import ssd

    rng = np.random.default_rng(0)
    B, S, H, P, N = (shape[k] for k in ("B", "S", "H", "P", "N"))
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), shape["dtype"])
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B, S, H)),
                             jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), shape["dtype"])
    ch = config["chunk"]
    return lambda: ssd(xh, a, Bm, Bm, chunk=ch)[0]


register_tiling(TilingModel(
    name="ssm_scan", candidates=candidates, cost=cost, default=default,
    runner=_runner,
), overwrite=True)
