"""Pure-jnp oracle for the SSD kernel: the chunked scan from
``repro.models.layers.ssd_scan`` restricted to a single B/C group, plus a
naive O(S²) sequential-recurrence oracle used to validate both."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ssd_scan

__all__ = ["ssd_ref", "ssd_naive"]


def ssd_ref(xh, a, Bm, Cm, *, chunk: int = 128, initial_state=None):
    """xh: (B,S,H,P); a: (B,S,H); Bm/Cm: (B,S,N) → (y, final_state)."""
    return ssd_scan(xh, a, Bm[:, :, None, :], Cm[:, :, None, :], chunk,
                    initial_state=initial_state)


def ssd_naive(xh, a, Bm, Cm, initial_state=None):
    """Token-by-token recurrence (the SSM definition, no chunking)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    st = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))
    ys = []
    for t in range(S):
        dec = jnp.exp(a[:, t].astype(jnp.float32))             # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xh[:, t].astype(jnp.float32),
                         Bm[:, t].astype(jnp.float32))
        st = st * dec[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", st, Cm[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(xh.dtype), st
