"""Jitted SSD op: Pallas intra-chunk kernel + JAX inter-chunk recurrence.

``chunk=None`` consults the process autotuner (roofline-ranked,
device-keyed cache — ``repro.kernels.autotune``); an explicit chunk
always wins, snapped to the largest divisor of S ≤ the request so
arbitrary sequence lengths run.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.autotune import largest_dividing_block, tuned_config

from . import tiling
from .kernel import ssd_chunk_kernel
from .ref import ssd_ref

__all__ = ["ssd"]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(xh, a, Bm, Cm, *, chunk, initial_state=None, interpret=False):
    if not (jax.default_backend() == "tpu" or interpret):
        return ssd_ref(xh, a, Bm, Cm, chunk=chunk, initial_state=initial_state)

    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    y_diag, states, chunk_decay, cum = ssd_chunk_kernel(
        xh, a, Bm, Cm, chunk=chunk, interpret=interpret,
    )
    # inter-chunk recurrence over (B, nc, H, N, P) states
    s0 = (initial_state.astype(jnp.float32).transpose(0, 1, 3, 2)
          if initial_state is not None else jnp.zeros((B, H, N, P), jnp.float32))

    def step(carry, inp):
        st, dec = inp                     # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                 # emit state entering the chunk

    st_seq = jnp.moveaxis(states, 1, 0)          # (nc,B,H,N,P)
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)    # (nc,B,H)
    final, prev = jax.lax.scan(step, s0, (st_seq, dec_seq))
    prev = jnp.moveaxis(prev, 0, 1)              # (B,nc,H,N,P)

    # y_off[b,c,l,h,p] = exp(cum) · C_l · prev_state
    Cc = Cm.reshape(B, nc, chunk, N)
    y_off = jnp.einsum("bcln,bchnp,bchl->bclhp",
                       Cc.astype(jnp.float32), prev, jnp.exp(cum))
    y = y_diag.astype(jnp.float32) + y_off.reshape(B, S, H, P)
    return y.astype(xh.dtype), final.transpose(0, 1, 3, 2)


def ssd(xh, a, Bm, Cm, *, chunk=None, initial_state=None, interpret=False):
    """Full SSD: y (B,S,H,P) and final state (B,H,P,N).

    Pallas path: intra-chunk kernel (parallel, MXU-heavy) + lax.scan over the
    per-chunk states (sequential, tiny) + y_off correction.
    """
    S = xh.shape[1]
    if chunk is None:
        shape = tiling.shape_key(xh.shape, Bm.shape[-1], dtype=xh.dtype)
        chunk = tuned_config("ssm_scan", shape,
                             tiling.default(shape)).get("chunk", 128)
    chunk = largest_dividing_block(S, chunk)
    return _ssd_jit(xh, a, Bm, Cm, chunk=chunk, initial_state=initial_state,
                    interpret=interpret)
