"""Jitted SSD op: Pallas intra-chunk kernel + JAX inter-chunk recurrence."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_kernel
from .ref import ssd_ref

__all__ = ["ssd"]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xh, a, Bm, Cm, *, chunk=128, initial_state=None, interpret=False):
    """Full SSD: y (B,S,H,P) and final state (B,H,P,N).

    Pallas path: intra-chunk kernel (parallel, MXU-heavy) + lax.scan over the
    per-chunk states (sequential, tiny) + y_off correction.
    """
    if not (jax.default_backend() == "tpu" or interpret):
        return ssd_ref(xh, a, Bm, Cm, chunk=chunk, initial_state=initial_state)

    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    y_diag, states, chunk_decay, cum = ssd_chunk_kernel(
        xh, a, Bm, Cm, chunk=chunk, interpret=interpret,
    )
    # inter-chunk recurrence over (B, nc, H, N, P) states
    s0 = (initial_state.astype(jnp.float32).transpose(0, 1, 3, 2)
          if initial_state is not None else jnp.zeros((B, H, N, P), jnp.float32))

    def step(carry, inp):
        st, dec = inp                     # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                 # emit state entering the chunk

    st_seq = jnp.moveaxis(states, 1, 0)          # (nc,B,H,N,P)
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)    # (nc,B,H)
    final, prev = jax.lax.scan(step, s0, (st_seq, dec_seq))
    prev = jnp.moveaxis(prev, 0, 1)              # (B,nc,H,N,P)

    # y_off[b,c,l,h,p] = exp(cum) · C_l · prev_state
    Cc = Cm.reshape(B, nc, chunk, N)
    y_off = jnp.einsum("bcln,bchnp,bchl->bclhp",
                       Cc.astype(jnp.float32), prev, jnp.exp(cum))
    y = y_diag.astype(jnp.float32) + y_off.reshape(B, S, H, P)
    return y.astype(xh.dtype), final.transpose(0, 1, 3, 2)
