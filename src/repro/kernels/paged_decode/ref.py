"""Pure-jnp oracle for paged single-query decode attention.

Semantically identical to the serve path's gather fallback
(``models/layers.py`` paged branch: ``pool[block_table]`` → dense
``blocked_attention``), restated as one f32 masked softmax so the kernel
has an XLA-only reference for correctness tests and the CPU dispatch
path.  Key positions run over the *logical* gathered view
``NB·bs``; position ``k`` is attended iff ``k <= cache_len[b]`` — the
freshly scattered token at ``cache_len`` included, everything beyond
(junk blocks, scratch padding) masked out.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["paged_decode_ref"]

NEG_INF = -1e30


def paged_decode_ref(q, k_pool, v_pool, block_table, cache_len, *,
                     scale: float | None = None):
    """q: (B, H, Dh); k/v_pool: (P, bs, Hkv, Dh); block_table: (B, NB)
    int32; cache_len: (B,) int32 → (B, H, Dh).

    ``cache_len[b]`` is row b's highest valid logical position (the
    decode step's freshly written token), so ``cache_len[b] + 1`` keys
    are attended.  GQA: consecutive groups of ``H // Hkv`` query heads
    share one KV head.
    """
    B, H, Dh = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NB = block_table.shape[1]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)

    k = k_pool[block_table].reshape(B, NB * bs, Hkv, Dh).astype(jnp.float32)
    v = v_pool[block_table].reshape(B, NB * bs, Hkv, Dh).astype(jnp.float32)
    qr = (q.astype(jnp.float32) * scale).reshape(B, Hkv, rep, Dh)

    s = jnp.einsum("bgrd,bkgd->bgrk", qr, k)               # (B, Hkv, rep, L)
    pos = jnp.arange(NB * bs)
    valid = pos[None, :] <= cache_len[:, None]             # (B, L)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v)
    return o.reshape(B, H, Dh).astype(q.dtype)
