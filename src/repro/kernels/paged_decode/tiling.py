"""Tiling search space + static cost model for paged decode attention.

Executable form of the traffic story in ``kernel.py``'s docstring.  Grid
= (B, Hkv, n_splits, NB/n_splits); costs are evaluated at the pool's
steady state — rows half full (``ctx = NB·bs/2``) — because that is what
a continuously batched serve loop actually runs at, not the worst-case
full table the gather fallback always pays for:

* ``block_kv`` — inner ``fori_loop`` chunk inside one pool block; wider
  chunks cut loop trips and fill MXU columns at 4·rep·bkv extra f32
  score bytes.  Candidates divide the pool block size by construction,
  which is the structural half of the serve_kv ⇄ paged_decode joint
  resolution (serve_kv's cost model is the other half — it prices each
  candidate pool block through :func:`cost` at this model's default).
* ``n_splits`` — flash-decode KV-axis parallelism.  A single query row
  exposes only ``rep = H/Hkv`` MXU rows, so per-core utilisation cannot
  improve with context; splits instead let the two TensorCores
  (MegaCore) chew disjoint halves of the live blocks, at the price of
  f32 partial (acc, m, l) traffic and a combine pass.

:func:`gather_cost` models the XLA gather fallback at the same shape —
three full passes over the ``NB·bs`` logical view regardless of
``cache_len`` — giving kernel_bench an honest modelled baseline row.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    largest_dividing_block,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default", "gather_cost"]

_BLOCK_SEEDS = (16, 32, 64, 128, 256, 512)
_SPLIT_SEEDS = (1, 2, 4, 8)

# TensorCores per chip sharing HBM: n_splits > 1 buys parallel grid-step
# sequencing up to this factor (crude — models MegaCore as perfectly
# splitting the sequenced-step chain, nothing else).
_MEGACORE = 2


def shape_key(B, H, Hkv, Dh, NB, bs, dtype) -> dict:
    return {"B": int(B), "H": int(H), "Hkv": int(Hkv), "Dh": int(Dh),
            "NB": int(NB), "bs": int(bs), "dtype": str(dtype)}


def candidates(shape: dict) -> list[dict]:
    bs, NB = shape["bs"], shape["NB"]
    bkvs = sorted({largest_dividing_block(bs, b) for b in _BLOCK_SEEDS} | {bs})
    splits = sorted({min(s, NB) for s in _SPLIT_SEEDS})
    return [{"block_kv": bkv, "n_splits": ns} for bkv in bkvs for ns in splits]


def default(shape: dict) -> dict:
    # the kernel's own argument defaults: 128-wide chunks, no split
    return {"block_kv": largest_dividing_block(shape["bs"], 128),
            "n_splits": 1}


def _steady_live_blocks(shape: dict) -> int:
    # rows half full: ctx = NB·bs/2 valid positions ⇒ live = ctx//bs + 1
    return (shape["NB"] * shape["bs"] // 2) // shape["bs"] + 1


def cost(shape: dict, config: dict) -> KernelCost:
    B, H, Hkv, Dh = shape["B"], shape["H"], shape["Hkv"], shape["Dh"]
    NB, bs = shape["NB"], shape["bs"]
    rep = H // Hkv
    bkv = largest_dividing_block(bs, config.get("block_kv"))
    ns = max(1, min(int(config.get("n_splits", 1)), NB))
    bpe = bytes_per_element(shape["dtype"])
    live = _steady_live_blocks(shape)

    # qk^T + pv over live keys only (early exit) for every query head
    flops = 4.0 * B * H * live * bs * Dh
    # touched KV (live blocks, once per kv head via revisit elision) +
    # q in / combined o out + f32 split partials (acc, m, l) written by
    # the kernel and re-read by the combine + the int32 table/cache_len
    hbm = (bpe * 2.0 * B * Hkv * live * bs * Dh
           + bpe * 2.0 * B * H * Dh
           + 4.0 * 2.0 * B * H * ns * (Dh + 2)
           + 4.0 * (B * NB + B))
    vmem = (bpe * (rep * Dh + 2 * bs * Dh)      # q block + k/v pool blocks
            + 4.0 * rep * Dh * 2                # f32 acc scratch + o partial
            + 4.0 * rep * bkv                   # f32 score/prob chunk
            + 4.0 * 2 * rep * 128)              # m/l lane-padded stats
    # Sequenced chain per (b, h): live grid steps (dead ones are clamped
    # revisits — free) × loop trips; splits run on parallel cores.
    npb = -(-NB // ns)
    live_steps = min(live, npb * ns)
    n_steps = B * Hkv * live_steps * (1 + bs // bkv) / min(ns, _MEGACORE)
    return KernelCost(
        op="paged_decode", op_class="matmul", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=int(max(n_steps, 1)),
        mxu_min_dim=min(rep, bkv, Dh),
    )


def gather_cost(shape: dict) -> KernelCost:
    """The XLA fallback at the same shape: materialise the full
    ``(B, NB·bs)`` logical K and V views (pool read + gathered write),
    then dense attention re-reads them — cache_len-oblivious."""
    B, H, Hkv, Dh = shape["B"], shape["H"], shape["Hkv"], shape["Dh"]
    L = shape["NB"] * shape["bs"]
    bpe = bytes_per_element(shape["dtype"])
    flops = 4.0 * B * H * L * Dh                     # full width, no exit
    hbm = (bpe * 2.0 * B * Hkv * L * Dh * 3.0        # gather r+w, attn read
           + bpe * 2.0 * B * H * Dh)
    return KernelCost(
        op="paged_decode_gather", op_class="matmul", origin="fallback",
        flops=flops, hbm_bytes=hbm, vmem_bytes=0.0,
        n_steps=1, mxu_min_dim=min(H // Hkv, Dh),
    )


def _runner(shape: dict, config: dict):
    import jax.numpy as jnp
    import numpy as np

    from .ops import paged_decode_attention

    rng = np.random.default_rng(0)
    B, Hkv, Dh = shape["B"], shape["Hkv"], shape["Dh"]
    NB, bs = shape["NB"], shape["bs"]
    P = B * NB + 1
    q = jnp.asarray(rng.standard_normal((B, shape["H"], Dh)), shape["dtype"])
    kp = jnp.asarray(rng.standard_normal((P, bs, Hkv, Dh)), shape["dtype"])
    vp = jnp.asarray(rng.standard_normal((P, bs, Hkv, Dh)), shape["dtype"])
    bt = jnp.asarray(1 + np.arange(B * NB).reshape(B, NB), jnp.int32)
    cl = jnp.asarray(np.full(B, NB * bs // 2, np.int32))  # steady state
    bkv, ns = config["block_kv"], config["n_splits"]
    return lambda: paged_decode_attention(
        q, kp, vp, bt, cl, block_kv=bkv, n_splits=ns)


register_tiling(TilingModel(
    name="paged_decode", candidates=candidates, cost=cost, default=default,
    runner=_runner,
), overwrite=True)
