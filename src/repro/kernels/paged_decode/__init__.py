"""Decode-specialized paged attention (single query, block-pool KV).

The serving decode hot path: one query token per slot attends over that
slot's KV history, which lives scattered across a fixed-size block pool
behind a per-slot ``block_table``.  The kernel reads K/V directly from
the pool (no gathered logical view) with online softmax, per-row
``cache_len`` masking, block-granular early exit, GQA head-group
broadcast and an optional split-KV partial reduction; see
docs/kernels.md "paged_decode".
"""

from repro.kernels.paged_decode.ops import paged_decode_attention
from repro.kernels.paged_decode.ref import paged_decode_ref

__all__ = ["paged_decode_attention", "paged_decode_ref"]
