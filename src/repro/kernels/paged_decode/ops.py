"""Jitted public wrapper for paged decode attention.

``impl`` selects the execution path (mirrors the ``REPRO_PAGED_DECODE``
env knob the serve layer reads):

* ``None`` / ``"auto"`` — Pallas kernel on TPU, pure-jnp ref elsewhere
  (the ref is XLA-only, so CPU containers stay fast and exact).
* ``"kernel"`` — always the Pallas kernel (interpret mode off-TPU).
* ``"interpret"`` — force interpret mode even on TPU (debugging).
* ``"ref"`` — always the jnp reference.

``block_kv=None`` / ``n_splits=None`` consult the process autotuner
(roofline-ranked, device-keyed cache — ``repro.kernels.autotune``) for
this launch shape; explicit values always win.  Resolution happens
outside the jit so tuned values participate in the static-arg cache key.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.autotune import tuned_config

from . import tiling
from .kernel import paged_decode_kernel
from .ref import paged_decode_ref

__all__ = ["paged_decode_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("scale", "block_kv", "n_splits", "impl"))
def _paged_decode_jit(q, k_pool, v_pool, block_table, cache_len, *,
                      scale, block_kv, n_splits, impl):
    use_kernel = impl in ("kernel", "interpret") or (
        impl in (None, "auto") and _on_tpu())
    if use_kernel:
        return paged_decode_kernel(
            q, k_pool, v_pool, block_table, cache_len, scale=scale,
            block_kv=block_kv, n_splits=n_splits,
            interpret=impl == "interpret" or not _on_tpu(),
        )
    return paged_decode_ref(q, k_pool, v_pool, block_table, cache_len,
                            scale=scale)


def paged_decode_attention(q, k_pool, v_pool, block_table, cache_len, *,
                           scale=None, block_kv=None, n_splits=None,
                           impl=None):
    """q: (B, H, Dh); k/v_pool: (P, bs, Hkv, Dh); block_table: (B, NB)
    int32; cache_len: (B,) int32 → (B, H, Dh), attending logical
    positions ``<= cache_len[b]`` of each row's paged KV history."""
    if block_kv is None or n_splits is None:
        B, H, Dh = q.shape
        shape = tiling.shape_key(B, H, k_pool.shape[2], Dh,
                                 block_table.shape[1], k_pool.shape[1],
                                 q.dtype)
        tuned = tuned_config("paged_decode", shape, tiling.default(shape))
        block_kv = block_kv if block_kv is not None else tuned.get(
            "block_kv", 128)
        n_splits = n_splits if n_splits is not None else tuned.get(
            "n_splits", 1)
    return _paged_decode_jit(q, k_pool, v_pool, block_table, cache_len,
                             scale=scale, block_kv=int(block_kv),
                             n_splits=int(n_splits), impl=impl)
