"""Paged single-query decode attention — Pallas TPU kernel.

TPU mapping: grid = (B, Hkv, n_splits, NB/n_splits), block axis innermost.
The block table and per-row cache lengths ride in scalar-prefetch SMEM
(``PrefetchScalarGridSpec``) so the K/V ``BlockSpec`` index maps can chase
``block_table[b, i]`` — each grid step DMAs ONE physical pool block for
one KV head straight from HBM; the gathered ``(B, NB·bs)`` logical view
the XLA fallback materialises never exists.

Early exit is block-granular: row ``b`` owns ``cache_len[b]//bs + 1``
live blocks, and the index map *clamps* dead steps to the last live
block — consecutive dead steps fetch the same block, which Pallas's
revisit elision turns into zero HBM traffic — while ``pl.when`` skips
their compute entirely.  Inside a live block the score loop runs in
``block_kv``-wide chunks (``block_kv`` divides the pool block size; the
serve_kv tiling resolves the two jointly) with per-position
``pos <= cache_len`` masking, so the freshly written token at
``cache_len`` is attended and nothing past it is.

Split-KV: with ``n_splits > 1`` each (b, kv head) is cut into
``n_splits`` independent partial reductions (flash-decode style — a
single query exposes only ``H/Hkv`` MXU rows, so long contexts need the
KV axis for parallelism).  The kernel emits per-split unnormalised
accumulators plus running (m, l) stats; :func:`combine_splits` merges
them in one tiny jnp pass.

VMEM per program (bf16, bs=64, Dh=128, rep=4): q/o 2 KiB + k/v blocks
32 KiB + f32 acc/stats ~3 KiB ≈ 37 KiB « 16 MiB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.autotune import largest_dividing_block

__all__ = ["paged_decode_kernel", "combine_splits"]

NEG_INF = -1e30
_STAT_LANES = 128  # f32 stat scratch padded to one full lane register


def _decode_body(bt_ref, cl_ref, q_ref, k_ref, v_ref,
                 o_ref, m_ref, l_ref,
                 acc_scr, m_scr, l_scr, *,
                 scale, bs, block_kv, npb):
    """One (batch row, kv head, split, block-step) program."""
    b = pl.program_id(0)
    s = pl.program_id(2)
    j = pl.program_id(3)
    i = s * npb + j                                 # global block index
    rep, dh = q_ref.shape[-2], q_ref.shape[-1]

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    cl = cl_ref[b]
    n_live = cl // bs + 1                           # row's live block count

    @pl.when(i < n_live)
    def _live():
        q = q_ref[0, 0].astype(jnp.float32) * scale             # (rep, dh)

        def chunk(c, _):
            k = k_ref[0, pl.dslice(c * block_kv, block_kv), 0, :].astype(
                jnp.float32)                                    # (bkv, dh)
            v = v_ref[0, pl.dslice(c * block_kv, block_kv), 0, :].astype(
                jnp.float32)
            sc = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)             # (rep, bkv)
            pos = (i * bs + c * block_kv
                   + jax.lax.broadcasted_iota(jnp.int32, (rep, block_kv), 1))
            sc = jnp.where(pos <= cl, sc, NEG_INF)
            m_prev = m_scr[:, 0]
            l_prev = l_scr[:, 0]
            m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[:, None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(axis=-1)
            acc_scr[...] = (acc_scr[...] * alpha[:, None]
                            + jax.lax.dot_general(
                                p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
            m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
            l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)
            return 0

        jax.lax.fori_loop(0, bs // block_kv, chunk, 0)

    # Unnormalised partials flush when the split's output block rotates.
    o_ref[0, 0, 0] = acc_scr[...]
    m_ref[0, 0, 0] = m_scr[:, 0]
    l_ref[0, 0, 0] = l_scr[:, 0]


def combine_splits(acc, m, l, out_dtype):
    """Merge per-split partials: acc/m/l are (B, Hkv, n_splits, rep[, Dh])
    f32 → (B, H, Dh).  Dead splits carry (acc=0, m=NEG_INF, l=0) and
    vanish under the global-max renormalisation (NEG_INF is finite, so
    the exp underflows to exactly 0 instead of producing NaN)."""
    B, Hkv, n_splits, rep, Dh = acc.shape
    m_g = jnp.max(m, axis=2, keepdims=True)                 # (B, Hkv, 1, rep)
    w = jnp.exp(m - m_g)                                    # (B, Hkv, s, rep)
    l_g = jnp.sum(w * l, axis=2)                            # (B, Hkv, rep)
    o = jnp.sum(w[..., None] * acc, axis=2)                 # (B, Hkv, rep, Dh)
    l_g = jnp.where(l_g == 0.0, 1.0, l_g)  # fully-masked rows (idle slots)
    return (o / l_g[..., None]).reshape(B, Hkv * rep, Dh).astype(out_dtype)


def paged_decode_kernel(q, k_pool, v_pool, block_table, cache_len, *,
                        scale: float | None = None,
                        block_kv: int | None = None,
                        n_splits: int = 1,
                        interpret: bool = False):
    """q: (B, H, Dh); k/v_pool: (P, bs, Hkv, Dh); block_table: (B, NB);
    cache_len: (B,) → (B, H, Dh).  Attends positions ``<= cache_len[b]``.
    """
    B, H, Dh = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    NB = block_table.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    block_kv = largest_dividing_block(bs, block_kv or 128)
    n_splits = max(1, min(int(n_splits), NB))
    npb = -(-NB // n_splits)                       # blocks per split

    qr = q.reshape(B, Hkv, rep, Dh)

    def kv_index(b, h, s, j, bt_ref, cl_ref):
        i = s * npb + j
        n_live = cl_ref[b] // bs + 1
        live = jnp.minimum(i, n_live - 1)          # clamp dead steps →
        return (bt_ref[b, live], 0, h, 0)          # revisit elision, no DMA

    grid = (B, Hkv, n_splits, npb)
    kernel = functools.partial(_decode_body, scale=scale, bs=bs,
                               block_kv=block_kv, npb=npb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_table, cache_len
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, Dh), lambda b, h, s, j, bt, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, Dh), kv_index),
            pl.BlockSpec((1, bs, 1, Dh), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, rep, Dh),
                         lambda b, h, s, j, bt, cl: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, rep),
                         lambda b, h, s, j, bt, cl: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, rep),
                         lambda b, h, s, j, bt, cl: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, Dh), jnp.float32),          # acc
            pltpu.VMEM((rep, _STAT_LANES), jnp.float32),  # running max
            pltpu.VMEM((rep, _STAT_LANES), jnp.float32),  # running sum
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, n_splits, rep, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, n_splits, rep), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, n_splits, rep), jnp.float32),
        ],
        interpret=interpret,
    )(block_table, cache_len, qr, k_pool, v_pool)
    return combine_splits(acc, m, l, q.dtype)
