"""Tiling search space + static cost model for the paged serve KV cache.

The serving engine stores K/V in fixed-size blocks (``PagedKVCache``);
``block_size`` is the one knob.  Since the decode-specialised
``paged_decode`` kernel landed, the consumer of the pool layout is that
kernel, so this model resolves the pool block size **jointly** with it:
each candidate ``bs`` is priced by running the paged_decode cost model
at its own default config for a pool of ``bs``-sized blocks spanning the
context window.  Two things follow structurally:

* the kernel's ``block_kv`` candidates divide the pool block size by
  construction (``largest_dividing_block`` over the same seed list), so
  the two tuners cannot pick incompatible blockings;
* the fragmentation/step-overhead trade-off the old hand-rolled model
  priced is inherited — the kernel streams each *live* block in full
  (``ceil(ctx/bs)`` blocks ≈ ctx + fragmentation tokens) and pays
  sequenced steps per live block, so big blocks still cost dead-token
  bandwidth and small blocks still cost loop trips and MXU underfill.

On top of the kernel launch the pool itself pays the step's scatter
write and the block-table re-read, added here.  Costs are modelled at
the expected steady-state occupancy ``max_len/2`` (uniform admission
over the context window), matching the serve bench's mixed-length
traces.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    register_tiling,
)
from repro.kernels.paged_decode import tiling as pd_tiling

__all__ = ["shape_key", "candidates", "cost", "default"]

_BLOCK_SEEDS = (16, 32, 64, 128, 256, 512)


def shape_key(n_slots: int, max_len: int, n_kv_heads: int, head_dim: int,
              dtype, n_heads: int | None = None) -> dict:
    return {"B": int(n_slots), "L": int(max_len), "Hkv": int(n_kv_heads),
            "H": int(n_heads if n_heads is not None else n_kv_heads),
            "Dh": int(head_dim), "dtype": str(dtype)}


def candidates(shape: dict) -> list[dict]:
    cands = [{"block_size": b} for b in _BLOCK_SEEDS if b <= shape["L"]]
    return cands or [{"block_size": shape["L"]}]


def default(shape: dict) -> dict:
    # dense-cache parity: one block spans a quarter of the window, the
    # hand-picked constant the engine used before the pool existed
    return {"block_size": max(16, min(shape["L"] // 4, 256))}


def cost(shape: dict, config: dict) -> KernelCost:
    B, L = shape["B"], shape["L"]
    H = shape.get("H", shape["Hkv"])
    Hkv, Dh = shape["Hkv"], shape["Dh"]
    bs = max(1, min(int(config.get("block_size", L)), L))
    bpe = bytes_per_element(shape["dtype"])
    NB = max(1, -(-L // bs))           # full-window table width

    # Joint resolution: price this pool layout through the decode
    # kernel's own cost model at the kernel's default config for bs.
    pd_shape = pd_tiling.shape_key(B, H, Hkv, Dh, NB, bs, shape["dtype"])
    pd = pd_tiling.cost(pd_shape, pd_tiling.default(pd_shape))

    # + the pool's own per-step work: scatter the step's K/V row in,
    # re-read the block tables
    hbm = pd.hbm_bytes + bpe * 2.0 * B * Hkv * Dh + 4.0 * B * NB
    return KernelCost(
        op="serve_kv", op_class="matmul", origin="kernel",
        flops=pd.flops, hbm_bytes=hbm, vmem_bytes=pd.vmem_bytes,
        n_steps=pd.n_steps,
        mxu_min_dim=pd.mxu_min_dim,
    )


register_tiling(TilingModel(
    name="serve_kv", candidates=candidates, cost=cost, default=default,
    runner=None,
), overwrite=True)
