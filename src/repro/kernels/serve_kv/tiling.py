"""Tiling search space + static cost model for the paged serve KV cache.

The serving engine stores K/V in fixed-size blocks (``PagedKVCache``);
every decode step gathers each slot's block list back into a contiguous
view and attends over it.  ``block_size`` is the one knob, and it trades
two costs the roofline ranker can see:

* **internal fragmentation** — a sequence of length ``ctx`` occupies
  ``ceil(ctx/bs)·bs`` pool tokens, so the gather streams on average an
  extra ``bs/2`` tokens of dead K/V per slot per step (HBM bytes grow
  with ``bs``);
* **gather/step overhead** — each block is one scatter/gather descriptor,
  so per-step sequenced work scales with ``ceil(ctx/bs)`` per slot
  (``n_steps`` shrinks with ``bs``), and tiny blocks starve the MXU
  (``mxu_min_dim``).

Costs are modelled at the expected steady-state occupancy ``max_len/2``
(uniform admission over the context window), matching how the serve
bench exercises mixed-length traces.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default"]

_BLOCK_SEEDS = (16, 32, 64, 128, 256, 512)


def shape_key(n_slots: int, max_len: int, n_kv_heads: int, head_dim: int,
              dtype) -> dict:
    return {"B": int(n_slots), "L": int(max_len), "Hkv": int(n_kv_heads),
            "Dh": int(head_dim), "dtype": str(dtype)}


def candidates(shape: dict) -> list[dict]:
    cands = [{"block_size": b} for b in _BLOCK_SEEDS if b <= shape["L"]]
    return cands or [{"block_size": shape["L"]}]


def default(shape: dict) -> dict:
    # dense-cache parity: one block spans a quarter of the window, the
    # hand-picked constant the engine used before the pool existed
    return {"block_size": max(16, min(shape["L"] // 4, 256))}


def cost(shape: dict, config: dict) -> KernelCost:
    B, L = shape["B"], shape["L"]
    Hkv, Dh = shape["Hkv"], shape["Dh"]
    bs = max(1, min(int(config.get("block_size", L)), L))
    bpe = bytes_per_element(shape["dtype"])

    ctx = L / 2.0                      # expected steady-state occupancy
    padded = ctx + bs / 2.0            # + mean fragmentation per slot
    n_blocks = max(1, -(-int(ctx) // bs))
    # decode-step attention over the gathered view: qk^T + pv
    flops = 4.0 * B * Hkv * padded * Dh
    # K/V streamed once per step (incl. dead fragmentation tokens), the
    # step's own k/v written once, block tables re-read every step
    hbm = (bpe * 2.0 * B * padded * Hkv * Dh
           + bpe * 2.0 * B * Hkv * Dh
           + 4.0 * B * n_blocks)
    vmem = (bpe * 2.0 * bs * Hkv * Dh   # one K and one V block resident
            + 4.0 * bs                   # f32 score strip for the block
            + 4.0 * Dh)                  # f32 accumulator row
    return KernelCost(
        op="serve_kv", op_class="matmul", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=B * n_blocks,
        mxu_min_dim=min(bs, Dh),
    )


register_tiling(TilingModel(
    name="serve_kv", candidates=candidates, cost=cost, default=default,
    runner=None,
), overwrite=True)
