"""Tiling search space + static cost model for flash attention.

Executable form of the VMEM budget in ``kernel.py``'s docstring.  Grid =
(B, H, Sq/block_q), K/V for the head fully VMEM-resident — so HBM
traffic is block-independent (q/o once, K/V once per kv head via revisit
elision) and the blocks trade sequenced-step count and MXU fill against
the q/accumulator/score-tile working set:

* ``block_q`` — programs per (b, h); bigger blocks amortise grid steps
  and fill MXU rows, at (bq·Dh)·(bpe + 8) + 4·bq·bk VMEM.
* ``block_k`` — inner ``fori_loop`` trips; bigger chunks cut loop
  overhead and fill MXU columns, at 4·bq·bk f32 score-tile bytes.
"""

from __future__ import annotations

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    largest_dividing_block,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default"]

_BLOCK_SEEDS = (64, 128, 256, 512, 1024)


def shape_key(q_shape, k_shape, *, causal: bool, dtype) -> dict:
    B, H, Sq, Dh = (int(d) for d in q_shape)
    Hkv, Sk = int(k_shape[1]), int(k_shape[2])
    return {"B": B, "H": H, "Hkv": Hkv, "Sq": Sq, "Sk": Sk, "Dh": Dh,
            "causal": bool(causal), "dtype": str(dtype)}


def _snap(n: int) -> list[int]:
    return sorted({largest_dividing_block(n, b) for b in _BLOCK_SEEDS} | {n})


def candidates(shape: dict) -> list[dict]:
    return [{"block_q": bq, "block_k": bk}
            for bq in _snap(shape["Sq"]) for bk in _snap(shape["Sk"])]


def default(shape: dict) -> dict:
    # the kernel's hand-picked constants, after its own min(·, S) clamp
    return {"block_q": largest_dividing_block(shape["Sq"], 512),
            "block_k": largest_dividing_block(shape["Sk"], 512)}


def cost(shape: dict, config: dict) -> KernelCost:
    B, H, Hkv = shape["B"], shape["H"], shape["Hkv"]
    Sq, Sk, Dh = shape["Sq"], shape["Sk"], shape["Dh"]
    bq = largest_dividing_block(Sq, config.get("block_q"))
    bk = largest_dividing_block(Sk, config.get("block_k"))
    bpe = bytes_per_element(shape["dtype"])

    frac = 0.5 if shape["causal"] else 1.0  # masked-out score work skipped
    flops = 4.0 * B * H * Sq * Sk * Dh * frac
    # q/o once per program = once total; K/V once per kv head (consecutive
    # q-heads sharing a kv head revisit the same block — no re-fetch)
    hbm = bpe * (2.0 * B * H * Sq * Dh + 2.0 * B * Hkv * Sk * Dh)
    vmem = (bpe * (bq * Dh + 2 * Sk * Dh + bq * Dh)   # q, K/V, o blocks
            + 4.0 * bq * Dh                            # f32 accumulator
            + 4.0 * bq * bk                            # f32 score/prob tile
            + 4.0 * 3 * bq)                            # m/l running stats
    n_programs = B * H * (Sq // bq)
    return KernelCost(
        op="flash_attention", op_class="matmul", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=n_programs * (1 + Sk // bk),
        mxu_min_dim=min(bq, bk, Dh),
    )


def _runner(shape: dict, config: dict):
    import jax.numpy as jnp
    import numpy as np

    from .ops import flash_attention

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(
        (shape["B"], shape["H"], shape["Sq"], shape["Dh"])), shape["dtype"])
    kv = (shape["B"], shape["Hkv"], shape["Sk"], shape["Dh"])
    k = jnp.asarray(rng.standard_normal(kv), shape["dtype"])
    v = jnp.asarray(rng.standard_normal(kv), shape["dtype"])
    bq, bk = config["block_q"], config["block_k"]
    return lambda: flash_attention(q, k, v, causal=shape["causal"],
                                   block_q=bq, block_k=bk)


register_tiling(TilingModel(
    name="flash_attention", candidates=candidates, cost=cost, default=default,
    runner=_runner,
), overwrite=True)
