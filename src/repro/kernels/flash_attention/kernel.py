"""Flash-attention Pallas TPU kernel (blockwise online softmax).

TPU mapping: grid = (batch, q_head, Sq/block_q).  Each program loads one
query block into VMEM, streams the full K/V for the matching KV head
(GQA: kv_head = q_head // (H/Hkv)) in ``block_k`` chunks, and maintains the
online-softmax running max/sum in f32 VREGs.  MXU dims are aligned by
construction (block_q × Dh and block_q × block_k matmuls, Dh and blocks
multiples of 128 for full-size configs; smaller test shapes still validate
in interpret mode).

VMEM budget per program (bf16, block_q=512, block_k=512, Dh=128):
  q 128 KiB + k/v tiles 2×128 KiB + acc f32 256 KiB  ≈ 0.7 MiB  « 16 MiB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.autotune import largest_dividing_block

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def _attn_body(q_ref, k_ref, v_ref, o_ref, *, scale, causal, block_k, q_offset):
    """One (batch, head, q-block) program."""
    bq, dh = q_ref.shape[-2], q_ref.shape[-1]
    sk = k_ref.shape[-2]
    q = q_ref[0, 0].astype(jnp.float32) * scale           # (bq, dh)
    qi = pl.program_id(2)
    q_pos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)

    nkb = sk // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)
        if causal:
            k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nkb, body, (acc0, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (padding)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q, k, v, *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
):
    """q: (B, H, Sq, Dh);  k, v: (B, Hkv, Sk, Dh)  →  (B, H, Sq, Dh).

    ``q_offset``: position of q[0] relative to k[0] (decode/chunked use).
    """
    B, H, Sq, Dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)

    # Non-dividing blocks fall back to the largest dividing block ≤ the
    # request (e.g. Sq=384, block_q=512 → 384) so arbitrary sequence
    # lengths run instead of crashing on a divisibility assert.
    block_q = largest_dividing_block(Sq, block_q)
    block_k = largest_dividing_block(Sk, block_k)

    grid = (B, H, Sq // block_q)
    kernel = functools.partial(
        _attn_body, scale=scale, causal=causal, block_k=block_k,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, Dh), lambda b, h, i: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, Sk, Dh), lambda b, h, i: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
