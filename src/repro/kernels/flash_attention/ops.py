"""Jitted public wrapper for flash attention.

On TPU this dispatches to the Pallas kernel; elsewhere (CPU container) it
runs the kernel in interpret mode (tests) or falls back to the blocked-XLA
path used by the model code.
"""

from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_kernel
from .ref import attention_ref

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "q_offset",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    q_offset=0, interpret=False):
    if _on_tpu() or interpret:
        return flash_attention_kernel(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            q_offset=q_offset, interpret=interpret or not _on_tpu(),
        )
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset)
