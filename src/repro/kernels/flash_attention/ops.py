"""Jitted public wrapper for flash attention.

On TPU this dispatches to the Pallas kernel; elsewhere (CPU container) it
runs the kernel in interpret mode (tests) or falls back to the blocked-XLA
path used by the model code.

``block_q=None`` / ``block_k=None`` consult the process autotuner
(roofline-ranked, device-keyed cache — ``repro.kernels.autotune``) for
this launch shape; explicit blocks always win.  Resolution happens
outside the jit so tuned values participate in the static-arg cache key.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.autotune import tuned_config

from . import tiling
from .kernel import flash_attention_kernel
from .ref import attention_ref

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "q_offset",
                                   "interpret"))
def _flash_attention_jit(q, k, v, *, causal, block_q, block_k, q_offset,
                         interpret):
    if _on_tpu() or interpret:
        return flash_attention_kernel(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            q_offset=q_offset, interpret=interpret or not _on_tpu(),
        )
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset)


def flash_attention(q, k, v, *, causal=True, block_q=None, block_k=None,
                    q_offset=0, interpret=False):
    if block_q is None or block_k is None:
        shape = tiling.shape_key(q.shape, k.shape, causal=causal,
                                 dtype=q.dtype)
        tuned = tuned_config("flash_attention", shape, tiling.default(shape))
        block_q = block_q if block_q is not None else tuned.get("block_q", 512)
        block_k = block_k if block_k is not None else tuned.get("block_k", 512)
    return _flash_attention_jit(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k, q_offset=q_offset,
                                interpret=interpret)
