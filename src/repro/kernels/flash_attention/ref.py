"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, scale=None, q_offset=0):
    """q: (B,H,Sq,Dh); k,v: (B,Hkv,Sk,Dh) → (B,H,Sq,Dh).  f32 softmax."""
    B, H, Sq, Dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = H // Hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    if causal:
        qp = q_offset + jnp.arange(Sq)[:, None]
        kp = jnp.arange(Sk)[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
