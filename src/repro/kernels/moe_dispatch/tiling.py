"""Tiling search space + static cost model for MoE capacity dispatch.

Not a Pallas kernel — the GShard-style dispatch in ``models/layers.py``
(``moe_block``) is XLA-lowered — but its two free knobs are exactly a
tiling problem, so it goes through the same
:class:`~repro.kernels.autotune.KernelTuner` candidate/cost-model
interface as the Pallas kernels:

* ``groups`` — token groups vmapped over the (data-sharded) batch axis.
  Fewer groups amortise the per-8 capacity rounding and the per-(group ×
  expert) program overhead; more groups shrink the per-group working set
  (capacity ∝ 1/groups) and keep routing device-local on wider meshes.
* ``capacity_factor`` — expert buffer slack.  Candidates never go BELOW
  the architecture's configured factor: a smaller buffer drops more
  tokens, which changes model quality, and the tuner must never trade
  accuracy for speed.  Larger factors are explored for the timed path
  (padding can win on real hardware when it aligns the expert matmul).

Compute overhead over the ideal is exactly ``capacity · rounding``, which
is what ``cost`` charges; the working set is the per-(group, expert)
expert-matmul operand block.
"""

from __future__ import annotations

import math

from repro.kernels.autotune import (
    KernelCost,
    TilingModel,
    bytes_per_element,
    largest_dividing_block,
    register_tiling,
)

__all__ = ["shape_key", "candidates", "cost", "default"]

_GROUP_SEEDS = (1, 2, 4, 8, 16, 32, 64)
_FACTOR_SLACK = (1.0, 1.25, 1.5)


def _capacity(tokens: int, n_experts: int, k: int, factor: float) -> int:
    """Per-expert slot count — MUST match ``models.layers.moe_capacity``
    (multiple of 8, floor 8); asserted in tests."""
    c = int(math.ceil(tokens * k / n_experts * factor))
    return max(8, -(-c // 8) * 8)


def shape_key(B: int, S: int, D: int, E: int, K: int, F: int,
              capacity_factor: float, dtype) -> dict:
    return {"B": int(B), "S": int(S), "D": int(D), "E": int(E), "K": int(K),
            "F": int(F), "cf": float(capacity_factor), "dtype": str(dtype)}


def default(shape: dict) -> dict:
    # the hand-picked constants moe_block used before autotuning
    return {"groups": math.gcd(shape["B"], 32),
            "capacity_factor": shape["cf"]}


def candidates(shape: dict) -> list[dict]:
    groups = sorted({largest_dividing_block(shape["B"], g)
                     for g in _GROUP_SEEDS})
    factors = sorted({round(shape["cf"] * s, 4) for s in _FACTOR_SLACK})
    return [{"groups": g, "capacity_factor": f}
            for g in groups for f in factors]


def cost(shape: dict, config: dict) -> KernelCost:
    B, S, D = shape["B"], shape["S"], shape["D"]
    E, K, F = shape["E"], shape["K"], shape["F"]
    G = largest_dividing_block(B, config.get("groups"))
    f = max(float(config.get("capacity_factor", shape["cf"])), shape["cf"])
    bpe = bytes_per_element(shape["dtype"])

    Tg = (B // G) * S
    C = _capacity(Tg, E, K, f)

    router = 2.0 * B * S * D * E                     # logits einsum (f32)
    experts = 6.0 * G * E * C * D * F                # gate/up/down matmuls
    sort = B * S * K * max(math.log2(max(Tg * K, 2)), 1.0)
    flops = router + experts + sort

    buf = G * E * C                                  # expert slots total
    hbm = bpe * (
        2.0 * B * S * D                              # x in, out
        + 3.0 * buf * D                              # dispatch buf w+r, out_buf
        + 2.0 * buf * F                              # hidden w+r
        + 3.0 * E * D * F                            # expert weights
    ) + 4.0 * B * S * E                              # f32 router logits
    # Per-(group, expert) program working set: one expert's operand block.
    vmem = bpe * (C * D + C * F + D * F)
    return KernelCost(
        op="moe_dispatch", op_class="matmul", origin="kernel",
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        n_steps=G * E,
        mxu_min_dim=min(C, D, F),
    )


register_tiling(TilingModel(
    name="moe_dispatch", candidates=candidates, cost=cost, default=default,
    runner=None,
), overwrite=True)
