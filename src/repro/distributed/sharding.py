"""Sharding rules: DP (+pod) × TP (+EP) GSPMD PartitionSpecs for every
parameter, batch input, cache and optimizer-state leaf, per architecture.

Conventions (see DESIGN.md §6):
  * "model" axis: attention heads / FFN hidden / vocab / experts / SSD heads.
  * "data" axis:  batch (training & batched decode); KV-cache sequence for the
    single-sequence long-context cell; ZeRO/FSDP shard of opt-state & (for
    very large archs) parameters.
  * "pod" axis:   outermost data parallelism (gradient all-reduce crosses DCI).

Every rule checks divisibility against the actual mesh axis size and falls
back to replication — a 40-head arch on a 16-way model axis replicates heads
rather than producing an invalid spec (recorded by ``describe_sharding``).
"""

from __future__ import annotations

from dataclasses import dataclass
from types import SimpleNamespace

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T

__all__ = [
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "state_pspecs",
    "to_named",
    "fsdp_wanted",
    "LeafSharding",
    "describe_sharding",
]


def _axis(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= _axis(mesh, a)
    return n


def _maybe(axis_name: str, dim: int, mesh) -> str | None:
    """axis_name if dim divides evenly on the mesh, else None (replicate)."""
    sz = _axis(mesh, axis_name)
    return axis_name if sz > 1 and dim % sz == 0 else None


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _param_rule(name: str, shape: tuple[int, ...], stacked: bool, mesh, cfg) -> P:
    """PartitionSpec for one parameter leaf (shape includes the stack dim
    when ``stacked``)."""
    s = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    def spec(*dims):
        return P(*lead, *dims)

    m = "model"
    if name == "embed":
        return spec(_maybe(m, s[0], mesh), None)
    if name == "lm_head":
        return spec(None, _maybe(m, s[1], mesh))
    if name in ("wq",):
        return spec(None, _maybe(m, s[1], mesh))
    if name in ("wk", "wv"):
        return spec(None, _maybe(m, s[1], mesh))
    if name == "wo":
        return spec(_maybe(m, s[0], mesh), None)
    if name in ("gate", "up"):
        if len(s) == 3:  # MoE expert (E, D, F): expert-parallel
            return spec(_maybe(m, s[0], mesh), None, None)
        return spec(None, _maybe(m, s[1], mesh))
    if name == "down":
        if len(s) == 3:  # (E, F, D)
            return spec(_maybe(m, s[0], mesh), None, None)
        return spec(_maybe(m, s[0], mesh), None)
    if name == "router":
        return spec(None, None)
    if name in ("w_z", "w_x"):
        return spec(None, _maybe(m, s[1], mesh))
    if name == "out_proj":
        return spec(_maybe(m, s[0], mesh), None)
    if name in ("bq",):
        return spec(_maybe(m, s[0], mesh))
    # small/replicated: norms, biases, router, conv, dt/A/D, w_B, w_C, w_dt
    return spec(*([None] * len(s)))


def param_pspecs(cfg: ArchConfig, mesh, *, fsdp: bool = False) -> dict:
    shape_tree = T._shape_tree(cfg)

    def leaf(path, shape):
        name = path[-1].key
        stacked = any(
            getattr(p, "key", None) in ("blocks", "encoder") for p in path
        )
        spec = _param_rule(name, shape, stacked, mesh, cfg)
        if fsdp:
            spec = _zero_extend(spec, shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(
        leaf, shape_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def fsdp_wanted(cfg: ArchConfig, mesh, hbm_budget_gb: float = 8.0) -> bool:
    """FSDP the parameters when the TP-sharded copy alone would eat more than
    ``hbm_budget_gb`` per device."""
    m = _axis(mesh, "model")
    return cfg.param_count() * 2 / m > hbm_budget_gb * 1e9


# ---------------------------------------------------------------------------
# ZeRO extension (optimizer state / FSDP params)
# ---------------------------------------------------------------------------


def _zero_extend(spec: P, shape: tuple[int, ...], mesh, axis: str = "data") -> P:
    """Shard the largest still-replicated dim over ``axis`` (ZeRO-style)."""
    sz = _axis(mesh, axis)
    if sz <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % sz == 0 and d > best_dim:
            best, best_dim = i, d
    if best >= 0:
        entries[best] = axis
    return P(*entries)


def state_pspecs(cfg: ArchConfig, mesh, *, kind: str = "adamw",
                 fsdp: bool | None = None) -> dict:
    fsdp = fsdp_wanted(cfg, mesh) if fsdp is None else fsdp
    ps = param_pspecs(cfg, mesh, fsdp=fsdp)
    shape_tree = T._shape_tree(cfg)
    slots = jax.tree_util.tree_map_with_path(
        lambda path, shape: _zero_extend(
            _param_rule(
                path[-1].key, shape,
                any(getattr(p, "key", None) in ("blocks", "encoder") for p in path),
                mesh, cfg,
            ),
            shape, mesh,
        ),
        shape_tree, is_leaf=lambda x: isinstance(x, tuple),
    )
    opt = {"step": P(), "m": slots}
    if kind == "adamw":
        opt["v"] = slots
    return {"params": ps, "opt": opt}


# ---------------------------------------------------------------------------
# Batch & cache
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> dict:
    dp = _dp_axes(mesh)
    dp_ok = shape.global_batch % _dp_size(mesh) == 0
    b = dp if dp_ok else None
    specs: dict = {"tokens": P(b, None)}
    if shape.kind in ("train", "prefill"):
        if cfg.n_prefix:
            specs["patches"] = P(b, None, None)
        if cfg.n_encoder_layers:
            specs["frames"] = P(b, None, None)
    else:
        specs["tokens"] = P(b, None)
        specs["cache_len"] = P()
        if cfg.n_encoder_layers:
            specs["memory"] = P(b, None, None)
    return specs


def cache_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> dict:
    """Decode-cache specs.  Batched decode shards batch over DP; the
    single-sequence long-context cell shards the KV sequence dim over data
    (sequence parallelism for cache reads)."""
    dp = _dp_axes(mesh)
    dp_ok = shape.global_batch % _dp_size(mesh) == 0
    b = dp if dp_ok else None
    seq = None if dp_ok else _maybe("data", shape.seq_len, mesh)
    shapes = T.cache_shapes(cfg, shape.global_batch, shape.seq_len)

    def leaf(path, s):
        name = path[-1].key
        if name in ("k", "v"):      # (n, B, S, Hkv, Dh)
            return P(None, b, seq, _maybe("model", s[3], mesh), None)
        if name == "state":          # (n, B, H, P, N)
            return P(None, b, _maybe("model", s[2], mesh), None, None)
        if name == "conv":           # (n, B, W-1, d_conv_ch)
            return P(None, b, None, None)
        raise KeyError(name)

    return jax.tree_util.tree_map_with_path(
        leaf, shapes, is_leaf=lambda x: isinstance(x, tuple)
    )


def to_named(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Introspection: what did the rules decide, and where did they fall back?
# ---------------------------------------------------------------------------


class _ProbeSize:
    """Axis-size stand-in that passes every ``_maybe`` check (``> 1`` is
    True, every ``dim %`` is 0).  Probing ``_param_rule`` with it reveals
    which leaves the rule *wants* to shard on an axis, independent of
    whether the real axis size divides the leaf dims — the ground truth
    for "replicated as a fallback" vs "replicated by design"."""

    def __gt__(self, other):
        return True

    def __rmod__(self, other):
        return 0


def _probe_mesh(axis_names) -> SimpleNamespace:
    return SimpleNamespace(
        axis_names=tuple(axis_names),
        devices=SimpleNamespace(shape=tuple(_ProbeSize() for _ in axis_names)),
    )


@dataclass(frozen=True)
class LeafSharding:
    """One parameter leaf's sharding decision on a concrete mesh."""

    path: str
    shape: tuple
    elements: int
    spec: tuple            # applied PartitionSpec entries (len == len(shape))
    wanted: tuple          # entries the rule would pick if everything divided
    shard: int             # product of applied mesh-axis sizes
    model_shard: int       # applied "model"-axis factor only
    data_shard: int        # applied "pod"/"data"-axis factor only
    replicated_model: bool  # model sharding wanted but fell back to replicate


def _entries(spec: P, ndim: int) -> tuple:
    e = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    return e[:ndim]


def describe_sharding(cfg: ArchConfig, mesh, *, fsdp: bool | None = None
                      ) -> list[LeafSharding]:
    """Per-leaf report of :func:`param_pspecs` on ``mesh``: the applied
    spec, the spec the rules *wanted* (probed with an always-divisible
    axis size), and whether the model-axis fallback to replication fired.

    This is the accounting substrate for
    :func:`repro.distributed.collectives.layout_collectives` — the planner
    prices replication fallbacks from here instead of silently accepting
    them.  ``mesh`` only needs ``axis_names``/``devices.shape``, so an
    abstract stand-in works (no real devices required)."""
    fsdp = fsdp_wanted(cfg, mesh) if fsdp is None else fsdp
    shape_tree = T._shape_tree(cfg)
    probe = _probe_mesh(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_sz = int(sizes.get("model", 1))
    out: list[LeafSharding] = []

    def leaf(path, shape):
        name = path[-1].key
        stacked = any(
            getattr(p, "key", None) in ("blocks", "encoder") for p in path
        )
        spec = _param_rule(name, shape, stacked, mesh, cfg)
        if fsdp:
            spec = _zero_extend(spec, shape, mesh)
        want = _param_rule(name, shape, stacked, probe, cfg)
        applied = _entries(spec, len(shape))
        wanted = _entries(want, len(shape))
        shard = model_shard = data_shard = 1
        for ax in applied:
            if ax is None:
                continue
            sz = int(sizes.get(ax, 1))
            shard *= sz
            if ax == "model":
                model_shard *= sz
            elif ax in ("pod", "data"):
                data_shard *= sz
        n = 1
        for d in shape:
            n *= int(d)
        out.append(LeafSharding(
            path=".".join(str(getattr(p, "key", p)) for p in path),
            shape=tuple(int(d) for d in shape),
            elements=n,
            spec=applied,
            wanted=wanted,
            shard=shard,
            model_shard=model_shard,
            data_shard=data_shard,
            replicated_model=(model_sz > 1 and "model" in wanted
                              and "model" not in applied),
        ))
        return spec

    jax.tree_util.tree_map_with_path(
        leaf, shape_tree, is_leaf=lambda x: isinstance(x, tuple))
    return out
