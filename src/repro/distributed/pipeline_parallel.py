"""Pipeline parallelism: GPipe-style microbatched schedule over a ``pipe``
mesh axis, expressed with ``shard_map`` + ``collective-permute``.

The schedule runs ``n_micro + n_stages − 1`` ticks; at each tick every stage
processes the microbatch it holds and permutes activations to its successor.
Bubble fraction = (S−1)/(M+S−1) — reported by ``bubble_fraction`` and used by
the perf layer when PP is enabled as a hillclimb knob.

Works on any mesh that carries a ``pipe`` axis; validated against the
sequential model by tests (multi-device via subprocess with forced host
devices).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn, stage_params, x_micro, mesh, *, axis: str = "pipe"):
    """Run ``stage_fn(params_stage, x)`` over pipeline stages.

    stage_params: pytree stacked on the leading stage dim (sharded over
    ``axis``);  x_micro: (n_micro, micro_batch, ...) inputs.
    Returns (n_micro, micro_batch, ...) outputs (valid on the last stage,
    broadcast back to all stages).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_stage(params, xm):
        # params: (1, ...) this stage's slice;  xm: (n_micro, mb, ...) full
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        mb = xm.shape[1:]
        buf = jnp.zeros((n_micro,) + mb, xm.dtype)   # collected outputs
        carry = jnp.zeros(mb, xm.dtype)              # activation in flight

        def tick(t, state):
            carry, buf = state
            m_in = t                                  # microbatch entering stage 0
            # stage 0 ingests its own microbatch; others use the permuted carry
            x_own = jax.lax.dynamic_index_in_dim(
                xm, jnp.clip(m_in, 0, n_micro - 1), keepdims=False)
            x = jnp.where(stage == 0, x_own, carry)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params, x)
            y = jnp.where(active, y, carry)
            # last stage stores its completed microbatch
            m_done = t - (n_stages - 1)
            store = (stage == n_stages - 1) & (m_done >= 0) & (m_done < n_micro)
            buf = jax.lax.cond(
                store,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, y, jnp.clip(m_done, 0, n_micro - 1), 0),
                lambda b: b,
                buf,
            )
            # permute activations to the next stage
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, buf

        _, buf = jax.lax.fori_loop(0, ticks, tick, (carry, buf))
        # broadcast final outputs from the last stage to all stages
        return jax.lax.all_gather(buf, axis)[n_stages - 1]

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x_micro)
