"""Layout-level collective-traffic and memory-split accounting.

One function — :func:`layout_collectives` — turns ``(ArchConfig ×
ShapeSpec × mesh)`` into the per-device, per-class collective bytes a
training/inference step moves under the repo's own sharding rules
(``distributed/sharding.py``), plus the per-device memory split the same
rules imply.  Everything is derived from the actual PartitionSpecs via
:func:`~repro.distributed.sharding.describe_sharding`, so the accounting
can never drift from what GSPMD would be told to do; **replication
fallbacks are priced, not silently accepted** — a leaf the rules wanted
model-sharded but had to replicate contributes an extra model-axis
gradient all-reduce and keeps its unsplit memory.

Numpy/stdlib only on the hot path (jax is used for tree walking, never
compiled): the planner prices hundreds of layouts for meshes far larger
than the host with zero compiles.  ``abstract_mesh`` builds the mesh
stand-in the sharding rules need (``axis_names`` + ``devices.shape``)
without touching device state.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from types import SimpleNamespace

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding as sh
from repro.distributed.pipeline_parallel import bubble_fraction

__all__ = [
    "COLLECTIVE_CLASSES",
    "abstract_mesh",
    "LayoutCollectives",
    "layout_collectives",
]

_BYTES_PER_EL = {"bfloat16": 2, "float16": 2, "float32": 4}

# The collective classes the accounting buckets bytes into.  ``all_reduce``
# carries DP gradient rings, TP activation reductions AND the replication
# penalty; ``all_gather``/``reduce_scatter`` are the ZeRO/FSDP pair;
# ``ppermute`` is the pipeline's stage-boundary activation forwarding.
COLLECTIVE_CLASSES: tuple[str, ...] = (
    "all_reduce", "all_gather", "reduce_scatter", "ppermute",
)


def abstract_mesh(dims, axes=None) -> SimpleNamespace:
    """A mesh stand-in carrying exactly what the pspec rules read
    (``axis_names``, ``devices.shape``) — lets the planner price a
    256-device layout on a 1-CPU host without any jax device state."""
    dims = tuple(int(d) for d in dims)
    if axes is None:
        axes = ("pod", "data", "model")[-len(dims):]
    axes = tuple(axes)
    if len(axes) != len(dims):
        raise ValueError(f"mesh dims {dims} vs axes {axes} length mismatch")
    n = 1
    for d in dims:
        n *= d
    return SimpleNamespace(
        axis_names=axes,
        devices=SimpleNamespace(shape=dims, size=n),
    )


@dataclass
class LayoutCollectives:
    """Per-device collective bytes (one training/inference step) and the
    per-device memory split a layout implies.

    ``per_class`` keys are :data:`COLLECTIVE_CLASSES`; ``memory`` carries
    ``param_bytes_dev / grad_bytes_dev / opt_bytes_dev / act_bytes_dev /
    kv_bytes_dev / total_bytes_dev / param_bytes_total /
    replicated_bytes``; ``replicated`` lists the leaf paths whose wanted
    model-axis shard fell back to replication (priced via the extra
    model-axis all-reduce in ``per_class["all_reduce"]``)."""

    per_class: dict = field(default_factory=dict)
    memory: dict = field(default_factory=dict)
    replicated: list = field(default_factory=list)
    replicated_fraction: float = 0.0
    bubble: float = 0.0
    fsdp: bool = False

    @property
    def total_bytes(self) -> float:
        return float(sum(self.per_class.values()))

    def to_dict(self) -> dict:
        return {
            "per_class": {k: float(v) for k, v in self.per_class.items()},
            "total_bytes": self.total_bytes,
            "memory": {k: float(v) for k, v in self.memory.items()},
            "replicated": list(self.replicated),
            "replicated_fraction": float(self.replicated_fraction),
            "bubble": float(self.bubble),
            "fsdp": bool(self.fsdp),
        }


def layout_collectives(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh,
    *,
    pipe: int = 1,
    n_micro: int = 1,
    fsdp: bool | None = None,
    bytes_per_el: int | None = None,
) -> LayoutCollectives:
    """Account one step's per-device collective bytes + memory split for
    ``cfg × shape`` sharded on ``mesh`` (with ``pipe`` pipeline stages
    splitting the layer stack outside the mesh axes).

    All byte counts come from walking the real PartitionSpecs:

    * **DP gradient ring all-reduce** — ``2·B·(d−1)/d`` per device over the
      data axes, where ``B`` is the per-model-shard gradient bytes (the
      classic ring cost); replaced by the reduce-scatter + all-gather pair
      under ZeRO/FSDP.
    * **TP activation all-reduces** — two per layer forward (attention out,
      FFN out), doubled for backward on train cells, each moving the
      per-device activation slab ``(m−1)/m``-scaled.
    * **Replication penalty** — leaves whose wanted model shard fell back
      to replication gradient-all-reduce over the *model* axis too (each
      model-axis replica computed partial grads for them): priced, never
      silently dropped.
    * **Pipeline ppermute** — stage-boundary activation forwarding,
      fwd+bwd, ``(p−1)/p``-scaled.
    """
    bpe = bytes_per_el or _BYTES_PER_EL.get(cfg.dtype, 2)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_par = 1
    for ax in ("pod", "data"):
        d_par *= int(sizes.get(ax, 1))
    m_par = int(sizes.get("model", 1))
    pipe = max(int(pipe), 1)
    if fsdp is None:
        fsdp = sh.fsdp_wanted(cfg, mesh)
    train = shape.kind == "train"

    leaves = sh.describe_sharding(cfg, mesh, fsdp=fsdp)
    param_total = grad_dev = param_dev = repl_bytes_dev = 0.0
    replicated: list[str] = []
    for lf in leaves:
        nbytes = lf.elements * bpe
        param_total += nbytes
        param_dev += nbytes / lf.shard
        # Gradients mirror the TP shard (model axis) but are summed over
        # the data axes, so per-device grad bytes divide by model only —
        # exactly the tensor each DP ring round-trips.
        grad_dev += nbytes / max(lf.model_shard, 1)
        if lf.replicated_model:
            replicated.append(lf.path)
            repl_bytes_dev += nbytes  # unsplit on every model-axis device

    # Pipeline stages split the layer stack; embeddings/head don't split,
    # but at the accounting granularity here the 1/pipe factor on the
    # per-device totals is the intended first-order effect.
    param_dev /= pipe
    grad_dev /= pipe
    repl_dev = repl_bytes_dev / pipe

    # Optimizer state: AdamW m/v in f32, sharded by the param spec plus the
    # ZeRO extension over the data axis (state_pspecs always applies it).
    opt_dev = 0.0
    probe_state = sh.describe_sharding(cfg, mesh, fsdp=True)
    for lf in probe_state:
        opt_dev += 2 * 4 * lf.elements / lf.shard
    opt_dev /= pipe

    # Activations: the coarse lm_features slab (tokens × d_model × layers),
    # batch-sharded over DP when divisible, layer-sharded over pipe.
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    dp_ok = shape.global_batch % max(d_par, 1) == 0
    tokens_dev = tokens / (d_par if dp_ok else 1)
    act_dev = bpe * tokens_dev * cfg.d_model * max(cfg.n_layers, 1) / pipe

    kv_dev = 0.0
    if shape.kind != "train":
        kv_len = shape.seq_len + cfg.n_prefix
        kv_dev = (2.0 * bpe * (shape.global_batch / (d_par if dp_ok else 1))
                  * kv_len * max(cfg.n_kv_heads, 1) * cfg.head_dim_
                  * max(cfg.n_layers, 1) / max(m_par, 1)) / pipe

    per_class = {cls: 0.0 for cls in COLLECTIVE_CLASSES}

    # DP gradient exchange (train only): ring all-reduce, or the ZeRO
    # reduce-scatter + all-gather pair when params are FSDP-sharded.
    if train and d_par > 1:
        ring = (d_par - 1) / d_par
        if fsdp:
            per_class["reduce_scatter"] += grad_dev * ring
            per_class["all_gather"] += param_dev * ring
        else:
            per_class["all_reduce"] += 2.0 * grad_dev * ring

    # TP activation all-reduces: 2 per layer forward, ×2 for backward.
    if m_par > 1:
        n_ar = (4.0 if train else 2.0) * max(cfg.n_layers, 1) / pipe
        per_class["all_reduce"] += (
            n_ar * bpe * tokens_dev * cfg.d_model * (m_par - 1) / m_par)

    # Replication penalty: wanted-but-replicated leaves sum partial grads
    # over the model axis (train) — the fallback's price.
    if train and m_par > 1 and repl_dev > 0:
        per_class["all_reduce"] += 2.0 * repl_dev * (m_par - 1) / m_par

    # Pipeline stage-boundary activation forwarding (fwd + bwd on train).
    bubble = bubble_fraction(pipe, max(n_micro, 1)) if pipe > 1 else 0.0
    if pipe > 1:
        per_class["ppermute"] += ((2.0 if train else 1.0) * bpe * tokens_dev
                                  * cfg.d_model * (pipe - 1) / pipe)

    total_dev = param_dev + act_dev + kv_dev + (
        (grad_dev + opt_dev) if train else 0.0)
    return LayoutCollectives(
        per_class=per_class,
        memory={
            "param_bytes_dev": param_dev,
            "grad_bytes_dev": grad_dev if train else 0.0,
            "opt_bytes_dev": opt_dev if train else 0.0,
            "act_bytes_dev": act_dev,
            "kv_bytes_dev": kv_dev,
            "total_bytes_dev": total_dev,
            "param_bytes_total": param_total,
            "replicated_bytes_dev": repl_dev,
        },
        replicated=replicated,
        replicated_fraction=(repl_bytes_dev / param_total if param_total
                             else 0.0),
        bubble=bubble,
        fsdp=bool(fsdp),
    )
