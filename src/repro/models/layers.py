"""Transformer / MoE / SSD building blocks (pure JAX, GSPMD-friendly).

Design notes
------------
* All matmul-bearing ops are written as einsums over named dims so the GSPMD
  partitioner propagates shardings cleanly (heads / experts / ffn on "model",
  batch on "pod"+"data").
* Attention is *blocked*: a ``lax.scan`` over query blocks with full-row
  softmax per block.  This bounds the score tensor to
  (B, H, block_q, S_kv) — the XLA fallback of the Pallas flash-attention
  kernel in ``repro.kernels.flash_attention`` (used on real TPU).
* MoE uses capacity-based dispatch (GShard-style): sort tokens by expert,
  scatter into an (E, C, D) buffer (sharded E→model, C→data; the scatter is
  the all-to-all), batched-einsum the experts, gather back.  Compute overhead
  over the ideal is exactly the capacity factor.
* The SSD (Mamba-2) mixer is the chunked state-space-duality algorithm:
  quadratic attention-like compute inside chunks, linear state passing across
  chunks; single-step recurrence for decode.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "rope",
    "blocked_attention",
    "attention_block",
    "mlp_block",
    "moe_block",
    "ssd_block",
    "moe_capacity",
]

_NEG_INF = -1e30


_HINT_MESH = None  # set by the launcher (dryrun/train) for activation hints
SP_HINT = True     # sequence-parallel residual stream (helps dense, hurts MoE
                   # collectives — see EXPERIMENTS.md §Perf iteration A2)


def set_hint_mesh(mesh, *, sp: bool = True) -> None:
    """Install the mesh used for activation sharding hints inside model code
    (launcher-only; smoke tests leave it unset and hints become no-ops)."""
    global _HINT_MESH, SP_HINT
    _HINT_MESH = mesh
    SP_HINT = sp


def _maybe_constrain(x, *spec_dims):
    """with_sharding_constraint against the launcher-installed hint mesh, or
    a no-op when none is set / axes are missing.

    spec dims may be None, an axis name, or the special "dp" marker resolved
    to the data-parallel axes present on the mesh (("pod","data")/("data",)).
    Divisibility is checked per dim; non-divisible dims fall back to None.
    """
    mesh = _HINT_MESH
    if mesh is None:
        return x
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    dims = []
    for i, d in enumerate(spec_dims):
        if d == "dp":
            dp = tuple(a for a in ("pod", "data") if a in names)
            n = 1
            for a in dp:
                n *= sizes[a]
            dims.append(dp if dp and x.shape[i] % n == 0 else None)
        elif d is not None and d in names and x.shape[i] % sizes[d] == 0:
            dims.append(d)
        else:
            dims.append(None)
    from jax.sharding import NamedSharding, PartitionSpec as _P

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, _P(*dims)))


def _tuned_attention_block_q(q, k, causal: bool) -> int:
    """Query-block size for :func:`blocked_attention`, from the autotuner.

    Shares the flash-attention tiling model (and its device-keyed cache)
    with the Pallas kernel — the XLA fallback blocks over the same q axis,
    so the same roofline/working-set trade-off picks its block.  Runs at
    trace time (shapes are static); falls back to the historical 512.
    """
    from repro.kernels.autotune import tuned_config
    from repro.kernels.flash_attention import tiling

    B, Sq, Hkv, rep, Dh = q.shape  # (B, S, G, R, Dh) pre-blocking layout
    shape = tiling.shape_key((B, Hkv * rep, Sq, Dh),
                             (B, Hkv, k.shape[1], Dh),
                             causal=causal, dtype=q.dtype)
    return int(tuned_config("flash_attention", shape,
                            tiling.default(shape)).get("block_q", 512))


def _tuned_moe_dispatch(B: int, S: int, cfg, dtype) -> tuple[int, float]:
    """(groups, capacity_factor) for :func:`moe_block`, from the autotuner
    (the ``moe_dispatch`` tiling model; trace-time only).

    Falls back to the historical constants — ``gcd(B, moe_groups or 32)``
    groups at the configured capacity factor.  The tuned factor is clamped
    to never fall below the configured one: capacity controls token drops
    (model quality), so the tuner may only add slack, never remove it.

    Reproducibility contract: unlike the attention/SSM block sizes, these
    knobs change the routing arithmetic (group segmentation, slot counts),
    so the SAME checkpoint can produce numerically different logits under
    a different tuning cache or device.  Bit-reproducibility across
    machines therefore requires either ``REPRO_AUTOTUNE=0`` (config
    constants everywhere) or shipping the tuning-cache file with the
    checkpoint — the cache is content-keyed and device-salted exactly so
    it CAN be shipped.
    """
    from repro.kernels.autotune import tuned_config
    from repro.kernels.moe_dispatch import tiling

    g_default = math.gcd(B, getattr(cfg, "moe_groups", 32) or 32)
    shape = tiling.shape_key(B, S, cfg.d_model, cfg.n_experts,
                             cfg.experts_per_token, cfg.moe_d_ff_,
                             cfg.capacity_factor, dtype)
    tuned = tuned_config("moe_dispatch", shape,
                         {"groups": g_default,
                          "capacity_factor": cfg.capacity_factor})
    groups = math.gcd(B, int(tuned.get("groups", g_default)) or g_default)
    factor = max(float(tuned.get("capacity_factor", cfg.capacity_factor)),
                 cfg.capacity_factor)
    return groups, factor


def _tuned_ssm_chunk(xh, n_state: int, default_chunk: int) -> int:
    """Chunk length for :func:`ssd_scan`, from the autotuner (the
    ``ssm_scan`` tiling model; trace-time only, falls back to the config
    constant)."""
    from repro.kernels.autotune import tuned_config
    from repro.kernels.ssm_scan import tiling

    shape = tiling.shape_key(xh.shape, n_state, dtype=xh.dtype)
    return int(tuned_config("ssm_scan", shape,
                            {"chunk": default_chunk}).get("chunk",
                                                          default_chunk))


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, kind: str, chunk: int, prefix: int, kv_len=None):
    """Additive mask bias (0 or -inf).

    q_pos: (Sq,) or (B, Sq); k_pos: (Sk,) or (B, Sk) — leading batch dims
    broadcast, so ragged (per-row) positions yield a (B, Sq, Sk) bias.
    Negative key positions mark left-padding slots and are always masked
    out.  ``kv_len`` may be a scalar or a per-row (B,) vector.
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if kind == "causal":
        ok = k <= q
    elif kind == "chunked":  # causal within a local chunk window
        ok = (k <= q) & (q - k < chunk) & (q // chunk == k // chunk)
    elif kind == "prefix":   # bidirectional over first `prefix`, causal after
        ok = (k <= q) | (k < prefix)
    elif kind == "full":
        ok = jnp.ones_like(k <= q)
    else:
        raise ValueError(kind)
    if kind != "full":
        ok = ok & (k >= 0)  # left-padding slots carry negative positions
    if kv_len is not None:  # decode: only attend to valid cache entries
        kv = jnp.asarray(kv_len)
        if kv.ndim:
            kv = kv[..., None, None]
        ok = ok & (k <= kv)
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def blocked_attention(
    q, k, v, *,
    q_positions, k_positions,
    mask_kind: str = "causal",
    chunk: int = 8192,
    prefix: int = 0,
    kv_len=None,
    block_q: int | None = None,
    scale: float | None = None,
):
    """GQA attention, scanned over query blocks (memory-bounded).

    q: (B, Sq, H, Dh);  k, v: (B, Sk, Hkv, Dh).  Returns (B, Sq, H, Dh).
    ``block_q=None`` → autotuned (shared flash-attention tiling cache).
    """
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qr = (q * scale).reshape(B, Sq, Hkv, rep, Dh)
    if block_q is None:
        block_q = _tuned_attention_block_q(qr, k, mask_kind != "full")

    def expand(bias):
        # (B, Sq, Sk) per-row bias → broadcast over (G, R); 2-D passes through
        return bias[:, None, None] if bias.ndim == 3 else bias

    if Sq <= block_q:
        bias = _mask_bias(q_positions, k_positions, mask_kind, chunk, prefix, kv_len)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qr, k, preferred_element_type=jnp.float32)
        s = s + expand(bias)  # (B, G, R, Sq, Sk)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v)
        return o.reshape(B, Sq, H, Dh)

    nb = -(-Sq // block_q)
    pad = nb * block_q - Sq
    if pad:
        qr = jnp.pad(qr, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        # padded query rows are sliced off below; their positions are junk
        q_positions = jnp.pad(q_positions, [(0, 0)] * (q_positions.ndim - 1)
                              + [(0, pad)])
    qb = qr.reshape(B, nb, block_q, Hkv, rep, Dh).transpose(1, 0, 2, 3, 4, 5)
    if q_positions.ndim == 2:  # ragged: per-row positions ride along per block
        pb = q_positions.reshape(B, nb, block_q).transpose(1, 0, 2)
    else:
        pb = q_positions.reshape(nb, block_q)

    def body(_, blk):
        qblk, qpos = blk
        bias = _mask_bias(qpos, k_positions, mask_kind, chunk, prefix, kv_len)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, k, preferred_element_type=jnp.float32)
        s = s + expand(bias)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v)
        return None, o

    _, ob = jax.lax.scan(body, None, (qb, pb))  # (nb, B, block_q, Hkv, rep, Dh)
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, nb * block_q, H, Dh)
    return o[:, :Sq]


def _paged_decode_fast_path(q, k_pool, v_pool, block_table, cache_len):
    """Dispatch the S == 1 paged decode step to the specialised kernel,
    or return ``None`` to fall through to the gather + dense path.

    ``REPRO_PAGED_DECODE`` (read per call, so tests can flip it):
      ``auto``      kernel on TPU, gather elsewhere (default — keeps the
                    CPU path bit-identical to the pre-kernel behaviour)
      ``kernel``    always the Pallas kernel (interpret mode off-TPU)
      ``interpret`` force interpret mode (debugging/tests)
      ``gather``    always the gather + dense fallback
    """
    import os

    mode = os.environ.get("REPRO_PAGED_DECODE", "auto").lower()
    if mode == "gather":
        return None
    import jax as _jax

    if mode == "auto" and _jax.default_backend() != "tpu":
        return None
    from repro.kernels.paged_decode import paged_decode_attention

    impl = {"auto": "kernel", "kernel": "kernel",
            "interpret": "interpret"}.get(mode)
    if impl is None:  # unknown value: be conservative, gather
        return None
    o = paged_decode_attention(q[:, 0], k_pool, v_pool, block_table,
                               cache_len, impl=impl)
    return o[:, None]  # (B, 1, H, Dh)


def attention_block(
    x, p, cfg, *,
    positions,
    mask_kind: str,
    cache=None,          # (k_cache, v_cache): (B, Smax, Hkv, Dh) or None,
    #                      or a paged pool {"k_pool","v_pool"}: (P, bs, Hkv, Dh)
    cache_len=None,      # int32 scalar OR per-row (B,) vector: cache fill
    kv_source=None,      # cross-attention memory (B, Sm, D)
    pos_offset=None,     # (B,) left-padding per row (ragged prompts)
    block_table=None,    # (B, NB) logical→physical block map (paged cache)
):
    """Full attention sublayer: projections + RoPE + blocked attention.

    Returns (out, new_cache).  ``p`` holds wq/wk/wv/wo (+q_norm/k_norm/biases).

    Ragged support: ``positions`` may be per-row (B, S) with negative values
    marking left-padding (masked out of the keys, clamped for RoPE), and
    ``cache_len`` may be a per-row vector — decode slots at different fill
    levels write their new KV at per-row offsets (continuous batching).
    With a paged cache, K/V live in a fixed-size block pool indexed through
    ``block_table``; the step scatters the new tokens' KV into their blocks
    and attends either via the decode-specialised paged kernel (S == 1,
    ``REPRO_PAGED_DECODE``) or over the gathered logical view (fallback,
    and the S > 1 chunked-prefill path).
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(D, H, Dh))
    src = kv_source if kv_source is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].reshape(D, Hkv, Dh))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].reshape(D, Hkv, Dh))
    if cfg.attn_bias:
        q = q + p["bq"].reshape(H, Dh)
        k = k + p["bk"].reshape(Hkv, Dh)
        v = v + p["bv"].reshape(Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    if kv_source is None:  # self-attention: RoPE on q and k
        rope_pos = jnp.maximum(positions, 0)  # pad slots: masked, not rotated
        q = rope(q, rope_pos, cfg.rope_theta)
        k = rope(k, rope_pos, cfg.rope_theta)
        if cache is None:
            k_pos = positions
            new_cache = None
            kv_len = None
            k_full, v_full = k, v
        elif "k_pool" in cache:
            # Paged path: scatter the S new tokens' KV into their blocks.
            # Slot i's token t lands at logical position cache_len[i] + t =
            # physical (block_table[i, pos//bs], pos % bs).  S == 1 is the
            # decode step; S > 1 is a chunked-prefill chunk riding the same
            # path (right-padded rows route their junk positions to block
            # indices past the row's live table entries — the caller sizes
            # the table so those columns exist and point at scratch).
            kp, vp = cache["k_pool"], cache["v_pool"]
            bs_blk = kp.shape[1]
            cl = (cache_len if jnp.ndim(cache_len)
                  else jnp.full((B,), cache_len, jnp.int32))
            tok_pos = cl[:, None] + jnp.arange(S)            # (B, S)
            blk = tok_pos // bs_blk
            off = tok_pos % bs_blk
            phys = block_table[jnp.arange(B)[:, None], blk]  # (B, S)
            kp = kp.at[phys, off].set(k.astype(kp.dtype))
            vp = vp.at[phys, off].set(v.astype(vp.dtype))
            new_cache = {"k_pool": kp, "v_pool": vp}
            kv_len = cl + S - 1                              # (B,)
            if S == 1 and mask_kind == "causal":
                # Decode fast path: single-query paged attention reads K/V
                # straight from the pool (no gathered logical view), with
                # block-granular early exit at each row's last live block.
                # REPRO_PAGED_DECODE picks the impl; the gather fallback
                # below stays the CPU default and exactness oracle.
                o = _paged_decode_fast_path(q, kp, vp, block_table, kv_len)
                if o is not None:
                    out = jnp.einsum("bshk,hkd->bsd", o,
                                     p["wo"].reshape(H, Dh, D))
                    return out, new_cache
            k_full = kp[block_table].reshape(B, -1, Hkv, Dh)  # (B, NB·bs, ·)
            v_full = vp[block_table].reshape(B, -1, Hkv, Dh)
            k_pos = jnp.arange(k_full.shape[1])
        else:
            kc, vc = cache["k"], cache["v"]
            k_pos = jnp.arange(kc.shape[1])
            if jnp.ndim(cache_len):
                # per-row fill (continuous batching): each slot writes its
                # single new token at its own offset
                assert S == 1, "per-row cache_len is a single-token decode path"
                kc = kc.at[jnp.arange(B), cache_len].set(k[:, 0].astype(kc.dtype))
                vc = vc.at[jnp.arange(B), cache_len].set(v[:, 0].astype(vc.dtype))
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_len, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_len, axis=1)
            new_cache = {"k": kc, "v": vc}
            kv_len = cache_len + S - 1
            if pos_offset is not None:
                # left-padded rows: cache slot j holds logical position
                # j - pad, pad slots (< 0) masked out by _mask_bias
                k_pos = k_pos[None, :] - pos_offset[:, None]
                kv_len = kv_len - pos_offset
            k_full, v_full = kc, vc
    else:  # cross-attention: no RoPE, full mask over memory
        k_pos = jnp.arange(src.shape[1])
        new_cache = None
        kv_len = None
        k_full, v_full = k, v
        mask_kind = "full"

    o = blocked_attention(
        q, k_full, v_full,
        q_positions=positions, k_positions=k_pos,
        mask_kind=mask_kind, chunk=cfg.chunk_size, prefix=cfg.n_prefix,
        kv_len=kv_len,
    )
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, Dh, D))
    return out, new_cache


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU and capacity-dispatch MoE
# ---------------------------------------------------------------------------


def mlp_block(x, p):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["up"])
    return jnp.einsum("bsf,fd->bsd", h, p["down"])


def moe_capacity(tokens: int, n_experts: int, k: int, factor: float) -> int:
    c = int(math.ceil(tokens * k / n_experts * factor))
    return max(8, -(-c // 8) * 8)  # multiple of 8, floor 8


def _moe_dispatch_group(xt, gates, ids, p, E, K, C):
    """Capacity dispatch for one token group.  xt: (T,D); gates/ids: (T,K).

    Gather-only formulation (perf iteration A1, EXPERIMENTS.md §Perf): the
    (E, C, D) buffer is built by *gathering* tokens through a per-expert
    slot-index matrix instead of scattering — GSPMD lowers cross-shard
    scatters into full-buffer all-reduces (measured 48×4.3 GB/step on
    qwen3-moe), while gathers stay as slices/all-gathers of the shard."""
    T, D = xt.shape
    flat_e = ids.reshape(-1)                                  # (T·K,)
    sort_idx = jnp.argsort(flat_e)                            # stable
    sorted_e = flat_e[sort_idx]
    seg_starts = jnp.searchsorted(sorted_e, jnp.arange(E))    # (E,)
    seg_ends = jnp.append(seg_starts[1:], T * K)
    # slot (e, c) holds sorted position seg_starts[e]+c while inside segment
    pos = seg_starts[:, None] + jnp.arange(C)[None, :]        # (E, C)
    valid = pos < seg_ends[:, None]
    tok_for_slot = sort_idx[jnp.clip(pos, 0, T * K - 1)] // K
    buf = jnp.where(valid[..., None], xt[tok_for_slot], 0)    # gather (E,C,D)
    pos_in_e = jnp.arange(T * K) - seg_starts[sorted_e]
    dest_c = jnp.where(pos_in_e < C, pos_in_e, C)             # C ⇒ dropped
    return buf, (sorted_e, dest_c, sort_idx)


def _moe_combine_group(out_buf, route, gates, K):
    sorted_e, dest_c, sort_idx = route
    T = gates.shape[0]
    slot_out = out_buf.at[sorted_e, dest_c].get(
        mode="fill", fill_value=0)                            # gather (T·K, D)
    inv = jnp.argsort(sort_idx)
    unsorted = slot_out[inv]                                  # gather un-sort
    return (unsorted.reshape(T, K, -1)
            * gates[..., None].astype(out_buf.dtype)).sum(axis=1)


def moe_block(x, p, cfg):
    """Top-k capacity MoE: GShard-style dispatch, SwiGLU experts.

    Tokens are split into ``G`` groups along the (data-sharded) batch axis and
    dispatch/sort/scatter run *per group* (vmapped) — each group lives on one
    data shard, so routing stays device-local under GSPMD and only the
    (G, E, C, ·) expert buffer crosses the mesh (the all-to-all), exactly the
    GShard communication pattern.  Expert FFNs run as batched einsums over the
    expert-sharded (model-axis) weights.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    G, factor = _tuned_moe_dispatch(B, S, cfg, x.dtype)
    Tg = (B // G) * S
    C = moe_capacity(Tg, E, K, factor)
    xg = x.reshape(G, Tg, D)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                      # (G, Tg, K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    buf, route = jax.vmap(
        lambda xt, g, i: _moe_dispatch_group(xt, g, i, p, E, K, C)
    )(xg, gates, ids)                                          # buf: (G, E, C, D)
    # Expert-parallel layout: groups on DP, experts on the model axis.  The
    # reshard from (G@dp, E) to (G@dp, E@model) IS the GShard all-to-all.
    buf = _maybe_constrain(buf, "dp", "model", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["up"])
    h = _maybe_constrain(h, "dp", "model", None, None)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["down"])       # (G, E, C, D)
    out_buf = _maybe_constrain(out_buf, "dp", "model", None, None)

    out = jax.vmap(
        lambda ob, rt, g: _moe_combine_group(ob, rt, g, K)
    )(out_buf, route, gates)                                   # (G, Tg, D)
    out = _maybe_constrain(out, "dp", None, None)
    aux = _load_balance_loss(probs.reshape(-1, E), ids.reshape(-1, K), E)
    return out.reshape(B, S, D), aux


def _load_balance_loss(probs, ids, E):
    """Switch-style auxiliary load-balancing loss (returned for the trainer)."""
    T = probs.shape[0]
    frac_tokens = jnp.zeros(E).at[ids.reshape(-1)].add(1.0) / ids.size
    frac_probs = probs.mean(axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


# ---------------------------------------------------------------------------
# Mamba-2 SSD mixer
# ---------------------------------------------------------------------------


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i], -inf for j>i."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(xh, a, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD (Mamba-2 alg. 1 / "minimal ssd").

    xh: (B, S, H, P) inputs (already dt-scaled)
    a:  (B, S, H)    log-decay per step (dt · A, negative)
    Bm, Cm: (B, S, G, N) state in/out projections (G groups, broadcast to H)
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    B, S0, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    pad = (-S0) % chunk
    if pad:  # zero-pad: a=0 ⇒ decay 1, x=0 ⇒ no state contribution
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = S0 + pad
    nc = S // chunk
    rep = H // G

    def c(t):  # (B, S, ...) -> (B, nc, chunk, ...)
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:])

    xc, ac, Bc, Cc = c(xh), c(a), c(Bm), c(Cm)
    ac = jnp.moveaxis(ac, -1, 2)            # (B, nc, H, chunk)
    cum_a = jnp.cumsum(ac, axis=-1)         # (B, nc, H, chunk)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(ac.astype(jnp.float32)))                  # (B,nc,H,l,l)
    Cr = jnp.repeat(Cc, rep, axis=3) if G != H else Cc            # broadcast groups
    Br = jnp.repeat(Bc, rep, axis=3) if G != H else Bc
    s = jnp.einsum("bclhn,bcshn->bchls", Cr, Br, preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", s, L, xc.astype(jnp.float32))

    # 2. per-chunk final states
    decay_states = jnp.exp(cum_a[..., -1:] - cum_a)               # (B,nc,H,l)
    states = jnp.einsum(
        "bclhn,bchl,bclhp->bchpn", Br, decay_states.astype(jnp.float32),
        xc.astype(jnp.float32),
    )                                                              # (B,nc,H,P,N)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(cum_a[..., -1])                          # (B,nc,H)
    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the *previous* state (state entering chunk)

    st_seq = jnp.moveaxis(states, 1, 0)         # (nc, B, H, P, N)
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)   # (nc, B, H)
    final_state, prev_states = jax.lax.scan(step, s0, (st_seq, dec_seq))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    # 4. state → output contribution
    state_decay = jnp.exp(cum_a)                                   # (B,nc,H,l)
    y_off = jnp.einsum(
        "bclhn,bchpn,bchl->bclhp", Cr, prev_states, state_decay.astype(jnp.float32)
    )
    y = (y_diag + y_off).reshape(B, S, H, P)[:, :S0]
    return y.astype(xh.dtype), final_state


def ssd_block(x, p, cfg, *, cache=None, valid=None):
    """Mamba-2 block: in_proj → causal conv1d → SSD → gated norm → out_proj.

    cache (decode): dict(conv=(B, W-1, d_conv_ch), state=(B, H, P, N)).
    ``valid`` (B, S) bool marks real tokens in a left-padded ragged batch:
    invalid steps contribute zero conv taps, zero state input and unit
    decay (a = 0), so the recurrence matches an unpadded run exactly.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    d_inner = cfg.ssm_expand * D
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    G = 1  # single B/C group
    d_conv_ch = d_inner + 2 * G * N
    W = cfg.ssm_conv_width

    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xbc = jnp.concatenate(
        [jnp.einsum("bsd,de->bse", x, p["w_x"]),
         jnp.einsum("bsd,de->bse", x, p["w_B"]),
         jnp.einsum("bsd,de->bse", x, p["w_C"])], axis=-1)
    dt = jnp.einsum("bsd,de->bse", x, p["w_dt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid is not None:  # pad steps feed zero taps into the causal conv
        xbc = jnp.where(valid[..., None], xbc, 0)

    # causal depthwise conv over (x, B, C) channels
    if cache is None:
        pad = jnp.zeros((B, W - 1, d_conv_ch), xbc.dtype)
        conv_in = jnp.concatenate([pad, xbc], axis=1)
        new_conv = None
    else:
        conv_in = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv = conv_in[:, -(W - 1):]
    stack = [conv_in[:, i : i + S] for i in range(W)]
    xbc = sum(s * p["conv_w"][i] for i, s in enumerate(stack)) + p["conv_b"]
    xbc = jax.nn.silu(xbc)

    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,), negative
    a = dt * A                                                 # (B,S,H) log-decay
    xh = xs * dt[..., None].astype(xs.dtype)
    if valid is not None:  # pad steps: no state input, unit decay
        xh = jnp.where(valid[..., None, None], xh, 0)
        a = jnp.where(valid[..., None], a, 0.0)

    ssm_chunk = (_tuned_ssm_chunk(xh, N, cfg.ssm_chunk)
                 if S > 1 else cfg.ssm_chunk)
    if cache is None:
        y, final_state = ssd_scan(xh, a, Bm, Cm, ssm_chunk)
        new_cache = None
    elif S > 1:  # prefill with cache: chunked scan seeded by cached state
        y, final_state = ssd_scan(
            xh, a, Bm, Cm, ssm_chunk, initial_state=cache["state"]
        )
        new_cache = {"conv": new_conv, "state": final_state}
    else:
        # single-step recurrence (S == 1)
        st = cache["state"].astype(jnp.float32)                # (B,H,P,N)
        dec = jnp.exp(a[:, 0])                                 # (B,H)
        Br = jnp.repeat(Bm[:, 0], H // G, axis=1) if G != H else Bm[:, 0]
        Cr = jnp.repeat(Cm[:, 0], H // G, axis=1) if G != H else Cm[:, 0]
        upd = jnp.einsum("bhp,bhn->bhpn", xh[:, 0].astype(jnp.float32), Br.astype(jnp.float32))
        st = st * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", st, Cr.astype(jnp.float32))[:, None]
        new_cache = {"conv": new_conv, "state": st}

    y = y + xs.astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])                # gated RMSNorm
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache
