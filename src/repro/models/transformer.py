"""Unified LM: dense / MoE / SSM / hybrid / VLM / enc-dec assembly.

One model covers all 10 assigned architectures through a per-arch *layer
plan*: the sequence of (mixer, ffn) sublayers that one ``lax.scan`` step
executes.  Uniform archs scan over ``n_layers`` identical blocks; Jamba scans
over superblocks of 8 sublayers (7 SSD + 1 attention, alternating dense/MoE
FFN); Whisper adds a separately-scanned bidirectional encoder and
cross-attention in the decoder.

Parameters are stacked on the scan dimension — one compiled block body per
sublayer *kind*, independent of depth (critical for dry-run compile time at
48 layers × 512 devices).

Entry points (all jit/pjit-able, ShapeDtypeStruct-friendly):
    loss_fn(params, batch, cfg)              -- training loss (+ MoE aux)
    prefill(params, batch, cfg)              -- last-token logits + KV/SSM cache
    decode_step(params, cache, batch, cfg)   -- one-token step with cache
    init_params(cfg, seed) / param_specs(cfg)
    init_cache(cfg, batch, max_len) / cache_specs(...)
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import (
    _maybe_constrain,
    attention_block,
    mlp_block,
    moe_block,
    rms_norm,
    ssd_block,
)

__all__ = [
    "layer_plan",
    "param_specs",
    "init_params",
    "loss_fn",
    "forward",
    "prefill",
    "decode_step",
    "cache_specs",
    "init_cache",
    "paged_cache_shapes",
    "init_paged_cache",
    "input_specs",
    "warm_autotune",
]

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Layer plans
# ---------------------------------------------------------------------------


def layer_plan(cfg: ArchConfig) -> tuple[int, list[tuple[str, str | None]]]:
    """(n_scan, [(mixer, ffn), ...] per scan step)."""
    if cfg.family == "ssm":
        return cfg.n_layers, [("ssm", None)]
    if cfg.hybrid_period:
        assert cfg.n_layers % cfg.hybrid_period == 0
        plan = []
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "ssm"
            ffn = "moe" if (cfg.moe_every and i % cfg.moe_every == 1) else "mlp"
            plan.append((mixer, ffn))
        return cfg.n_layers // cfg.hybrid_period, plan
    mixer = "attn_cross" if cfg.n_encoder_layers else "attn"
    ffn = "moe" if cfg.is_moe else "mlp"
    return cfg.n_layers, [(mixer, ffn)]


# ---------------------------------------------------------------------------
# Parameter shapes / init
# ---------------------------------------------------------------------------


def _attn_shapes(cfg) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    s = {
        "wq": (D, H * Dh),
        "wk": (D, Hkv * Dh),
        "wv": (D, Hkv * Dh),
        "wo": (H * Dh, D),
    }
    if cfg.qk_norm:
        s["q_norm"] = (Dh,)
        s["k_norm"] = (Dh,)
    if cfg.attn_bias:
        s["bq"] = (H * Dh,)
        s["bk"] = (Hkv * Dh,)
        s["bv"] = (Hkv * Dh,)
    return s


def _mlp_shapes(cfg) -> dict:
    return {"gate": (cfg.d_model, cfg.d_ff), "up": (cfg.d_model, cfg.d_ff),
            "down": (cfg.d_ff, cfg.d_model)}


def _moe_shapes(cfg) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff_
    return {"router": (D, E), "gate": (E, D, F), "up": (E, D, F), "down": (E, F, D)}


def _ssm_shapes(cfg) -> dict:
    # Separate projections per segment (z, x, B, C, dt) so tensor-parallel
    # sharding of d_inner/heads stays clean (no mixed-sharded concat dim).
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    P, N, G, W = cfg.ssm_head_dim, cfg.ssm_state, 1, cfg.ssm_conv_width
    H = d_inner // P
    d_conv_ch = d_inner + 2 * G * N
    return {
        "w_z": (D, d_inner),
        "w_x": (D, d_inner),
        "w_B": (D, G * N),
        "w_C": (D, G * N),
        "w_dt": (D, H),
        "conv_w": (W, d_conv_ch),
        "conv_b": (d_conv_ch,),
        "dt_bias": (H,),
        "A_log": (H,),
        "D": (H,),
        "norm": (d_inner,),
        "out_proj": (d_inner, D),
    }


def _block_shapes(cfg, plan) -> dict:
    out = {}
    for i, (mixer, ffn) in enumerate(plan):
        sub: dict = {"ln1": (cfg.d_model,)}
        if mixer.startswith("attn"):
            sub["attn"] = _attn_shapes(cfg)
            if mixer == "attn_cross":
                sub["cross"] = _attn_shapes(cfg)
                sub["ln_cross"] = (cfg.d_model,)
        else:
            sub["ssm"] = _ssm_shapes(cfg)
        if ffn is not None:
            sub["ln2"] = (cfg.d_model,)
            sub[ffn] = _mlp_shapes(cfg) if ffn == "mlp" else _moe_shapes(cfg)
        out[f"sub{i}"] = sub
    return out


def _shape_tree(cfg: ArchConfig) -> dict:
    n_scan, plan = layer_plan(cfg)
    V = cfg.padded_vocab()
    tree: dict = {
        "embed": (V, cfg.d_model),
        "final_norm": (cfg.d_model,),
        "blocks": jax.tree.map(
            lambda s: (n_scan, *s), _block_shapes(cfg, plan),
            is_leaf=lambda x: isinstance(x, tuple),
        ),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (cfg.d_model, V)
    if cfg.n_encoder_layers:
        enc_plan = [("attn", "mlp")]
        tree["encoder"] = jax.tree.map(
            lambda s: (cfg.n_encoder_layers, *s), _block_shapes(cfg, enc_plan),
            is_leaf=lambda x: isinstance(x, tuple),
        )
        tree["enc_final_norm"] = (cfg.d_model,)
    return tree


def param_specs(cfg: ArchConfig) -> dict:
    """ShapeDtypeStruct pytree (for AOT lowering — no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, DTYPE),
        _shape_tree(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_params(cfg: ArchConfig, seed: int = 0) -> dict:
    """Real (numpy) init for smoke tests / the training driver."""
    rng = np.random.default_rng(seed)

    def leaf(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln1", "ln2", "ln_cross", "final_norm", "enc_final_norm",
                    "norm", "q_norm", "k_norm"):
            return np.zeros(s, np.float32).astype(jnp.bfloat16)
        if name in ("conv_b", "bq", "bk", "bv", "dt_bias", "D"):
            return (np.zeros(s) if name != "D" else np.ones(s)).astype(jnp.bfloat16)
        if name == "A_log":
            return np.log(rng.uniform(1.0, 16.0, s)).astype(jnp.bfloat16)
        fan_in = s[-2] if len(s) >= 2 else s[-1]
        return (rng.standard_normal(s) * (1.0 / math.sqrt(fan_in))).astype(jnp.bfloat16)

    return jax.tree_util.tree_map_with_path(
        leaf, _shape_tree(cfg), is_leaf=lambda x: isinstance(x, tuple)
    )


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mask_kind(cfg: ArchConfig) -> str:
    if cfg.attention == "chunked":
        return "chunked"
    if cfg.n_prefix:
        return "prefix"
    return "causal"


def _run_stack(
    blocks, x, cfg, plan, *,
    positions, mask_kind, memory=None,
    cache=None, cache_len=None, want_cache=False, remat=True,
    pos_offset=None, block_table=None,
):
    """Scan the (stacked) blocks over x.  Returns (x, aux_loss, new_cache).

    ``pos_offset`` (B,) marks left-padding per row (ragged prompts):
    attention masks the pad slots, SSD mixers treat them as zero-input
    unit-decay steps.  ``block_table`` routes attention K/V through a
    paged block pool (see :func:`paged_cache_shapes`).
    """
    # per-row validity for SSD mixers: pad positions carry negatives
    ssm_valid = positions >= 0 if positions.ndim == 2 else None

    def body(carry, inp):
        x, aux = carry
        lp, lc = inp if cache is not None else (inp, None)
        new_lc = {} if (want_cache or cache is not None) else None
        for i, (mixer, ffn) in enumerate(plan):
            sp = lp[f"sub{i}"]
            sc = lc[f"sub{i}"] if lc is not None else None
            h = rms_norm(x, sp["ln1"])
            if mixer.startswith("attn"):
                mo, nc = attention_block(
                    h, sp["attn"], cfg, positions=positions, mask_kind=mask_kind,
                    cache=sc, cache_len=cache_len,
                    pos_offset=pos_offset, block_table=block_table,
                )
                x = x + mo
                if mixer == "attn_cross":
                    h = rms_norm(x, sp["ln_cross"])
                    co, _ = attention_block(
                        h, sp["cross"], cfg, positions=positions,
                        mask_kind="full", kv_source=memory,
                    )
                    x = x + co
            else:
                mo, nc = ssd_block(h, sp["ssm"], cfg, cache=sc, valid=ssm_valid)
                x = x + mo
            if new_lc is not None:
                new_lc[f"sub{i}"] = nc
            if ffn is not None:
                h = rms_norm(x, sp["ln2"])
                if ffn == "mlp":
                    x = x + mlp_block(h, sp["mlp"])
                else:
                    fo, a = moe_block(h, sp["moe"], cfg)
                    x = x + fo
                    aux = aux + a
        # Sequence parallelism (perf iteration B2): the scan carry — the
        # remat-saved residual stream — is sharded over the model axis on its
        # sequence dim, shrinking saved activations by the TP degree and
        # turning boundary all-reduces into reduce-scatter/all-gather pairs.
        # No-op without a hint mesh or when S doesn't divide (decode S=1).
        from repro.models import layers as _L

        if _L.SP_HINT:
            x = _maybe_constrain(x, "dp", "model", None)
        return (x, aux), new_lc

    if remat:
        body = jax.checkpoint(body)
    xs = (blocks, cache) if cache is not None else blocks
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, aux, new_cache


def _prefill_like(cfg, params, batch, *, max_len, want_cache):
    """Shared forward: embeddings → stack → final norm.  Used by training
    (want_cache=False) and prefill (want_cache=True, cache written).

    batch: tokens (B,S) int32 [+ patches (B,P,D) | frames (B,F,D)
    | pos_offset (B,)].  ``pos_offset`` marks per-row left-padding (ragged
    prompts): positions become per-row, pad slots carry negatives and are
    masked out of attention keys / SSD state updates.
    """
    n_scan, plan = layer_plan(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_prefix:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)
    pos_offset = batch.get("pos_offset")
    if pos_offset is not None:
        assert not cfg.n_prefix and not cfg.n_encoder_layers, \
            "ragged (left-padded) prompts need a plain self-attention stack"
        positions = positions[None, :] - pos_offset[:, None]  # (B, S_total)

    memory = None
    if cfg.n_encoder_layers:
        enc_pos = jnp.arange(batch["frames"].shape[1])
        memory, _, _ = _run_stack(
            params["encoder"], batch["frames"].astype(x.dtype), cfg,
            [("attn", "mlp")], positions=enc_pos, mask_kind="full",
        )
        memory = rms_norm(memory, params["enc_final_norm"])

    cache = None
    if want_cache:
        cache = init_cache(cfg, B, max_len, dtype=DTYPE, stacked=True, zeros=jnp)
        cache_len = jnp.int32(0)
    else:
        cache_len = None

    x, aux, new_cache = _run_stack(
        params["blocks"], x, cfg, plan,
        positions=positions, mask_kind=_mask_kind(cfg), memory=memory,
        cache=cache, cache_len=cache_len, want_cache=want_cache,
        pos_offset=pos_offset,
    )
    x = rms_norm(x, params["final_norm"])
    return x, aux, new_cache, memory


def _logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward(params, batch, cfg: ArchConfig):
    """Training-mode forward → (logits over text positions, aux loss)."""
    x, aux, _, _ = _prefill_like(cfg, params, batch, max_len=0, want_cache=False)
    if cfg.n_prefix:
        x = x[:, cfg.n_prefix:]
    return _logits(cfg, params, x), aux


def loss_fn(params, batch, cfg: ArchConfig, *, z_loss: float = 1e-4,
            moe_aux: float = 1e-2, seq_chunk: int | None = None):
    """Next-token CE (f32 logsumexp) + z-loss + MoE load-balance aux.

    ``seq_chunk``: compute logits+CE over sequence chunks via ``lax.map`` so
    the (B, S, V) logits tensor is never materialised (perf iteration B2) —
    peak goes from B·S·V to B·seq_chunk·V.
    """
    if seq_chunk is None:
        logits, aux = forward(params, batch, cfg)
        logits = logits[:, :-1].astype(jnp.float32)
        targets = batch["tokens"][:, 1:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        zl = jnp.mean(lse**2)
        return ce + z_loss * zl + moe_aux * aux, {"ce": ce, "aux": aux}

    x, aux, _, _ = _prefill_like(cfg, params, batch, max_len=0, want_cache=False)
    if cfg.n_prefix:
        x = x[:, cfg.n_prefix:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B, S, D = x.shape
    # drop the final position (no next-token target), pad S-1 up to chunks
    xs = x[:, :-1]
    targets = batch["tokens"][:, 1:]
    n_tok = B * (S - 1)
    nc = -(-(S - 1) // seq_chunk)
    pad = nc * seq_chunk - (S - 1)
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    xs = xs.reshape(B, nc, seq_chunk, D).transpose(1, 0, 2, 3)
    tg = targets.reshape(B, nc, seq_chunk).transpose(1, 0, 2)
    valid = (jnp.arange(nc * seq_chunk) < (S - 1)).reshape(nc, 1, seq_chunk)

    def chunk_ce(args):
        xc, tc, vc = args
        lg = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        ce_sum = jnp.sum((lse - gold) * vc)
        zl_sum = jnp.sum((lse**2) * vc)
        return ce_sum, zl_sum

    ce_sums, zl_sums = jax.lax.map(chunk_ce, (xs, tg, valid))
    ce = ce_sums.sum() / n_tok
    zl = zl_sums.sum() / n_tok
    return ce + z_loss * zl + moe_aux * aux, {"ce": ce, "aux": aux}


def prefill(params, batch, cfg: ArchConfig, *, max_len: int | None = None):
    """Process the prompt; return (last-token logits, cache, memory)."""
    S = batch["tokens"].shape[1] + cfg.n_prefix
    max_len = max_len if max_len is not None else S
    x, _, cache, memory = _prefill_like(cfg, params, batch, max_len=max_len,
                                        want_cache=True)
    logits = _logits(cfg, params, x[:, -1:])
    out = {"logits": logits, "cache": cache, "cache_len": jnp.int32(S)}
    if memory is not None:
        out["memory"] = memory
    return out


def decode_step(params, cache, batch, cfg: ArchConfig):
    """One-token decode.  batch: tokens (B,1), cache_len (), [memory].
    With a paged cache, tokens may be (B,S) — chunked prefill feeds
    prompt chunks through this same path (scatter S tokens, attend
    causally from each row's cache_len offset).

    Ragged / continuous-batching extensions (serve path):

    * ``cache_len`` may be a per-row (B,) vector — slots at different fill
      levels decode together, each writing its new KV at its own offset;
    * ``pos_offset`` (B,) shifts per-row positions for left-padded prompts
      (legacy ``generate`` ragged mode);
    * ``block_table`` (B, NB) routes K/V through a paged block pool
      (``cache`` then holds ``k_pool``/``v_pool`` leaves, see
      :func:`paged_cache_shapes`).
    """
    n_scan, plan = layer_plan(cfg)
    tokens, cache_len = batch["tokens"], batch["cache_len"]
    pos_offset = batch.get("pos_offset")
    x = jnp.take(params["embed"], tokens, axis=0)
    steps = jnp.arange(x.shape[1])
    if jnp.ndim(cache_len) or pos_offset is not None:
        cl = jnp.broadcast_to(jnp.asarray(cache_len), (tokens.shape[0],))
        if pos_offset is not None:
            cl = cl - pos_offset
        positions = cl[:, None] + steps[None, :]             # (B, S)
    else:
        positions = cache_len + steps
    x, _, new_cache = _run_stack(
        params["blocks"], x, cfg, plan,
        positions=positions, mask_kind=_mask_kind(cfg),
        memory=batch.get("memory"), cache=cache, cache_len=cache_len,
        want_cache=False, remat=False,
        pos_offset=pos_offset, block_table=batch.get("block_table"),
    )
    x = rms_norm(x, params["final_norm"])
    return _logits(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# Caches and input specs
# ---------------------------------------------------------------------------


def _sub_cache_shape(cfg, mixer, B, max_len):
    if mixer.startswith("attn"):
        Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
        return {"k": (B, max_len, Hkv, Dh), "v": (B, max_len, Hkv, Dh)}
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    d_conv_ch = d_inner + 2 * cfg.ssm_state
    return {
        "conv": (B, cfg.ssm_conv_width - 1, d_conv_ch),
        "state": (B, H, cfg.ssm_head_dim, cfg.ssm_state),
    }


def cache_shapes(cfg: ArchConfig, B: int, max_len: int) -> dict:
    n_scan, plan = layer_plan(cfg)
    out = {}
    for i, (mixer, _) in enumerate(plan):
        shapes = _sub_cache_shape(cfg, mixer, B, max_len)
        out[f"sub{i}"] = {k: (n_scan, *s) for k, s in shapes.items()}
    return out


def _cache_dtype(name: str):
    return jnp.float32 if name == "state" else DTYPE


def cache_specs(cfg: ArchConfig, B: int, max_len: int) -> dict:
    return jax.tree_util.tree_map_with_path(
        lambda p, s: jax.ShapeDtypeStruct(s, _cache_dtype(p[-1].key)),
        cache_shapes(cfg, B, max_len),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_cache(cfg: ArchConfig, B: int, max_len: int, *, dtype=DTYPE,
               stacked=True, zeros=np) -> dict:
    def mk(path, s):
        if not stacked:
            s = s[1:]
        name = path[-1].key
        if zeros is jnp:
            return jnp.zeros(s, _cache_dtype(name))
        np_dt = np.float32 if name == "state" else jnp.bfloat16
        return np.zeros(s, np_dt)

    return jax.tree_util.tree_map_with_path(
        mk, cache_shapes(cfg, B, max_len), is_leaf=lambda x: isinstance(x, tuple)
    )


def paged_cache_shapes(cfg: ArchConfig, n_blocks: int, block_size: int) -> dict:
    """Shapes of the paged KV block pool (the serve path's cache layout).

    Each self-attention sublayer stores K/V in a pool of ``n_blocks``
    fixed-size blocks of ``block_size`` tokens; a per-slot block table maps
    logical positions to physical blocks (``decode_step``'s
    ``block_table``).  Pool capacity is a *budget*, not ``n_slots ×
    max_len`` — long-context configs no longer allocate dense caches they
    never fill.  Physical block 0 is reserved as scratch for idle slots.
    """
    n_scan, plan = layer_plan(cfg)
    out = {}
    for i, (mixer, _) in enumerate(plan):
        if mixer != "attn":
            raise ValueError(
                f"paged KV cache needs a pure self-attention stack; "
                f"{cfg.name} has a {mixer!r} mixer (use the dense cache)")
        s = (n_scan, n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim_)
        out[f"sub{i}"] = {"k_pool": s, "v_pool": s}
    return out


def init_paged_cache(cfg: ArchConfig, n_blocks: int, block_size: int) -> dict:
    """Zero-filled device block pool (see :func:`paged_cache_shapes`)."""
    return jax.tree.map(
        lambda s: jnp.zeros(s, DTYPE),
        paged_cache_shapes(cfg, n_blocks, block_size),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def warm_autotune(cfg: ArchConfig, *, batch_size: int, seq_len: int,
                  stages: tuple = ("train", "prefill", "decode")) -> dict:
    """Pre-populate the kernel tuning cache for one workload cell.

    Abstractly traces the requested entry points (``jax.eval_shape`` — no
    compile, no allocation), which fires every trace-time autotune lookup
    in ``models/layers.py`` with exactly the shapes the real jit will see
    and persists the winners to the device-keyed
    :class:`~repro.kernels.autotune.TuningCache`.  Launchers call this
    once before building the jitted step so compilation never blocks on a
    cold tuning search.  Returns the tuner's {hits, misses} delta.
    """
    from repro.kernels.autotune import autotune_enabled, get_tuner
    from repro.configs.base import ShapeSpec

    if not autotune_enabled():
        return {"hits": 0, "misses": 0}
    tuner = get_tuner()
    h0, m0 = tuner.hits, tuner.misses
    params = param_specs(cfg)
    for stage in stages:
        kind = stage if stage in ("train", "prefill", "decode") else "train"
        spec = input_specs(
            cfg, ShapeSpec("warm", seq_len, batch_size, kind),
            include_params=False)
        if kind == "decode":
            jax.eval_shape(
                lambda p, c, b: decode_step(p, c, b, cfg),
                params, spec["cache"], spec["batch"])
        elif kind == "prefill":
            jax.eval_shape(
                lambda p, b: prefill(p, b, cfg, max_len=seq_len),
                params, spec["batch"])
        else:
            jax.eval_shape(
                lambda p, b: loss_fn(p, b, cfg)[0], params, spec["batch"])
    return {"hits": tuner.hits - h0, "misses": tuner.misses - m0}


def input_specs(cfg: ArchConfig, shape, *, include_params: bool = True) -> dict:
    """ShapeDtypeStruct stand-ins for every step input of a workload cell.

    train   → {params, batch={tokens, labels-implicit, [patches|frames]}}
    prefill → {params, batch={tokens, [patches|frames]}}
    decode  → {params, cache, batch={tokens(B,1), cache_len, [memory]}}
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if include_params:
        specs["params"] = param_specs(cfg)
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.n_prefix:
            batch["patches"] = jax.ShapeDtypeStruct((B, cfg.n_prefix, cfg.d_model), DTYPE)
        if cfg.n_encoder_layers:
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.n_audio_frames, cfg.d_model), DTYPE)
        specs["batch"] = batch
    else:  # decode: one new token against a cache of size S
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache_len": jax.ShapeDtypeStruct((), i32),
        }
        if cfg.n_encoder_layers:
            batch["memory"] = jax.ShapeDtypeStruct((B, cfg.n_audio_frames, cfg.d_model), DTYPE)
        specs["batch"] = batch
        specs["cache"] = cache_specs(cfg, B, S)
    return specs
