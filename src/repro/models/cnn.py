"""CNN zoo for the paper's profiling substrate (paper §5.1/§6).

The paper profiles AlexNet, ResNet18/50, MobileNetV2, SqueezeNet, MnasNet and
GoogLeNet on the Jetson TX2, generating datapoints by structured filter
pruning.  We implement all seven in pure JAX through a small declarative
graph IR so that

  * the same definition yields (i) ``init``/``apply`` for real training-step
    profiling, (ii) a :class:`~repro.core.features.NetworkSpec` for the
    analytical features, and (iii) a per-channel-group ``widths`` dict that
    the pruning process rewrites to derive topologies;
  * pure-Python shape propagation extracts features in ~100 µs per topology
    (paper §6.4 needs 0.1 s/model prediction for the 50 000-model ES search —
    no jax tracing may be involved).

Layout is NHWC / HWIO.  BatchNorm runs in training mode (batch statistics),
matching the paper's profiled attribute (training step, not inference).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import ConvLayerSpec, NetworkSpec

__all__ = [
    "CNNModel",
    "build_alexnet",
    "build_resnet18",
    "build_resnet50",
    "build_mobilenetv2",
    "build_squeezenet",
    "build_mnasnet",
    "build_googlenet",
    "CNN_BUILDERS",
    "canonical_widths",
]

NUM_CLASSES = 100  # CIFAR-100 is the paper's proxy dataset (via [19])


# ---------------------------------------------------------------------------
# Graph IR
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Node:
    def out_shape(self, s: tuple[int, int, int], rec: list | None = None):
        raise NotImplementedError

    def init(self, rng, s):
        raise NotImplementedError

    def apply(self, params, x):
        raise NotImplementedError


def _act(x, kind: str):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if kind == "none":
        return x
    raise ValueError(kind)


@dataclass(frozen=True)
class C(Node):
    """Conv(+BN)(+act).  ``depthwise=True`` ties out=in, groups=channels.

    ``group`` names the prunable channel group this conv's filters belong to
    (the *primary* producer of that group) — used by the L1 pruning strategy
    to score filters.
    """

    out: int
    k: int
    stride: int = 1
    padding: int | None = None  # None = "same"-ish (k//2)
    depthwise: bool = False
    act: str = "relu"
    bn: bool = True
    bias: bool = False
    group: str | None = None

    @property
    def pad(self) -> int:
        return self.k // 2 if self.padding is None else self.padding

    def _geom(self, s):
        h, w, cin = s
        cout = cin if self.depthwise else self.out
        groups = cin if self.depthwise else 1
        oh = 1 + (h + 2 * self.pad - self.k) // self.stride
        ow = 1 + (w + 2 * self.pad - self.k) // self.stride
        return cin, cout, groups, oh, ow

    def out_shape(self, s, rec=None):
        cin, cout, groups, oh, ow = self._geom(s)
        if rec is not None:
            rec.append(
                ConvLayerSpec(
                    n=cout, m=cin, k=self.k, stride=self.stride,
                    padding=self.pad, groups=groups, ip=s[0],
                )
            )
        return (oh, ow, cout)

    def init(self, rng, s):
        cin, cout, groups, *_ = self._geom(s)
        fan_in = self.k * self.k * (cin // groups)
        # numpy init: zero dispatch/compile cost until the jitted step runs
        p = {"w": (rng.standard_normal((self.k, self.k, cin // groups, cout))
                   * np.sqrt(2.0 / fan_in)).astype(np.float32)}
        if self.bias:
            p["b"] = np.zeros((cout,), np.float32)
        if self.bn:
            p["scale"] = np.ones((cout,), np.float32)
            p["shift"] = np.zeros((cout,), np.float32)
        return p

    def apply(self, params, x):
        cin = x.shape[-1]
        groups = cin if self.depthwise else 1
        y = jax.lax.conv_general_dilated(
            x, params["w"],
            window_strides=(self.stride, self.stride),
            padding=[(self.pad, self.pad)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups,
        )
        if self.bias:
            y = y + params["b"]
        if self.bn:
            mu = jnp.mean(y, axis=(0, 1, 2), keepdims=True)
            var = jnp.var(y, axis=(0, 1, 2), keepdims=True)
            y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
            y = y * params["scale"] + params["shift"]
        return _act(y, self.act)


@dataclass(frozen=True)
class Seq(Node):
    nodes: tuple[Node, ...]

    def out_shape(self, s, rec=None):
        for n in self.nodes:
            s = n.out_shape(s, rec)
        return s

    def init(self, rng, s):
        params = {}
        for i, n in enumerate(self.nodes):
            params[str(i)] = n.init(rng, s)
            s = n.out_shape(s)
        return params

    def apply(self, params, x):
        for i, n in enumerate(self.nodes):
            x = n.apply(params[str(i)], x)
        return x


def seq(*nodes: Node) -> Seq:
    return Seq(tuple(nodes))


@dataclass(frozen=True)
class Residual(Node):
    """out = act(body(x) + shortcut(x)); identity shortcut when None."""

    body: Node
    shortcut: Node | None = None
    act: str = "relu"

    def out_shape(self, s, rec=None):
        out = self.body.out_shape(s, rec)
        sc = self.shortcut.out_shape(s, rec) if self.shortcut else s
        if out != sc:
            raise ValueError(f"residual mismatch: body {out} vs shortcut {sc}")
        return out

    def init(self, rng, s):
        p = {"body": self.body.init(rng, s)}
        if self.shortcut:
            p["shortcut"] = self.shortcut.init(rng, s)
        return p

    def apply(self, params, x):
        y = self.body.apply(params["body"], x)
        sc = self.shortcut.apply(params["shortcut"], x) if self.shortcut else x
        return _act(y + sc, self.act)


@dataclass(frozen=True)
class Concat(Node):
    branches: tuple[Node, ...]

    def out_shape(self, s, rec=None):
        outs = [b.out_shape(s, rec) for b in self.branches]
        hw = {(o[0], o[1]) for o in outs}
        if len(hw) != 1:
            raise ValueError(f"concat spatial mismatch: {outs}")
        return (outs[0][0], outs[0][1], sum(o[2] for o in outs))

    def init(self, rng, s):
        params = {}
        for i, b in enumerate(self.branches):
            params[str(i)] = b.init(rng, s)
        return params

    def apply(self, params, x):
        return jnp.concatenate(
            [b.apply(params[str(i)], x) for i, b in enumerate(self.branches)], axis=-1
        )


@dataclass(frozen=True)
class Pool(Node):
    kind: str  # "max" | "avg"
    k: int
    stride: int
    padding: int = 0

    def out_shape(self, s, rec=None):
        h, w, c = s
        oh = 1 + (h + 2 * self.padding - self.k) // self.stride
        ow = 1 + (w + 2 * self.padding - self.k) // self.stride
        return (oh, ow, c)

    def init(self, rng, s):
        return {}

    def apply(self, params, x):
        dims = (1, self.k, self.k, 1)
        strides = (1, self.stride, self.stride, 1)
        pads = ((0, 0), (self.padding,) * 2, (self.padding,) * 2, (0, 0))
        if self.kind == "max":
            return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, dims, strides, pads)
        ones = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add, dims, strides, pads)
        summed = jax.lax.reduce_window(x, 0.0, jax.lax.add, dims, strides, pads)
        return summed / ones


@dataclass(frozen=True)
class GlobalAvgPool(Node):
    def out_shape(self, s, rec=None):
        return (1, 1, s[2])

    def init(self, rng, s):
        return {}

    def apply(self, params, x):
        return jnp.mean(x, axis=(1, 2), keepdims=True)


@dataclass(frozen=True)
class Dense(Node):
    out: int
    act: str = "none"
    group: str | None = None

    def out_shape(self, s, rec=None):
        cin = int(np.prod(s))
        if rec is not None:
            # FC recorded as a 1x1 conv on a 1x1 map (exact allocations).
            rec.append(ConvLayerSpec(n=self.out, m=cin, k=1, ip=1))
        return (1, 1, self.out)

    def init(self, rng, s):
        cin = int(np.prod(s))
        return {
            "w": (rng.standard_normal((cin, self.out)) * np.sqrt(2.0 / cin)).astype(np.float32),
            "b": np.zeros((self.out,), np.float32),
        }

    def apply(self, params, x):
        x = x.reshape(x.shape[0], -1)
        return _act(x @ params["w"] + params["b"], self.act)


# ---------------------------------------------------------------------------
# Model container
# ---------------------------------------------------------------------------


@dataclass
class CNNModel:
    name: str
    family: str
    graph: Node
    widths: dict[str, int]
    input_hw: int = 32
    num_classes: int = NUM_CLASSES

    def conv_specs(self) -> NetworkSpec:
        rec: list[ConvLayerSpec] = []
        self.graph.out_shape((self.input_hw, self.input_hw, 3), rec)
        return NetworkSpec(name=self.name, layers=tuple(rec))

    def init(self, seed: "int | np.random.Generator" = 0) -> dict:
        """Initialise parameters as numpy arrays (He init); zero JAX dispatch
        cost — the jitted step converts on first call."""
        rng = seed if isinstance(seed, np.random.Generator) else np.random.default_rng(seed)
        return self.graph.init(rng, (self.input_hw, self.input_hw, 3))

    def apply(self, params, x) -> jax.Array:
        return self.graph.apply(params, x).reshape(x.shape[0], -1)

    def num_params(self) -> int:
        specs = self.conv_specs()
        return int(sum(l.n * l.m / l.groups * l.k**2 for l in specs.layers))


# ---------------------------------------------------------------------------
# Width utilities
# ---------------------------------------------------------------------------


def _scale_widths(widths: dict[str, int], mult: float, floor: int = 4) -> dict[str, int]:
    return {k: max(floor, int(round(v * mult))) for k, v in widths.items()}


def _w(widths: dict[str, int], key: str) -> int:
    if key not in widths:
        raise KeyError(f"missing width group {key!r}")
    return widths[key]


# ---------------------------------------------------------------------------
# AlexNet (used by the paper only to tune the training-set-size hyperparameter)
# ---------------------------------------------------------------------------

ALEXNET_WIDTHS = {"c1": 64, "c2": 192, "c3": 384, "c4": 256, "c5": 256, "fc1": 1024, "fc2": 1024}


def build_alexnet(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    w = widths or _scale_widths(ALEXNET_WIDTHS, width_mult)
    g = seq(
        C(_w(w, "c1"), k=3, stride=2, group="c1"),
        Pool("max", 2, 2),
        C(_w(w, "c2"), k=3, group="c2"),
        Pool("max", 2, 2),
        C(_w(w, "c3"), k=3, group="c3"),
        C(_w(w, "c4"), k=3, group="c4"),
        C(_w(w, "c5"), k=3, group="c5"),
        Pool("max", 2, 2),
        Dense(_w(w, "fc1"), act="relu", group="fc1"),
        Dense(_w(w, "fc2"), act="relu", group="fc2"),
        Dense(NUM_CLASSES),
    )
    return CNNModel("alexnet", "alexnet", g, dict(w), input_hw)


# ---------------------------------------------------------------------------
# ResNet18 / ResNet50  (basic-block vs bottleneck residuals, App. C)
# ---------------------------------------------------------------------------


def _resnet18_widths() -> dict[str, int]:
    w = {"stem": 64}
    for si, c in enumerate([64, 128, 256, 512]):
        w[f"s{si}"] = c
        for bi in range(2):
            w[f"s{si}b{bi}"] = c  # internal 3x3 width, prunable independently
    return w


def build_resnet18(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    w = widths or _scale_widths(_resnet18_widths(), width_mult)
    nodes: list[Node] = [C(_w(w, "stem"), k=3, group="stem")]
    in_group = "stem"
    for si in range(4):
        stride = 1 if si == 0 else 2
        for bi in range(2):
            s = stride if bi == 0 else 1
            out_c, mid_c = _w(w, f"s{si}"), _w(w, f"s{si}b{bi}")
            body = seq(
                C(mid_c, k=3, stride=s, group=f"s{si}b{bi}"),
                C(out_c, k=3, act="none", group=f"s{si}" if bi == 0 else None),
            )
            need_proj = s != 1 or _w(w, in_group) != out_c
            sc = C(out_c, k=1, stride=s, act="none") if need_proj else None
            nodes.append(Residual(body, sc))
            in_group = f"s{si}"
    nodes += [GlobalAvgPool(), Dense(NUM_CLASSES)]
    return CNNModel("resnet18", "resnet", seq(*nodes), dict(w), input_hw)


def _resnet50_widths() -> dict[str, int]:
    w = {"stem": 64}
    blocks = [3, 4, 6, 3]
    for si, (c_out, c_mid) in enumerate(zip([256, 512, 1024, 2048], [64, 128, 256, 512])):
        w[f"s{si}"] = c_out
        for bi in range(blocks[si]):
            w[f"s{si}b{bi}"] = c_mid
    return w


def build_resnet50(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    w = widths or _scale_widths(_resnet50_widths(), width_mult)
    blocks = [3, 4, 6, 3]
    nodes: list[Node] = [C(_w(w, "stem"), k=3, group="stem")]
    in_group = "stem"
    for si in range(4):
        stride = 1 if si == 0 else 2
        for bi in range(blocks[si]):
            s = stride if bi == 0 else 1
            out_c, mid_c = _w(w, f"s{si}"), _w(w, f"s{si}b{bi}")
            body = seq(
                C(mid_c, k=1, group=f"s{si}b{bi}"),
                C(mid_c, k=3, stride=s),
                C(out_c, k=1, act="none", group=f"s{si}" if bi == 0 else None),
            )
            need_proj = s != 1 or _w(w, in_group) != out_c
            sc = C(out_c, k=1, stride=s, act="none") if need_proj else None
            nodes.append(Residual(body, sc))
            in_group = f"s{si}"
    nodes += [GlobalAvgPool(), Dense(NUM_CLASSES)]
    return CNNModel("resnet50", "resnet", seq(*nodes), dict(w), input_hw)


# ---------------------------------------------------------------------------
# MobileNetV2 / MnasNet  (depthwise-separable inverted residuals, App. C)
# ---------------------------------------------------------------------------

_MBV2_SETTINGS = [  # (expansion t, out c, repeats n, stride s) — ImageNet strides
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def _mbnet_widths(settings, stem=32, head=1280) -> dict[str, int]:
    w = {"stem": stem, "head": head}
    idx = 0
    for t, c, n, s in settings:
        for bi in range(n):
            w[f"b{idx}_out"] = c
            if t > 1:
                w[f"b{idx}_exp"] = t * (stem if idx == 0 else settings_in(settings, idx))
            idx += 1
    return w


def settings_in(settings, flat_idx):
    """Input channels of flattened block ``flat_idx`` under canonical widths."""
    idx = 0
    prev_c = None
    for t, c, n, s in settings:
        for bi in range(n):
            if idx == flat_idx:
                return prev_c if prev_c is not None else c
            prev_c = c
            idx += 1
    raise IndexError(flat_idx)


def _build_mbnet(name, settings, widths, width_mult, input_hw, kernel_per_stage=None):
    canonical = _mbnet_widths(settings)
    w = widths or _scale_widths(canonical, width_mult)
    nodes: list[Node] = [C(_w(w, "stem"), k=3, stride=2, act="relu6", group="stem")]
    in_c = _w(w, "stem")
    idx = 0
    for stage_i, (t, c, n, s) in enumerate(settings):
        k = 3 if kernel_per_stage is None else kernel_per_stage[stage_i]
        for bi in range(n):
            stride = s if bi == 0 else 1
            out_c = _w(w, f"b{idx}_out")
            inner: list[Node] = []
            if t > 1:
                inner.append(C(_w(w, f"b{idx}_exp"), k=1, act="relu6", group=f"b{idx}_exp"))
            inner.append(C(0, k=k, stride=stride, depthwise=True, act="relu6"))
            inner.append(C(out_c, k=1, act="none", group=f"b{idx}_out"))
            body = seq(*inner)
            if stride == 1 and in_c == out_c:
                nodes.append(Residual(body, None, act="none"))
            else:
                nodes.append(body)
            in_c = out_c
            idx += 1
    nodes += [C(_w(w, "head"), k=1, act="relu6", group="head"), GlobalAvgPool(), Dense(NUM_CLASSES)]
    return CNNModel(name, "mbnet", seq(*nodes), dict(w), input_hw)


def build_mobilenetv2(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    return _build_mbnet("mobilenetv2", _MBV2_SETTINGS, widths, width_mult, input_hw)


_MNAS_SETTINGS = [  # MnasNet-B1-ish, ImageNet strides
    (1, 16, 1, 1), (3, 24, 3, 2), (3, 40, 3, 2), (6, 80, 3, 2),
    (6, 96, 2, 1), (6, 192, 4, 2), (6, 320, 1, 1),
]
_MNAS_KERNELS = [3, 3, 5, 5, 3, 5, 3]


def build_mnasnet(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    return _build_mbnet(
        "mnasnet", _MNAS_SETTINGS, widths, width_mult, input_hw, _MNAS_KERNELS
    )


# ---------------------------------------------------------------------------
# SqueezeNet (fire modules) / GoogLeNet (inception modules) — App. C
# ---------------------------------------------------------------------------

_FIRE_SETTINGS = [(16, 64, 64), (16, 64, 64), (32, 128, 128), (32, 128, 128),
                  (48, 192, 192), (48, 192, 192), (64, 256, 256), (64, 256, 256)]


def _squeezenet_widths() -> dict[str, int]:
    w = {"stem": 64}
    for i, (sq, e1, e3) in enumerate(_FIRE_SETTINGS):
        w[f"f{i}_sq"], w[f"f{i}_e1"], w[f"f{i}_e3"] = sq, e1, e3
    return w


def build_squeezenet(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    w = widths or _scale_widths(_squeezenet_widths(), width_mult)
    nodes: list[Node] = [
        C(_w(w, "stem"), k=3, stride=2, bn=False, bias=True, group="stem"),
        Pool("max", 2, 2),
    ]
    for i in range(len(_FIRE_SETTINGS)):
        fire = seq(
            C(_w(w, f"f{i}_sq"), k=1, bn=False, bias=True, group=f"f{i}_sq"),
            Concat((
                C(_w(w, f"f{i}_e1"), k=1, bn=False, bias=True, group=f"f{i}_e1"),
                C(_w(w, f"f{i}_e3"), k=3, bn=False, bias=True, group=f"f{i}_e3"),
            )),
        )
        nodes.append(fire)
        if i in (1, 3):
            nodes.append(Pool("max", 2, 2))
    nodes += [C(NUM_CLASSES, k=1, bn=False, bias=True), GlobalAvgPool(), Dense(NUM_CLASSES)]
    return CNNModel("squeezenet", "squeezenet", seq(*nodes), dict(w), input_hw)


_INCEPTION_SETTINGS = {  # name: (#1x1, #3x3red, #3x3, #5x5red, #5x5, pool-proj)
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def _googlenet_widths() -> dict[str, int]:
    w = {"stem1": 64, "stem2": 64, "stem3": 192}
    for name, (b1, r3, b3, r5, b5, pp) in _INCEPTION_SETTINGS.items():
        w.update({
            f"i{name}_1": b1, f"i{name}_3r": r3, f"i{name}_3": b3,
            f"i{name}_5r": r5, f"i{name}_5": b5, f"i{name}_p": pp,
        })
    return w


def build_googlenet(widths=None, width_mult=1.0, input_hw=32) -> CNNModel:
    w = widths or _scale_widths(_googlenet_widths(), width_mult)
    nodes: list[Node] = [
        C(_w(w, "stem1"), k=3, stride=2, group="stem1"),
        C(_w(w, "stem2"), k=1, group="stem2"),
        C(_w(w, "stem3"), k=3, group="stem3"),
        Pool("max", 2, 2),
    ]
    for name in _INCEPTION_SETTINGS:
        inc = Concat((
            C(_w(w, f"i{name}_1"), k=1, group=f"i{name}_1"),
            seq(C(_w(w, f"i{name}_3r"), k=1, group=f"i{name}_3r"),
                C(_w(w, f"i{name}_3"), k=3, group=f"i{name}_3")),
            seq(C(_w(w, f"i{name}_5r"), k=1, group=f"i{name}_5r"),
                C(_w(w, f"i{name}_5"), k=5, group=f"i{name}_5")),
            seq(Pool("max", 3, 1, 1), C(_w(w, f"i{name}_p"), k=1, group=f"i{name}_p")),
        ))
        nodes.append(inc)
        if name in ("3b", "4e"):
            nodes.append(Pool("max", 2, 2))
    nodes += [GlobalAvgPool(), Dense(NUM_CLASSES)]
    return CNNModel("googlenet", "googlenet", seq(*nodes), dict(w), input_hw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

CNN_BUILDERS = {
    "alexnet": build_alexnet,
    "resnet18": build_resnet18,
    "resnet50": build_resnet50,
    "mobilenetv2": build_mobilenetv2,
    "squeezenet": build_squeezenet,
    "mnasnet": build_mnasnet,
    "googlenet": build_googlenet,
}


def canonical_widths(family: str, width_mult: float = 1.0) -> dict[str, int]:
    """Canonical (unpruned) channel-group widths for a network family."""
    model = CNN_BUILDERS[family](width_mult=width_mult)
    return dict(model.widths)


def iter_tagged(node: Node, params: dict):
    """Yield (group, node, node_params) for every group-tagged C/Dense node,
    walking the graph and the params pytree in lockstep."""
    if isinstance(node, (C, Dense)):
        if node.group is not None:
            yield node.group, node, params
    elif isinstance(node, Seq):
        for i, n in enumerate(node.nodes):
            yield from iter_tagged(n, params[str(i)])
    elif isinstance(node, Residual):
        yield from iter_tagged(node.body, params["body"])
        if node.shortcut is not None:
            yield from iter_tagged(node.shortcut, params["shortcut"])
    elif isinstance(node, Concat):
        for i, b in enumerate(node.branches):
            yield from iter_tagged(b, params[str(i)])
    # Pool / GlobalAvgPool: no params, nothing to yield
