"""Batched serving example: prefill + KV-cache decode with the ServeEngine
on a smoke-scale qwen3-family model.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    cfg = get_config("qwen3-4b", reduced=True)
    print(f"serving {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    params = T.init_params(cfg, 0)
    engine = ServeEngine(cfg, params,
                         ServeConfig(max_len=128, n_slots=4, temperature=0.0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, (4, 16)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=24)
    dt = time.time() - t0
    toks = out["tokens"]
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({toks.size / dt:.0f} tok/s on CPU)")
    for i, row in enumerate(toks):
        print(f"  seq {i}: {row[:12].tolist()}...")
    # decode batch 2 again — greedy determinism
    out2 = engine.generate(prompts, max_new_tokens=24)
    assert np.array_equal(out["tokens"], out2["tokens"])
    print("greedy decode is deterministic ✓")


if __name__ == "__main__":
    main()
