"""Quickstart: the full perf4sight loop in one script (~2 min on CPU).

1. Profile a small grid of pruned SqueezeNet topologies (network-wise
   strategy: whole training steps, §5.1).
2. Extract the 42 analytical features per (topology, batch size) (§5.2.1).
3. Fit the Γ/Φ random forests (§5.2).
4. Predict memory/latency for an unseen topology and check against a real
   profile; use the predictor as an admission gate (§6.4).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.dataset import DatasetCache, GridSpec, collect_grid
from repro.core.predictor import Perf4Sight
from repro.core.profiler import profile_training
from repro.core.pruning import pruned_model
from repro.core.features import network_features


def main() -> None:
    cache = DatasetCache("benchmarks/cache/cnn_profile.json")

    print("1) profiling pruned SqueezeNet training steps (cache-aware)...")
    grid = GridSpec("squeezenet", levels=(0.0, 0.3, 0.5, 0.7, 0.9),
                    strategy="random", batch_sizes=(2, 8, 16, 32))
    train_pts = collect_grid(grid, cache, verbose=True)
    cache.flush()

    print("\n2-3) fitting Γ/Φ random forests on", len(train_pts), "points...")
    model = Perf4Sight(n_estimators=100).fit(train_pts)
    print(f"   OOB: Γ {model.gamma_model.oob_mape_ * 100:.1f}% "
          f"Φ {model.phi_model.oob_mape_ * 100:.1f}%")

    print("\n4) predicting an UNSEEN topology (40% pruned)...")
    m = pruned_model("squeezenet", 0.4, "random", seed=7,
                     width_mult=0.25, input_hw=16)
    spec = m.conv_specs()
    for bs in (4, 24):
        pg, pp = model.predict(spec, bs)
        real = profile_training(m, bs)
        print(f"   bs={bs:3d}: predicted Γ={pg:6.1f}MB Φ={pp:6.1f}ms | "
              f"measured Γ={real.gamma_mb:6.1f}MB Φ={real.phi_ms:6.1f}ms | "
              f"err Γ={abs(pg - real.gamma_mb) / real.gamma_mb * 100:4.1f}% "
              f"Φ={abs(pp - real.phi_ms) / real.phi_ms * 100:4.1f}%")

    print("\n5) admission gate (the launcher's safety check):")
    ok, info = model.admit(spec, 32, gamma_budget_mb=50.0)
    print(f"   bs=32 under 50MB budget → {'ADMIT' if ok else 'REFUSE'} ({info})")
    ok, info = model.admit(spec, 32, gamma_budget_mb=1.0)
    print(f"   bs=32 under  1MB budget → {'ADMIT' if ok else 'REFUSE'} ({info})")


if __name__ == "__main__":
    main()
