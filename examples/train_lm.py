"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU with the full production loop — checkpointing, auto-resume, straggler
monitoring, cosine schedule, gradient clipping.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.optim.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 12L, d=512, 8H, ff=2048, 32k vocab
CFG_100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000, qk_norm=True,
    rope_theta=1e4, tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    print(f"model: {CFG_100M.param_count() / 1e6:.0f}M params")
    shape = ShapeSpec("train", args.seq, args.batch, "train")
    opt = OptimizerConfig(kind="adamw", lr=1e-3, warmup_steps=20,
                          total_steps=args.steps, clip_norm=1.0)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    trainer = Trainer(CFG_100M, shape, opt, tcfg)
    out = trainer.train(args.steps)
    h = out["history"]
    for rec in h[:: max(len(h) // 20, 1)]:
        print(f"  step {rec['step']:4d}  loss {rec['loss']:.4f}  "
              f"ce {rec['ce']:.4f}  {rec['dt'] * 1e3:6.1f} ms"
              f"{'  [STRAGGLER]' if rec['straggler'] else ''}")
    first = np.mean([r["ce"] for r in h[:10]])
    last = np.mean([r["ce"] for r in h[-10:]])
    print(f"\nce: {first:.3f} → {last:.3f}  "
          f"({len(out['stragglers'])} straggler steps flagged)")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
