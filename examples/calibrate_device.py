"""Calibrate the analytical cost model for THIS device (instant when the
profiling cache is warm, e.g. the checked-in benchmarks/cache fixture).

1. Build a small (topology × batch) workload grid of pruned SqueezeNets.
2. Get ground truth per workload — cached datapoint or a real profiled
   training step through ProfilerBackend.
3. Solve for the device's roofline constants (peak FLOP/s, memory
   bandwidth, launch overhead) and memory constants (weight/activation
   scale) by nonnegative least squares, and compare prediction accuracy
   before vs after.
4. Persist the fitted DeviceSpec (atomic JSON) for launchers and servers:
   `python -m repro.launch.train --device /tmp/device_spec.json ...`

    PYTHONPATH=src python examples/calibrate_device.py
"""

import argparse
import os
import tempfile

from repro.core.dataset import DatasetCache
from repro.engine import (
    AnalyticalBackend,
    ProfilerBackend,
    calibrate,
    default_workloads,
    evaluate_accuracy,
    measure_ground_truth,
    save_device_spec,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default="benchmarks/cache/cnn_profile.json",
                    help="profiling cache (warm = no profiling runs)")
    ap.add_argument("--out", default="/tmp/device_spec.json",
                    help="where to persist the fitted DeviceSpec (.json/.npz)")
    ap.add_argument("--base-device", default="host_cpu",
                    help="registry entry seeding capacity/interconnect")
    args = ap.parse_args()

    backend = AnalyticalBackend(device=args.base_device)
    profiler = ProfilerBackend(repeats=2, warmup=1)
    workloads = default_workloads()
    cache = DatasetCache(args.cache)
    if os.path.abspath(args.cache) == os.path.abspath(
            "benchmarks/cache/cnn_profile.json"):
        # The default cache is the git-tracked golden fixture the accuracy
        # tests assert against: read its datapoints, but redirect any new
        # profiles to a scratch file so the fixture is never rewritten.
        cache.path = os.path.join(tempfile.gettempdir(),
                                  "perf4sight_device_cache.json")

    print(f"1) ground truth for {len(workloads)} workloads "
          f"({len(cache)} cached datapoints available)...")
    dps, profiled = measure_ground_truth(profiler, workloads, cache)
    print(f"   {profiled} profiled live, {len(dps) - profiled} from cache")

    before = evaluate_accuracy(backend, dps)
    print(f"2) uncalibrated ({backend.device.name}): "
          f"latency MAPE {before['phi_mape']:.1%}, "
          f"memory MAPE {before['gamma_mape']:.1%}")

    spec = calibrate(backend, profiler, workloads, datapoints=dps)
    after = evaluate_accuracy(backend, dps)
    print(f"3) calibrated ({spec.name}): "
          f"latency MAPE {after['phi_mape']:.1%}, "
          f"memory MAPE {after['gamma_mape']:.1%}")
    print(f"   peak_flops={spec.peak_flops:.3g} FLOP/s  "
          f"hbm_bw={spec.hbm_bw:.3g} B/s  "
          f"launch_overhead={spec.launch_overhead_s * 1e3:.3g} ms")
    print(f"   mem: base={spec.mem_base_mb:.3g} MB  "
          f"weight_scale={spec.mem_weight_scale:.3g}  "
          f"act_scale={spec.mem_act_scale:.3g}")

    save_device_spec(args.out, spec)
    print(f"4) saved fitted spec -> {args.out}  "
          f"(fingerprint {spec.fingerprint()})")


if __name__ == "__main__":
    main()
