"""§6.4 in miniature: evolutionary search for the largest sub-network that
fits hard (Γ, γ, φ) budgets, gated by the perf4sight predictors — then a
ground-truth profile of the winner to verify the constraints held.

    PYTHONPATH=src python examples/config_search.py
"""

import time

import numpy as np

from repro.core.dataset import DatasetCache, GridSpec, collect_grid
from repro.core.features import network_features
from repro.core.predictor import Perf4Sight
from repro.core.profiler import profile_inference, profile_training
from repro.core.search import Constraints, evolutionary_search, sample_subnetwork
from repro.engine import CostEngine, ForestBackend
from repro.models.cnn import build_resnet50

WM, HW = 0.25, 16


def main() -> None:
    cache = DatasetCache("benchmarks/cache/cnn_profile.json")
    print("training-Γ model from the ResNet50 grid...")
    train_pts = collect_grid(
        GridSpec("resnet50", (0.0, 0.3, 0.5, 0.7, 0.9), "random", (2, 8, 16, 32)),
        cache, verbose=True)
    cache.flush()
    gamma_model = Perf4Sight(n_estimators=80).fit(train_pts)

    print("γ/φ inference models from sampled sub-networks...")
    base = build_resnet50(width_mult=WM, input_hw=HW)
    X, g, p = [], [], []
    for i in range(8):
        rng = np.random.default_rng(100 + i)
        m = build_resnet50(widths=sample_subnetwork(base.widths, rng), input_hw=HW)
        spec = m.conv_specs()
        for bs in (1, 4):
            r = profile_inference(m, bs)
            X.append(network_features(spec, bs))
            g.append(r.gamma_mb)
            p.append(r.phi_ms)
    infer_model = Perf4Sight(n_estimators=80).fit_arrays(
        np.array(X), np.array(g), np.array(p))

    cons = Constraints(gamma_mb=15.0, gamma_inf_mb=5.0, phi_inf_ms=15.0,
                       train_bs=16, infer_bs=1)
    print(f"searching under Γ≤{cons.gamma_mb}MB γ≤{cons.gamma_inf_mb}MB "
          f"φ≤{cons.phi_inf_ms}ms ...")
    t0 = time.time()
    engine = CostEngine(ForestBackend(train=gamma_model, infer=infer_model),
                        cache="benchmarks/cache/estimates.json",
                        flush_every=512)  # amortize writes in the hot loop
    r = evolutionary_search("resnet50", engine, cons,
                            population=32, iterations=30,
                            width_mult=WM, input_hw=HW)
    engine.flush()
    print(f"  engine cache: {engine.hits} hits / {engine.misses} misses")
    print(f"  {r.evaluations} candidates in {time.time() - t0:.1f}s "
          f"({r.evaluations / (time.time() - t0):.0f} evals/s)")
    print(f"  best: {int(r.fitness)} filters kept, predicted "
          f"Γ={r.gamma_mb:.1f}MB γ={r.gamma_inf_mb:.1f}MB φ={r.phi_inf_ms:.1f}ms")

    print("verifying the winner against ground truth...")
    m = build_resnet50(widths=r.widths, input_hw=HW)
    t = profile_training(m, cons.train_bs)
    inf = profile_inference(m, cons.infer_bs)
    print(f"  measured Γ={t.gamma_mb:.1f}MB γ={inf.gamma_mb:.1f}MB "
          f"φ={inf.phi_ms:.1f}ms")
    ok = (t.gamma_mb <= cons.gamma_mb * 1.2
          and inf.gamma_mb <= cons.gamma_inf_mb * 1.2
          and inf.phi_ms <= cons.phi_inf_ms * 1.5)
    print("  constraints", "HELD" if ok else "VIOLATED (prediction error)")


if __name__ == "__main__":
    main()
