"""Sharding-rule validity for every arch on the production meshes (pure spec
computation against a mesh stub — no devices needed)."""

from dataclasses import dataclass

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.distributed import sharding as sh
from repro.models import transformer as T


@dataclass
class _FakeDevices:
    shape: tuple

    @property
    def size(self):
        return int(np.prod(self.shape))


@dataclass
class FakeMesh:
    axis_names: tuple
    devices: _FakeDevices


SINGLE = FakeMesh(("data", "model"), _FakeDevices((16, 16)))
MULTI = FakeMesh(("pod", "data", "model"), _FakeDevices((2, 16, 16)))


def _check_divisible(spec: P, shape, mesh, where=""):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            assert a in sizes, f"{where}: unknown axis {a}"
            n *= sizes[a]
        assert dim % n == 0, f"{where}: dim {dim} not divisible by {n} ({spec}, {shape})"


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shape_tree = T._shape_tree(cfg)
    specs = sh.param_pspecs(cfg, mesh, fsdp=sh.fsdp_wanted(cfg, mesh))
    flat_shapes = jax.tree_util.tree_flatten_with_path(
        shape_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for (path, shape), spec in zip(flat_shapes, flat_specs):
        _check_divisible(spec, shape, mesh, where=f"{arch}:{path}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_state_specs_cover_optimizer(arch):
    cfg = get_config(arch)
    specs = sh.state_pspecs(cfg, SINGLE, kind="adamw")
    assert "params" in specs and "opt" in specs
    assert "m" in specs["opt"] and "v" in specs["opt"]
    # ZeRO: at least some opt-state leaves pick up the data axis
    used_data = any(
        any("data" in ((e,) if isinstance(e, str) else (e or ()))
            for e in spec)
        for spec in jax.tree_util.tree_leaves(
            specs["opt"]["m"], is_leaf=lambda x: isinstance(x, P))
    )
    assert used_data, f"{arch}: optimizer state not ZeRO-sharded"


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_cache_specs_all_decode_cells(mesh):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname in ("decode_32k", "long_500k"):
            shape = SHAPES[sname]
            if not cell_supported(cfg, shape)[0]:
                continue
            shapes = T.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            specs = sh.cache_pspecs(cfg, shape, mesh)
            flat_shapes = jax.tree_util.tree_flatten_with_path(
                shapes, is_leaf=lambda x: isinstance(x, tuple))[0]
            flat_specs = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            for (path, s), spec in zip(flat_shapes, flat_specs):
                _check_divisible(spec, s, mesh, where=f"{arch}:{sname}:{path}")


def test_long_context_shards_sequence():
    cfg = get_config("jamba-v0.1-52b")
    specs = sh.cache_pspecs(cfg, SHAPES["long_500k"], SINGLE)
    kv = specs["sub4"]["k"]  # the attention sublayer in the jamba period
    assert kv[2] == "data"   # (n, B, S@data, Hkv, Dh)


def test_fsdp_triggers_only_for_large_archs():
    assert sh.fsdp_wanted(get_config("llama4-scout-17b-a16e"), SINGLE)
    assert not sh.fsdp_wanted(get_config("internlm2-1.8b"), SINGLE)
