"""Structured-pruning invariants (paper §5.1, §6.2)."""

import numpy as np
import pytest

from tests._hypothesis import given, settings, st

from repro.core.pruning import (
    l1_scores,
    prune_widths,
    pruned_model,
    random_profile_widths,
)
from repro.models.cnn import CNN_BUILDERS, canonical_widths


def test_level_zero_is_identity():
    w = canonical_widths("resnet18", 0.5)
    assert prune_widths(w, 0.0, "random") == w


@pytest.mark.parametrize("strategy", ["random", "uniform", "early", "middle", "late"])
def test_total_filters_close_to_level(strategy):
    w = canonical_widths("resnet18", 1.0)
    total = sum(w.values())
    rng = np.random.default_rng(0)
    kept = prune_widths(w, 0.5, strategy, rng)
    frac = sum(kept.values()) / total
    assert 0.42 <= frac <= 0.58, f"{strategy}: kept {frac}"


def test_l1_prunes_globally_smallest():
    w = {"a": 4, "b": 4}
    scores = {"a": np.array([0.1, 0.2, 10, 11]), "b": np.array([5, 6, 7, 8])}
    kept = prune_widths(w, 0.25, "l1", scores=scores)
    assert kept == {"a": 2, "b": 4}  # the two smallest live in group a


def test_l1_scores_cover_all_groups():
    m = CNN_BUILDERS["mobilenetv2"](width_mult=0.25)
    scores = l1_scores(m)
    for g, n in m.widths.items():
        assert g in scores and len(scores[g]) == n


def test_position_profiles_differ():
    w = canonical_widths("resnet18", 0.5)
    rng = np.random.default_rng(0)
    early = prune_widths(w, 0.5, "early", rng)
    late = prune_widths(w, 0.5, "late", np.random.default_rng(0))
    groups = list(w)
    first = groups[: len(groups) // 3]
    assert sum(early[g] for g in first) < sum(late[g] for g in first)


@given(level=st.floats(0.05, 0.9), seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_pruned_widths_valid(level, seed):
    w = canonical_widths("squeezenet", 0.5)
    kept = prune_widths(w, level, "random", np.random.default_rng(seed), min_ch=2)
    assert set(kept) == set(w)
    for g in w:
        assert 2 <= kept[g] <= w[g]


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_random_profile_widths_valid(seed):
    w = canonical_widths("resnet18", 0.5)
    kept = random_profile_widths(w, 0.5, np.random.default_rng(seed))
    for g in w:
        assert 2 <= kept[g] <= w[g]


def test_pruned_model_builds_and_extracts_specs():
    m = pruned_model("mnasnet", 0.7, "random", width_mult=0.25, input_hw=16)
    spec = m.conv_specs()
    base = CNN_BUILDERS["mnasnet"](width_mult=0.25, input_hw=16).conv_specs()
    assert len(spec.layers) == len(base.layers)
    assert m.num_params() < CNN_BUILDERS["mnasnet"](width_mult=0.25).num_params()


def test_pruned_features_shrink():
    from repro.core.features import network_features

    base = CNN_BUILDERS["resnet18"](width_mult=0.5)
    pruned = pruned_model("resnet18", 0.5, "uniform", width_mult=0.5)
    fb = network_features(base.conv_specs(), 8)
    fp = network_features(pruned.conv_specs(), 8)
    assert np.all(fp <= fb + 1e-9)
    assert fp.sum() < fb.sum()
