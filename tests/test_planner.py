"""Auto-sharding planner: enumeration, pricing, refusal semantics,
zero-compile guarantee, the shared mesh validator, and the collective
calibration metadata the planner's pricing consumes."""

from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config
from repro.distributed.collectives import abstract_mesh, layout_collectives
from repro.engine.devices import DeviceSpec
from repro.launch.mesh import MeshSpecError, make_mesh, validate_mesh_spec
from repro.planner import LayoutPlanner, MeshLayout, enumerate_layouts

TRAIN_4 = ShapeSpec("t4", 16, 4, "train")


def _planner(device, **base):
    base = {"phi_ms": 100.0, "gamma_mb": 100.0, "energy_j": 1.0, **base}
    return LayoutPlanner(device=device, reduced=True, base=base)


def _device(**kw):
    kw.setdefault("name", "test_dev")
    kw.setdefault("peak_flops", 1e12)
    kw.setdefault("hbm_bw", 1e11)
    kw.setdefault("ici_bw", 1e9)
    kw.setdefault("hbm_bytes", 1e15)  # effectively no memory refusals
    return DeviceSpec(**kw)


# ---------------------------------------------------------------------------
# Layout enumeration
# ---------------------------------------------------------------------------


class TestEnumeration:
    def test_deterministic_and_complete(self):
        a = enumerate_layouts(16)
        b = enumerate_layouts(16)
        assert a == b                       # byte-identical across calls
        assert a == sorted(a)               # deterministic order
        assert len(set(a)) == len(a)        # no duplicates
        assert all(l.n_devices == 16 for l in a)
        # every ordered factorization of 16 into 3 parts: C(4+2, 2) = 15
        assert len(a) == 15

    def test_max_pipe_prunes_at_enumeration(self):
        ls = enumerate_layouts(16, max_pipe=1)
        assert all(l.pipe == 1 for l in ls)
        assert len(ls) == 5                 # (d, m) divisor pairs of 16

    def test_parse_roundtrip(self):
        lay = MeshLayout.parse("2x4x8")
        assert (lay.pipe, lay.data, lay.model) == (2, 4, 8)
        assert MeshLayout.parse(lay.descriptor) == lay
        assert MeshLayout.parse("4x8") == MeshLayout(1, 4, 8)
        with pytest.raises(ValueError):
            MeshLayout.parse("2x4x8x16")
        with pytest.raises(ValueError):
            MeshLayout.parse("nope")

    def test_mesh_shape_convention(self):
        lay = MeshLayout(2, 4, 8)
        assert lay.mesh_shape == (4, 8)     # model axis last, pipe outside
        assert lay.mesh_axes == ("data", "model")
        assert lay.n_devices == 64


# ---------------------------------------------------------------------------
# Shared mesh validator (the make_mesh bugfix)
# ---------------------------------------------------------------------------


class TestMeshValidator:
    def test_device_deficit_is_structured(self):
        import jax

        avail = len(jax.devices())
        with pytest.raises(MeshSpecError) as ei:
            make_mesh((avail + 1, 2), ("data", "model"))
        e = ei.value
        assert e.needed == (avail + 1) * 2
        assert e.available == avail
        assert e.deficit == e.needed - avail
        assert str(e.needed) in str(e) and "short" in str(e)

    def test_non_positive_dims(self):
        with pytest.raises(MeshSpecError, match="non-positive"):
            validate_mesh_spec((2, 0), ("data", "model"))
        with pytest.raises(MeshSpecError, match="non-positive"):
            validate_mesh_spec((-1,), ("data",))

    def test_duplicate_and_mismatched_axes(self):
        with pytest.raises(MeshSpecError, match="unique"):
            validate_mesh_spec((2, 2), ("data", "data"))
        with pytest.raises(MeshSpecError, match="dims"):
            validate_mesh_spec((2, 2), ("data",))
        with pytest.raises(MeshSpecError, match="empty"):
            validate_mesh_spec((), ())

    def test_is_a_value_error(self):
        # callers catching the old ValueError keep working
        with pytest.raises(ValueError):
            validate_mesh_spec((2, 2), ("data",))

    def test_valid_spec_returns_count(self):
        assert validate_mesh_spec((2, 4), ("data", "model")) == 8
        assert validate_mesh_spec((2, 4), ("data", "model"), available=8) == 8

    def test_make_mesh_single_device_still_works(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        assert mesh.devices.size == 1


# ---------------------------------------------------------------------------
# Layout collective/memory accounting
# ---------------------------------------------------------------------------


class TestLayoutCollectives:
    def test_single_device_moves_nothing(self):
        cfg = get_config("qwen3-4b", reduced=True)
        lc = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 1)))
        assert lc.total_bytes == 0.0
        assert lc.replicated == []
        assert lc.bubble == 0.0

    def test_dp_and_tp_charge_different_classes(self):
        cfg = get_config("qwen3-4b", reduced=True)
        dp = layout_collectives(cfg, TRAIN_4, abstract_mesh((2, 1)))
        tp = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 2)))
        assert dp.total_bytes > 0 and tp.total_bytes > 0
        # DP grad exchange rides all_reduce (or the ZeRO pair); TP rides
        # activation all_reduces only.
        assert dp.per_class["ppermute"] == 0.0
        assert tp.per_class["all_reduce"] > 0.0
        assert tp.per_class["reduce_scatter"] == 0.0
        # TP halves the per-device parameter bytes; DP doesn't.
        assert tp.memory["param_bytes_dev"] < dp.memory["param_bytes_dev"]

    def test_memory_split_scales_down_with_sharding(self):
        cfg = get_config("qwen3-4b", reduced=True)
        one = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 1)))
        four = layout_collectives(cfg, TRAIN_4, abstract_mesh((2, 2)))
        assert four.memory["total_bytes_dev"] < one.memory["total_bytes_dev"]
        assert one.memory["param_bytes_total"] == \
            four.memory["param_bytes_total"]

    def test_pipeline_divides_params_and_adds_ppermute(self):
        cfg = get_config("qwen3-4b", reduced=True)
        flat = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 1)))
        piped = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 1)),
                                   pipe=2, n_micro=4)
        assert piped.memory["param_bytes_dev"] == pytest.approx(
            flat.memory["param_bytes_dev"] / 2)
        assert piped.per_class["ppermute"] > 0.0
        assert piped.bubble == pytest.approx(1 / 5)  # (S-1)/(M+S-1)

    def test_indivisible_model_axis_priced_as_replication(self):
        """The headline fallback semantics: a model axis nothing divides
        must REPLICATE (recorded + priced), never produce an invalid
        spec or silently vanish."""
        cfg = get_config("qwen3-4b", reduced=True)  # d_model 128, vocab 512
        lc = layout_collectives(cfg, TRAIN_4, abstract_mesh((1, 3)))
        assert lc.replicated_fraction > 0.9
        assert len(lc.replicated) > 0
        # the replication penalty charges the model-axis grad all-reduce
        assert lc.per_class["all_reduce"] > 0.0


# ---------------------------------------------------------------------------
# Planner: planted-cost recovery, refusal semantics, ranking
# ---------------------------------------------------------------------------


class TestPlanner:
    def test_planted_collective_coeff_recovers_cheapest_layout(self):
        """Seed the device's fitted collective coefficient so high that
        the collective term dominates everything: the planner must pick
        exactly the layout an independent byte count says moves the
        fewest bytes — provably cheapest by construction."""
        dev = _device(class_coeffs={"lm_latency": {"collective": 1.0}})
        cfg = get_config("qwen3-4b", reduced=True)
        plan = _planner(dev).plan("qwen3-4b", TRAIN_4, 4,
                                  cfg=cfg, max_pipe=1)
        assert plan.ranked, plan.refused
        bytes_of = {
            d.layout: layout_collectives(
                cfg, TRAIN_4,
                abstract_mesh(d.layout.mesh_shape, d.layout.mesh_axes),
                pipe=d.layout.pipe).total_bytes
            for d in plan.ranked
        }
        expect = min(bytes_of, key=lambda l: (bytes_of[l], l.descriptor))
        assert plan.chosen.layout == expect
        # 1 s/B × kilobytes ⇒ ranking IS the byte ranking
        order = [d.layout for d in plan.ranked]
        assert order == sorted(order,
                               key=lambda l: (bytes_of[l], l.descriptor))

    def test_zero_collective_cost_prefers_pure_dp_over_pipeline(self):
        """With collectives priced at ~0 (huge ici_bw, no fitted coeff)
        the only differences are the bubble and replication: an
        unbubbled full-width layout must beat any bubbled pipeline
        split of the same device count."""
        dev = _device(ici_bw=1e30)
        plan = _planner(dev).plan("qwen3-4b", TRAIN_4, 4)
        assert plan.chosen.layout.pipe == 1
        dp = plan.decision_for("1x4x1")
        piped = plan.decision_for("2x2x1")
        assert piped is not None and dp.phi_ms < piped.phi_ms
        assert piped.breakdown["bubble"] > 0.0

    def test_indivisible_heads_ranked_with_penalty_not_refused(self):
        """A model axis nothing divides (3-way on d_model 128) is priced
        with the replication penalty and RANKED — never refused."""
        dev = _device()
        shape = ShapeSpec("t3", 16, 3, "train")
        plan = _planner(dev).plan("qwen3-4b", shape, 3, max_pipe=1)
        refused = {r.layout.descriptor for r in plan.refused}
        assert "1x1x3" not in refused
        tp = plan.decision_for("1x1x3")
        assert tp is not None
        assert tp.breakdown["replicated_fraction"] > 0.9
        # full replication ⇒ the model axis speeds up (almost) nothing,
        # so pure DP must rank strictly better
        dp = plan.decision_for("1x3x1")
        assert dp.phi_ms < tp.phi_ms
        assert plan.chosen.layout == MeshLayout(1, 3, 1)

    def test_batch_divisibility_refused_with_reason(self):
        plan = _planner(_device()).plan(
            "qwen3-4b", ShapeSpec("t2", 16, 2, "train"), 4, max_pipe=1)
        reasons = {r.layout.descriptor: r.reason for r in plan.refused}
        assert "1x4x1" in reasons
        assert "not divisible" in reasons["1x4x1"]
        assert plan.decision_for("1x4x1") is None

    def test_pipe_refused_when_layers_dont_split(self):
        cfg = get_config("qwen3-4b", reduced=True)  # 2 layers when reduced
        plan = _planner(_device()).plan(
            "qwen3-4b", TRAIN_4, 4, cfg=cfg)
        reasons = {r.layout.descriptor: r.reason for r in plan.refused}
        assert "4x1x1" in reasons and "pipeline stages" in reasons["4x1x1"]
        # pipe=2 divides the 2-layer reduced stack: it must be ranked
        assert plan.decision_for("2x2x1") is not None

    def test_memory_refusal_names_capacity(self):
        dev = _device(hbm_bytes=4e9)  # 4000 MB
        plan = _planner(dev, gamma_mb=1e6).plan("qwen3-4b", TRAIN_4, 1)
        assert plan.chosen is None
        assert len(plan.refused) == 1
        assert "capacity" in plan.refused[0].reason
        # capacity planning view keeps it ranked
        plan2 = _planner(dev, gamma_mb=1e6).plan(
            "qwen3-4b", TRAIN_4, 1, check_memory=False)
        assert plan2.chosen is not None

    def test_plan_serializes(self):
        import json

        plan = _planner(_device()).plan("qwen3-4b", TRAIN_4, 4)
        d = json.loads(json.dumps(plan.to_dict()))
        assert d["chosen"]["layout"]["descriptor"] == \
            plan.chosen.layout.descriptor
        assert len(d["ranked"]) == len(plan.ranked)
        assert d["meta"]["n_ranked"] + d["meta"]["n_refused"] == \
            d["meta"]["n_layouts"]

    def test_energy_conserves_power_model(self):
        """Per-device energy scales with per-device time (same power
        envelope); the fleet total multiplies by the device count."""
        plan = _planner(_device()).plan("qwen3-4b", TRAIN_4, 4, max_pipe=1)
        base = plan.base
        for d in plan.ranked:
            assert d.energy_j == pytest.approx(
                base["energy_j"] * d.phi_ms / base["phi_ms"])
            assert d.energy_total_j == pytest.approx(
                d.energy_j * d.layout.n_devices)


# ---------------------------------------------------------------------------
# Zero-compile guarantee (the engine-backed path, compiler booby-trapped)
# ---------------------------------------------------------------------------


class _FakeLMForest:
    """Fitted-forest stand-in: constant (Γ, Φ) per query, no jax anywhere."""

    fitted = True
    meta: dict = {}

    def __init__(self, gamma_mb=200.0, phi_ms=50.0):
        from repro.engine import get_device

        self.gamma_mb, self.phi_ms = gamma_mb, phi_ms
        self.default_device = get_device("host_cpu")

    def content_hash(self):
        return f"fake-{self.gamma_mb}-{self.phi_ms}"

    def predict_queries(self, queries):
        n = len(queries)
        return (np.full(n, self.gamma_mb), np.full(n, self.phi_ms))


def test_planner_zero_compiles(monkeypatch):
    """The whole plan — base query through the engine, every layout
    priced — with jax.jit AND the analytical AOT path booby-trapped."""
    import jax

    from repro.engine import (
        AnalyticalBackend,
        CostEngine,
        ForestBackend,
        get_device,
    )

    def boom(*a, **k):
        raise AssertionError("planner pricing invoked the jax compiler")

    monkeypatch.setattr(jax, "jit", boom)
    monkeypatch.setattr(AnalyticalBackend, "_compile_arch", boom)

    engine = CostEngine(ForestBackend(lm=_FakeLMForest()),
                        device=get_device("tpu_v5e"))
    plan = LayoutPlanner(engine, reduced=True).plan("qwen3-4b", TRAIN_4, 16)
    assert plan.chosen is not None
    assert plan.base["source"] == "forest"
    assert plan.meta["n_ranked"] > 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCLI:
    BASE = ["--arch", "qwen3-4b", "--device", "host_cpu", "--reduced",
            "--base-phi-ms", "100", "--base-gamma-mb", "100",
            "--base-energy-j", "1", "--seq", "16", "--batch", "4"]

    def test_plan_table(self, capsys):
        from repro.planner.__main__ import main

        assert main(["plan", "--devices", "4", *self.BASE]) == 0
        out = capsys.readouterr().out
        assert "phi_ms" in out and "1x4x1" in out

    def test_plan_json(self, capsys):
        import json

        from repro.planner.__main__ import main

        assert main(["plan", "--devices", "4", "--json", *self.BASE]) == 0
        d = json.loads(capsys.readouterr().out)
        assert d["chosen"] is not None and d["n_devices"] == 4

    def test_explain_ranked_and_refused(self, capsys):
        import json

        from repro.planner.__main__ import main

        assert main(["explain", "--devices", "4", "--layout", "1x2x2",
                     *self.BASE]) == 0
        d = json.loads(capsys.readouterr().out)
        assert d["layout"]["descriptor"] == "1x2x2"
        assert "rank" in d or d.get("refused")

    def test_explain_wrong_device_count(self, capsys):
        from repro.planner.__main__ import main

        assert main(["explain", "--devices", "4", "--layout", "1x2x4",
                     *self.BASE]) == 2


# ---------------------------------------------------------------------------
# Collective-calibration fit metadata (what the planner's pricing reads)
# ---------------------------------------------------------------------------


def test_fit_meta_reports_collective_columns():
    """Synthetic ledger with a planted collective price: the fit's meta
    must say how many cells moved collective bytes and that the
    collective column entered the class-wise system — the field the
    threshold gate (and collective_seconds) depends on."""
    from repro.campaign import fit_hlo_constants
    from repro.engine.decompose import collective_seconds

    c0, c_fl, c_coll = 1e-3, 5e-12, 3e-9
    rng = np.random.default_rng(0)
    records = []
    for i in range(12):
        fl = float(rng.uniform(1e6, 1e8))
        cb = float(rng.uniform(1e5, 1e7)) if i % 2 else 0.0
        classes = {
            "matmul": {"flops": fl, "hbm_bytes": 0.0,
                       "collective_bytes": 0.0, "count": 3},
            "collective": {"flops": 0.0, "hbm_bytes": 0.0,
                           "collective_bytes": cb, "count": 1},
        }
        phi_s = c0 + c_fl * fl + c_coll * cb
        records.append({
            "status": "ok", "device": "host_cpu", "plan_hash": "x",
            "flops": fl, "hbm_bytes": 0.0, "collective_bytes": cb,
            "cost_classes": classes, "phi_ms": phi_s * 1e3,
        })
    spec = fit_hlo_constants(records)
    meta = spec.meta
    assert meta["collective_cells"] == 6
    assert meta["collective_column_fitted"] is True
    assert "collective" in meta["classwise_columns"]
    assert meta["collective_coeff_classwise"] == pytest.approx(
        c_coll, rel=1e-3)
    assert meta["collective_coeff_aggregate"] > 0.0
    # and collective_seconds prices with the fitted coefficient
    assert float(collective_seconds(1e6, spec)) == pytest.approx(
        meta["collective_coeff_classwise"] * 1e6, rel=1e-9)


def test_collective_seconds_roofline_fallback():
    from repro.engine.decompose import collective_seconds

    dev = _device(ici_bw=2e9)
    assert float(collective_seconds(4e9, dev)) == pytest.approx(2.0)


def test_collective_smoke_plan_spans_multidevice_meshes():
    from repro.campaign.plan import collective_smoke_plan

    plan = collective_smoke_plan()
    meshes = {c.mesh for c in plan.cells}
    assert {"1x1", "2x1", "1x2"} <= meshes
    assert len(plan) == 6
    # value semantics: re-enumeration is hash-stable
    assert plan.plan_hash == collective_smoke_plan().plan_hash
