"""Data-pipeline determinism / resume tests."""

import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config
from repro.data.pipeline import TokenPipeline, make_batch

SHAPE = ShapeSpec("t", seq_len=16, global_batch=4, kind="train")


def test_make_batch_deterministic():
    cfg = get_config("qwen3-4b", reduced=True)
    a = make_batch(cfg, SHAPE, step=7, seed=3)
    b = make_batch(cfg, SHAPE, step=7, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, SHAPE, step=8, seed=3)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_modalities_present():
    vlm = get_config("paligemma-3b", reduced=True)
    b = make_batch(vlm, SHAPE, 0)
    assert b["patches"].shape == (4, vlm.n_prefix, vlm.d_model)
    audio = get_config("whisper-tiny", reduced=True)
    b = make_batch(audio, SHAPE, 0)
    assert b["frames"].shape == (4, audio.n_audio_frames, audio.d_model)


def test_pipeline_resume_matches_fresh():
    cfg = get_config("qwen3-4b", reduced=True)
    p1 = TokenPipeline(cfg, SHAPE, seed=0)
    seen = [next(p1) for _ in range(5)]
    p1.close()
    p2 = TokenPipeline(cfg, SHAPE, seed=0, start_step=3)
    s, b = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(b["tokens"], seen[3][1]["tokens"])


def test_pipeline_monotone_steps():
    cfg = get_config("qwen3-4b", reduced=True)
    p = TokenPipeline(cfg, SHAPE, seed=0)
    steps = [next(p)[0] for _ in range(6)]
    p.close()
    assert steps == list(range(6))
