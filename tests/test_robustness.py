"""Fault-tolerance tests (ISSUE 8): seeded fault injection, preemption
under pool pressure with recompute-on-resume, deadlines + watchdog,
backend failover into static degraded mode, KV-pool conservation, and
the pool-capacity admission-livelock regression."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.engine import (
    BackendUnavailable,
    CostEngine,
    CostEstimate,
    CostQuery,
    EnsembleBackend,
    ForestBackend,
    HealthState,
    get_device,
)
from repro.models import transformer as T
from repro.serve import (
    ContinuousConfig,
    ContinuousEngine,
    Decision,
    FailoverChain,
    Fault,
    FaultPlan,
    PagedKVCache,
    Request,
    RequestState,
    SLOScheduler,
    TERMINAL_STATES,
)


def _cfg():
    return get_config("internlm2-1.8b", reduced=True)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, T.init_params(cfg, 0)


def _prompts(lens, seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, (n,)).astype(np.int32) for n in lens]


def _assert_drained(ce):
    """The engine-wide safety contract after a drain: every submitted
    request is terminal, nothing leaked, every block is back in the
    free list."""
    assert ce.idle
    assert ce.lost == 0
    for r in ce.finished + ce.refused + ce.expired:
        assert r.state in TERMINAL_STATES and not r.blocks
    assert ce.kv.n_free_blocks == ce.kv.usable_blocks


# ---------------------------------------------------------------------------
# fault plan: deterministic, budgeted, accounted
# ---------------------------------------------------------------------------


def test_fault_plan_seeded_deterministic():
    kw = dict(n_steps=50, p_alloc=0.3, p_backend=0.2, p_slow=0.1)
    a, b = FaultPlan.seeded(3, **kw), FaultPlan.seeded(3, **kw)
    assert a.planned == b.planned
    assert [(f.step, f.kind) for f in a.faults] == \
           [(f.step, f.kind) for f in b.faults]
    assert sum(a.planned.values()) > 0
    c = FaultPlan.seeded(4, **kw)
    assert [(f.step, f.kind) for f in a.faults] != \
           [(f.step, f.kind) for f in c.faults]


def test_fault_plan_budget_and_summary():
    plan = FaultPlan([Fault(step=2, kind="alloc", count=2),
                      Fault(step=2, kind="slow", delay_s=0.5),
                      Fault(step=3, kind="backend")])
    assert plan.fire("alloc") == 0          # before any begin_step
    plan.begin_step(1)
    assert plan.fire("alloc") == 0          # nothing planned at step 1
    plan.begin_step(2)
    assert plan.fire("alloc") == 1 and plan.fire("alloc") == 1
    assert plan.fire("alloc") == 0          # count=2 budget consumed
    assert plan.fire("slow") == 0.5 and plan.fire("slow") == 0
    plan.begin_step(3)
    assert plan.fire("backend") == 1 and plan.fire("backend") == 0
    s = plan.summary()
    assert s["planned"] == {"alloc": 2, "backend": 1, "slow": 1}
    assert s["fired"] == {"alloc": 2, "backend": 1, "slow": 1}


def test_fault_plan_rejects_bad_faults():
    with pytest.raises(ValueError):
        Fault(step=1, kind="meteor")
    with pytest.raises(ValueError):
        Fault(step=-1, kind="alloc")
    with pytest.raises(ValueError):
        FaultPlan().fire("meteor")


# ---------------------------------------------------------------------------
# health state machine + failover chain
# ---------------------------------------------------------------------------


def test_health_state_step_down_probe_recover():
    h = HealthState(["forest", "analytical", "static"],
                    fail_threshold=2, probe_every=4)
    assert h.current == "forest" and not h.degraded
    assert not h.record_failure("flake one")
    assert h.record_failure("flake two")    # 2nd consecutive trips
    assert h.current == "analytical" and h.failovers == 1
    h.record_failure()
    h.record_failure()
    assert h.current == "static" and h.degraded
    probes = [h.probe_level() for _ in range(8)]
    assert probes.count(1) == 2             # every 4th call probes up
    assert all(p in (None, 1) for p in probes)
    h.record_success(1)                     # probe succeeded one up
    assert h.current == "analytical" and h.recoveries == 1
    assert h.metrics()["failovers"] == 2
    assert "flake two" in h.metrics()["last_error"]


def test_health_success_at_worse_level_does_not_absolve_trusted():
    """A fallback answer must not reset the trusted level's failure
    count, or a permanently-broken head level would never step down."""
    h = HealthState(["a", "b"], fail_threshold=2)
    h.record_failure()
    h.record_success(level=1)               # deeper level answered
    assert h.record_failure()               # still trips at 2 consecutive
    assert h.current == "b"


class _Flaky:
    """Backend that crashes (a real exception, not BackendUnavailable)
    until healed."""

    name = "flaky"

    def __init__(self, fail=True):
        self.fail, self.calls = fail, 0

    def supports(self, query):
        return True

    def cache_salt(self):
        return "flaky"

    def estimate(self, queries):
        self.calls += 1
        if self.fail:
            raise RuntimeError("poisoned forest file")
        return [CostEstimate(gamma_mb=1.0, phi_ms=1.0, source="flaky")
                for _ in queries]


class _Steady(_Flaky):
    name = "steady"

    def __init__(self):
        super().__init__(fail=False)

    def cache_salt(self):
        return "steady"

    def estimate(self, queries):
        self.calls += 1
        return [CostEstimate(gamma_mb=2.0, phi_ms=2.0, source="steady")
                for _ in queries]


def _query():
    return CostQuery(arch="internlm2-1.8b", bs=1, seq=64, stage="infer",
                     reduced=True)


def test_failover_chain_steps_down_and_probe_recovers():
    flaky, steady = _Flaky(), _Steady()
    fc = FailoverChain(CostEngine(EnsembleBackend([flaky, steady])),
                       fail_threshold=2, probe_every=3)
    assert fc.health.levels == ["flaky", "steady", "static"]
    # Crashes are absorbed: every call still answers, from the fallback.
    for _ in range(2):
        assert fc.estimate_one(_query()).source == "steady"
    assert fc.health.current == "steady" and fc.health.failovers == 1
    # Call 3 is the scheduled probe: the broken head is retried, fails,
    # and the trusted level is unchanged (failed probes don't count).
    assert fc.estimate_one(_query()).source == "steady"
    assert fc.health.level == 1 and fc.health.probes == 1
    # Off-probe calls don't consult the broken head at all.
    flaky_calls = flaky.calls
    fc.estimate_one(_query())
    fc.estimate_one(_query())
    assert flaky.calls == flaky_calls
    # Once healed, the next probe recovers the trusted level.
    flaky.fail = False
    assert fc.estimate_one(_query()).source == "flaky"
    assert fc.health.level == 0 and fc.health.recoveries == 1


def test_failover_chain_exhausts_to_static_none():
    fc = FailoverChain(CostEngine(EnsembleBackend([_Flaky(), _Flaky()])),
                       fail_threshold=1, probe_every=100)
    assert fc.estimate_one(_query()) is None    # static degraded signal
    assert fc.degraded and fc.health.current == "static"
    assert fc.metrics()["failovers"] == 2


def test_failover_chain_backend_unavailable_passes_through():
    class _Unavail:
        name = "unavail"

        def supports(self, query):
            return True

        def estimate(self, queries):
            raise BackendUnavailable("cannot score this arch")

    fc = FailoverChain(CostEngine(_Unavail()))
    with pytest.raises(BackendUnavailable):
        fc.estimate_one(_query())
    # semantic misses are health-neutral
    assert fc.health.level == 0 and fc.health.failovers == 0


def test_scheduler_degraded_static_budget():
    """With every model-backed level down, admission falls back to a
    conservative static concurrency cap: ADMIT under it, DEFER over it
    (never REFUSE — degraded mode sheds throughput, not requests)."""
    eng = CostEngine(EnsembleBackend([_Flaky()]))
    fc = FailoverChain(eng, fail_threshold=1, probe_every=1000)
    sched = SLOScheduler(_cfg(), eng, max_len=64, n_slots=4,
                         gamma_budget_mb=1e6, failover=fc, degraded_slots=2)
    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    dec, info = sched.admit(req, n_running=0)
    assert dec is Decision.ADMIT and info["degraded"]
    assert info["health"] == "static" and info["static_slots"] == 2
    dec, info = sched.admit(req, n_running=2)
    assert dec is Decision.DEFER and "static" in info["reason"]


# ---------------------------------------------------------------------------
# KV pool conservation (property test) + double-free guard
# ---------------------------------------------------------------------------


def test_kv_pool_conservation_property():
    """free + allocated always sums to the pool, across a random walk of
    alloc/free — including allocs denied by injected faults."""
    plan = FaultPlan([Fault(step=1, kind="alloc", count=8)])
    kv = PagedKVCache(_cfg(), n_slots=4, max_len=128, block_size=16,
                      pool_tokens=256, faults=plan)
    rng = np.random.default_rng(0)
    held = []
    for i in range(300):
        if i == 150:
            plan.begin_step(1)          # mid-walk: 8 denied allocs
        if rng.random() < 0.55:
            got = kv.alloc(int(rng.integers(1, 5)))
            if got is not None:
                held.append(got)
        elif held:
            kv.free(held.pop(int(rng.integers(0, len(held)))))
        assert kv.n_free_blocks + len(kv._allocated) == kv.usable_blocks
    assert plan.fired["alloc"] > 0
    for blocks in held:
        kv.free(blocks)
    assert kv.n_free_blocks == kv.usable_blocks


def test_kv_pool_double_free_raises():
    kv = PagedKVCache(_cfg(), n_slots=2, max_len=64, block_size=16,
                      pool_tokens=64)
    a = kv.alloc(2)
    kv.free(a)
    with pytest.raises(ValueError, match="double free|unallocated"):
        kv.free(a)
    with pytest.raises(ValueError):
        kv.free([kv.n_blocks + 7])      # foreign block id


# ---------------------------------------------------------------------------
# satellite regression: a request larger than the whole pool must be
# REFUSED, not retried forever (admission livelock)
# ---------------------------------------------------------------------------


def test_admission_refuses_request_larger_than_pool(model):
    cfg, params = model
    # pool of 32 tokens = 2 usable blocks; prompt 40 + 8 new = 3 blocks.
    # Pre-fix the pool silently inflated to max_len and the engine
    # retried the head forever once pools could actually be small.
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16, pool_tokens=32))
    assert ce.kv.usable_blocks == 2
    big = Request(prompt=np.arange(1, 41, dtype=np.int32), max_new_tokens=8)
    ok = Request(prompt=_prompts([5])[0], max_new_tokens=4)
    ce.run([big, ok], max_steps=64)
    assert big.state is RequestState.REFUSED
    assert "pool" in str(big.refusal)
    assert big.refusal.info["need_blocks"] == 3
    assert ok.state is RequestState.FINISHED   # the queue kept moving
    _assert_drained(ce)


# ---------------------------------------------------------------------------
# preemption: recompute-on-resume restores the exact greedy stream
# ---------------------------------------------------------------------------


def test_preemption_resume_restores_greedy_stream(model):
    """Two growers on a pool too small for both lifetimes: the youngest
    is preempted mid-decode, resumes later, and must end with exactly
    the tokens a solo uncontended run produces."""
    cfg, params = model
    prompts = _prompts([5, 5], seed=3)

    def solo(p):
        ce = ContinuousEngine(cfg, params, ContinuousConfig(
            max_len=64, n_slots=1, eos_id=0, block_size=16))
        req = Request(prompt=p, max_new_tokens=40)
        ce.run([req])
        return req.tokens

    # 64-token pool = 4 usable blocks; each request's lifetime is 45
    # tokens = 3 blocks, so both cannot finish without a preemption.
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16, pool_tokens=64))
    reqs = [Request(prompt=p, max_new_tokens=40) for p in prompts]
    ce.run(reqs)
    assert ce.counters["preemptions"] >= 1
    assert ce.counters["resumes"] >= 1
    victim = max(reqs, key=lambda r: r.preemptions)
    assert victim.preemptions >= 1
    for req, p in zip(reqs, prompts):
        assert req.state is RequestState.FINISHED
        assert req.tokens == solo(p)
    _assert_drained(ce)


def test_preemption_victim_is_youngest_and_oldest_progresses(model):
    """Anti-livelock: under sustained pressure the oldest admitted
    request is never the victim while a younger one holds blocks."""
    cfg, params = model
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16, pool_tokens=64))
    old = Request(prompt=_prompts([5], seed=1)[0], max_new_tokens=40)
    young = Request(prompt=_prompts([5], seed=2)[0], max_new_tokens=40)
    ce.submit(old)
    ce.step()                   # old admitted first → lower admit_seq
    ce.submit(young)
    ce.run()
    assert old.preemptions == 0
    assert young.preemptions >= 1
    assert old.state is RequestState.FINISHED
    assert young.state is RequestState.FINISHED
    assert old.admit_seq < young.admit_seq
    _assert_drained(ce)


# ---------------------------------------------------------------------------
# deadlines, watchdog, backpressure
# ---------------------------------------------------------------------------


def test_deadline_and_watchdog_expire_requests(model):
    cfg, params = model
    t = [0.0]
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=1, eos_id=0, block_size=16,
        watchdog_ms=500.0), clock=lambda: t[0])
    runner = Request(prompt=_prompts([5])[0], max_new_tokens=50)
    waiter = Request(prompt=_prompts([6])[0], max_new_tokens=4,
                     deadline_ms=100.0)
    runner.t_arrival = waiter.t_arrival = 0.0   # enter the virtual clock
    ce.submit(runner)
    ce.submit(waiter)
    for _ in range(3):
        ce.step()               # runner occupies the only slot
    assert runner.state is RequestState.RUNNING
    assert waiter.state is RequestState.QUEUED
    t[0] = 0.2                  # 200ms: past waiter's 100ms deadline
    ce.step()
    assert waiter.state is RequestState.EXPIRED
    assert "deadline" in waiter.expiry
    assert ce.counters["expired_queued"] == 1
    t[0] = 0.6                  # 600ms: past the 500ms watchdog
    ce.step()
    assert runner.state is RequestState.EXPIRED
    assert "watchdog" in runner.expiry
    assert ce.counters["expired_running"] == 1
    assert runner.n_generated > 0       # partial output retained
    _assert_drained(ce)


def test_slow_faults_skew_virtual_clock_into_deadline(model):
    """A "slow" fault stalls the virtual clock — deadline paths fire
    without real sleeps."""
    cfg, params = model
    t = [0.0]
    plan = FaultPlan([Fault(step=2, kind="slow", delay_s=1.0)])
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=1, eos_id=0, block_size=16),
        faults=plan, clock=lambda: t[0])
    fast = Request(prompt=_prompts([5])[0], max_new_tokens=30,
                   deadline_ms=500.0)
    fast.t_arrival = 0.0
    ce.submit(fast)
    ce.step()                   # admitted + first decode
    assert fast.state is RequestState.RUNNING
    ce.step()                   # slow fault: clock jumps 1s > deadline
    ce.step()
    assert fast.state is RequestState.EXPIRED
    assert plan.fired["slow"] == 1
    _assert_drained(ce)


def test_bounded_queue_sheds_at_submit(model):
    cfg, params = model
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=1, eos_id=0, block_size=16, max_queue=2))
    reqs = [Request(prompt=p, max_new_tokens=3)
            for p in _prompts([4, 5, 6])]
    for r in reqs:
        ce.submit(r)
    assert reqs[2].state is RequestState.REFUSED
    assert "queue full" in str(reqs[2].refusal)
    assert ce.counters["shed_backpressure"] == 1
    ce.run()
    assert reqs[0].state is RequestState.FINISHED
    assert reqs[1].state is RequestState.FINISHED
    _assert_drained(ce)


# ---------------------------------------------------------------------------
# chaos: seeded fault plan through the full gated engine
# ---------------------------------------------------------------------------


class _FakeLMForest:
    fitted = True
    meta: dict = {}

    def __init__(self, gamma_mb=50.0, phi_ms=1.0):
        self.gamma_mb, self.phi_ms = gamma_mb, phi_ms
        self.default_device = get_device("host_cpu")

    def content_hash(self):
        return f"fake-{self.gamma_mb}-{self.phi_ms}"

    def predict_queries(self, queries):
        n = len(queries)
        return (np.full(n, self.gamma_mb), np.full(n, self.phi_ms))


def test_chaos_no_escape_no_loss(model):
    """The headline contract: with faults injected at every layer, no
    exception escapes step(), every request reaches a terminal state,
    and the pool conserves."""
    cfg, params = model
    plan = FaultPlan(
        [Fault(step=s, kind="alloc") for s in (1, 2, 4, 6, 8)]
        + [Fault(step=s, kind="backend") for s in (1, 2, 3, 4, 5)]
        + [Fault(step=3, kind="slow", delay_s=0.01)])
    engine = CostEngine(ForestBackend(lm=_FakeLMForest()))
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16, pool_tokens=96,
        gamma_budget_mb=1e6, health_fail_threshold=2),
        cost_engine=engine, faults=plan)
    reqs = [Request(prompt=p, max_new_tokens=m)
            for p, m in zip(_prompts([4, 7, 3, 11, 6, 5], seed=5),
                            (3, 10, 5, 2, 8, 4))]
    ce.run(reqs)                # any escape fails the test here
    assert all(r.state in TERMINAL_STATES for r in reqs)
    m = ce.metrics()
    assert m["lost"] == 0 and m["submitted"] == len(reqs)
    assert m["alloc_denied"] > 0
    assert m["faults"]["fired"]["alloc"] > 0
    assert m["faults"]["fired"]["backend"] > 0
    # repeated injected backend crashes stepped health down to static
    assert m["health"]["failovers"] >= 1
    _assert_drained(ce)


def test_chaos_greedy_outputs_survive_faults(model):
    """Faults may delay requests but never corrupt them: greedy tokens
    under the fault plan equal the fault-free run's."""
    cfg, params = model
    prompts = _prompts([5, 9, 13], seed=7)

    def run(faults):
        ce = ContinuousEngine(cfg, params, ContinuousConfig(
            max_len=64, n_slots=3, eos_id=0, block_size=16),
            faults=faults)
        reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
        ce.run(reqs)
        assert all(r.state is RequestState.FINISHED for r in reqs)
        _assert_drained(ce)
        return [r.tokens for r in reqs]

    clean = run(None)
    faulted = run(FaultPlan.seeded(11, n_steps=12, p_alloc=0.5))
    assert clean == faulted


def test_metrics_surfaces_robustness_counters(model):
    cfg, params = model
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=1, eos_id=0, block_size=16))
    ce.run([Request(prompt=_prompts([5])[0], max_new_tokens=3)])
    m = ce.metrics()
    for key in ("preemptions", "resumes", "expired_queued",
                "expired_running", "shed_backpressure", "defer_backoffs",
                "alloc_denied", "failovers", "degraded_steps",
                "lost", "expired", "submitted"):
        assert key in m, key
    assert m["lost"] == 0 and m["preemptions"] == 0
