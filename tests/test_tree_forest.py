"""Tests for the CART tree and random-forest regressors."""

import numpy as np
import pytest

from tests._hypothesis import given, settings, st

from repro.core.forest import RandomForestRegressor
from repro.core.tree import RegressionTree


def test_tree_fits_step_function_exactly():
    X = np.linspace(0, 1, 100).reshape(-1, 1)
    y = (X[:, 0] > 0.5).astype(float) * 10.0
    t = RegressionTree().fit(X, y)
    np.testing.assert_allclose(t.predict(X), y)


def test_tree_constant_target_is_single_leaf():
    X = np.random.default_rng(0).normal(size=(50, 3))
    y = np.full(50, 7.0)
    t = RegressionTree().fit(X, y)
    assert t.node_count == 1
    np.testing.assert_allclose(t.predict(X), 7.0)


def test_tree_respects_max_depth():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(200, 4))
    y = rng.normal(size=200)
    t = RegressionTree(max_depth=3).fit(X, y)
    assert t.depth <= 3


def test_tree_respects_min_samples_leaf():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(64, 2))
    y = rng.normal(size=64)
    t = RegressionTree(min_samples_leaf=10).fit(X, y)
    leaf_sizes = [n.n_samples for n in t._nodes if n.feature == -1]
    assert min(leaf_sizes) >= 10


def test_tree_piecewise_linear_fit_quality():
    """Deep tree approximates a smooth function well in-sample."""
    X = np.linspace(0, 2 * np.pi, 500).reshape(-1, 1)
    y = np.sin(X[:, 0])
    t = RegressionTree(min_samples_leaf=2).fit(X, y)
    assert np.mean((t.predict(X) - y) ** 2) < 1e-3


def test_forest_interpolates_linear_in_range():
    """Paper App. B: attributes are linear in batch size — the forest must
    capture that well within the profiled range."""
    rng = np.random.default_rng(3)
    bs = rng.uniform(2, 256, size=300)
    X = bs.reshape(-1, 1)
    y = 3.5 * bs + 120.0
    f = RandomForestRegressor(n_estimators=50, min_samples_leaf=1, seed=0).fit(X, y)
    test_bs = np.linspace(10, 250, 40).reshape(-1, 1)
    pred = f.predict(test_bs)
    err = np.abs(pred - (3.5 * test_bs[:, 0] + 120)) / (3.5 * test_bs[:, 0] + 120)
    assert err.mean() < 0.03


def test_forest_predictions_within_target_range():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(200, 5))
    y = rng.uniform(10, 20, size=200)
    f = RandomForestRegressor(n_estimators=20, seed=1).fit(X, y)
    pred = f.predict(rng.normal(size=(100, 5)) * 10)
    assert np.all(pred >= y.min() - 1e-9) and np.all(pred <= y.max() + 1e-9)


def test_forest_feature_importance_identifies_signal():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(300, 6))
    y = 10 * X[:, 2] + 0.01 * rng.normal(size=300)
    f = RandomForestRegressor(n_estimators=30, max_features=None, seed=2).fit(X, y)
    assert int(np.argmax(f.feature_importances_)) == 2
    assert f.feature_importances_[2] > 0.9


def test_forest_oob_error_reported():
    rng = np.random.default_rng(6)
    X = rng.uniform(0, 1, size=(150, 3))
    y = X @ np.array([1.0, 2.0, 3.0]) + 5
    f = RandomForestRegressor(n_estimators=40, seed=3).fit(X, y)
    assert f.oob_mape_ is not None and f.oob_mape_ < 0.2


def test_forest_vectorized_predict_matches_per_tree():
    """The packed cross-tree traversal must agree exactly with averaging
    per-tree predictions (the pre-vectorization path)."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(120, 6))
    y = X[:, 0] * 3 - X[:, 3] ** 2 + rng.normal(size=120) * 0.1
    f = RandomForestRegressor(n_estimators=25, seed=9).fit(X, y)
    Xt = rng.normal(size=(64, 6)) * 2
    np.testing.assert_allclose(f.predict(Xt), f._predict_per_tree(Xt), rtol=1e-12)
    # single-sample and 1-D input paths
    np.testing.assert_allclose(f.predict(Xt[0]), f._predict_per_tree(Xt[0]))


def test_forest_array_roundtrip_matches():
    rng = np.random.default_rng(12)
    X = rng.normal(size=(90, 4))
    y = X @ np.array([1.0, -2.0, 0.5, 3.0])
    f = RandomForestRegressor(n_estimators=12, seed=2).fit(X, y)
    f2 = RandomForestRegressor.from_arrays(f.to_arrays("g_"), "g_")
    np.testing.assert_allclose(f2.predict(X), f.predict(X))
    assert f2._y_min == f._y_min and f2._y_max == f._y_max


def test_forest_serialisation_roundtrip():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(100, 4))
    y = X[:, 0] ** 2 + X[:, 1]
    f = RandomForestRegressor(n_estimators=10, seed=4).fit(X, y)
    f2 = RandomForestRegressor.from_dict(f.to_dict())
    np.testing.assert_allclose(f2.predict(X), f.predict(X))


def test_forest_deterministic_given_seed():
    rng = np.random.default_rng(8)
    X = rng.normal(size=(80, 3))
    y = rng.normal(size=80)
    p1 = RandomForestRegressor(n_estimators=10, seed=5).fit(X, y).predict(X)
    p2 = RandomForestRegressor(n_estimators=10, seed=5).fit(X, y).predict(X)
    np.testing.assert_array_equal(p1, p2)


@given(
    n=st.integers(10, 80),
    d=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_tree_in_sample_never_worse_than_mean_predictor(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = rng.normal(size=n)
    t = RegressionTree(min_samples_leaf=1).fit(X, y)
    sse_tree = np.sum((t.predict(X) - y) ** 2)
    sse_mean = np.sum((y - y.mean()) ** 2)
    assert sse_tree <= sse_mean + 1e-9


@given(seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_forest_prediction_bounded_by_training_extremes(seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(60, 4))
    y = rng.normal(size=60)
    f = RandomForestRegressor(n_estimators=8, seed=seed).fit(X, y)
    pred = f.predict(rng.normal(size=(30, 4)) * 5)
    assert pred.min() >= y.min() - 1e-9
    assert pred.max() <= y.max() + 1e-9
