"""Unified cost-engine tests: backend parity, ensemble fallback ordering,
estimate cache hit/miss, predictor serialization round-trips, and the
batched-vs-scalar speedup guarantee (ISSUE 1 acceptance)."""

import json
import os
import time

import numpy as np
import pytest

from repro.core.dataset import Datapoint, DatasetCache
from repro.core.features import network_features
from repro.core.predictor import Perf4Sight
from repro.core.pruning import pruned_model
from repro.core.search import Constraints, evolutionary_search, fold_population
from repro.engine import (
    AnalyticalBackend,
    BackendUnavailable,
    CostEngine,
    CostEstimate,
    CostQuery,
    EnsembleBackend,
    EstimateCache,
    ForestBackend,
    ProfilerBackend,
)

WM, HW = 0.25, 16


def _synthetic_dps(n=50, seed=0, family="squeezenet"):
    rng = np.random.default_rng(seed)
    dps = []
    for _ in range(n):
        level = float(rng.uniform(0, 0.9))
        bs = int(rng.integers(2, 33))
        m = pruned_model(family, level, "uniform", seed=0,
                         width_mult=WM, input_hw=HW)
        f = network_features(m.conv_specs(), bs)
        dps.append(Datapoint(
            family=family, level=level, strategy="uniform", bs=bs,
            width_mult=WM, input_hw=HW, seed=0,
            gamma_mb=5.0 + f[4] / 1e5, phi_ms=2.0 + f[14] / 1e7,
            features=[float(v) for v in f]))
    return dps


@pytest.fixture(scope="module")
def predictor():
    return Perf4Sight(n_estimators=40).fit(_synthetic_dps())


@pytest.fixture(scope="module")
def candidate_specs():
    rng = np.random.default_rng(7)
    return [
        pruned_model("squeezenet", float(rng.uniform(0, 0.8)), "random",
                     seed=i, width_mult=WM, input_hw=HW).conv_specs()
        for i in range(30)
    ]


# -- CostQuery ---------------------------------------------------------------


def test_query_key_is_content_keyed(candidate_specs):
    s = candidate_specs[0]
    q1 = CostQuery(spec=s, bs=8, stage="train")
    renamed = type(s)(name="other-name", layers=s.layers)
    assert q1.key == CostQuery(spec=renamed, bs=8, stage="train").key
    assert q1.key != CostQuery(spec=s, bs=16, stage="train").key
    assert q1.key != CostQuery(spec=s, bs=8, stage="infer").key
    assert q1.key != CostQuery(spec=candidate_specs[1], bs=8, stage="train").key


def test_query_validation():
    with pytest.raises(ValueError):
        CostQuery(bs=8)  # no spec/arch/model
    with pytest.raises(ValueError):
        CostQuery(bs=8, arch="qwen3-4b", stage="decode")


def test_arch_query_key_sensitive_to_reduced():
    base = CostQuery(bs=8, arch="qwen3-4b")
    assert CostQuery(bs=8, arch="qwen3-4b", reduced=True).key != base.key
    assert CostQuery(bs=8, arch="qwen3-4b", reduced=False).key != base.key
    assert (CostQuery(bs=8, arch="qwen3-4b", reduced=True).key
            != CostQuery(bs=8, arch="qwen3-4b", reduced=False).key)


def test_feature_matrix_tolerates_layerless_specs():
    """The vectorized path must return zeros (like the scalar reference),
    not crash on a float64 empty index array."""
    from repro.core.features import NetworkSpec, feature_matrix

    X = feature_matrix([(NetworkSpec("empty"), 4)])
    assert X.shape[0] == 1 and (X == 0).all()


def test_load_json_tolerant_quarantines_non_dict(tmp_path):
    from repro.core.fileio import load_json_tolerant

    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write("[1, 2, 3]")   # valid JSON, wrong shape
    assert load_json_tolerant(path) == {}
    assert os.path.exists(path + ".corrupt")


# -- ForestBackend parity ----------------------------------------------------


def test_forest_backend_batched_matches_legacy_scalar(predictor, candidate_specs):
    backend = ForestBackend(train=predictor)
    queries = [CostQuery(spec=s, bs=16, stage="train") for s in candidate_specs]
    ests = backend.estimate(queries)
    for est, spec in zip(ests, candidate_specs):
        g, p = predictor.predict(spec, 16)
        assert est.gamma_mb == pytest.approx(g, rel=1e-9)
        assert est.phi_ms == pytest.approx(p, rel=1e-9)
        assert est.source == "forest"


def test_forest_backend_mixed_stages(predictor, candidate_specs):
    backend = ForestBackend(train=predictor, infer=predictor)
    queries = [
        CostQuery(spec=s, bs=4, stage=("train" if i % 2 == 0 else "infer"))
        for i, s in enumerate(candidate_specs[:10])
    ]
    ests = backend.estimate(queries)
    for q, est in zip(queries, ests):
        g, p = predictor.predict(q.spec, q.bs)
        assert est.gamma_mb == pytest.approx(g, rel=1e-9)


def test_forest_backend_unfitted_stage_unsupported(predictor, candidate_specs):
    backend = ForestBackend(train=predictor)  # no infer predictor
    assert not backend.supports(CostQuery(spec=candidate_specs[0], bs=4,
                                          stage="infer"))
    with pytest.raises(BackendUnavailable):
        backend.estimate([CostQuery(spec=candidate_specs[0], bs=4, stage="infer")])


# -- AnalyticalBackend (CNN closed forms) ------------------------------------


def test_analytical_backend_cnn_specs(candidate_specs):
    backend = AnalyticalBackend()
    qs = [CostQuery(spec=s, bs=8, stage="train") for s in candidate_specs[:5]]
    ests = backend.estimate(qs)
    for est in ests:
        assert est.gamma_mb > 0 and est.phi_ms > 0
        assert est.source == "analytical"
    # batch size monotonicity: bigger batch, bigger footprint
    small = backend.estimate([CostQuery(spec=candidate_specs[0], bs=2)])[0]
    big = backend.estimate([CostQuery(spec=candidate_specs[0], bs=32)])[0]
    assert big.gamma_mb > small.gamma_mb
    # inference cheaper than training at the same batch size
    inf = backend.estimate(
        [CostQuery(spec=candidate_specs[0], bs=2, stage="infer")])[0]
    assert inf.gamma_mb < small.gamma_mb


# -- EnsembleBackend fallback ordering ---------------------------------------


class _StubBackend:
    def __init__(self, name, answer=None, supported=True, fail=False):
        self.name = name
        self.answer = answer
        self.supported = supported
        self.fail = fail
        self.calls = 0

    def supports(self, q):
        return self.supported

    def estimate(self, queries):
        self.calls += 1
        if self.fail:
            raise BackendUnavailable(f"{self.name} down")
        return [CostEstimate(gamma_mb=self.answer, phi_ms=self.answer,
                             source=self.name) for _ in queries]


def test_ensemble_first_supporting_backend_wins(candidate_specs):
    a = _StubBackend("a", answer=1.0)
    b = _StubBackend("b", answer=2.0)
    ens = EnsembleBackend([a, b])
    ests = ens.estimate([CostQuery(spec=candidate_specs[0], bs=4)])
    assert ests[0].source == "a"
    assert b.calls == 0


def test_ensemble_falls_through_unsupported_and_failing(candidate_specs):
    unsupported = _StubBackend("skipme", supported=False)
    failing = _StubBackend("failing", fail=True)
    answering = _StubBackend("answering", answer=3.0)
    ens = EnsembleBackend([unsupported, failing, answering])
    ests = ens.estimate([CostQuery(spec=candidate_specs[0], bs=4)] * 3)
    assert all(e.source == "answering" for e in ests)
    # tried on the batch, then per-query salvage retries, then dropped out
    assert failing.calls == 1 + 3


class _PartialBackend:
    """Answers queries individually but raises on any batch containing a
    poisoned query — the AnalyticalBackend arch-compile-failure shape."""

    name = "partial"

    def __init__(self, poisoned: set):
        self.poisoned = poisoned

    def supports(self, q):
        return True

    def estimate(self, queries):
        if any(q.bs in self.poisoned for q in queries):
            raise BackendUnavailable("poisoned query in batch")
        return [CostEstimate(gamma_mb=1.0, phi_ms=1.0, source=self.name)
                for _ in queries]


def test_ensemble_one_poisoned_query_does_not_discard_batch(candidate_specs):
    """A single failing query must not push the whole batch to the next
    link: the ensemble retries per query and only the poisoned one falls
    through."""
    fallback = _StubBackend("fallback", answer=9.0)
    ens = EnsembleBackend([_PartialBackend(poisoned={13}), fallback])
    qs = [CostQuery(spec=candidate_specs[0], bs=bs) for bs in (2, 13, 4)]
    ests = ens.estimate(qs)
    assert [e.source for e in ests] == ["partial", "fallback", "partial"]
    assert fallback.calls == 1  # only the poisoned query reached it


def test_cache_isolated_from_caller_detail_mutation(candidate_specs, tmp_path):
    """Annotating a returned estimate's detail (even with non-JSON values)
    must neither break the cache flush nor leak into future hits."""
    path = str(tmp_path / "estimates.json")
    engine = CostEngine(_StubBackend("s", answer=1.0),
                        cache=EstimateCache(path), flush_every=10)
    q = CostQuery(spec=candidate_specs[0], bs=8)
    est = engine.estimate_one(q)
    est.detail["annotation"] = object()     # not JSON-serializable
    engine.flush()                          # deferred write must not raise
    hit = CostEngine(_StubBackend("s", answer=1.0),
                     cache=EstimateCache(path)).estimate_one(q)
    assert hit.detail.get("cached") and "annotation" not in hit.detail


def test_ensemble_exhausted_raises(candidate_specs):
    ens = EnsembleBackend([_StubBackend("x", supported=False)])
    with pytest.raises(BackendUnavailable):
        ens.estimate([CostQuery(spec=candidate_specs[0], bs=4)])


def test_ensemble_forest_to_analytical_chain(predictor, candidate_specs):
    """Real chain: fitted forest answers train queries, analytical catches
    the stage the forest was never fitted for."""
    ens = EnsembleBackend([ForestBackend(train=predictor), AnalyticalBackend()])
    qs = [CostQuery(spec=candidate_specs[0], bs=4, stage="train"),
          CostQuery(spec=candidate_specs[0], bs=4, stage="infer")]
    ests = ens.estimate(qs)
    assert ests[0].source == "forest"
    assert ests[1].source == "analytical"


# -- estimate cache ----------------------------------------------------------


def test_engine_cache_hit_miss(predictor, candidate_specs, tmp_path):
    path = str(tmp_path / "estimates.json")
    counting = _StubBackend("counting", answer=1.5)
    engine = CostEngine(counting, cache=EstimateCache(path))
    qs = [CostQuery(spec=s, bs=8) for s in candidate_specs[:6]]
    engine.estimate(qs)
    assert (engine.hits, engine.misses) == (0, 6)
    assert counting.calls == 1

    engine.estimate(qs)
    assert (engine.hits, engine.misses) == (6, 6)
    assert counting.calls == 1  # all served from cache

    # a fresh process (new engine) reads the flushed file
    engine2 = CostEngine(counting, cache=EstimateCache(path))
    ests = engine2.estimate(qs)
    assert engine2.hits == 6 and counting.calls == 1
    assert all(e.detail.get("cached") for e in ests)
    assert all(e.gamma_mb == 1.5 for e in ests)


def test_cache_keys_salted_by_backend_identity(predictor, candidate_specs, tmp_path):
    """Estimates cached under one fitted predictor (or backend config) must
    not be served for a different one — the key is salted with the backend's
    content hash."""
    path = str(tmp_path / "estimates.json")
    qs = [CostQuery(spec=candidate_specs[0], bs=8)]

    e1 = CostEngine(ForestBackend(train=predictor), cache=EstimateCache(path))
    e1.estimate(qs)
    assert e1.misses == 1

    # same cache file, differently-fitted predictor → must miss, not alias
    other = Perf4Sight(n_estimators=10).fit(_synthetic_dps(30, seed=99))
    e2 = CostEngine(ForestBackend(train=other), cache=EstimateCache(path))
    e2.estimate(qs)
    assert (e2.hits, e2.misses) == (0, 1)

    # same fitted predictor again → hit
    e3 = CostEngine(ForestBackend(train=predictor), cache=EstimateCache(path))
    e3.estimate(qs)
    assert (e3.hits, e3.misses) == (1, 0)

    # analytical backend config is part of the salt too
    a1 = CostEngine(AnalyticalBackend(reduced=True), cache=EstimateCache(path))
    a1.estimate(qs)
    a2 = CostEngine(AnalyticalBackend(reduced=False), cache=EstimateCache(path))
    a2.estimate(qs)
    assert a2.hits == 0 and a2.misses == 1


def test_refit_predictor_invalidates_cache_on_same_engine(candidate_specs, tmp_path):
    """The salt is recomputed per batch: refitting the predictor behind a
    live engine must stop cache hits from the old fit."""
    path = str(tmp_path / "estimates.json")
    model = Perf4Sight(n_estimators=8).fit(_synthetic_dps(25, seed=1))
    engine = CostEngine(ForestBackend(train=model), cache=EstimateCache(path))
    qs = [CostQuery(spec=candidate_specs[0], bs=8)]
    engine.estimate(qs)
    engine.estimate(qs)
    assert (engine.hits, engine.misses) == (1, 1)
    model.fit(_synthetic_dps(25, seed=2))  # refit in place
    engine.estimate(qs)
    assert (engine.hits, engine.misses) == (1, 2)  # miss, not a stale hit


def test_engine_flush_every_amortizes_writes(candidate_specs, tmp_path):
    path = str(tmp_path / "estimates.json")
    backend = _StubBackend("s", answer=1.0)
    engine = CostEngine(backend, cache=EstimateCache(path), flush_every=100)
    engine.estimate([CostQuery(spec=s, bs=8) for s in candidate_specs[:5]])
    assert not os.path.exists(path)  # below threshold: nothing written yet
    engine.flush()
    assert os.path.exists(path)
    assert CostEngine(backend, cache=EstimateCache(path)).estimate(
        [CostQuery(spec=candidate_specs[0], bs=8)])[0].detail.get("cached")


def test_ensemble_failure_message_names_causes(candidate_specs):
    ens = EnsembleBackend([_StubBackend("down", fail=True)])
    with pytest.raises(BackendUnavailable, match="down"):
        ens.estimate([CostQuery(spec=candidate_specs[0], bs=4)])


def test_model_only_query_keys_distinguish_pruned_variants():
    m1 = pruned_model("squeezenet", 0.3, "uniform", width_mult=WM, input_hw=HW)
    m2 = pruned_model("squeezenet", 0.7, "uniform", width_mult=WM, input_hw=HW)
    q1 = CostQuery(bs=4, spec=None, model=m1)
    q2 = CostQuery(bs=4, spec=None, model=m2)
    assert q1.key != q2.key


def test_estimate_cache_corrupt_file_quarantined(tmp_path):
    path = str(tmp_path / "estimates.json")
    with open(path, "w") as f:
        f.write('{"truncated": ')
    cache = EstimateCache(path)  # must not raise
    assert len(cache) == 0
    assert os.path.exists(path + ".corrupt")
    cache.put("k", CostEstimate(gamma_mb=1.0, phi_ms=2.0, source="s"))
    cache.flush()
    assert EstimateCache(path).get("k").phi_ms == 2.0


def test_dataset_cache_corrupt_file_quarantined(tmp_path):
    path = str(tmp_path / "profile.json")
    with open(path, "w") as f:
        f.write('NOT JSON {{{')
    c = DatasetCache(path)  # must not raise
    assert len(c) == 0
    assert os.path.exists(path + ".corrupt")
    c.flush()
    with open(path) as f:
        assert json.load(f) == {}


# -- predictor serialization --------------------------------------------------


@pytest.mark.parametrize("ext", ["json", "npz"])
def test_perf4sight_save_load_roundtrip(predictor, candidate_specs, tmp_path, ext):
    path = str(tmp_path / f"model.{ext}")
    predictor.save(path)
    loaded = Perf4Sight.load(path)
    assert loaded.fitted
    X = np.stack([network_features(s, 8) for s in candidate_specs[:8]])
    g0, p0 = predictor.predict_features(X)
    g1, p1 = loaded.predict_features(X)
    np.testing.assert_allclose(g1, g0, rtol=1e-12)
    np.testing.assert_allclose(p1, p0, rtol=1e-12)


def test_pure_forest_npz_roundtrip(tmp_path):
    dps = _synthetic_dps(30, seed=3)
    model = Perf4Sight(n_estimators=15, hybrid=False).fit(dps)
    path = str(tmp_path / "forest.npz")
    model.save(path)
    loaded = Perf4Sight.load(path)
    spec = pruned_model("squeezenet", 0.4, "uniform",
                        width_mult=WM, input_hw=HW).conv_specs()
    assert loaded.predict(spec, 8) == model.predict(spec, 8)


# -- batched search + speedup acceptance --------------------------------------


def test_search_uses_batched_estimates(predictor):
    """The ES must drive the engine (batched estimate calls), and the engine
    must see exactly 2 calls per generation (train + infer stages)."""
    calls = []

    class _SpyEngine(CostEngine):
        def estimate(self, queries):
            calls.append(len(queries))
            return super().estimate(queries)

    engine = _SpyEngine(ForestBackend(train=predictor, infer=predictor))
    r = evolutionary_search(
        "squeezenet", engine, Constraints(gamma_mb=1e9, train_bs=8, infer_bs=1),
        population=12, iterations=3, width_mult=WM, input_hw=HW, seed=0)
    assert r.fitness > 0  # loose budget → feasible
    # 1 initial population + 3 generations of children, × 2 stages
    assert len(calls) == 8
    assert calls[0] == 12  # whole population in ONE call
    assert r.evaluations == 12 + 3 * 9  # pop + iter × (pop - parents)


def test_fold_population_unit():
    w1, w2 = {"a": 4, "b": 8}, {"a": 2, "b": 8}
    uniq, fan_in = fold_population([w1, w2, dict(w1), w1])
    assert uniq == [w1, w2]
    assert fan_in == [0, 1, 0, 0]


def test_population_dedup_folds_identical_candidates(predictor, monkeypatch):
    """ROADMAP dedup item: N identical candidates in a generation must reach
    the engine as ONE query per stage (estimate call fan-in == n_unique),
    while per-candidate results still fan back out."""
    import repro.core.search as S

    calls = []

    class _SpyEngine(CostEngine):
        def estimate(self, queries):
            calls.append(len(queries))
            return super().estimate(queries)

    # force a fully-degenerate initial population: every candidate identical
    monkeypatch.setattr(
        S, "sample_subnetwork",
        lambda canonical, rng, min_ch=2: {g: max(min_ch, n // 2)
                                          for g, n in canonical.items()})
    engine = _SpyEngine(ForestBackend(train=predictor, infer=predictor))
    r = evolutionary_search(
        "squeezenet", engine, Constraints(gamma_mb=1e9, train_bs=8, infer_bs=1),
        population=10, iterations=0, width_mult=WM, input_hw=HW, seed=0)
    assert r.evaluations == 10          # every candidate was scored...
    assert calls == [1, 1]              # ...from one query per stage
    assert r.fitness > 0


def test_batched_estimate_5x_faster_than_scalar(predictor):
    """ISSUE 1 acceptance: ≥5× on a 100-candidate population vs the
    per-candidate scalar path (same work, N Python round-trips)."""
    rng = np.random.default_rng(5)
    specs = [
        pruned_model("squeezenet", float(rng.uniform(0, 0.8)), "random",
                     seed=100 + i, width_mult=WM, input_hw=HW).conv_specs()
        for i in range(100)
    ]
    backend = ForestBackend(train=predictor)
    queries = [CostQuery(spec=s, bs=16) for s in specs]
    backend.estimate(queries[:2])          # warm packed forest
    predictor.predict(specs[0], 16)        # warm scalar path

    t_batch = min(_timed(lambda: backend.estimate(queries)) for _ in range(3))
    t_scalar = min(
        _timed(lambda: [predictor.predict(s, 16) for s in specs])
        for _ in range(3))
    assert t_scalar / t_batch >= 5.0, (
        f"batched {t_batch * 1e3:.1f}ms vs scalar {t_scalar * 1e3:.1f}ms "
        f"({t_scalar / t_batch:.1f}x, need >=5x)")


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# -- profiler backend (ground truth, slow) ------------------------------------


@pytest.mark.slow
def test_profiler_backend_ground_truth():
    m = pruned_model("squeezenet", 0.5, "uniform", width_mult=WM, input_hw=HW)
    backend = ProfilerBackend(repeats=1, warmup=0)
    q = CostQuery(spec=m.conv_specs(), bs=2, model=m)
    assert backend.supports(q)
    est = backend.estimate([q])[0]
    assert est.gamma_mb > 0 and est.phi_ms > 0
    assert est.source == "profiler"
