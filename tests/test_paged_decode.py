"""Paged decode attention: ref == kernel (interpret) == the serve path's
gather + dense fallback, across ragged cache_len, block-boundary fills,
GQA head counts and split-KV; plus the scatter/mask boundary regression
(ISSUE 10 satellite) on both gather and kernel paths."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_decode import paged_decode_attention, paged_decode_ref
from repro.kernels.paged_decode.kernel import paged_decode_kernel
from repro.models.layers import blocked_attention

TOL = {"float32": 2e-4, "bfloat16": 3e-2}


def _case(B, H, Hkv, Dh, NB, bs, dtype, cache_lens, seed=0):
    rng = np.random.default_rng(seed)
    P = B * NB + 1                       # block 0 = scratch, like the pool
    q = jnp.asarray(rng.standard_normal((B, H, Dh)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, bs, Hkv, Dh)), dtype)
    vp = jnp.asarray(rng.standard_normal((P, bs, Hkv, Dh)), dtype)
    bt = jnp.asarray(rng.permutation(B * NB).reshape(B, NB) + 1, jnp.int32)
    cl = jnp.asarray(cache_lens, jnp.int32)
    return q, kp, vp, bt, cl


def _gather_oracle(q, kp, vp, bt, cl):
    """The layers.py fallback, verbatim semantics: gather the logical
    view, dense causal attention with q at position cache_len."""
    B, H, Dh = q.shape
    Hkv = kp.shape[2]
    k = kp[bt].reshape(B, -1, Hkv, Dh)
    v = vp[bt].reshape(B, -1, Hkv, Dh)
    o = blocked_attention(
        q[:, None], k, v,
        q_positions=cl[:, None], k_positions=jnp.arange(k.shape[1]),
        mask_kind="causal", chunk=8192, prefix=0, kv_len=cl)
    return o[:, 0]


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("n_splits", [1, 2, 4])
def test_ref_kernel_gather_agree(dtype, H, Hkv, n_splits):
    # ragged fills incl. block-boundary values (bs-1, bs, 2·bs)
    q, kp, vp, bt, cl = _case(4, H, Hkv, 64, 4, 16, dtype,
                              [0, 15, 16, 32])
    ref = paged_decode_ref(q, kp, vp, bt, cl)
    ker = paged_decode_kernel(q, kp, vp, bt, cl, n_splits=n_splits,
                              interpret=True)
    gat = _gather_oracle(q, kp, vp, bt, cl)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(gat, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block_kv", [8, 16])
def test_block_kv_sweep(block_kv):
    q, kp, vp, bt, cl = _case(2, 8, 2, 64, 4, 16, "float32", [7, 55])
    ref = paged_decode_ref(q, kp, vp, bt, cl)
    ker = paged_decode_kernel(q, kp, vp, bt, cl, block_kv=block_kv,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_wrapper_auto_matches_ref_on_cpu():
    # impl=None off-TPU routes to the jnp ref — exact
    q, kp, vp, bt, cl = _case(2, 4, 2, 32, 3, 16, "float32", [10, 40])
    out = paged_decode_attention(q, kp, vp, bt, cl)
    ref = paged_decode_ref(q, kp, vp, bt, cl)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0


def test_scatter_mask_boundary_off_by_one():
    """Regression (ISSUE 10 satellite): the freshly written token at
    ``cache_len`` sitting exactly on a block boundary (off == 0, first
    slot of a new block) is attended; the position one past it is not.
    A huge-norm K marker makes attention collapse onto its V if and only
    if the marker position is <= cache_len."""
    B, H, Hkv, Dh, NB, bs = 1, 4, 2, 32, 3, 16
    q, kp, vp, bt, _ = _case(B, H, Hkv, Dh, NB, bs, "float32", [0])
    cl_val = bs                                 # block 1, offset 0
    phys = int(bt[0, cl_val // bs])
    q = jnp.ones_like(q)                        # q·k_marker >> any other
    kp = kp.at[phys, cl_val % bs].set(
        100.0 * math.sqrt(Dh) * jnp.ones((Hkv, Dh)))
    marker_v = vp[phys, cl_val % bs]            # (Hkv, Dh)
    want = jnp.broadcast_to(marker_v[:, None],
                            (Hkv, H // Hkv, Dh)).reshape(1, H, Dh)

    cl = jnp.asarray([cl_val], jnp.int32)
    for out in (paged_decode_ref(q, kp, vp, bt, cl),
                paged_decode_kernel(q, kp, vp, bt, cl, interpret=True),
                _gather_oracle(q, kp, vp, bt, cl)):
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-2, atol=1e-2)

    # one before the marker: it must be invisible on every path
    cl = jnp.asarray([cl_val - 1], jnp.int32)
    ref = paged_decode_ref(q, kp, vp, bt, cl)
    assert float(jnp.max(jnp.abs(ref - want))) > 0.1  # didn't collapse
    for out in (paged_decode_kernel(q, kp, vp, bt, cl, interpret=True),
                _gather_oracle(q, kp, vp, bt, cl)):
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_empty_row_cache_len_zero():
    # cache_len == 0 attends exactly one position (the fresh token)
    q, kp, vp, bt, cl = _case(2, 4, 2, 32, 2, 16, "float32", [0, 0])
    ref = paged_decode_ref(q, kp, vp, bt, cl)
    want = jnp.broadcast_to(
        kp[bt[:, 0], 0][:, :, None].astype(jnp.float32) * 0
        + vp[bt[:, 0], 0][:, :, None], (2, 2, 2, 32)).reshape(2, 4, 32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    ker = paged_decode_kernel(q, kp, vp, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_layers_paged_branch_kernel_vs_gather(monkeypatch):
    """attention_block's paged decode branch produces the same output
    under REPRO_PAGED_DECODE=interpret (Pallas kernel) as under gather
    (the XLA fallback), KV scatter included."""
    import jax

    from repro.configs.registry import get_config
    from repro.models import transformer as T

    cfg = get_config("internlm2-1.8b", reduced=True)
    params = T.init_params(cfg, 0)
    bs, n_blocks, B = 16, 7, 2
    rng = np.random.default_rng(3)

    def run(mode):
        monkeypatch.setenv("REPRO_PAGED_DECODE", mode)
        pool = T.init_paged_cache(cfg, n_blocks, bs)
        # identical random history in both runs, incl. a block-boundary
        # fill (cache_len[1] == bs): scatter lands at off == 0
        for sub in pool.values():
            for name in ("k_pool", "v_pool"):
                sub[name] = jnp.asarray(
                    rng.standard_normal(sub[name].shape), sub[name].dtype)
        rng2 = np.random.default_rng(7)
        batch = {
            "tokens": jnp.asarray(rng2.integers(2, cfg.vocab, (B, 1)),
                                  jnp.int32),
            "cache_len": jnp.asarray([5, bs], jnp.int32),
            "block_table": jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32),
        }
        logits, new_pool = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg))(params, pool, batch)
        return np.asarray(logits, np.float32), new_pool

    lg_gather, pool_g = run("gather")
    # re-seed: the two runs must see identical pools
    rng = np.random.default_rng(3)
    lg_kernel, pool_k = run("interpret")
    # full-stack bf16: the kernel keeps f32 probabilities where the XLA
    # fallback casts them to bf16 before p·V, so logits drift a little
    np.testing.assert_allclose(lg_kernel, lg_gather, rtol=8e-2, atol=8e-2)
    # The first layer's scatter input (embeddings) is identical on both
    # paths, so its pool slice must match bitwise (sub-caches stack the
    # scanned layers on axis 0); deeper layers' K/V are projections of
    # earlier attention outputs and inherit the bf16 drift.
    for name in ("k_pool", "v_pool"):
        np.testing.assert_array_equal(np.asarray(pool_g["sub0"][name][0]),
                                      np.asarray(pool_k["sub0"][name][0]))
    for sub in pool_g:
        for name in ("k_pool", "v_pool"):
            np.testing.assert_allclose(
                np.asarray(pool_g[sub][name], np.float32),
                np.asarray(pool_k[sub][name], np.float32),
                rtol=8e-2, atol=8e-2)
