"""Predictor integration: fit/predict/evaluate/save/admit on synthetic and
real profiled data (the paper's core loop at miniature scale)."""

import numpy as np
import pytest

from repro.core.dataset import Datapoint
from repro.core.features import network_features
from repro.core.predictor import EvalReport, Perf4Sight, mape
from repro.core.pruning import pruned_model


def _synthetic_dps(n=60, seed=0):
    """Datapoints whose targets are smooth functions of real features."""
    rng = np.random.default_rng(seed)
    dps = []
    for i in range(n):
        level = float(rng.uniform(0, 0.9))
        bs = int(rng.integers(2, 33))
        m = pruned_model("squeezenet", level, "uniform", seed=0,
                         width_mult=0.25, input_hw=16)
        f = network_features(m.conv_specs(), bs)
        gamma = 5.0 + f[4] / 1e5          # alloc-total driven
        phi = 2.0 + f[14] / 1e7           # ops-sum driven
        dps.append(Datapoint(
            family="squeezenet", level=level, strategy="uniform", bs=bs,
            width_mult=0.25, input_hw=16, seed=0,
            gamma_mb=gamma, phi_ms=phi, features=[float(v) for v in f]))
    return dps


def test_fit_predict_on_feature_driven_targets():
    dps = _synthetic_dps()
    model = Perf4Sight(n_estimators=60).fit(dps[:45])
    rep = model.evaluate(dps[45:])
    assert isinstance(rep, EvalReport)
    assert rep.gamma_mape < 0.10
    assert rep.phi_mape < 0.15


def test_predict_spec_path():
    dps = _synthetic_dps()
    model = Perf4Sight(n_estimators=40).fit(dps)
    m = pruned_model("squeezenet", 0.45, "uniform", width_mult=0.25, input_hw=16)
    g, p = model.predict(m.conv_specs(), 8)
    assert g > 0 and p > 0


def test_admission_gate_budgets():
    dps = _synthetic_dps()
    model = Perf4Sight(n_estimators=40).fit(dps)
    m = pruned_model("squeezenet", 0.3, "uniform", width_mult=0.25, input_hw=16)
    spec = m.conv_specs()
    ok, info = model.admit(spec, 8, gamma_budget_mb=1e9)
    assert ok
    ok, info = model.admit(spec, 8, gamma_budget_mb=1e-3)
    assert not ok
    assert info["gamma_eff"] > info["gamma_mb"]  # safety margin applied


def test_save_load_roundtrip(tmp_path):
    dps = _synthetic_dps(40)
    model = Perf4Sight(n_estimators=20).fit(dps)
    p = str(tmp_path / "model.json")
    model.save(p)
    loaded = Perf4Sight.load(p)
    m = pruned_model("squeezenet", 0.5, "uniform", width_mult=0.25, input_hw=16)
    assert loaded.predict(m.conv_specs(), 16) == model.predict(m.conv_specs(), 16)


def test_mape_metric():
    assert mape(np.array([110.0]), np.array([100.0])) == pytest.approx(0.1)
    assert mape(np.array([0.0]), np.array([0.0])) == 0.0


@pytest.mark.slow
def test_end_to_end_profile_fit_predict(tmp_path):
    """The paper's actual loop: profile real training steps, fit, predict an
    unseen topology within tolerance (small grid ⇒ loose bound).

    Train-grid profiling and the held-out measurement must happen on the
    SAME host at the SAME speed, so the grid is profiled fresh into a
    scratch cache — fitting on the checked-in golden fixture and comparing
    to a live timing fails whenever the host's speed drifts from the
    fixture's recording conditions (and this test must never rewrite that
    fixture either; tests/test_calibration.py owns it, read-only)."""
    from repro.core.dataset import DatasetCache, GridSpec, collect_grid
    from repro.core.profiler import profile_training

    cache = DatasetCache(str(tmp_path / "profile.json"))
    grid = GridSpec("squeezenet", (0.0, 0.3, 0.5, 0.7, 0.9), "random", (2, 8, 16, 32))
    dps = collect_grid(grid, cache)
    cache.flush()
    model = Perf4Sight(n_estimators=100).fit(dps)
    m = pruned_model("squeezenet", 0.4, "random", seed=3,
                     width_mult=0.25, input_hw=16)
    res = profile_training(m, 16)
    g, p = model.predict(m.conv_specs(), 16)
    assert abs(g - res.gamma_mb) / res.gamma_mb < 0.35
    assert abs(p - res.phi_ms) / res.phi_ms < 0.60
