"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv_mm.kernel import conv_mm_kernel
from repro.kernels.conv_mm.ref import conv_im2col_ref, conv_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.kernel import ssd_chunk_kernel
from repro.kernels.ssm_scan.ops import ssd
from repro.kernels.ssm_scan.ref import ssd_naive, ssd_ref

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, H, Hkv, Sq, Sk, Dh, causal, block_q, block_k)
    (1, 2, 2, 128, 128, 32, True, 64, 64),
    (2, 4, 2, 128, 128, 64, True, 64, 64),      # GQA 2:1
    (2, 8, 1, 64, 64, 32, True, 32, 32),        # MQA
    (1, 2, 2, 128, 128, 32, False, 64, 64),     # bidirectional
    (1, 2, 1, 64, 256, 32, True, 64, 64),       # Sk > Sq (decode-ish)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("spec", FLASH_SHAPES)
def test_flash_attention_matches_ref(spec, dtype):
    B, H, Hkv, Sq, Sk, Dh, causal, bq, bk = spec
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, H, Sq, Dh), dtype)
    k = _rand(rng, (B, Hkv, Sk, Dh), dtype)
    v = _rand(rng, (B, Hkv, Sk, Dh), dtype)
    q_offset = Sk - Sq  # align last q with last k
    out = flash_attention_kernel(
        q, k, v, causal=causal, block_q=bq, block_k=bk,
        q_offset=q_offset, interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, q_offset=q_offset)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **TOL[dtype]
    )


def test_flash_attention_decode_single_query():
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 4, 8, 64), jnp.float32)  # block_q=8 (padded decode)
    k = _rand(rng, (2, 2, 128, 64), jnp.float32)
    v = _rand(rng, (2, 2, 128, 64), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, q_offset=120,
                                 block_q=8, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, q_offset=120)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# conv_mm
# ---------------------------------------------------------------------------

CONV_SHAPES = [
    # (N, H, W, C, KH, O, stride, padding)
    (2, 8, 8, 8, 3, 16, 1, 1),
    (1, 16, 16, 4, 3, 8, 2, 1),
    (2, 8, 8, 16, 1, 32, 1, 0),     # 1x1 conv
    (1, 9, 9, 8, 5, 8, 2, 2),       # 5x5 stride 2
    (2, 8, 8, 3, 3, 8, 1, 0),       # valid padding
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("spec", CONV_SHAPES)
def test_conv_mm_matches_xla(spec, dtype):
    N, H, W, C, K, O, s, p = spec
    rng = np.random.default_rng(2)
    x = _rand(rng, (N, H, W, C), dtype)
    w = _rand(rng, (K, K, C, O), dtype) * 0.2
    out = conv_mm_kernel(x, w, stride=s, padding=p, block_o=O, interpret=True)
    ref = conv_ref(x, w, stride=s, padding=p)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **TOL[dtype]
    )


def test_conv_im2col_ref_matches_xla():
    """The paper's materialising im2col variant equals the XLA conv."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 10, 10, 6), jnp.float32)
    w = _rand(rng, (3, 3, 6, 12), jnp.float32)
    np.testing.assert_allclose(
        conv_im2col_ref(x, w, stride=1, padding=1),
        conv_ref(x, w, stride=1, padding=1), rtol=1e-4, atol=1e-4,
    )


def test_conv_mm_output_channel_tiling():
    rng = np.random.default_rng(4)
    x = _rand(rng, (1, 8, 8, 4), jnp.float32)
    w = _rand(rng, (3, 3, 4, 32), jnp.float32)
    out = conv_mm_kernel(x, w, stride=1, padding=1, block_o=8, interpret=True)
    ref = conv_ref(x, w, stride=1, padding=1)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssm (SSD)
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 32, 32, 32),
    (1, 96, 1, 8, 64, 32),
]


def _ssd_inputs(rng, B, S, H, P, N, dtype=jnp.float32):
    xh = _rand(rng, (B, S, H, P), dtype) * 0.5
    a = -jnp.abs(_rand(rng, (B, S, H), jnp.float32)) * 0.3  # log-decays < 0
    Bm = _rand(rng, (B, S, N), dtype) * 0.5
    Cm = _rand(rng, (B, S, N), dtype) * 0.5
    return xh, a, Bm, Cm


@pytest.mark.parametrize("spec", SSD_SHAPES)
def test_ssd_kernel_matches_chunked_ref(spec):
    B, S, H, P, N, chunk = spec
    rng = np.random.default_rng(5)
    xh, a, Bm, Cm = _ssd_inputs(rng, B, S, H, P, N)
    y, st = ssd(xh, a, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, st_ref = ssd_ref(xh, a, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(st, st_ref, rtol=1e-3, atol=1e-3)


def test_ssd_chunked_ref_matches_naive_recurrence():
    """The chunked SSD algorithm equals the token-by-token SSM recurrence."""
    rng = np.random.default_rng(6)
    xh, a, Bm, Cm = _ssd_inputs(rng, 1, 32, 2, 8, 8)
    y_ref, st_ref = ssd_ref(xh, a, Bm, Cm, chunk=8)
    y_naive, st_naive = ssd_naive(xh, a, Bm, Cm)
    np.testing.assert_allclose(y_ref, y_naive, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_ref, st_naive, rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half and passing the state must equal the
    full-sequence scan (prefill→decode correctness)."""
    rng = np.random.default_rng(7)
    xh, a, Bm, Cm = _ssd_inputs(rng, 1, 64, 2, 8, 16)
    y_full, st_full = ssd_ref(xh, a, Bm, Cm, chunk=16)
    y1, st1 = ssd_ref(xh[:, :32], a[:, :32], Bm[:, :32], Cm[:, :32], chunk=16)
    y2, st2 = ssd_ref(xh[:, 32:], a[:, 32:], Bm[:, 32:], Cm[:, 32:], chunk=16,
                      initial_state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st2, st_full, rtol=1e-4, atol=1e-4)


def test_ssd_bf16_tolerance():
    rng = np.random.default_rng(8)
    xh, a, Bm, Cm = _ssd_inputs(rng, 1, 64, 2, 16, 16, jnp.bfloat16)
    y, st = ssd(xh, a, Bm, Cm, chunk=16, interpret=True)
    y_ref, st_ref = ssd_ref(xh, a, Bm, Cm, chunk=16)
    np.testing.assert_allclose(y.astype(np.float32), y_ref.astype(np.float32),
                               rtol=5e-2, atol=5e-2)
