"""Golden calibration accuracy harness (ISSUE 2 acceptance).

Calibrates the AnalyticalBackend against ProfilerBackend ground truth on a
small (network × batch) grid — served from the checked-in profiling fixture
``benchmarks/cache/cnn_profile.json`` so the harness is hermetic and fast —
and asserts the paper's Table-4 framing: calibrated latency MAPE strictly
improves on the uncalibrated HOST-CPU-guess baseline, and memory error
stays ≤ 10%.
"""

import os

import numpy as np
import pytest

from repro.core.dataset import DatasetCache, Datapoint
from repro.engine import (
    AnalyticalBackend,
    CostEngine,
    CostQuery,
    EstimateCache,
    ProfilerBackend,
    calibrate,
    default_workloads,
    evaluate_accuracy,
    load_device_spec,
    save_device_spec,
)
from repro.engine.calibrate import (
    CalibrationWorkload,
    measure_ground_truth,
    nnls,
)

FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                       "benchmarks", "cache", "cnn_profile.json")

# Three small CNN topologies (pruning levels of the profile-scale
# squeezenet) × four batch sizes — every cell is present in the fixture, so
# the profiler is never invoked and the harness stays deterministic.
WORKLOADS = default_workloads(families=("squeezenet",),
                              levels=(0.0, 0.30, 0.50),
                              batch_sizes=(2, 8, 16, 32))


@pytest.fixture(scope="module")
def ground_truth():
    cache = DatasetCache(FIXTURE)
    assert len(cache) > 0, f"fixture missing: {FIXTURE}"
    dps, profiled = measure_ground_truth(ProfilerBackend(repeats=1, warmup=0),
                                         WORKLOADS, cache)
    assert profiled == 0, "harness must run entirely from the fixture"
    return dps


def test_workload_keys_match_dataset_cache_keys():
    w = WORKLOADS[0]
    dp = Datapoint(family=w.family, level=w.level, strategy=w.strategy,
                   bs=w.bs, width_mult=w.width_mult, input_hw=w.input_hw,
                   seed=w.seed, gamma_mb=0.0, phi_ms=0.0)
    assert w.key == dp.key
    assert w.key in DatasetCache(FIXTURE)._data


def test_calibration_golden_accuracy(ground_truth):
    """The acceptance assertion: calibrate() on the profiler grid reduces
    latency MAPE vs the host_cpu baseline; memory error ≤ 10%."""
    backend = AnalyticalBackend()          # uncalibrated registry default
    assert backend.device.name == "host_cpu" and not backend.device.calibrated

    before = evaluate_accuracy(backend, ground_truth)
    spec = calibrate(backend, ProfilerBackend(repeats=1, warmup=0),
                     WORKLOADS, cache=FIXTURE)
    after = evaluate_accuracy(backend, ground_truth)

    assert spec.calibrated and spec.combine == "sum"
    assert backend.device is spec          # apply=True threads it in place
    assert spec.meta["n_profiled"] == 0
    # latency: strict improvement over the hand-guessed constants
    assert after["phi_mape"] < before["phi_mape"], (before, after)
    # the guesses are off by ~10x; calibration must land in a sane band too
    assert after["phi_mape"] < 0.5 * before["phi_mape"]
    # memory: within the paper's accuracy band
    assert after["gamma_mape"] <= 0.10, after
    # fitted constants are physical
    assert spec.peak_flops > 0 and spec.hbm_bw > 0
    assert spec.launch_overhead_s >= 0 and spec.mem_base_mb >= 0


def test_calibrated_estimates_never_alias_uncalibrated(ground_truth, tmp_path):
    """Engine cache keys are salted by the device fingerprint: the same
    query under the fitted spec must MISS, not read the stale uncalibrated
    estimate."""
    path = str(tmp_path / "estimates.json")
    backend = AnalyticalBackend()
    q = [CostQuery(spec=WORKLOADS[0].build_model().conv_specs(), bs=8)]

    e1 = CostEngine(backend, cache=EstimateCache(path))
    uncal = e1.estimate(q)[0]
    assert (e1.hits, e1.misses) == (0, 1)

    calibrate(backend, ProfilerBackend(repeats=1, warmup=0),
              WORKLOADS, cache=FIXTURE)
    e2 = CostEngine(backend, cache=EstimateCache(path))
    cal = e2.estimate(q)[0]
    assert (e2.hits, e2.misses) == (0, 1)      # miss: salt changed
    assert cal.phi_ms != uncal.phi_ms
    # and the calibrated estimate is itself cached under the new salt
    e3 = CostEngine(backend, cache=EstimateCache(path))
    assert e3.estimate(q)[0].detail.get("cached")


def test_fitted_spec_persists_and_predicts_identically(ground_truth, tmp_path):
    backend = AnalyticalBackend()
    spec = calibrate(backend, ProfilerBackend(repeats=1, warmup=0),
                     WORKLOADS, cache=FIXTURE, name="fit_roundtrip")
    queries = [CostQuery(spec=dp_spec, bs=4) for dp_spec in
               [WORKLOADS[i].build_model().conv_specs() for i in (0, 4, 8)]]
    want = AnalyticalBackend(device=spec).estimate(queries)
    for ext in ("json", "npz"):
        path = str(tmp_path / f"spec.{ext}")
        save_device_spec(path, spec)
        loaded = load_device_spec(path)
        assert loaded.fingerprint() == spec.fingerprint()
        got = AnalyticalBackend(device=loaded).estimate(queries)
        for a, b in zip(want, got):
            assert (a.gamma_mb, a.phi_ms) == (b.gamma_mb, b.phi_ms)


def test_calibrate_requires_enough_workloads():
    with pytest.raises(ValueError, match="3 workloads"):
        calibrate(AnalyticalBackend(), ProfilerBackend(),
                  WORKLOADS[:2], cache=FIXTURE)


def test_calibrate_accepts_premeasured_datapoints(ground_truth):
    """Callers that already measured the grid pass it straight in — no
    re-measurement, identical fit."""
    b1, b2 = AnalyticalBackend(), AnalyticalBackend()
    via_cache = calibrate(b1, ProfilerBackend(repeats=1, warmup=0),
                          WORKLOADS, cache=FIXTURE)
    via_dps = calibrate(b2, ProfilerBackend(repeats=1, warmup=0),
                        WORKLOADS, datapoints=list(ground_truth))
    assert via_dps.fingerprint() == via_cache.fingerprint()
    assert via_dps.meta["n_profiled"] == 0


def test_calibrated_constants_do_not_leak_into_infer_stage(ground_truth):
    """The launch overhead and additive combine are fitted on FULL training
    steps; inference estimates must not inherit that intercept (it would
    dominate small candidates and break phi_inf constraint screening)."""
    backend = AnalyticalBackend()
    spec = calibrate(backend, ProfilerBackend(repeats=1, warmup=0),
                     WORKLOADS, datapoints=list(ground_truth))
    assert spec.launch_overhead_s > 0          # the fit found an intercept
    net = WORKLOADS[0].build_model().conv_specs()
    inf = backend.estimate([CostQuery(spec=net, bs=1, stage="infer")])[0]
    # infer phi is the bare roofline over the fitted denominators
    expect_ms = max(inf.detail["compute_s"], inf.detail["memory_s"]) * 1e3
    assert inf.phi_ms == pytest.approx(expect_ms)
    assert inf.phi_ms < spec.launch_overhead_s * 1e3 + expect_ms
    # train phi DOES carry the fitted intercept — through the class-wise
    # coefficients when the fit chose them, the additive aggregate combine
    # otherwise
    tr = backend.estimate([CostQuery(spec=net, bs=1, stage="train")])[0]
    coeffs = spec.class_coeffs.get("cnn_latency")
    if tr.detail["latency_fit"] == "classwise":
        import numpy as np

        from repro.core.features import network_features
        from repro.engine.decompose import (
            classwise_seconds,
            latency_class_columns,
        )

        cols = latency_class_columns(
            np.asarray(network_features(net, 1), dtype=np.float64), 4)
        expect_tr = float(np.atleast_1d(
            classwise_seconds(cols, coeffs))[0]) * 1e3
    else:
        expect_tr = (spec.launch_overhead_s
                     + tr.detail["compute_s"] + tr.detail["memory_s"]) * 1e3
    assert tr.phi_ms == pytest.approx(expect_tr)


def test_calibration_does_not_mutate_fixture(ground_truth):
    """All-cached calibration must never rewrite the checked-in fixture."""
    mtime = os.path.getmtime(FIXTURE)
    calibrate(AnalyticalBackend(), ProfilerBackend(repeats=1, warmup=0),
              WORKLOADS, cache=FIXTURE)
    assert os.path.getmtime(FIXTURE) == mtime


# -- the NNLS solver ----------------------------------------------------------


def test_nnls_recovers_nonnegative_solution():
    rng = np.random.default_rng(0)
    A = rng.uniform(0, 1, size=(40, 3))
    x_true = np.array([0.5, 0.0, 2.0])
    x = nnls(A, A @ x_true)
    np.testing.assert_allclose(x, x_true, atol=1e-8)
    assert (x >= 0).all()


def test_nnls_clamps_negative_ls_solution():
    A = np.ones((4, 1))
    x = nnls(A, np.array([-1.0, -2.0, -1.5, -0.5]))
    assert x.shape == (1,) and x[0] == 0.0


def test_nnls_satisfies_kkt_on_correlated_columns():
    """Calibration-shaped systems (ones + two correlated positive columns)
    drove a remove-only active set to suboptimal fits; the Lawson–Hanson
    solution must satisfy the NNLS KKT conditions: nonnegative x, gradient
    ~0 on the support, ≤0 off it."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        base = rng.uniform(1e9, 1e12, size=30)
        A = np.stack([np.ones(30), base,
                      base * rng.uniform(0.5, 2.0, size=30)], axis=1)
        b = rng.uniform(1e-3, 1e-1, size=30)
        x = nnls(A, b)
        assert (x >= 0).all()
        scale = np.linalg.norm(A, axis=0)
        w = (A / scale).T @ (b - A @ x)          # gradient, scaled coords
        tol = 1e-8 * np.linalg.norm(b)
        assert (np.abs(w[x > 0]) <= tol).all()   # stationary on the support
        assert (w[x == 0] <= tol).all()          # no ascent direction off it


def test_nnls_handles_wildly_scaled_columns():
    # Columns spanning ~15 orders of magnitude (a constant vs FLOP counts) —
    # the exact shape of the calibration system.
    rng = np.random.default_rng(1)
    flops = rng.uniform(1e9, 1e12, size=30)
    byts = rng.uniform(1e6, 1e9, size=30)
    A = np.stack([np.ones(30), flops, byts], axis=1)
    x_true = np.array([2e-3, 1e-13, 5e-10])
    x = nnls(A, A @ x_true)
    np.testing.assert_allclose(x, x_true, rtol=1e-6)


# -- slow path: live profiling fills a cold cache -----------------------------


@pytest.mark.slow
def test_calibrate_profiles_on_cache_miss(tmp_path):
    """With a cold cache the profiler actually runs (and the result is
    written back), so calibration works on a fresh device too."""
    cache_path = str(tmp_path / "cold.json")
    backend = AnalyticalBackend()
    tiny = [CalibrationWorkload("squeezenet", 0.0, bs=2),
            CalibrationWorkload("squeezenet", 0.5, bs=2),
            CalibrationWorkload("squeezenet", 0.5, bs=4)]
    spec = calibrate(backend, ProfilerBackend(repeats=1, warmup=0),
                     tiny, cache=cache_path)
    assert spec.calibrated
    assert spec.meta["n_profiled"] == len(tiny)
    assert len(DatasetCache(cache_path)) == len(tiny)
