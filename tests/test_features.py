"""Unit + property tests for the paper's 42 analytical features (App. B)."""

import math

import numpy as np
import pytest

from tests._hypothesis import given, settings, st

from repro.core.features import (
    FEATURE_NAMES,
    FEATURE_NAMES_CONCAT,
    ConvLayerSpec,
    NetworkSpec,
    feature_matrix,
    layer_features,
    network_features,
)


def test_feature_count_matches_paper():
    assert len(FEATURE_NAMES) == 42  # paper §5.3: "These set of 42 features"
    assert len(FEATURE_NAMES_CONCAT) == 42 + 14  # winograd applied twice


def test_ofm_size_formula():
    # op = 1 + floor((ip + 2p - k)/s)
    l = ConvLayerSpec(n=8, m=3, k=3, stride=2, padding=1, ip=32)
    assert l.op == 1 + (32 + 2 - 3) // 2  # 16


def test_hand_computed_tensor_allocations():
    # 3x3 conv, 4 filters, 2 in-channels, 8x8 input, stride 1, pad 1, bs=2
    l = ConvLayerSpec(n=4, m=2, k=3, stride=1, padding=1, ip=8)
    f = layer_features(l, bs=2)
    assert l.op == 8
    assert f["mem_w"] == 4 * 2 * 9                      # n * m/g * k^2
    assert f["mem_w_grad"] == 2 * 4 * 2 * 9             # bs * n * m/g * k^2
    assert f["mem_ifm_grad"] == 2 * 2 * 64              # bs * m * ip^2
    assert f["mem_ofm_grad"] == 2 * 4 * 64              # bs * n * op^2
    assert f["mem_alloc_total"] == (
        f["mem_w"] + f["mem_w_grad"] + f["mem_ifm_grad"] + f["mem_ofm_grad"]
    )


def test_hand_computed_matmul_features():
    l = ConvLayerSpec(n=4, m=2, k=3, stride=1, padding=1, ip=8)
    f = layer_features(l, bs=2)
    assert f["mm_i2c_fwd_total"] == 2 * 64 * 9 * 2      # bs * op^2 * k^2 * m
    assert f["mm_i2c_fwd_index"] == 2 * 64              # bs * op^2
    assert f["mm_ops_fwd"] == 2 * 4 * 64 * 9 * 2        # bs * n * op^2 * k^2 * m/g
    assert f["mm_ops_bwdx"] == 2 * 2 * 64 * 9 * 4       # bs * m * ip^2 * k^2 * n
    assert f["mm_ops_sum"] == 2 * f["mm_ops_fwd"] + f["mm_ops_bwdx"]


def test_hand_computed_fft_features():
    l = ConvLayerSpec(n=4, m=2, k=3, stride=1, padding=1, ip=8)
    f = layer_features(l, bs=2)
    assert f["fft_w_fwd"] == 4 * 2 * 8 * 9              # n * m/g * ip * (1+ip)
    assert f["fft_ifm_fwd"] == 2 * 2 * 8 * 9            # bs * m * ip * (1+ip)
    common = 2 * (2 + 4) + 4 * 2
    expected_ops = 64 * math.log(8) * common + 2 * 4 * 2 * 64
    assert f["fft_ops_fwd"] == pytest.approx(expected_ops)


def test_hand_computed_winograd_features():
    l = ConvLayerSpec(n=4, m=2, k=3, stride=1, padding=1, ip=8)
    f43 = layer_features(l, bs=2, qr_mode="concat")
    # (q,r) = (4,3): tiles = ceil(8/4)^2 = 4, had = 36
    assert f43["wino_mem_fwd_q4r3"] == 2 * 4 * 4 * 3 * 36
    # ops_fwd = bs*n*(m/g)*tiles_ip*tiles_k*had ; tiles_k = ceil(3/3)^2 = 1
    assert f43["wino_ops_fwd_q4r3"] == 2 * 4 * 2 * 4 * 1 * 36
    # "sum" mode adds the (3,2) instantiation
    f = layer_features(l, bs=2, qr_mode="sum")
    f32 = f43["wino_mem_fwd_q3r2"]
    assert f["wino_mem_fwd"] == f43["wino_mem_fwd_q4r3"] + f32


def test_grouped_conv_divides_channels():
    lg = ConvLayerSpec(n=8, m=8, k=3, groups=8, ip=16, padding=1)
    ld = ConvLayerSpec(n=8, m=8, k=3, groups=1, ip=16, padding=1)
    fg, fd = layer_features(lg, 4), layer_features(ld, 4)
    assert fg["mem_w"] == fd["mem_w"] / 8
    assert fg["mm_ops_fwd"] == fd["mm_ops_fwd"] / 8


def test_batch_feature_matrix_matches_scalar_path():
    """The vectorized batch path must reproduce the scalar reference exactly
    (same formulas over flat arrays + segment sum)."""
    rng = np.random.default_rng(0)
    nets_and_bs = []
    for i in range(12):
        layers = tuple(
            ConvLayerSpec(
                n=int(rng.integers(1, 64)),
                m=int(rng.integers(1, 64)),
                k=int(rng.choice([1, 3, 5])),
                stride=int(rng.integers(1, 3)),
                padding=int(rng.integers(0, 3)),
                ip=int(rng.integers(8, 48)),
            )
            for _ in range(int(rng.integers(1, 9)))
        )
        nets_and_bs.append((NetworkSpec(f"net{i}", layers), int(rng.integers(1, 64))))
    depthwise = ConvLayerSpec(n=8, m=8, k=3, groups=8, ip=16, padding=1)
    nets_and_bs.append((NetworkSpec("dw", (depthwise,)), 4))
    for qr_mode in ("sum", "concat"):
        batched = feature_matrix(nets_and_bs, qr_mode)
        scalar = np.stack([network_features(n, b, qr_mode) for n, b in nets_and_bs])
        np.testing.assert_allclose(batched, scalar, rtol=1e-12, atol=0)


def test_batch_feature_matrix_empty():
    assert feature_matrix([]).shape == (0, len(FEATURE_NAMES))


def test_network_features_sum_over_layers():
    l1 = ConvLayerSpec(n=4, m=3, k=3, padding=1, ip=8)
    l2 = ConvLayerSpec(n=8, m=4, k=3, padding=1, ip=8)
    net12 = NetworkSpec("a", (l1, l2))
    f1 = network_features(NetworkSpec("l1", (l1,)), 2)
    f2 = network_features(NetworkSpec("l2", (l2,)), 2)
    np.testing.assert_allclose(network_features(net12, 2), f1 + f2)


layer_strategy = st.builds(
    ConvLayerSpec,
    n=st.integers(1, 64),
    m=st.integers(1, 64),
    k=st.sampled_from([1, 3, 5, 7]),
    stride=st.integers(1, 2),
    padding=st.integers(0, 3),
    groups=st.just(1),
    ip=st.integers(8, 64),
)


@given(l=layer_strategy, bs=st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_features_nonnegative_finite(l, bs):
    f = layer_features(l, bs)
    v = np.array(list(f.values()))
    assert np.all(np.isfinite(v))
    assert np.all(v >= 0)


@given(l=layer_strategy, bs=st.integers(1, 32))
@settings(max_examples=40, deadline=None)
def test_features_monotone_in_batch_size(l, bs):
    """More batch ⇒ no feature shrinks (weights are bs-independent)."""
    f1 = np.array(list(layer_features(l, bs).values()))
    f2 = np.array(list(layer_features(l, bs + 1).values()))
    assert np.all(f2 >= f1)


@given(l=layer_strategy, bs=st.integers(1, 32), extra=st.integers(1, 32))
@settings(max_examples=40, deadline=None)
def test_features_monotone_in_filters(l, bs, extra):
    """More filters ⇒ every memory/op term is >= (pruning shrinks features)."""
    import dataclasses

    bigger = dataclasses.replace(l, n=l.n + extra)
    f1 = np.array(list(layer_features(l, bs).values()))
    f2 = np.array(list(layer_features(bigger, bs).values()))
    assert np.all(f2 >= f1)
