"""Kernel autotuner: cache round-trip + corruption tolerance, device-
fingerprint salting, VMEM pruning, tuned-vs-default parity in interpret
mode, and the divisibility fallbacks that replaced the hard asserts."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.devices import DeviceSpec, get_device
from repro.kernels.autotune import (
    VMEM_BUDGET_FRACTION,
    VMEM_BYTES,
    KernelCost,
    KernelTuner,
    TuningCache,
    get_tiling,
    largest_dividing_block,
    list_tilings,
    roofline_seconds,
    set_tuner,
    vmem_ok,
)
from repro.kernels.conv_mm import tiling as conv_tiling
from repro.kernels.conv_mm.kernel import conv_mm_kernel
from repro.kernels.conv_mm.ref import conv_ref
from repro.kernels.flash_attention import tiling as flash_tiling
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_decode import tiling as pd_tiling
from repro.kernels.ssm_scan import tiling as ssm_tiling
from repro.kernels.ssm_scan.ops import ssd
from repro.kernels.ssm_scan.ref import ssd_ref

TPU = "tpu_v5e"

CONV_SHAPE = conv_tiling.shape_key(
    (2, 16, 16, 32), (3, 3, 32, 64), stride=1, padding=1, dtype="float32")
FLASH_SHAPE = flash_tiling.shape_key(
    (1, 4, 512, 64), (1, 2, 512, 64), causal=True, dtype="bfloat16")
SSM_SHAPE = ssm_tiling.shape_key((1, 256, 4, 32), 32, dtype="float32")
PD_SHAPE = pd_tiling.shape_key(4, 8, 2, 64, 8, 32, dtype="bfloat16")


@pytest.fixture
def tuner(tmp_path):
    return KernelTuner(device=get_device(TPU),
                       cache=str(tmp_path / "tune.json"), measure=False)


@pytest.fixture(autouse=True)
def _isolated_default_tuner(tmp_path):
    """Keep implicit ops/model lookups off the user-level cache file."""
    set_tuner(KernelTuner(device=get_device(TPU),
                          cache=str(tmp_path / "default_tune.json"),
                          measure=False))
    yield
    set_tuner(None)


# ---------------------------------------------------------------------------
# helpers / registry
# ---------------------------------------------------------------------------


def test_largest_dividing_block():
    assert largest_dividing_block(96, 256) == 96
    assert largest_dividing_block(96, 64) == 48
    assert largest_dividing_block(384, 512) == 384
    assert largest_dividing_block(384, 128) == 128
    assert largest_dividing_block(7, 4) == 1
    assert largest_dividing_block(128, None) == 128
    with pytest.raises(ValueError):
        largest_dividing_block(0, 8)


def test_all_kernels_register_tilings():
    assert list_tilings() == ["conv_mm", "flash_attention", "moe_dispatch",
                              "paged_decode", "serve_kv", "ssm_scan"]


@pytest.mark.parametrize("kernel,shape", [
    ("conv_mm", CONV_SHAPE),
    ("flash_attention", FLASH_SHAPE),
    ("ssm_scan", SSM_SHAPE),
    ("paged_decode", PD_SHAPE),
])
def test_default_config_is_a_candidate(kernel, shape):
    tiling = get_tiling(kernel)
    assert tiling.default(shape) in list(tiling.candidates(shape))


# ---------------------------------------------------------------------------
# cache round-trip + corruption tolerance
# ---------------------------------------------------------------------------


def test_tuning_cache_roundtrip(tmp_path, tuner):
    cfg1 = tuner.tune("conv_mm", CONV_SHAPE)
    assert tuner.misses == 1
    # same tuner: in-memory hit
    assert tuner.tune("conv_mm", CONV_SHAPE) == cfg1
    assert (tuner.hits, tuner.misses) == (1, 1)
    # fresh tuner on the same file: disk hit, no re-search
    t2 = KernelTuner(device=get_device(TPU),
                     cache=str(tmp_path / "tune.json"), measure=False)
    assert t2.tune("conv_mm", CONV_SHAPE) == cfg1
    assert (t2.hits, t2.misses) == (1, 0)


def test_tuning_cache_corrupt_file_tolerated(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{definitely not json")
    t = KernelTuner(device=get_device(TPU), cache=str(path), measure=False)
    cfg = t.tune("conv_mm", CONV_SHAPE)   # restarts from empty, re-tunes
    assert t.misses == 1 and cfg
    assert os.path.exists(str(path) + ".corrupt")
    # the re-tuned winner was flushed atomically over the quarantined file
    assert json.loads(path.read_text())


def test_tuning_cache_entries_are_json_round_trippable(tmp_path, tuner):
    for kernel, shape in [("conv_mm", CONV_SHAPE),
                          ("flash_attention", FLASH_SHAPE),
                          ("ssm_scan", SSM_SHAPE)]:
        tuner.tune(kernel, shape)
    data = json.loads((tmp_path / "tune.json").read_text())
    assert len(data) == 3
    for entry in data.values():
        assert entry["source"] == "model"
        assert entry["config"]
        assert entry["model_us"] <= entry["default_model_us"] * (1 + 1e-9)


# ---------------------------------------------------------------------------
# device-fingerprint salting
# ---------------------------------------------------------------------------


def test_device_fingerprint_salts_keys(tmp_path):
    """Two specs never alias: same shape tunes independently per device."""
    path = str(tmp_path / "tune.json")
    a = KernelTuner(device=get_device(TPU), cache=path, measure=False)
    b = KernelTuner(device=get_device("tx2_like"), cache=path, measure=False)
    assert a.key("conv_mm", CONV_SHAPE) != b.key("conv_mm", CONV_SHAPE)
    a.tune("conv_mm", CONV_SHAPE)
    b.tune("conv_mm", CONV_SHAPE)
    assert b.misses == 1          # a's entry was NOT served to b
    assert len(TuningCache(path)) == 2


def test_fingerprint_sensitive_to_constants(tmp_path):
    base = get_device(TPU)
    slower = DeviceSpec(name=base.name, peak_flops=base.peak_flops / 2,
                        hbm_bw=base.hbm_bw)
    a = KernelTuner(device=base, cache=None)
    b = KernelTuner(device=slower, cache=None)
    assert a.key("conv_mm", CONV_SHAPE) != b.key("conv_mm", CONV_SHAPE)


# ---------------------------------------------------------------------------
# VMEM pruning + ranking
# ---------------------------------------------------------------------------


def test_vmem_infeasible_candidates_rejected(tuner):
    # big image × wide channels: large block_o working sets blow VMEM
    shape = conv_tiling.shape_key((1, 64, 64, 256), (3, 3, 256, 512),
                                  stride=1, padding=1, dtype="float32")
    entry = tuner.explain("conv_mm", shape)
    assert entry["rejected_vmem"] > 0
    cost = get_tiling("conv_mm").cost(shape, entry["config"])
    assert vmem_ok(cost)
    assert cost.vmem_bytes <= VMEM_BYTES * VMEM_BUDGET_FRACTION
    # and the infeasible configs really are over budget
    big = get_tiling("conv_mm").cost(shape, {"block_o": 512})
    assert not vmem_ok(big)


def test_all_infeasible_falls_back_to_smallest_working_set(tuner):
    # pathological: even block_o=1's padded image exceeds a tiny budget
    t = KernelTuner(device=get_device(TPU), cache=None,
                    vmem_budget_bytes=1024)
    cfg = t.tune("conv_mm", CONV_SHAPE)
    costs = {json.dumps(c, sort_keys=True):
             get_tiling("conv_mm").cost(CONV_SHAPE, c)
             for c in get_tiling("conv_mm").candidates(CONV_SHAPE)}
    assert (get_tiling("conv_mm").cost(CONV_SHAPE, cfg).vmem_bytes
            == min(c.vmem_bytes for c in costs.values()))


def test_tuned_never_worse_than_default_by_model(tuner):
    for kernel, shape in [("conv_mm", CONV_SHAPE),
                          ("flash_attention", FLASH_SHAPE),
                          ("ssm_scan", SSM_SHAPE)]:
        entry = tuner.explain(kernel, shape)
        tiling = get_tiling(kernel)
        tuned_t = roofline_seconds(tiling.cost(shape, entry["config"]),
                                   tuner.device)
        default_t = roofline_seconds(tiling.cost(shape, entry["default_config"]),
                                     tuner.device)
        assert tuned_t <= default_t * (1 + 1e-9), (kernel, entry)


def test_roofline_prefers_fewer_steps_at_equal_traffic():
    dev = get_device(TPU)
    small = KernelCost(flops=1e9, hbm_bytes=1e6, vmem_bytes=1e3,
                       n_steps=1000, mxu_min_dim=128)
    big = KernelCost(flops=1e9, hbm_bytes=1e6, vmem_bytes=1e3,
                     n_steps=10, mxu_min_dim=128)
    assert roofline_seconds(big, dev) < roofline_seconds(small, dev)


def test_mxu_underfill_penalised():
    dev = get_device(TPU)
    narrow = KernelCost(flops=1e9, hbm_bytes=1e6, vmem_bytes=1e3,
                        n_steps=10, mxu_min_dim=8)
    full = KernelCost(flops=1e9, hbm_bytes=1e6, vmem_bytes=1e3,
                      n_steps=10, mxu_min_dim=128)
    assert roofline_seconds(narrow, dev) > roofline_seconds(full, dev)


# ---------------------------------------------------------------------------
# tuned vs default kernel outputs (interpret mode)
# ---------------------------------------------------------------------------


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def test_conv_tuned_config_parity(tuner):
    rng = np.random.default_rng(0)
    x = _rand(rng, (2, 16, 16, 32))
    w = _rand(rng, (3, 3, 32, 64)) * 0.2
    bo = tuner.tune("conv_mm", CONV_SHAPE)["block_o"]
    out = conv_mm_kernel(x, w, stride=1, padding=1, block_o=bo, interpret=True)
    ref = conv_ref(x, w, stride=1, padding=1)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_tuned_config_parity(tuner):
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 4, 512, 64))
    k = _rand(rng, (1, 2, 512, 64))
    v = _rand(rng, (1, 2, 512, 64))
    shape = flash_tiling.shape_key(q.shape, k.shape, causal=True,
                                   dtype="float32")
    cfg = tuner.tune("flash_attention", shape)
    out = flash_attention_kernel(q, k, v, causal=True, interpret=True, **cfg)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ssd_tuned_config_parity(tuner):
    rng = np.random.default_rng(2)
    xh = _rand(rng, (1, 256, 4, 32)) * 0.5
    a = -jnp.abs(_rand(rng, (1, 256, 4))) * 0.3
    Bm = _rand(rng, (1, 256, 32)) * 0.5
    cfg = tuner.tune("ssm_scan", SSM_SHAPE)
    y, st = ssd(xh, a, Bm, Bm, chunk=cfg["chunk"], interpret=True)
    y_ref, st_ref = ssd_ref(xh, a, Bm, Bm, chunk=64)
    np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(st, st_ref, rtol=1e-3, atol=1e-3)


def test_ops_autotuned_dispatch_matches_ref():
    """chunk=None → the op pulls its chunk from the (isolated) default
    tuner and still matches the reference."""
    rng = np.random.default_rng(3)
    xh = _rand(rng, (1, 96, 2, 16)) * 0.5
    a = -jnp.abs(_rand(rng, (1, 96, 2))) * 0.3
    Bm = _rand(rng, (1, 96, 16)) * 0.5
    y, st = ssd(xh, a, Bm, Bm, interpret=True)
    y_ref, st_ref = ssd_ref(xh, a, Bm, Bm, chunk=32)
    np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(st, st_ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# divisibility fallbacks (previously hard asserts)
# ---------------------------------------------------------------------------


def test_conv_nondividing_block_o_runs():
    rng = np.random.default_rng(4)
    x = _rand(rng, (1, 8, 8, 4))
    w = _rand(rng, (3, 3, 4, 96)) * 0.2   # O=96 with the old min(O,256)=96… force 256
    out = conv_mm_kernel(x, w, stride=1, padding=1, block_o=256, interpret=True)
    ref = conv_ref(x, w, stride=1, padding=1)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_conv_nondividing_small_block_o_runs():
    rng = np.random.default_rng(5)
    x = _rand(rng, (1, 8, 8, 4))
    w = _rand(rng, (3, 3, 4, 24)) * 0.2
    out = conv_mm_kernel(x, w, stride=1, padding=1, block_o=16,  # → 12? no: 8
                         interpret=True)
    ref = conv_ref(x, w, stride=1, padding=1)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_nondividing_blocks_run():
    rng = np.random.default_rng(6)
    q = _rand(rng, (1, 2, 384, 32))       # Sq=384 with block_q=512
    k = _rand(rng, (1, 2, 384, 32))
    v = _rand(rng, (1, 2, 384, 32))
    out = flash_attention_kernel(q, k, v, causal=True, block_q=512,
                                 block_k=512, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=2e-4, atol=2e-4)


def test_flash_nondividing_block_k_runs():
    rng = np.random.default_rng(7)
    q = _rand(rng, (1, 2, 64, 32))
    k = _rand(rng, (1, 2, 96, 32))        # Sk=96, block_k=64 → 48
    v = _rand(rng, (1, 2, 96, 32))
    out = flash_attention_kernel(q, k, v, causal=True, q_offset=32,
                                 block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, q_offset=32)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ssd_nondividing_chunk_runs():
    rng = np.random.default_rng(8)
    xh = _rand(rng, (1, 96, 2, 16)) * 0.5
    a = -jnp.abs(_rand(rng, (1, 96, 2))) * 0.3
    Bm = _rand(rng, (1, 96, 16)) * 0.5
    y, st = ssd(xh, a, Bm, Bm, chunk=64, interpret=True)  # 96 % 64 → 48
    y_ref, st_ref = ssd_ref(xh, a, Bm, Bm, chunk=32)
    np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# model warm-up entry point
# ---------------------------------------------------------------------------


def test_warm_autotune_populates_cache(tmp_path):
    from repro.configs.registry import get_config
    from repro.kernels.autotune import get_tuner
    from repro.models.transformer import warm_autotune

    cfg = get_config("qwen3-4b", reduced=True)
    stats = warm_autotune(cfg, batch_size=2, seq_len=32,
                          stages=("prefill", "decode"))
    assert stats["misses"] >= 1          # attention shapes were tuned
    tuner = get_tuner()
    assert len(tuner.cache) >= 1
    # second warm pass: everything already cached
    stats2 = warm_autotune(cfg, batch_size=2, seq_len=32,
                           stages=("prefill", "decode"))
    assert stats2["misses"] == 0 and stats2["hits"] >= 1
