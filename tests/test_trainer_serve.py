"""Trainer fault tolerance + serving engine tests (smoke-scale LM)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.optim.optimizer import OptimizerConfig
from repro.serve.engine import PlacementRefused, ServeConfig, ServeEngine
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig

SMOKE_SHAPE = ShapeSpec("smoke", seq_len=32, global_batch=4, kind="train")


def _cfg():
    return get_config("internlm2-1.8b", reduced=True)


def _opt():
    return OptimizerConfig(kind="adamw", lr=3e-3, warmup_steps=2,
                           total_steps=200, clip_norm=1.0)


def test_loss_decreases_over_training():
    tr = Trainer(_cfg(), SMOKE_SHAPE, _opt(), TrainerConfig())
    out = tr.train(25)
    first = np.mean([h["ce"] for h in out["history"][:5]])
    last = np.mean([h["ce"] for h in out["history"][-5:]])
    assert last < first - 0.1, (first, last)


def test_crash_resume_continues_exactly(tmp_path):
    d = str(tmp_path / "ck")
    tc = TrainerConfig(ckpt_dir=d, ckpt_every=5, fail_at_step=12)
    tr = Trainer(_cfg(), SMOKE_SHAPE, _opt(), tc)
    with pytest.raises(RuntimeError, match="injected failure"):
        tr.train(20)
    # restart without failure injection: resumes from step 10
    tc2 = TrainerConfig(ckpt_dir=d, ckpt_every=5)
    tr2 = Trainer(_cfg(), SMOKE_SHAPE, _opt(), tc2)
    out = tr2.train(20)
    resumed_steps = [h["step"] for h in out["history"]]
    assert resumed_steps[0] == 10  # latest ckpt was step 9
    assert resumed_steps[-1] == 19

    # bit-exact vs uninterrupted run (deterministic data + init)
    tr3 = Trainer(_cfg(), SMOKE_SHAPE, _opt(), TrainerConfig())
    out3 = tr3.train(20)
    np.testing.assert_allclose(
        out["history"][-1]["loss"], out3["history"][-1]["loss"], rtol=1e-4
    )


def test_grad_compression_path_trains():
    tc = TrainerConfig(grad_compression=0.25)
    tr = Trainer(_cfg(), SMOKE_SHAPE, _opt(), tc)
    out = tr.train(15)
    first = np.mean([h["ce"] for h in out["history"][:5]])
    last = np.mean([h["ce"] for h in out["history"][-5:]])
    assert last < first


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(factor=2.0)
    for i in range(10):
        m.observe(i, 0.1)
    assert m.observe(10, 0.5) is True
    assert m.flagged and m.flagged[-1][0] == 10
    assert m.observe(11, 0.1) is False


def test_admission_gate_refuses():
    def deny(cfg, shape):
        return False, {"reason": "predicted OOM"}

    with pytest.raises(RuntimeError, match="admission denied"):
        Trainer(_cfg(), SMOKE_SHAPE, _opt(), TrainerConfig(), admission=deny)


def test_serve_engine_greedy_generate():
    cfg = _cfg()
    params = T.init_params(cfg, 0)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2,
                                               eos_id=0))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out["tokens"].shape[0] == 2
    assert 1 <= out["tokens"].shape[1] <= 6
    assert out["decode_steps"] >= 1


class _StubCostEngine:
    """CostEngine stand-in: fixed admit verdict, records the query."""

    def __init__(self, ok, gamma_mb=100.0):
        self.ok = ok
        self.gamma_mb = gamma_mb
        self.queries = []

    def admit(self, query, *, gamma_budget_mb=None, phi_budget_ms=None,
              safety_margin=0.1):
        self.queries.append(query)
        self.budgets = getattr(self, "budgets", [])
        self.budgets.append(gamma_budget_mb)
        return self.ok, {"gamma_mb": self.gamma_mb, "phi_ms": 1.0,
                         "gamma_eff": self.gamma_mb * (1 + safety_margin),
                         "phi_eff": 1.1, "source": "stub"}


def test_serve_placement_admission_refuses_over_budget():
    cfg = _cfg()
    params = T.init_params(cfg, 0)
    gate = _StubCostEngine(ok=False)
    with pytest.raises(PlacementRefused):
        ServeEngine(cfg, params,
                    ServeConfig(max_len=64, n_slots=2, gamma_budget_mb=1.0),
                    cost_engine=gate)
    q = gate.queries[0]
    # _cfg() is the reduced "-smoke" variant: the gate must map it back to
    # the registry id and carry reduced-ness IN the query, so any engine
    # (whatever its backend's default) costs the config actually served
    assert cfg.name == "internlm2-1.8b-smoke"
    assert (q.arch, q.bs, q.seq, q.stage) == ("internlm2-1.8b", 2, 64, "infer")
    assert q.reduced is True


def test_serve_placement_admission_admits_and_serves():
    cfg = _cfg()
    params = T.init_params(cfg, 0)
    gate = _StubCostEngine(ok=True)
    eng = ServeEngine(cfg, params,
                      ServeConfig(max_len=64, n_slots=2, eos_id=0,
                                  gamma_budget_mb=1e6),
                      cost_engine=gate)
    assert eng.admission_info["source"] == "stub"
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out["tokens"].shape[0] == 2


def test_serve_device_capacity_budgets_external_engine():
    """A configured device must gate placement even through an externally
    supplied cost engine that doesn't carry it: the device's capacity
    becomes the budget."""
    from repro.engine import get_device

    cfg = _cfg()
    params = T.init_params(cfg, 0)
    gate = _StubCostEngine(ok=True)
    ServeEngine(cfg, params,
                ServeConfig(max_len=64, n_slots=2, device="tx2_like"),
                cost_engine=gate)
    assert gate.budgets == [get_device("tx2_like").hbm_bytes / 1e6]


def test_serve_without_device_or_budget_skips_gate():
    cfg = _cfg()
    params = T.init_params(cfg, 0)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2))
    assert eng.admission_info is None


def test_serve_deterministic_greedy():
    cfg = _cfg()
    params = T.init_params(cfg, 0)
    prompts = np.random.default_rng(1).integers(1, cfg.vocab, (2, 8)).astype(np.int32)
    a = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2)).generate(
        prompts, max_new_tokens=5)
    b = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2)).generate(
        prompts, max_new_tokens=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
