"""Per-op cost ledger: parity with the legacy aggregates, round-trips,
class-wise NNLS recovery, and the shared-schema contracts downstream."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import parse_hlo_cost
from repro.costmodel import OP_CLASSES, CostLedger, OpCost, classify_op


def _cost(fn, *args):
    return parse_hlo_cost(jax.jit(fn).lower(*args).compile().as_text())


def _golden_costs():
    """The golden HLO fixtures: the same programs test_hlo_cost.py pins
    exact FLOP counts for, plus a collective-free elementwise one."""
    x64 = jnp.zeros((64, 64))
    ws12 = jnp.zeros((12, 64, 64))
    x32 = jnp.zeros((32, 32))
    ws5 = jnp.zeros((5, 32, 32))

    def scan_f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def loss(ws, x):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0].sum()

    return {
        "dot": _cost(lambda a, b: a @ b, jnp.zeros((128, 64)),
                     jnp.zeros((64, 32))),
        "scan": _cost(scan_f, x64, ws12),
        "grad_scan": _cost(jax.grad(loss), ws5, x32),
        "elementwise": _cost(lambda x: x * 2 + 1,
                             jnp.zeros((1024, 1024), jnp.float32)),
    }


# ---------------------------------------------------------------------------
# parity: sum(ledger) == the legacy aggregates, exactly
# ---------------------------------------------------------------------------


class TestParity:
    def test_ledger_sums_equal_aggregates_exactly(self):
        for name, cost in _golden_costs().items():
            led = cost.ledger
            assert len(led) > 0, name
            # bit-identical, not approx: one accumulation path by design
            assert sum(r.flops for r in led) == cost.flops, name
            assert sum(r.hbm_bytes for r in led) == cost.hbm_bytes, name
            assert sum(r.collective_bytes for r in led) \
                == cost.collective_bytes, name
            # and the groupby view re-sums to the same totals
            sums = cost.by_class()
            assert sum(s["flops"] for s in sums.values()) == cost.flops
            assert sum(s["hbm_bytes"] for s in sums.values()) == cost.hbm_bytes

    def test_legacy_exact_flop_values_still_hold(self):
        costs = _golden_costs()
        assert costs["dot"].flops == 2 * 128 * 64 * 32
        assert costs["scan"].flops == 12 * 2 * 64**3
        assert costs["grad_scan"].flops == pytest.approx(15 * 2 * 32**3,
                                                         rel=0.01)

    def test_flops_attributed_to_matmul_class(self):
        sums = _golden_costs()["scan"].by_class()
        assert sums["matmul"]["flops"] == 12 * 2 * 64**3
        # nothing else claims flops
        assert all(s["flops"] == 0 for cls, s in sums.items()
                   if cls != "matmul")

    def test_scanned_records_carry_the_trip_multiplier(self):
        led = _golden_costs()["scan"].ledger
        scanned = [r for r in led if r.trip_multiplier == 12]
        assert scanned, "no record inherited the trip count"
        assert any(r.flops > 0 for r in scanned)

    def test_elementwise_program_has_no_matmul(self):
        sums = _golden_costs()["elementwise"].by_class()
        assert sums.get("matmul", {"flops": 0})["flops"] == 0
        assert sum(s["hbm_bytes"] for s in sums.values()) > 4e6


# ---------------------------------------------------------------------------
# the taxonomy
# ---------------------------------------------------------------------------


class TestClassify:
    def test_closed_vocabulary(self):
        for op in ("dot", "convolution", "all-reduce", "all-gather-start",
                   "reduce", "dynamic-slice", "add", "tanh", "custom-call",
                   "fusion", "weird-new-op"):
            assert classify_op(op) in OP_CLASSES

    def test_core_mappings(self):
        assert classify_op("dot") == "matmul"
        assert classify_op("convolution") == "conv"
        assert classify_op("all-reduce") == "collective"
        assert classify_op("all-reduce-start") == "collective"
        # the async second half must not fall through to elementwise —
        # its HBM output bytes are collective-class traffic
        assert classify_op("all-reduce-done") == "collective"
        assert classify_op("all-gather-done") == "collective"
        assert classify_op("copy-done") == "data_movement"
        assert classify_op("reduce") == "reduction"
        assert classify_op("dynamic-update-slice") == "data_movement"
        assert classify_op("add") == "elementwise"
        assert classify_op("custom-call") == "other"

    def test_wrapper_classifies_as_the_work_it_feeds(self):
        assert classify_op("fusion") == "elementwise"
        assert classify_op("fusion", dot_flops=1e6) == "matmul"
        assert classify_op("fusion", conv_flops=1e6) == "conv"
        assert classify_op("fusion", dot_flops=1.0, conv_flops=2.0) == "conv"


# ---------------------------------------------------------------------------
# container behaviour + persistence
# ---------------------------------------------------------------------------


class TestLedger:
    def _ledger(self):
        return CostLedger([
            OpCost(op="dot", op_class="matmul", dtype="f32", flops=100.0,
                   hbm_bytes=10.0, origin="entry"),
            OpCost(op="add", op_class="elementwise", dtype="bf16",
                   hbm_bytes=30.0, trip_multiplier=4.0, origin="body"),
            OpCost(op="all-reduce", op_class="collective", dtype="f32",
                   hbm_bytes=5.0, collective_bytes=50.0, origin="entry"),
        ])

    def test_totals_and_class_sums(self):
        led = self._ledger()
        assert led.totals() == {"flops": 100.0, "hbm_bytes": 45.0,
                                "collective_bytes": 50.0, "energy_j": 0.0}
        sums = led.class_sums()
        assert set(sums) == {"matmul", "elementwise", "collective"}
        assert sums["elementwise"] == {"flops": 0.0, "hbm_bytes": 30.0,
                                       "collective_bytes": 0.0,
                                       "energy_j": 0.0, "count": 1}

    def test_merge_class_sums_matches_ledger_view(self):
        led = self._ledger()
        merged = CostLedger.merge_class_sums([led.class_sums(),
                                              led.class_sums()])
        doubled = CostLedger(led.records * 2).class_sums()
        assert merged == doubled
        # missing/empty entries tolerated; zero classes filtered identically
        assert CostLedger.merge_class_sums([{}, None]) == {}
        assert "matmul" in CostLedger.merge_class_sums(
            [{}], keep_zero=True)

    def test_records_are_keyword_only(self):
        # positional construction would silently bind costs to the wrong
        # slots (flops into ``op``) — it must raise instead
        with pytest.raises(TypeError):
            OpCost("dot", "matmul")
        from repro.kernels.autotune import KernelCost

        with pytest.raises(TypeError):
            KernelCost(1e9, 1e6, 1e3)

    def test_top_k(self):
        led = self._ledger()
        assert [r.op for r in led.top_k(2, by="hbm_bytes")] == ["add", "dot"]
        assert [r.op for r in led.top_k(1, by="flops")] == ["dot"]
        with pytest.raises(KeyError):
            led.top_k(1, by="nope")

    def test_scaled(self):
        led = self._ledger().scaled(2.0)
        assert led.flops == 200.0 and led.collective_bytes == 100.0
        # vmem/trip metadata untouched
        assert led.records[1].trip_multiplier == 4.0

    @pytest.mark.parametrize("ext", ["json", "npz"])
    def test_roundtrip(self, tmp_path, ext):
        led = self._ledger()
        path = str(tmp_path / f"ledger.{ext}")
        led.save(path)
        loaded = CostLedger.load(path)
        assert loaded == led
        assert loaded.totals() == led.totals()

    @pytest.mark.parametrize("ext", ["json", "npz"])
    def test_roundtrip_real_parse(self, tmp_path, ext):
        cost = _golden_costs()["grad_scan"]
        path = str(tmp_path / f"ledger.{ext}")
        cost.ledger.save(path)
        loaded = CostLedger.load(path)
        assert loaded == cost.ledger
        assert loaded.flops == cost.flops
        assert loaded.hbm_bytes == cost.hbm_bytes

    def test_empty_roundtrip(self, tmp_path):
        for ext in ("json", "npz"):
            path = str(tmp_path / f"empty.{ext}")
            CostLedger().save(path)
            assert len(CostLedger.load(path)) == 0


# ---------------------------------------------------------------------------
# KernelCost is a view over OpCost (one schema for tuner + calibration rows)
# ---------------------------------------------------------------------------


class TestKernelCostView:
    def test_kernel_cost_is_an_opcost(self):
        from repro.kernels.autotune import KernelCost, get_tiling

        assert issubclass(KernelCost, OpCost)
        for kernel, want_cls in (("conv_mm", "conv"),
                                 ("flash_attention", "matmul"),
                                 ("ssm_scan", "matmul"),
                                 ("moe_dispatch", "matmul")):
            tiling = get_tiling(kernel)
            shape = _kernel_shape(kernel)
            cost = tiling.cost(shape, tiling.default(shape))
            assert isinstance(cost, OpCost), kernel
            assert cost.op_class == want_cls, kernel
            assert cost.op == kernel
            assert cost.flops > 0 and cost.vmem_bytes > 0

    def test_kernel_cost_feeds_a_ledger(self):
        from repro.kernels.autotune import get_tiling

        tiling = get_tiling("flash_attention")
        shape = _kernel_shape("flash_attention")
        led = CostLedger([tiling.cost(shape, tiling.default(shape))])
        sums = led.class_sums()
        assert sums["matmul"]["flops"] == led.flops > 0


def _kernel_shape(kernel: str) -> dict:
    from repro.kernels import (
        conv_mm,
        flash_attention,
        moe_dispatch,
        ssm_scan,
    )

    if kernel == "conv_mm":
        return conv_mm.tiling.shape_key((2, 16, 16, 32), (3, 3, 32, 64),
                                        stride=1, padding=1, dtype="float32")
    if kernel == "flash_attention":
        return flash_attention.tiling.shape_key(
            (1, 2, 256, 64), (1, 2, 256, 64), causal=True, dtype="bfloat16")
    if kernel == "ssm_scan":
        return ssm_scan.tiling.shape_key((2, 256, 4, 64), 16, dtype="float32")
    return moe_dispatch.tiling.shape_key(B=4, S=32, D=128, E=4, K=2, F=128,
                                         capacity_factor=1.25,
                                         dtype="bfloat16")


# ---------------------------------------------------------------------------
# class-wise NNLS: planted per-class constants are recovered
# ---------------------------------------------------------------------------


class TestClasswiseNnls:
    def test_cnn_calibrate_recovers_planted_class_constants(self):
        """Targets built with DIFFERENT per-byte-class costs: the aggregate
        3-term fit cannot represent them, the class-wise fit can — so
        calibrate() must choose class-wise and drive the MAPE to ~0."""
        from repro.core.dataset import Datapoint
        from repro.core.features import FEATURE_NAMES
        from repro.engine.backends import AnalyticalBackend
        from repro.engine.calibrate import calibrate
        from repro.engine.decompose import latency_class_columns, memory_terms

        c0, c_fl, c_alloc, c_i2c = 2e-3, 1e-11, 3e-9, 9e-8
        rng = np.random.default_rng(0)
        dps = []
        for i in range(10):
            f = rng.uniform(1e3, 1e6, size=len(FEATURE_NAMES))
            cols = latency_class_columns(f, 4)
            w, a = memory_terms(f, 4)
            phi_s = (c0 + c_fl * cols["flops_matmul"][0]
                     + c_alloc * cols["hbm_elementwise"][0]
                     + c_i2c * cols["hbm_data_movement"][0])
            dps.append(Datapoint(
                family="synthetic", level=0.1 * i, strategy="random", bs=2,
                width_mult=0.25, input_hw=16, seed=0,
                gamma_mb=float(5 + (w[0] + a[0]) / 1e6),
                phi_ms=float(phi_s * 1e3),
                features=[float(v) for v in f]))
        backend = AnalyticalBackend()
        spec = calibrate(backend, None, [], datapoints=dps, apply=True)
        assert spec.meta["latency_fit"] == "classwise"
        assert spec.meta["phi_mape"] < 1e-6
        # aggregate genuinely cannot fit these (distinct byte costs)
        assert spec.meta["phi_mape_aggregate"] > spec.meta["phi_mape"]
        coeffs = spec.class_coeffs["cnn_latency"]
        assert coeffs["_intercept"] == pytest.approx(c0, rel=1e-3)
        assert coeffs["flops_matmul"] == pytest.approx(c_fl, rel=1e-3)
        assert coeffs["hbm_elementwise"] == pytest.approx(c_alloc, rel=1e-3)
        assert coeffs["hbm_data_movement"] == pytest.approx(c_i2c, rel=1e-3)

    def test_lm_fit_hlo_constants_recovers_planted_class_constants(self):
        """Campaign records with per-class breakdowns and phi built from
        DIFFERENT per-class byte costs: aggregate 4-term can't represent
        them; the class-wise fit recovers the planted coefficients."""
        from repro.campaign import fit_hlo_constants

        c0, c_mm_f, c_ew_b, c_dm_b = 1e-3, 5e-12, 2e-9, 8e-8
        rng = np.random.default_rng(1)
        records = []
        for i in range(12):
            fl = float(rng.uniform(1e6, 1e8))
            ew = float(rng.uniform(1e5, 1e7))
            dm = float(rng.uniform(1e4, 1e6))
            classes = {
                "matmul": {"flops": fl, "hbm_bytes": 0.0,
                           "collective_bytes": 0.0, "count": 3},
                "elementwise": {"flops": 0.0, "hbm_bytes": ew,
                                "collective_bytes": 0.0, "count": 9},
                "data_movement": {"flops": 0.0, "hbm_bytes": dm,
                                  "collective_bytes": 0.0, "count": 2},
            }
            phi_s = c0 + c_mm_f * fl + c_ew_b * ew + c_dm_b * dm
            records.append({
                "status": "ok", "device": "host_cpu", "plan_hash": "x",
                "flops": fl, "hbm_bytes": ew + dm, "collective_bytes": 0.0,
                "cost_classes": classes, "phi_ms": phi_s * 1e3,
            })
        spec = fit_hlo_constants(records)
        assert spec.meta["latency_fit"] == "classwise"
        assert spec.meta["phi_mape"] < 1e-6
        assert spec.meta["phi_mape_aggregate"] > 1e-3
        coeffs = spec.class_coeffs["lm_latency"]
        assert coeffs["_intercept"] == pytest.approx(c0, rel=1e-3)
        assert coeffs["flops_matmul"] == pytest.approx(c_mm_f, rel=1e-3)
        assert coeffs["hbm_elementwise"] == pytest.approx(c_ew_b, rel=1e-3)
        assert coeffs["hbm_data_movement"] == pytest.approx(c_dm_b, rel=1e-3)

    def test_lm_fit_falls_back_without_breakdowns(self):
        from repro.campaign import fit_hlo_constants

        peak, bw, c0 = 2e9, 5e8, 3e-3
        rng = np.random.default_rng(0)
        records = []
        for _ in range(8):
            fl = float(rng.uniform(1e6, 1e8))
            hb = float(rng.uniform(1e5, 1e7))
            records.append({
                "status": "ok", "device": "host_cpu", "plan_hash": "x",
                "flops": fl, "hbm_bytes": hb, "collective_bytes": 0.0,
                "phi_ms": (c0 + fl / peak + hb / bw) * 1e3,
            })
        spec = fit_hlo_constants(records)  # no cost_classes anywhere
        assert spec.meta["latency_fit"] == "aggregate"
        assert spec.meta["phi_mape_classwise"] is None
        assert "lm_latency" not in spec.class_coeffs
        assert spec.peak_flops == pytest.approx(peak, rel=1e-4)


# ---------------------------------------------------------------------------
# decompose: class columns refine (and re-sum to) the aggregate terms
# ---------------------------------------------------------------------------


class TestDecomposeColumns:
    def test_cnn_columns_sum_to_aggregate_terms(self):
        from repro.core.features import FEATURE_NAMES
        from repro.engine.decompose import (
            latency_class_columns,
            latency_terms,
        )

        rng = np.random.default_rng(3)
        F = rng.uniform(0, 1e6, size=(7, len(FEATURE_NAMES)))
        flops, bytes_moved = latency_terms(F, 4)
        cols = latency_class_columns(F, 4)
        np.testing.assert_array_equal(cols["flops_matmul"], flops)
        np.testing.assert_allclose(
            cols["hbm_elementwise"] + cols["hbm_data_movement"], bytes_moved)

    def test_ledger_columns_sum_to_scalar_totals(self):
        from repro.engine.decompose import ledger_latency_columns

        cost = _golden_costs()["grad_scan"]
        cols = ledger_latency_columns([cost.ledger])
        assert sum(float(cols[f"flops_{c}"][0]) for c in OP_CLASSES) \
            == cost.flops
        assert sum(float(cols[f"hbm_{c}"][0]) for c in OP_CLASSES) \
            == cost.hbm_bytes
        assert float(cols["collective"][0]) == cost.collective_bytes

    def test_classwise_seconds_prices_the_columns(self):
        from repro.engine.decompose import classwise_seconds

        cols = {"flops_matmul": np.array([2.0, 4.0]),
                "hbm_elementwise": np.array([10.0, 0.0])}
        coeffs = {"_intercept": 1.0, "flops_matmul": 0.5,
                  "hbm_elementwise": 0.1, "never_seen": 99.0}
        np.testing.assert_allclose(classwise_seconds(cols, coeffs),
                                   [1.0 + 1.0 + 1.0, 1.0 + 2.0])


# ---------------------------------------------------------------------------
# lm_features: one histogram function, two providers
# ---------------------------------------------------------------------------


class TestClassFeatures:
    def test_feature_names_extended_consistently(self):
        from repro.campaign.lm_features import (
            CLASS_FEATURE_NAMES,
            LM_FEATURE_NAMES,
        )

        assert len(CLASS_FEATURE_NAMES) == 2 * len(OP_CLASSES)
        assert LM_FEATURE_NAMES[-len(CLASS_FEATURE_NAMES):] \
            == CLASS_FEATURE_NAMES

    def test_analytic_histogram_in_cell_features(self):
        from repro.campaign.lm_features import (
            CLASS_FEATURE_NAMES,
            LM_FEATURE_NAMES,
            cell_features,
        )
        from repro.configs.base import ShapeSpec
        from repro.configs.registry import get_config
        from repro.engine.devices import get_device

        cfg = get_config("qwen3-4b", reduced=True)
        shape = ShapeSpec("t", 32, 2, "train")
        x = cell_features(cfg, shape, (1, 1), get_device("host_cpu"))
        hist = dict(zip(CLASS_FEATURE_NAMES, x[-len(CLASS_FEATURE_NAMES):]))
        assert hist["flops_frac_matmul"] == 1.0  # all model flops are matmul
        assert 0 < hist["hbm_frac_elementwise"] < 1
        # fractions normalize
        assert sum(v for k, v in hist.items()
                   if k.startswith("hbm_frac_")) == pytest.approx(1.0)
        i = LM_FEATURE_NAMES.index("flops_frac_matmul")
        assert x[i] == 1.0

    def test_mesh_collective_histogram_nonzero_on_2dev(self):
        """The analytic class decomposition must expose collectives on a
        >1-device mesh and none on 1x1 (the mesh-dim validation contract;
        the compiled-HLO side is tests/test_multidevice.py)."""
        from repro.campaign.lm_features import LM_FEATURE_NAMES, cell_features
        from repro.configs.base import ShapeSpec
        from repro.configs.registry import get_config
        from repro.engine.devices import get_device

        cfg = get_config("qwen3-4b", reduced=True)
        shape = ShapeSpec("t", 32, 2, "train")
        dev = get_device("host_cpu")
        i_coll = LM_FEATURE_NAMES.index("coll_bytes_dev")
        one = cell_features(cfg, shape, (1, 1), dev)
        two = cell_features(cfg, shape, (2, 1), dev)
        assert one[i_coll] == 0.0
        assert two[i_coll] > 0.0

    def test_ledger_provider_shares_the_histogram(self):
        from repro.campaign.lm_features import (
            CLASS_FEATURE_NAMES,
            class_histogram,
            ledger_class_features,
        )

        classes = {"matmul": {"flops": 75.0, "hbm_bytes": 25.0},
                   "elementwise": {"flops": 25.0, "hbm_bytes": 75.0}}
        rec_feats = ledger_class_features({"cost_classes": classes})
        np.testing.assert_array_equal(rec_feats, class_histogram(classes))
        d = dict(zip(CLASS_FEATURE_NAMES, rec_feats))
        assert d["flops_frac_matmul"] == 0.75
        assert d["hbm_frac_elementwise"] == 0.75
        # missing breakdown → zeros, not a crash
        assert ledger_class_features({}).sum() == 0.0

    def test_feature_matrix_ledger_provider(self):
        from repro.campaign.lm_features import (
            CLASS_FEATURE_NAMES,
            feature_matrix,
        )

        rec = {
            "arch": "qwen3-4b", "mesh": "1x1", "device": "host_cpu",
            "reduced": True,
            "shape": {"name": "t", "seq_len": 32, "global_batch": 2,
                      "kind": "train"},
            "cost_classes": {"elementwise": {"flops": 1.0, "hbm_bytes": 9.0},
                             "matmul": {"flops": 3.0, "hbm_bytes": 1.0}},
        }
        n = len(CLASS_FEATURE_NAMES)
        analytic = feature_matrix([rec])
        ledgered = feature_matrix([rec], classes_from="ledger")
        # non-class features identical; class block swapped to the record's
        np.testing.assert_array_equal(analytic[0, :-n], ledgered[0, :-n])
        d = dict(zip(CLASS_FEATURE_NAMES, ledgered[0, -n:]))
        assert d["flops_frac_matmul"] == 0.75
        with pytest.raises(ValueError, match="classes_from"):
            feature_matrix([rec], classes_from="nope")
